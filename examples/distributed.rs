//! Distributed-training demo: shard a real embedding table across W
//! worker serve loops over loopback TCP (the same `run_worker` that
//! backs `alpt worker`), train an epoch through the CRC-framed
//! GATHER/UPDATE RPC, and check the result is bit-identical to the
//! single-process run — then the communication accounting that
//! motivates training-time compression (paper §1: "the communication
//! between multiple devices seriously affects the training efficiency").
//!
//! ```bash
//! cargo run --release --example distributed -- --workers 2
//! ```

use alpt::cli::Args;
use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::sharding::step_comm;
use alpt::coordinator::{
    run_worker, RpcConfig, Trainer, WorkerHub, WorkerOpts,
};
use alpt::data::batcher::Batcher;
use alpt::data::registry;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::embedding::EmbeddingStore;
use std::time::Instant;

use anyhow::Result;

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

fn main() -> Result<()> {
    let args = Args::from_env(false, &[])?;
    let workers: usize = args.get_parse("workers", 2)?;
    let n_samples: usize = args.get_parse("samples", 50_000)?;

    // --- real wire training over loopback -----------------------------
    println!("=== ALPT-8bit over {workers} loopback workers ===\n");
    let exp = Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        n_samples: 600,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        lr_emb: 0.3,
        ..Experiment::default()
    };
    let n = registry::open_source(&exp)?.schema().n_features();

    // single-process reference
    let mut local = Trainer::new(exp.clone(), n)?;
    let src = registry::open_source(&exp)?;
    local.train_stream(src.as_ref(), false, None)?;

    // the same run with the table sharded across worker threads
    let mut tr = Trainer::new(exp.clone(), n)?;
    let hub = WorkerHub::bind("127.0.0.1:0", RpcConfig::default())?;
    let addr = hub.local_addr()?.to_string();
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let opts = WorkerOpts {
                connect: addr.clone(),
                retry_delay_ms: 25,
                ..WorkerOpts::default()
            };
            std::thread::spawn(move || run_worker(&opts))
        })
        .collect();
    tr.attach_workers_hub(hub, workers)?;
    let t0 = Instant::now();
    let src = registry::open_source(&exp)?;
    tr.train_stream(src.as_ref(), false, None)?;
    println!(
        "epoch over the wire in {:.1} ms",
        t0.elapsed().as_secs_f64() * 1e3
    );
    let identical = gather_all(tr.store.as_ref())
        .iter()
        .zip(gather_all(local.store.as_ref()).iter())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "bit-identical to single-process: {}",
        if identical { "yes" } else { "NO (bug!)" }
    );
    assert!(identical);
    tr.store.as_remote().expect("remote store").shutdown()?;
    drop(tr);
    for h in handles {
        h.join().expect("worker thread")?;
    }

    // --- per-epoch communication by method/bit width ------------------
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, n_samples);
    let dim = 16;
    println!(
        "\ndataset: {} samples, {} features; table dim {dim}",
        ds.n_samples(),
        ds.schema.n_features()
    );
    println!("\nper-epoch leader<->worker traffic (one pass over the data):");
    println!(
        "  {:<12} {:>6} {:>12} {:>12} {:>10} {:>12}",
        "method", "bits", "down", "up", "total", "@10Gbps"
    );
    for (method, bits) in [
        (Method::Fp, 32u32),
        (Method::Lsq, 8),
        (Method::Lpt(RoundingMode::Sr), 16),
        (Method::Alpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 4),
        (Method::Alpt(RoundingMode::Sr), 2),
    ] {
        let mut total = alpt::coordinator::CommStats::default();
        for b in Batcher::new(&ds, 256, Some(1), true) {
            total.add(&step_comm(method, bits, dim, &b));
        }
        println!(
            "  {:<12} {:>6} {:>11.1}M {:>11.1}M {:>9.1}M {:>10.2}s",
            method.name(),
            bits,
            total.bytes_down as f64 / 1e6,
            total.bytes_up as f64 / 1e6,
            total.total_bytes() as f64 / 1e6,
            total.seconds_at(10.0)
        );
    }
    println!(
        "\nthe downlink (embedding rows) shrinks with the bit width — the \
         paper's train-time-compression motivation. The uplink stays f32 \
         because gradients are not quantized."
    );
    Ok(())
}
