//! Distributed-training simulation: an embedding table sharded across W
//! workers, parallel gathers, and the communication accounting that
//! motivates training-time compression (paper §1: "the communication
//! between multiple devices seriously affects the training efficiency").
//!
//! ```bash
//! cargo run --release --example distributed -- --workers 8
//! ```

use alpt::cli::Args;
use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::sharding::{step_comm, ShardedStore};
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::util::bench::fmt_rate;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let args = Args::from_env(false, &[])?;
    let workers: usize = args.get_parse("workers", 8)?;
    let n_samples: usize = args.get_parse("samples", 50_000)?;

    println!("=== sharded embedding table across {workers} workers ===\n");
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, n_samples);
    let n_features = ds.schema.n_features();
    let dim = 16;
    println!(
        "dataset: {} samples, {} features; table dim {dim}",
        ds.n_samples(),
        n_features
    );

    // parallel sharded gather throughput
    let exp = Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        use_runtime: false,
        ..Experiment::default()
    };
    let mut sharded = ShardedStore::new(&exp, n_features, dim, workers)?;
    let batches: Vec<_> = Batcher::new(&ds, 256, Some(1), true)
        .take(200)
        .collect();
    let mut out = vec![0.0f32; 256 * 24 * dim];
    let t0 = Instant::now();
    let mut rows = 0u64;
    for b in &batches {
        sharded.gather(&b.unique, &mut out[..b.unique.len() * dim]);
        rows += b.unique.len() as u64;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nparallel gather over {workers} shards: {} batches, {} rows in \
         {:.1} ms  ({} rows)",
        batches.len(),
        rows,
        dt * 1e3,
        fmt_rate(rows as f64 / dt)
    );
    println!(
        "sharded table: {:.1} MB total across workers ({:.1} MB/worker)",
        sharded.train_bytes() as f64 / 1e6,
        sharded.train_bytes() as f64 / 1e6 / workers as f64
    );

    // per-epoch communication by method/bit width
    println!("\nper-epoch leader<->worker traffic (one pass over the data):");
    println!(
        "  {:<12} {:>6} {:>12} {:>12} {:>10} {:>12}",
        "method", "bits", "down", "up", "total", "@10Gbps"
    );
    for (method, bits) in [
        (Method::Fp, 32u32),
        (Method::Lsq, 8),
        (Method::Lpt(RoundingMode::Sr), 16),
        (Method::Alpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 4),
        (Method::Alpt(RoundingMode::Sr), 2),
    ] {
        let mut total = alpt::coordinator::CommStats::default();
        for b in Batcher::new(&ds, 256, Some(1), true) {
            total.add(&step_comm(method, bits, dim, &b));
        }
        println!(
            "  {:<12} {:>6} {:>11.1}M {:>11.1}M {:>9.1}M {:>10.2}s",
            method.name(),
            bits,
            total.bytes_down as f64 / 1e6,
            total.bytes_up as f64 / 1e6,
            total.total_bytes() as f64 / 1e6,
            total.seconds_at(10.0)
        );
    }
    println!(
        "\nthe downlink (embedding rows) shrinks with the bit width — the \
         paper's train-time-compression motivation. The uplink stays f32 \
         because gradients are not quantized."
    );
    Ok(())
}
