//! Config-driven training launcher — the "real" entrypoint a user would
//! run for any Table-1/2/3 cell.
//!
//! ```bash
//! cargo run --release --example train_ctr -- \
//!     --dataset avazu --method alpt-sr --plan 8 --epochs 5 \
//!     --samples 200000 --out results/alpt8_avazu.json
//! # or from a config file (+ CLI overrides):
//! cargo run --release --example train_ctr -- --config exp.toml --plan 4
//! ```

use alpt::cli::Args;
use alpt::config::{Experiment, Method};
use alpt::coordinator::Trainer;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::util::json::Json;
use anyhow::{bail, Context, Result};

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-runtime", "quiet"])?;

    // config file first, CLI overrides second
    let mut exp = if let Some(path) = args.get("config") {
        let doc = alpt::config::toml::TomlDoc::parse_file(
            std::path::Path::new(path),
        )
        .with_context(|| format!("reading {path}"))?;
        Experiment::from_toml(&doc)?
    } else {
        Experiment::default()
    };
    if let Some(ds) = args.get("dataset") {
        exp = exp.with_dataset_defaults(ds);
    }
    if let Some(m) = args.get("method") {
        exp.method = Method::parse(m)?;
    }
    exp.bits = args.get_parse("bits", exp.bits.clone())?;
    exp.epochs = args.get_parse("epochs", exp.epochs)?;
    exp.seed = args.get_parse("seed", exp.seed)?;
    exp.n_samples = args.get_parse("samples", exp.n_samples)?;
    exp.lr_delta = args.get_parse("lr-delta", exp.lr_delta)?;
    exp.lr_emb = args.get_parse("lr-emb", exp.lr_emb)?;
    exp.clip = args.get_parse("clip", exp.clip)?;
    exp.vocab_scale = args.get_parse("vocab-scale", exp.vocab_scale)?;
    if let Some(m) = args.get("model") {
        exp.model = m.to_string();
    }
    if args.flag("no-runtime") {
        exp.use_runtime = false;
    }
    let verbose = !args.flag("quiet");

    // dataset
    let spec = match exp.dataset.as_str() {
        "avazu" => SyntheticSpec::avazu(exp.seed),
        "criteo" => SyntheticSpec::criteo(exp.seed),
        "tiny" => SyntheticSpec::tiny(exp.seed),
        other => bail!("unknown dataset {other:?}"),
    };
    let spec = if (exp.vocab_scale - 1.0).abs() > 1e-9 {
        spec.scale_vocabs(exp.vocab_scale)
    } else {
        spec
    };
    if verbose {
        println!(
            "generating {} samples of {} ({} fields, {} features)...",
            exp.n_samples,
            spec.name,
            spec.vocabs.len(),
            spec.vocabs.iter().map(|&v| v as u64).sum::<u64>()
        );
    }
    let ds = generate(&spec, exp.n_samples);
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), exp.seed);

    // train
    let mut trainer = Trainer::new(exp.clone(), ds.schema.n_features())?;
    if verbose {
        println!(
            "training {} on {} (bits {}, model {}, {} epochs, runtime={})",
            trainer.store.method_name(),
            spec.name,
            exp.bits,
            exp.model,
            exp.epochs,
            trainer.uses_runtime()
        );
    }
    let res = trainer.train(&train, &val, verbose)?;
    let test_ev = trainer.evaluate(&test)?;

    println!(
        "\n{}: test auc {:.4}  logloss {:.5}  best-epoch {}  \
         {:.1}s/epoch  train-compress {:.1}x  infer-compress {:.1}x",
        res.method,
        test_ev.auc,
        test_ev.logloss,
        res.best_epoch,
        res.seconds_per_epoch,
        res.train_compression,
        res.infer_compression
    );

    // optional JSON dump
    if let Some(out) = args.get("out") {
        let history = Json::Array(
            res.history
                .iter()
                .map(|h| {
                    Json::obj(vec![
                        ("epoch", Json::num(h.epoch as f64)),
                        ("loss", Json::num(h.mean_loss)),
                        ("val_auc", Json::num(h.val_auc)),
                        ("val_logloss", Json::num(h.val_logloss)),
                        ("seconds", Json::num(h.seconds)),
                    ])
                })
                .collect(),
        );
        let doc = Json::obj(vec![
            ("method", Json::str(res.method)),
            ("dataset", Json::str(&spec.name)),
            ("bits", exp.bits.echo_json()),
            ("test_auc", Json::num(test_ev.auc)),
            ("test_logloss", Json::num(test_ev.logloss)),
            ("best_epoch", Json::num(res.best_epoch as f64)),
            ("seconds_per_epoch", Json::num(res.seconds_per_epoch)),
            ("train_compression", Json::num(res.train_compression)),
            ("infer_compression", Json::num(res.infer_compression)),
            ("history", history),
        ]);
        if let Some(dir) = std::path::Path::new(out).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(out, doc.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}
