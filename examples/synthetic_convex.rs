//! The paper's Figure-3 synthetic convex experiment, interactively:
//! minimize f(w) = (w − 0.5)² for 1000 parameters under FP / LPT-DR /
//! LPT-SR and watch the distributions + the DR stall counter.
//!
//! ```bash
//! cargo run --release --example synthetic_convex
//! ```

use alpt::analysis::{run_convex, ConvexMode, ConvexSpec};

fn main() {
    let spec = ConvexSpec::default();
    let record = [10usize, 100, 1000];
    println!(
        "=== Figure 3: f(w) = (w - 0.5)^2, {} params, delta = {}, \
         eta = {} ===",
        spec.n_params, spec.delta, spec.eta0
    );
    println!(
        "(histograms span [{:.2}, {:.2}] around the optimum)\n",
        spec.target - 0.15,
        spec.target + 0.15
    );

    for mode in [ConvexMode::FullPrecision, ConvexMode::LptDr,
                 ConvexMode::LptSr] {
        let snaps = run_convex(&spec, mode, 1000, &record);
        println!("--- {} ---", mode.name());
        for s in &snaps {
            println!(
                "  t={:<5} mean obj {:.3e}  stalled {:>4}  |{}|",
                s.iteration,
                s.mean_obj,
                s.stalled,
                s.histogram.sparkline()
            );
        }
        println!();
    }
    println!(
        "Expected shape (paper §3.1): SR tracks FP and concentrates at the \
         optimum; DR freezes once |eta grad| < delta/2 (Remark 1) and its \
         histogram stops moving — the stalled counter saturates at {}.",
        spec.n_params
    );
}
