//! Serving demo: train a small FP model, quantize its embedding table
//! on-device through the `quantize` Pallas-kernel artifact (SR), then
//! serve batched CTR requests from the int-native `eval_lpt` path and
//! report latency / throughput / the accuracy cost of post-training
//! quantization vs trained-quantized (ALPT).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve
//! ```

use std::time::Instant;

use alpt::config::{Experiment, Method, RoundingMode};
use alpt::coordinator::Trainer;
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::metrics::EvalAccumulator;
use alpt::quant::{init_delta, BitWidth};
use alpt::runtime::{lit_f32, lit_i32, lit_scalar, to_f32, to_i32, Runtime};
use alpt::util::rng::Pcg32;
use alpt::util::stats::percentile;
use anyhow::Result;

fn main() -> Result<()> {
    println!("=== serve: quantized embedding table behind a batched \
              request loop ===\n");
    let spec = SyntheticSpec::tiny(7);
    let ds = generate(&spec, 20_000);
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), 3);
    let n_features = ds.schema.n_features();

    // 1. train an FP model (2 epochs is plenty for the demo)
    let exp = Experiment {
        method: Method::Fp,
        model: "tiny".into(),
        epochs: 2,
        lr_emb: 0.5,
        patience: 0,
        ..Experiment::default()
    };
    let mut fp = Trainer::new(exp.clone(), n_features)?;
    let _ = fp.train(&train, &val, false)?;
    let fp_ev = fp.evaluate(&test)?;
    println!("trained FP model: test auc {:.4}\n", fp_ev.auc);

    // 2. post-training-quantize the trained table with the `quantize`
    //    artifact (the L1 SR kernel, running on PJRT)
    let mut rt = Runtime::load(std::path::Path::new(&exp.artifacts_dir))?;
    let entry = rt.entry("tiny")?.clone();
    let (umax, d, b, f) = (entry.umax, entry.emb_dim, entry.batch,
                           entry.fields);
    let bw = BitWidth::B8;

    // pull the trained table out of the FP store
    let ids: Vec<u32> = (0..n_features as u32).collect();
    let mut table = vec![0.0f32; n_features * d];
    fp.store.gather(&ids, &mut table);

    // per-row LSQ-style deltas, then quantize row blocks on-device
    let deltas: Vec<f32> = (0..n_features)
        .map(|r| init_delta(&table[r * d..(r + 1) * d], bw))
        .collect();
    let mut rng = Pcg32::seeded(11);
    let mut codes = vec![0i32; n_features * d];
    let t0 = Instant::now();
    for start in (0..n_features).step_by(umax) {
        let end = (start + umax).min(n_features);
        let mut w = vec![0.0f32; umax * d];
        w[..(end - start) * d]
            .copy_from_slice(&table[start * d..end * d]);
        let mut dl = vec![1.0f32; umax];
        dl[..end - start].copy_from_slice(&deltas[start..end]);
        let mut noise = vec![0.0f32; umax * d];
        rng.fill_uniform(&mut noise);
        let out = rt.exec(
            "tiny",
            "quantize",
            &[
                lit_f32(&w, &[umax as i64, d as i64])?,
                lit_f32(&dl, &[umax as i64])?,
                lit_f32(&noise, &[umax as i64, d as i64])?,
                lit_scalar(bw.qn() as f32),
                lit_scalar(bw.qp() as f32),
            ],
        )?;
        let chunk = to_i32(&out[0])?;
        codes[start * d..end * d]
            .copy_from_slice(&chunk[..(end - start) * d]);
    }
    println!(
        "quantized {} rows to {} bits on-device in {:.1} ms \
         ({} PJRT calls)",
        n_features,
        bw.bits(),
        t0.elapsed().as_secs_f64() * 1e3,
        rt.executions
    );

    // 3. serve batched requests from the int table via eval_lpt
    let mut acc = EvalAccumulator::new();
    let mut latencies = Vec::new();
    let batches: Vec<_> = Batcher::new(&test, b, None, false).collect();
    // warm up the executable cache so latencies reflect steady state
    rt.prepare("tiny", "eval_lpt")?;
    for batch in &batches {
        let t = Instant::now();
        let n_u = batch.unique.len();
        let mut bc = vec![0i32; umax * d];
        let mut bd = vec![1.0f32; umax];
        for (i, &id) in batch.unique.iter().enumerate() {
            let id = id as usize;
            bc[i * d..(i + 1) * d]
                .copy_from_slice(&codes[id * d..(id + 1) * d]);
            bd[i] = deltas[id];
        }
        let _ = n_u;
        let outs = rt.exec(
            "tiny",
            "eval_lpt",
            &[
                lit_i32(&bc, &[umax as i64, d as i64])?,
                lit_f32(&bd, &[umax as i64])?,
                lit_i32(&batch.idx, &[b as i64, f as i64])?,
                lit_f32(&fp.dense, &[fp.dense.len() as i64])?,
            ],
        )?;
        let logits = to_f32(&outs[0])?;
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        acc.push(&logits, &batch.labels, batch.valid);
    }
    let total_ms: f64 = latencies.iter().sum();
    println!(
        "\nserved {} requests in {} batches:",
        acc.len(),
        latencies.len()
    );
    println!(
        "  latency p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms per batch \
         of {b}",
        percentile(&latencies, 50.0),
        percentile(&latencies, 95.0),
        percentile(&latencies, 99.0)
    );
    println!(
        "  throughput {:.0} req/s",
        acc.len() as f64 / (total_ms / 1e3)
    );
    println!(
        "  PTQ-8bit:  auc {:.4} (FP {:.4}, gap {:+.4})",
        acc.auc(),
        fp_ev.auc,
        fp_ev.auc - acc.auc()
    );
    println!(
        "  table: {} KB int8+delta vs {} KB fp32 ({:.1}x smaller)",
        (n_features * d + n_features * 4) / 1024,
        n_features * d * 4 / 1024,
        (n_features * d * 4) as f64
            / (n_features * d + n_features * 4) as f64
    );

    // 4. reference: ALPT trains the quantized table directly
    let mut alpt = Trainer::new(
        Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            lr_delta: 1e-4,
            ..exp
        },
        n_features,
    )?;
    let _ = alpt.train(&train, &val, false)?;
    let alpt_ev = alpt.evaluate(&test)?;
    println!(
        "\n  ALPT-8bit (trained quantized): auc {:.4} — no PTQ gap and \
         the same serving format.",
        alpt_ev.auc
    );
    Ok(())
}
