//! Serving demo: restore a *trained, quantized* embedding table + DCN
//! params from a versioned checkpoint into the shared
//! [`alpt::serve::InferenceEngine`] and score CTR requests from it — no
//! training step, no retraining, no PJRT requirement. This is the deploy
//! artifact the paper's training-stage compression pays for: the packed
//! int table plus per-row step sizes, restored bit-identically from
//! disk and scored concurrently by many threads against one immutable
//! engine.
//!
//! ```bash
//! cargo run --release --example serve -- --ckpt examples/fixtures/tiny_lpt8.ckpt
//! ```
//!
//! The committed fixture is a *trained* checkpoint: it is produced by
//! `scripts/train_fixture.py`, which rebuilds the tiny dataset's latent
//! ground truth bit-for-bit from the experiment seed, trains a DCN
//! against it and quantizes onto the 8-bit LPT grid — so the AUC this
//! demo reports is a real generalization number, not chance. To serve
//! your own model, produce a checkpoint the usual way:
//!
//! ```bash
//! cargo run --release -- train --dataset tiny --method lpt-sr --plan 8 \
//!     --no-runtime --save trained.ckpt
//! cargo run --release --example serve -- --ckpt trained.ckpt
//! ```
//!
//! The engine behind this demo is the same one `alpt serve` uses — both
//! the offline report below and the online HTTP server
//! (`alpt serve --listen 127.0.0.1:8080 --ckpt trained.ckpt`), so the
//! entry points cannot drift apart.

use std::sync::Arc;

use alpt::cli::Args;
use alpt::coordinator::serve_with_engine;
use alpt::serve::InferenceEngine;
use anyhow::Result;

const DEFAULT_CKPT: &str = "examples/fixtures/tiny_lpt8.ckpt";

fn main() -> Result<()> {
    let args = Args::from_env(false, &["help"])?;
    if args.flag("help") {
        println!(
            "usage: cargo run --example serve -- [--ckpt FILE.ckpt] \
             [--batches N] [--threads N]"
        );
        return Ok(());
    }
    let path = args.get_or("ckpt", DEFAULT_CKPT).to_string();
    let max_batches = args.get_parse("batches", usize::MAX)?;
    let n_threads = args.get_parse("threads", 4usize)?.max(1);
    println!(
        "=== serve: one shared InferenceEngine behind every scoring \
         entry point ===\n"
    );

    let engine =
        Arc::new(InferenceEngine::from_checkpoint(std::path::Path::new(
            &path,
        ))?);
    println!(
        "loaded {} from {path} in {:.1} ms",
        engine.method_name(),
        engine.load_ms()
    );
    println!(
        "  table: {} rows x {} dims = {} KB packed (+deltas) vs {} KB \
         fp32 ({:.1}x smaller)",
        engine.n_features(),
        engine.dim(),
        engine.infer_bytes() / 1024,
        engine.fp_bytes() / 1024,
        engine.fp_bytes() as f64 / engine.infer_bytes() as f64
    );

    // ---- the offline batch-eval report (shared with `alpt serve`) ----
    let report = serve_with_engine(&engine, max_batches)?;
    println!(
        "\nserved {} requests in {} batches (no training step, \
         +{:.0} ms regenerating the request stream):",
        report.requests, report.batches(), report.data_ms
    );
    println!(
        "  latency p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms per batch \
         of {}",
        report.p50_ms(),
        report.p95_ms(),
        report.p99_ms(),
        report.batch_size
    );
    println!("  throughput {:.0} req/s", report.requests_per_sec());
    println!("  auc {:.4}  logloss {:.5}", report.auc, report.logloss);
    for w in &report.warnings {
        eprintln!("  warning: {w}");
    }

    // ---- concurrent clients: N threads, one immutable engine ----
    // every thread scores the same record set through its own scratch;
    // the engine takes &self, so no lock anywhere — and the logits are
    // bit-identical to the serial pass
    let fields = engine.fields();
    let records: Vec<Vec<u32>> = (0..64u32)
        .map(|r| (0..fields as u32).map(|f| (r + f) % 8).collect())
        .collect();
    let serial: Vec<f32> = records
        .iter()
        .map(|rec| engine.score_records(rec).map(|l| l[0]))
        .collect::<Result<_>>()?;
    let t = std::time::Instant::now();
    let identical = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                let engine = Arc::clone(&engine);
                let records = &records;
                let serial = &serial;
                s.spawn(move || {
                    // per-thread scratch lives behind score_records'
                    // thread-local buffer — no shared mutable state
                    records.iter().zip(serial).all(|(rec, &want)| {
                        engine
                            .score_records(rec)
                            .map(|l| l[0].to_bits() == want.to_bits())
                            .unwrap_or(false)
                    })
                })
            })
            .collect();
        handles.into_iter().all(|h| h.join().unwrap())
    });
    let dt = t.elapsed().as_secs_f64();
    println!(
        "\nconcurrent clients: {n_threads} threads x {} records through \
         one shared engine in {:.1} ms ({:.0} req/s aggregate)",
        records.len(),
        dt * 1e3,
        (n_threads * records.len()) as f64 / dt
    );
    println!(
        "  bit-identical to the serial pass: {}",
        if identical { "yes" } else { "NO (bug!)" }
    );
    assert!(identical, "threaded scoring diverged from serial");

    println!(
        "\n(online scoring server over the same engine: \
         `cargo run --release -- serve --ckpt {path} --listen \
         127.0.0.1:8080`,\n warm-start training: \
         `cargo run --release -- train --resume {path}`)"
    );
    Ok(())
}
