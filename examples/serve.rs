//! Serving demo: load a *trained, quantized* embedding table + DCN params
//! from a versioned checkpoint file and serve batched CTR requests from
//! it — no training step, no retraining, no PJRT requirement. This is the
//! deploy artifact the paper's training-stage compression pays for: the
//! packed int table plus per-row step sizes, restored bit-identically
//! from disk.
//!
//! ```bash
//! cargo run --release --example serve -- --ckpt examples/fixtures/tiny_lpt8.ckpt
//! ```
//!
//! The committed fixture is a format/serving smoke checkpoint (see
//! `scripts/make_fixture.py`), so its AUC is chance-level by design. To
//! serve a *trained* model, produce a real checkpoint first:
//!
//! ```bash
//! cargo run --release -- train --dataset tiny --method lpt-sr --bits 8 \
//!     --no-runtime --save trained.ckpt
//! cargo run --release --example serve -- --ckpt trained.ckpt
//! ```
//!
//! The load/validate/inference loop itself lives in
//! `alpt::coordinator::serve` and is shared with the `alpt serve`
//! subcommand, so the demo and the CLI cannot drift apart.

use alpt::cli::Args;
use alpt::coordinator::serve_checkpoint;
use alpt::util::stats::percentile;
use anyhow::Result;

const DEFAULT_CKPT: &str = "examples/fixtures/tiny_lpt8.ckpt";

fn main() -> Result<()> {
    let args = Args::from_env(false, &["help"])?;
    if args.flag("help") {
        println!(
            "usage: cargo run --example serve -- [--ckpt FILE.ckpt] \
             [--batches N]"
        );
        return Ok(());
    }
    let path = args.get_or("ckpt", DEFAULT_CKPT).to_string();
    let max_batches = args.get_parse("batches", usize::MAX)?;
    println!("=== serve: checkpointed quantized table behind a batched \
              request loop ===\n");

    let report =
        serve_checkpoint(std::path::Path::new(&path), max_batches)?;

    println!(
        "loaded {} from {path} in {:.1} ms (+{:.0} ms regenerating the \
         synthetic request stream)",
        report.method, report.load_ms, report.data_ms
    );
    println!(
        "  table: {} rows x {} dims = {} KB packed (+deltas) vs {} KB \
         fp32 ({:.1}x smaller)",
        report.n_features,
        report.dim,
        report.infer_bytes / 1024,
        report.fp_bytes / 1024,
        report.fp_bytes as f64 / report.infer_bytes as f64
    );

    println!(
        "\nserved {} requests in {} batches (no training step):",
        report.requests,
        report.batches()
    );
    println!(
        "  latency p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms per batch \
         of {}",
        percentile(&report.latencies_ms, 50.0),
        percentile(&report.latencies_ms, 95.0),
        percentile(&report.latencies_ms, 99.0),
        report.batch_size
    );
    println!("  throughput {:.0} req/s", report.requests_per_sec());
    println!(
        "  auc {:.4}  logloss {:.5}",
        report.auc, report.logloss
    );
    println!(
        "\n(warm-start training from the same file: \
         `cargo run --release -- train --resume {path}`)"
    );
    Ok(())
}
