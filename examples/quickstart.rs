//! Quickstart — the end-to-end driver.
//!
//! Generates a synthetic CTR dataset, trains the DCN backbone with ALPT
//! 8-bit embeddings through the full three-layer stack (Rust coordinator →
//! PJRT-executed HLO containing the JAX model and Pallas kernels), logs
//! the loss curve, and compares against the FP baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use alpt::config::{Experiment, Method, RoundingMode};
use alpt::coordinator::Trainer;
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use anyhow::Result;

fn main() -> Result<()> {
    println!("=== ALPT quickstart: 8-bit embeddings, end to end ===\n");

    // 1. data: tiny synthetic CTR workload (8 fields, ~4k features)
    let spec = SyntheticSpec::tiny(42);
    let ds = generate(&spec, 20_000);
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), 7);
    println!(
        "dataset: {} samples, {} fields, {} features, ctr={:.3}",
        ds.n_samples(),
        ds.n_fields(),
        ds.schema.n_features(),
        ds.ctr()
    );

    // 2. train ALPT(SR) 8-bit through the PJRT runtime
    let exp = Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        model: "tiny".into(),
        epochs: 3,
        lr_emb: 0.5,
        lr_delta: 1e-4,
        patience: 0,
        ..Experiment::default()
    };
    let mut trainer = Trainer::new(exp.clone(), ds.schema.n_features())?;
    println!(
        "\nmethod: {} ({} runtime), bits {}, train compression {:.1}x",
        trainer.store.method_name(),
        if trainer.uses_runtime() { "PJRT" } else { "rust-nn" },
        exp.bits,
        alpt::embedding::fp_bytes(ds.schema.n_features(),
                                  trainer.entry.emb_dim) as f64
            / trainer.store.train_bytes() as f64,
    );

    // loss curve over the first few hundred steps
    println!("\nloss curve (first epoch):");
    let batches: Vec<_> =
        Batcher::new(&train, trainer.entry.batch, Some(1), true).collect();
    let mut running = 0.0f64;
    for (i, batch) in batches.iter().enumerate() {
        let out = trainer.step(batch, 1)?;
        running += out.loss as f64;
        if (i + 1) % 25 == 0 {
            println!("  step {:>4}: loss {:.5}", i + 1, running / 25.0);
            running = 0.0;
        }
    }
    let ev = trainer.evaluate(&val)?;
    println!("\nafter epoch 1: val auc {:.4}, logloss {:.5}", ev.auc,
             ev.logloss);

    // two more epochs through the high-level loop
    let res = trainer.train(&train, &val, true)?;
    let test_ev = trainer.evaluate(&test)?;
    println!(
        "\nALPT(SR) 8-bit:  test auc {:.4}  logloss {:.5}  \
         ({} epochs, {:.1}s/epoch)",
        test_ev.auc, test_ev.logloss, res.epochs_run, res.seconds_per_epoch
    );

    // 3. FP baseline for reference
    let mut fp = Trainer::new(
        Experiment { method: Method::Fp, ..exp },
        ds.schema.n_features(),
    )?;
    let _ = fp.train(&train, &val, false)?;
    let fp_ev = fp.evaluate(&test)?;
    println!(
        "FP baseline:     test auc {:.4}  logloss {:.5}",
        fp_ev.auc, fp_ev.logloss
    );
    println!(
        "\nAUC gap (FP - ALPT): {:+.4}  — the paper's claim is that this \
         is ~0 at 8 bits.",
        fp_ev.auc - test_ev.auc
    );
    Ok(())
}
