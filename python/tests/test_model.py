"""L2 correctness: the DCN model and the exported step functions.

Checks: pallas-vs-ref forward equivalence, gradient correctness (custom-vjp
path vs pure-autodiff reference path, plus finite differences on the loss),
parameter pack/unpack, and an end-to-end "loss goes down" training smoke on
a learnable synthetic batch distribution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.configs import CONFIGS, n_params, param_layout

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["tiny"]


def init_params(cfg, seed=0):
    """Mirror of the Rust-side initializer (manifest init spec)."""
    r = np.random.default_rng(seed)
    chunks = []
    for name, shape, init in param_layout(cfg):
        n = int(np.prod(shape))
        if init == "xavier":
            fan_in, fan_out = shape[0], shape[1] if len(shape) > 1 else 1
            a = np.sqrt(6.0 / (fan_in + fan_out))
            chunks.append(r.uniform(-a, a, size=n))
        elif init == "normal":
            chunks.append(r.normal(0, 0.01, size=n))
        else:
            chunks.append(np.zeros(n))
    return jnp.asarray(np.concatenate(chunks), jnp.float32)


def random_batch(cfg, seed=0):
    r = np.random.default_rng(seed)
    emb = jnp.asarray(r.normal(0, 0.1, size=(cfg.umax, cfg.emb_dim)),
                      jnp.float32)
    idx = jnp.asarray(r.integers(0, cfg.umax, size=(cfg.batch, cfg.fields)),
                      jnp.int32)
    labels = jnp.asarray(r.integers(0, 2, size=(cfg.batch,)), jnp.float32)
    mask = jnp.ones((cfg.batch, cfg.mlp_mask_dim), jnp.float32)
    return emb, idx, labels, mask


def test_pack_unpack_roundtrip():
    flat = init_params(CFG, 3)
    params = model.unpack_params(CFG, flat)
    assert set(params) == {n for n, _, _ in param_layout(CFG)}
    back = model.pack_params(CFG, params)
    assert np.array_equal(np.asarray(flat), np.asarray(back))
    assert flat.shape[0] == n_params(CFG)


def test_forward_pallas_matches_ref():
    flat = init_params(CFG, 1)
    emb, idx, labels, mask = random_batch(CFG, 1)
    lp = model.forward(CFG, emb, idx, flat, mask, use_pallas=True)
    lr = model.forward(CFG, emb, idx, flat, mask, use_pallas=False)
    assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-5, atol=1e-5)
    assert lp.shape == (CFG.batch,)


def test_train_fp_grads_pallas_matches_ref():
    flat = init_params(CFG, 2)
    emb, idx, labels, mask = random_batch(CFG, 2)
    out_p = model.train_fp(CFG, use_pallas=True)(emb, idx, labels, flat, mask)
    out_r = model.train_fp(CFG, use_pallas=False)(emb, idx, labels, flat, mask)
    names = ["loss", "logits", "d_emb", "d_params"]
    for name, a, b in zip(names, out_p, out_r):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5,
                        err_msg=name)


def test_train_fp_finite_diff_emb():
    """d loss / d emb via finite differences on a few coordinates."""
    flat = init_params(CFG, 4)
    emb, idx, labels, mask = random_batch(CFG, 4)
    step = model.train_fp(CFG, use_pallas=True)
    loss0, _, demb, _ = step(emb, idx, labels, flat, mask)

    def loss_at(e):
        return float(step(e, idx, labels, flat, mask)[0])

    r = np.random.default_rng(0)
    eps = 1e-3
    for _ in range(4):
        i = int(r.integers(0, CFG.umax))
        j = int(r.integers(0, CFG.emb_dim))
        e = np.asarray(emb).copy()
        e[i, j] += eps
        up = loss_at(jnp.asarray(e))
        e[i, j] -= 2 * eps
        dn = loss_at(jnp.asarray(e))
        fd = (up - dn) / (2 * eps)
        assert abs(fd - float(demb[i, j])) < 5e-3 + 0.05 * abs(fd)


def test_train_lpt_equals_fp_on_dequantized():
    """train_lpt(codes, delta) must equal train_fp(dequant(codes, delta)):
    the LPT artifact just fuses the dequant kernel in front."""
    flat = init_params(CFG, 5)
    _, idx, labels, mask = random_batch(CFG, 5)
    r = np.random.default_rng(5)
    codes = jnp.asarray(r.integers(-128, 128, size=(CFG.umax, CFG.emb_dim)),
                        jnp.int32)
    delta = jnp.asarray(r.uniform(1e-3, 0.01, size=(CFG.umax,)), jnp.float32)
    emb_hat = codes.astype(jnp.float32) * delta[:, None]

    out_lpt = model.train_lpt(CFG)(codes, delta, idx, labels, flat, mask)
    out_fp = model.train_fp(CFG)(emb_hat, idx, labels, flat, mask)
    for name, a, b in zip(["loss", "logits", "d_emb", "d_params"],
                          out_lpt, out_fp):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6,
                        err_msg=name)


def test_train_fq_grads_pallas_matches_ref():
    flat = init_params(CFG, 6)
    emb, idx, labels, mask = random_batch(CFG, 6)
    r = np.random.default_rng(6)
    delta = jnp.asarray(r.uniform(1e-3, 0.01, size=(CFG.umax,)), jnp.float32)
    qn, qp = -128.0, 127.0
    out_p = model.train_fq(CFG, use_pallas=True)(
        emb, delta, idx, labels, flat, mask, qn, qp)
    out_r = model.train_fq(CFG, use_pallas=False)(
        emb, delta, idx, labels, flat, mask, qn, qp)
    for name, a, b in zip(["loss", "logits", "d_w", "d_delta", "d_params"],
                          out_p, out_r):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=2e-5,
                        err_msg=name)


def test_delta_grad_variant_matches_train_fq():
    """The lean ALPT step-2 artifact must return exactly train_fq's
    d_delta (it is the same graph with the other outputs DCE'd)."""
    flat = init_params(CFG, 12)
    emb, idx, labels, mask = random_batch(CFG, 12)
    delta = jnp.full((CFG.umax,), 0.004, jnp.float32)
    qn, qp = -128.0, 127.0
    full = model.train_fq(CFG)(emb, delta, idx, labels, flat, mask, qn, qp)
    lean = model.delta_grad(CFG)(emb, delta, idx, labels, flat, mask, qn, qp)
    assert_allclose(np.asarray(lean[0]), np.asarray(full[3]), rtol=0,
                    atol=0)


def test_train_fq_delta_grad_nonzero():
    flat = init_params(CFG, 7)
    emb, idx, labels, mask = random_batch(CFG, 7)
    delta = jnp.full((CFG.umax,), 0.005, jnp.float32)
    out = model.train_fq(CFG)(emb, delta, idx, labels, flat, mask,
                              -128.0, 127.0)
    ddelta = np.asarray(out[3])
    assert ddelta.shape == (CFG.umax,)
    assert np.isfinite(ddelta).all()
    assert np.abs(ddelta).max() > 0


def test_eval_matches_forward():
    flat = init_params(CFG, 8)
    emb, idx, labels, mask = random_batch(CFG, 8)
    logits = model.eval_fp(CFG)(emb, idx, flat)
    want = model.forward(CFG, emb, idx, flat, mask, use_pallas=True)
    assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-6)

    r = np.random.default_rng(8)
    codes = jnp.asarray(r.integers(-8, 8, size=(CFG.umax, CFG.emb_dim)),
                        jnp.int32)
    delta = jnp.asarray(r.uniform(1e-3, 0.05, size=(CFG.umax,)), jnp.float32)
    le = model.eval_lpt(CFG)(codes, delta, idx, flat)
    lf = model.eval_fp(CFG)(codes.astype(jnp.float32) * delta[:, None], idx,
                            flat)
    assert_allclose(np.asarray(le), np.asarray(lf), rtol=1e-5, atol=1e-6)


def test_dropout_mask_applied():
    cfg = CONFIGS["tiny"]
    flat = init_params(cfg, 9)
    emb, idx, labels, _ = random_batch(cfg, 9)
    ones = jnp.ones((cfg.batch, cfg.mlp_mask_dim), jnp.float32)
    zeros = jnp.zeros((cfg.batch, cfg.mlp_mask_dim), jnp.float32)
    l1 = model.forward(cfg, emb, idx, flat, ones)
    l0 = model.forward(cfg, emb, idx, flat, zeros)
    # zero mask kills the deep tower -> different logits
    assert not np.allclose(np.asarray(l1), np.asarray(l0))


def test_bce_matches_numpy():
    r = np.random.default_rng(0)
    z = r.normal(0, 2, size=(64,)).astype(np.float32)
    y = r.integers(0, 2, size=(64,)).astype(np.float32)
    p = 1.0 / (1.0 + np.exp(-z))
    want = -np.mean(y * np.log(p) + (1 - y) * np.log(1 - p))
    got = float(model.bce_with_logits(jnp.asarray(z), jnp.asarray(y)))
    assert_allclose(got, want, rtol=1e-5)


@pytest.mark.slow
def test_training_reduces_loss():
    """End-to-end L2 smoke: SGD on a learnable synthetic pattern."""
    cfg = CFG
    r = np.random.default_rng(42)
    flat = init_params(cfg, 42)
    emb = jnp.asarray(r.normal(0, 0.05, size=(cfg.umax, cfg.emb_dim)),
                      jnp.float32)
    # ground truth: label depends on a latent weight per feature row
    latent = r.normal(0, 1.5, size=(cfg.umax,))
    step = jax.jit(model.train_fp(cfg, use_pallas=True))
    mask = jnp.ones((cfg.batch, cfg.mlp_mask_dim), jnp.float32)

    losses = []
    for t in range(200):
        idx = r.integers(0, cfg.umax, size=(cfg.batch, cfg.fields))
        logit_true = latent[idx].sum(axis=1) * 0.6
        y = (r.uniform(0, 1, size=cfg.batch)
             < 1 / (1 + np.exp(-logit_true))).astype(np.float32)
        loss, _, demb, dparams = step(emb, jnp.asarray(idx, jnp.int32),
                                      jnp.asarray(y), flat, mask)
        emb = emb - 5.0 * demb
        flat = flat - 0.2 * dparams
        losses.append(float(loss))
    # measured headroom: ~0.69 -> ~0.50 in 200 steps with these LRs
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.05
