"""AOT export: manifest consistency and HLO-text well-formedness."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot
from compile.configs import CONFIGS, n_params


def test_signatures_cover_all_variants():
    cfg = CONFIGS["tiny"]
    sigs = aot.variant_signatures(cfg)
    assert set(sigs) == {"train_fp", "train_lpt", "train_fq", "delta_grad",
                         "eval_fp", "eval_lpt", "quantize"}
    for variant, (specs, in_names, out_names) in sigs.items():
        assert len(specs) == len(in_names), variant
        assert len(out_names) >= 1, variant


def test_signature_shapes_tiny():
    cfg = CONFIGS["tiny"]
    sigs = aot.variant_signatures(cfg)
    specs, names, _ = sigs["train_lpt"]
    by_name = dict(zip(names, specs))
    assert by_name["codes"].shape == (cfg.umax, cfg.emb_dim)
    assert str(by_name["codes"].dtype) == "int32"
    assert by_name["delta"].shape == (cfg.umax,)
    assert by_name["idx"].shape == (cfg.batch, cfg.fields)
    assert by_name["params"].shape == (n_params(cfg),)
    assert by_name["mlp_mask"].shape == (cfg.batch, cfg.mlp_mask_dim)


def test_lowered_hlo_is_parseable_text():
    text, specs, in_names, out_names = aot.lower_variant(
        CONFIGS["tiny"], "quantize")
    assert "ENTRY" in text and "ROOT" in text
    # return_tuple=True: the root is a tuple even for single outputs
    assert "(s32[" in text or "tuple" in text


def test_lower_eval_variant_has_single_output():
    text, _, _, out_names = aot.lower_variant(CONFIGS["tiny"], "eval_fp")
    assert out_names == ["logits"]
    assert "ENTRY" in text


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--configs", "tiny"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        check=True, env=env)
    manifest = json.loads((out / "manifest.json").read_text())
    assert "tiny" in manifest["configs"]
    entry = manifest["configs"]["tiny"]
    assert entry["n_params"] == n_params(CONFIGS["tiny"])
    for variant, fname in entry["artifacts"].items():
        assert (out / fname).exists(), variant
        assert variant in entry["signatures"]
    # parameter layout offsets reconstruct n_params
    total = 0
    for p in entry["params"]:
        n = 1
        for s in p["shape"]:
            n *= s
        total += n
    assert total == entry["n_params"]
