"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes / bit widths / value ranges; assert_allclose with
tight tolerances (the kernels are the same math, so exact or near-exact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import cross as cross_k
from compile.kernels import lsq as lsq_k
from compile.kernels import quantize as quant_k
from compile.kernels import ref
from compile.kernels.common import row_block

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def qrange(bits):
    return float(-(2 ** (bits - 1))), float(2 ** (bits - 1) - 1)


# ----------------------------------------------------------------- row_block
@given(st.integers(1, 5000), st.sampled_from([64, 128, 256]))
@settings(max_examples=60, deadline=None)
def test_row_block_divides(n, target):
    b = row_block(n, target)
    assert n % b == 0
    assert 1 <= b <= n


# ------------------------------------------------------------------- dequant
@given(st.integers(1, 300), st.sampled_from([1, 4, 8, 16, 17]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_dequant_matches_ref(u, d, seed):
    r = rng(seed)
    codes = r.integers(-128, 128, size=(u, d)).astype(np.int32)
    delta = r.uniform(1e-4, 0.1, size=(u,)).astype(np.float32)
    got = quant_k.dequant(jnp.asarray(codes), jnp.asarray(delta))
    want = ref.dequant(jnp.asarray(codes), jnp.asarray(delta))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


# ------------------------------------------------------------------ quant_dr
@given(st.integers(1, 200), st.sampled_from([1, 3, 8, 16]),
       st.sampled_from([2, 4, 8, 16]), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_quant_dr_matches_ref(u, d, bits, seed):
    r = rng(seed)
    w = r.normal(0, 0.05, size=(u, d)).astype(np.float32)
    delta = r.uniform(1e-3, 0.05, size=(u,)).astype(np.float32)
    qn, qp = qrange(bits)
    got = quant_k.quant_dr(jnp.asarray(w), jnp.asarray(delta), qn, qp)
    want = ref.quant_dr(jnp.asarray(w), jnp.asarray(delta), qn, qp)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # codes stay in the integer range of the bit width
    assert np.asarray(got).min() >= qn and np.asarray(got).max() <= qp


def test_quant_dr_round_half_up():
    # R_D ties: 0.5 -> 1, -0.5 -> 0, -1.5 -> -1 (paper Eq. 3).
    w = jnp.asarray([[0.5, -0.5, -1.5, 1.5]], jnp.float32)
    delta = jnp.asarray([1.0], jnp.float32)
    got = np.asarray(quant_k.quant_dr(w, delta, -8.0, 7.0)).ravel()
    assert got.tolist() == [1, 0, -1, 2]


# ------------------------------------------------------------------ quant_sr
@given(st.integers(1, 200), st.sampled_from([2, 8]),
       st.sampled_from([2, 4, 8]), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_quant_sr_matches_ref(u, d, bits, seed):
    r = rng(seed)
    w = r.normal(0, 0.05, size=(u, d)).astype(np.float32)
    delta = r.uniform(1e-3, 0.05, size=(u,)).astype(np.float32)
    noise = r.uniform(0, 1, size=(u, d)).astype(np.float32)
    qn, qp = qrange(bits)
    got = quant_k.quant_sr(jnp.asarray(w), jnp.asarray(delta),
                           jnp.asarray(noise), qn, qp)
    want = ref.quant_sr(jnp.asarray(w), jnp.asarray(delta),
                        jnp.asarray(noise), qn, qp)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_quant_sr_unbiased():
    # E[R_S(x)] = x: average many independent SR draws of the same value.
    r = rng(0)
    u, d, n = 64, 8, 400
    w = r.normal(0, 0.03, size=(u, d)).astype(np.float32)
    delta = np.full((u,), 0.01, np.float32)
    acc = np.zeros((u, d), np.float64)
    for i in range(n):
        noise = r.uniform(0, 1, size=(u, d)).astype(np.float32)
        codes = ref.quant_sr(jnp.asarray(w), jnp.asarray(delta),
                             jnp.asarray(noise), -128.0, 127.0)
        acc += np.asarray(ref.dequant(codes, jnp.asarray(delta)))
    # standard error of the mean is delta/sqrt(12 n) ~ 1.4e-4; allow 5 sigma
    assert_allclose(acc / n, np.clip(w, -1.28, 1.27), atol=8e-4)


def test_sr_dr_agree_when_exact():
    # When w/delta is already an integer, SR == DR regardless of noise.
    w = jnp.asarray([[0.02, -0.05, 0.0]], jnp.float32)
    delta = jnp.asarray([0.01], jnp.float32)
    noise = jnp.asarray([[0.999, 0.0, 0.5]], jnp.float32)
    sr = quant_k.quant_sr(w, delta, noise, -128.0, 127.0)
    dr = quant_k.quant_dr(w, delta, -128.0, 127.0)
    assert np.array_equal(np.asarray(sr), np.asarray(dr))


# ---------------------------------------------------------------- fake_quant
@given(st.integers(1, 150), st.sampled_from([2, 8, 16]),
       st.sampled_from([2, 4, 8]), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_fake_quant_fwd_matches_ref(u, d, bits, seed):
    r = rng(seed)
    w = r.normal(0, 0.05, size=(u, d)).astype(np.float32)
    delta = r.uniform(1e-3, 0.05, size=(u,)).astype(np.float32)
    qn, qp = qrange(bits)
    got = lsq_k.fake_quant(jnp.asarray(w), jnp.asarray(delta), qn, qp)
    want = ref.lsq_fake_quant(jnp.asarray(w), jnp.asarray(delta), qn, qp)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


@given(st.integers(1, 100), st.sampled_from([2, 8]),
       st.sampled_from([2, 4, 8]), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_fake_quant_bwd_matches_ref(u, d, bits, seed):
    r = rng(seed)
    w = r.normal(0, 0.05, size=(u, d)).astype(np.float32)
    delta = r.uniform(1e-3, 0.05, size=(u,)).astype(np.float32)
    g = r.normal(0, 1, size=(u, d)).astype(np.float32)
    qn, qp = qrange(bits)

    def f(w_, d_):
        return jnp.sum(lsq_k.fake_quant(w_, d_, qn, qp) * jnp.asarray(g))

    dw, dd = jax.grad(f, argnums=(0, 1))(jnp.asarray(w), jnp.asarray(delta))
    dw_ref, dd_ref = ref.lsq_bwd(jnp.asarray(w), jnp.asarray(delta), qn, qp,
                                 jnp.asarray(g))
    assert_allclose(np.asarray(dw), np.asarray(dw_ref), rtol=1e-6, atol=1e-7)
    assert_allclose(np.asarray(dd), np.asarray(dd_ref), rtol=1e-5, atol=1e-6)


def test_fake_quant_delta_grad_finite_diff_clipped():
    """Eq. 7 is LSQ's *estimator* (it applies the STE to the rounding op, so
    in-range it returns R(x)-x, not the true local derivative R(x)). In the
    clipped region there is no rounding and Q = delta*qn (resp. qp) exactly,
    so the estimator equals the true derivative — finite differences must
    match there."""
    w = jnp.asarray([[1.0, -2.0, 0.5]], jnp.float32)   # w/delta >> qp, << qn
    delta = jnp.asarray([0.01], jnp.float32)
    qn, qp = -8.0, 7.0

    def f(d_):
        return jnp.sum(lsq_k.fake_quant(w, d_, qn, qp))

    g = jax.grad(f)(delta)
    eps = 1e-5
    fd = (f(delta + eps) - f(delta - eps)) / (2 * eps)
    assert_allclose(np.asarray(g)[0], float(fd), rtol=1e-3)
    assert_allclose(np.asarray(g)[0], qp + qn + qp, rtol=1e-6)


def test_fake_quant_clip_gradients():
    # Weights pushed beyond the clip range: dw = 0, d delta = qn/qp.
    w = jnp.asarray([[1.0, -1.0]], jnp.float32)
    delta = jnp.asarray([0.01], jnp.float32)   # w/delta = +-100, range 4-bit
    qn, qp = -8.0, 7.0

    def f(w_, d_):
        return jnp.sum(lsq_k.fake_quant(w_, d_, qn, qp))

    dw, dd = jax.grad(f, argnums=(0, 1))(w, delta)
    assert np.asarray(dw).tolist() == [[0.0, 0.0]]
    assert_allclose(np.asarray(dd)[0], qp + qn, rtol=1e-6)


# --------------------------------------------------------------- cross layer
@given(st.integers(1, 128), st.integers(1, 96), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_cross_fwd_matches_ref(b, k, seed):
    r = rng(seed)
    x0 = r.normal(0, 1, size=(b, k)).astype(np.float32)
    xl = r.normal(0, 1, size=(b, k)).astype(np.float32)
    w = r.normal(0, 0.1, size=(k,)).astype(np.float32)
    bias = r.normal(0, 0.1, size=(k,)).astype(np.float32)
    got = cross_k.cross_layer(*map(jnp.asarray, (x0, xl, w, bias)))
    want = ref.cross_layer(*map(jnp.asarray, (x0, xl, w, bias)))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(st.integers(1, 64), st.integers(1, 48), st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_cross_bwd_matches_autodiff_of_ref(b, k, seed):
    r = rng(seed)
    x0 = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))
    xl = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 0.1, size=(k,)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 0.1, size=(k,)).astype(np.float32))
    g = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))

    def loss_pallas(a0, al, aw, ab):
        return jnp.sum(cross_k.cross_layer(a0, al, aw, ab) * g)

    def loss_ref(a0, al, aw, ab):
        return jnp.sum(ref.cross_layer(a0, al, aw, ab) * g)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(x0, xl, w, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x0, xl, w, bias)
    for a, b_ in zip(gp, gr):
        assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)


def test_cross_layer_bwd_closed_form():
    # the hand-derived backward in ref.py equals autodiff of the forward
    r = rng(7)
    b, k = 16, 24
    x0 = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))
    xl = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 0.1, size=(k,)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 0.1, size=(k,)).astype(np.float32))
    g = jnp.asarray(r.normal(0, 1, size=(b, k)).astype(np.float32))

    def loss(a0, al, aw, ab):
        return jnp.sum(ref.cross_layer(a0, al, aw, ab) * g)

    auto = jax.grad(loss, argnums=(0, 1, 2, 3))(x0, xl, w, bias)
    manual = ref.cross_layer_bwd(x0, xl, w, g)
    for a, m in zip(auto, manual):
        assert_allclose(np.asarray(a), np.asarray(m), rtol=1e-5, atol=1e-5)
