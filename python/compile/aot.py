"""AOT compiler: lowers every (config x variant) step function to HLO text
and writes the artifact manifest the Rust runtime consumes.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--configs tiny,avazu,criteo,avazu_d32,criteo_d32]

Python runs exactly once, at build time. The Rust binary is self-contained
afterwards.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, n_params, param_layout


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def variant_signatures(cfg):
    """(input specs, human-readable input names) per exported variant."""
    u, d, b, f = cfg.umax, cfg.emb_dim, cfg.batch, cfg.fields
    p = n_params(cfg)
    m = cfg.mlp_mask_dim
    i32 = jnp.int32
    return {
        "train_fp": (
            [_spec((u, d)), _spec((b, f), i32), _spec((b,)), _spec((p,)),
             _spec((b, m))],
            ["emb", "idx", "labels", "params", "mlp_mask"],
            ["loss", "logits", "d_emb", "d_params"],
        ),
        "train_lpt": (
            [_spec((u, d), i32), _spec((u,)), _spec((b, f), i32),
             _spec((b,)), _spec((p,)), _spec((b, m))],
            ["codes", "delta", "idx", "labels", "params", "mlp_mask"],
            ["loss", "logits", "d_emb", "d_params"],
        ),
        "train_fq": (
            [_spec((u, d)), _spec((u,)), _spec((b, f), i32), _spec((b,)),
             _spec((p,)), _spec((b, m)), _spec(()), _spec(())],
            ["w", "delta", "idx", "labels", "params", "mlp_mask", "qn", "qp"],
            ["loss", "logits", "d_w", "d_delta", "d_params"],
        ),
        "delta_grad": (
            [_spec((u, d)), _spec((u,)), _spec((b, f), i32), _spec((b,)),
             _spec((p,)), _spec((b, m)), _spec(()), _spec(())],
            ["w", "delta", "idx", "labels", "params", "mlp_mask", "qn", "qp"],
            ["d_delta"],
        ),
        "eval_fp": (
            [_spec((u, d)), _spec((b, f), i32), _spec((p,))],
            ["emb", "idx", "params"],
            ["logits"],
        ),
        "eval_lpt": (
            [_spec((u, d), i32), _spec((u,)), _spec((b, f), i32),
             _spec((p,))],
            ["codes", "delta", "idx", "params"],
            ["logits"],
        ),
        "quantize": (
            [_spec((u, d)), _spec((u,)), _spec((u, d)), _spec(()),
             _spec(())],
            ["w", "delta", "noise", "qn", "qp"],
            ["codes"],
        ),
    }


def step_fn(cfg, variant, use_pallas=True):
    fns = {
        "train_fp": model.train_fp,
        "train_lpt": model.train_lpt,
        "train_fq": model.train_fq,
        "delta_grad": model.delta_grad,
        "eval_fp": model.eval_fp,
        "eval_lpt": model.eval_lpt,
        "quantize": model.quantize_sr,
    }
    fn = fns[variant](cfg, use_pallas=use_pallas)
    if variant in ("eval_fp", "eval_lpt", "quantize"):
        # Tuple-ify single outputs so every artifact unwraps uniformly.
        inner = fn
        if variant == "quantize":
            return lambda *a: (inner(*a),)
        return lambda *a: (inner(*a),)
    return fn


def lower_variant(cfg, variant, use_pallas=True):
    specs, in_names, out_names = variant_signatures(cfg)[variant]
    fn = step_fn(cfg, variant, use_pallas)
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), specs, in_names, out_names


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,avazu,criteo,avazu_d32,criteo_d32")
    ap.add_argument("--variants",
                    default="train_fp,train_lpt,train_fq,delta_grad,eval_fp,eval_lpt,quantize")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead (debugging)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "generated_unix": int(time.time()),
                "configs": {}}

    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        arts = {}
        io_sig = {}
        for variant in args.variants.split(","):
            t0 = time.time()
            text, specs, in_names, out_names = lower_variant(
                cfg, variant, use_pallas=not args.no_pallas)
            fname = f"{cname}_{variant}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            arts[variant] = fname
            io_sig[variant] = {
                "inputs": [
                    {"name": n, "shape": list(s.shape),
                     "dtype": str(s.dtype)}
                    for n, s in zip(in_names, specs)
                ],
                "outputs": out_names,
            }
            print(f"[aot] {cname}/{variant}: {len(text)} chars "
                  f"({time.time() - t0:.1f}s)")

        manifest["configs"][cname] = {
            "fields": cfg.fields,
            "emb_dim": cfg.emb_dim,
            "batch": cfg.batch,
            "umax": cfg.umax,
            "cross_depth": cfg.cross_depth,
            "mlp": list(cfg.mlp),
            "dropout": cfg.dropout,
            "input_dim": cfg.input_dim,
            "mlp_mask_dim": cfg.mlp_mask_dim,
            "n_params": n_params(cfg),
            "params": [
                {"name": name, "shape": list(shape), "init": init}
                for name, shape, init in param_layout(cfg)
            ],
            "artifacts": arts,
            "signatures": io_sig,
        }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
