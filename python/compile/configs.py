"""Model/artifact configurations shared by aot.py and the test suite.

Each `ModelConfig` describes one DCN (Deep & Cross Network, Wang et al. 2017)
geometry that gets AOT-lowered to a set of HLO artifacts. The Rust coordinator
reads `artifacts/manifest.json` (written by aot.py) to learn shapes, the dense
parameter layout and initialization spec, so Python never runs at train time.

Geometry notes
--------------
* `batch` and `umax` are baked into the HLO (XLA is shape-static). `umax` is
  the padded number of *unique* feature rows per batch; the coordinator dedups
  features Rust-side and scatters gradients back, so `umax = batch * fields`
  is always sufficient.
* The quantization range (qn, qp) is a *runtime input*, so a single artifact
  serves every bit width m (qn = -2^{m-1}, qp = 2^{m-1}-1).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    fields: int          # number of categorical feature fields F
    emb_dim: int         # embedding dimension d
    batch: int           # train/eval batch size B
    cross_depth: int     # number of DCN cross layers
    mlp: tuple           # deep-tower widths
    dropout: float = 0.0  # MLP dropout prob (mask supplied by the coordinator)

    @property
    def umax(self) -> int:
        return self.batch * self.fields

    @property
    def input_dim(self) -> int:
        return self.fields * self.emb_dim

    @property
    def mlp_mask_dim(self) -> int:
        """Total width of the concatenated per-layer dropout masks."""
        return sum(self.mlp)


# The paper trains on Avazu (24 fields after timestamp expansion) and Criteo
# (39 fields) with DCN depth 3 / MLP 1024-512-256 (Avazu) and depth 5 / MLP
# 1000x5 (Criteo). We keep the field counts and depths and scale the MLP
# widths for the CPU-PJRT testbed (see DESIGN.md section 5).
CONFIGS = {
    # test/CI-sized config: fast to lower, fast to execute.
    "tiny": ModelConfig("tiny", fields=8, emb_dim=8, batch=64,
                        cross_depth=2, mlp=(32, 16)),
    "avazu": ModelConfig("avazu", fields=24, emb_dim=16, batch=256,
                         cross_depth=3, mlp=(256, 128, 64)),
    "criteo": ModelConfig("criteo", fields=39, emb_dim=16, batch=256,
                          cross_depth=5, mlp=(200, 200, 200, 200, 200),
                          dropout=0.2),
    # Table-3 variants: larger embedding dimension.
    "avazu_d32": ModelConfig("avazu_d32", fields=24, emb_dim=32, batch=256,
                             cross_depth=3, mlp=(256, 128, 64)),
    "criteo_d32": ModelConfig("criteo_d32", fields=39, emb_dim=32, batch=256,
                              cross_depth=5, mlp=(200, 200, 200, 200, 200),
                              dropout=0.2),
}


def param_layout(cfg: ModelConfig):
    """Dense-parameter layout: list of (name, shape, init) in flat order.

    init is one of:
      "xavier"  — U(-a, a) with a = sqrt(6 / (fan_in + fan_out))
      "normal"  — N(0, 0.01)  (cross-layer weight vectors)
      "zero"    — zeros (biases)
    The Rust side materializes the flat vector from this spec.
    """
    k = cfg.input_dim
    layout = []
    for i in range(cfg.cross_depth):
        layout.append((f"cross_{i}_w", (k,), "normal"))
        layout.append((f"cross_{i}_b", (k,), "zero"))
    prev = k
    for i, w in enumerate(cfg.mlp):
        layout.append((f"mlp_{i}_w", (prev, w), "xavier"))
        layout.append((f"mlp_{i}_b", (w,), "zero"))
        prev = w
    layout.append(("final_w", (k + prev, 1), "xavier"))
    layout.append(("final_b", (1,), "zero"))
    return layout


def n_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape, _ in param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total
