"""DCN cross-layer interaction as a Pallas kernel.

x_{l+1} = x0 * (x_l . w) + b + x_l        (Wang et al. 2017)

This is the dense hot-spot of the backbone model outside the MLP matmuls
(which XLA already maps to the MXU); the cross layer's rank-1 structure is
what a naive lowering turns into a [B,K]x[K,K] outer-product matmul — the
kernel instead computes the [B]-vector of row dots and a fused
multiply-add, tiled over batch-row blocks sized for VMEM.

The backward pass is closed-form (see ref.cross_layer_bwd) and cheap —
plain jnp there lets XLA fuse it into the surrounding backprop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, row_block
from . import ref


def _cross_kernel(x0_ref, xl_ref, w_ref, b_ref, o_ref):
    x0 = x0_ref[...]
    xl = xl_ref[...]
    s = xl @ w_ref[...]          # [bb, 1] row dots
    o_ref[...] = x0 * s + b_ref[...] + xl


def _cross_forward(x0, xl, w, b):
    bsz, k = x0.shape
    bb = row_block(bsz, 128)
    return pl.pallas_call(
        _cross_kernel,
        grid=(bsz // bb,),
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), jnp.float32),
        interpret=INTERPRET,
    )(x0, xl, w.reshape(k, 1), b.reshape(1, k))


@jax.custom_vjp
def cross_layer(x0, xl, w, b):
    """Pallas forward + closed-form backward DCN cross layer."""
    return _cross_forward(x0, xl, w, b)


def _vjp_fwd(x0, xl, w, b):
    return _cross_forward(x0, xl, w, b), (x0, xl, w)


def _vjp_bwd(res, g):
    x0, xl, w = res
    dx0, dxl, dw, db = ref.cross_layer_bwd(x0, xl, w, g)
    return dx0, dxl, dw, db


cross_layer.defvjp(_vjp_fwd, _vjp_bwd)
