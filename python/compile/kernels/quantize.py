"""Pallas kernels for the paper's quantization ops (Eq. 1-4).

Layout convention: weight rows are [U, d] with a per-row (feature-wise,
paper section 3.2) step size delta [U]. The quantization range (qn, qp) is a
runtime (1,1) scalar input so a single lowered artifact serves every bit
width m: qn = -2^{m-1}, qp = 2^{m-1}-1.

These ops are never differentiated: `dequant` feeds the forward pass from
integer storage (grads are taken w.r.t. its *output*), and `quant_*` run
after the update step (LPT Eq. 8). The differentiable fake-quant lives in
lsq.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, row_block


def _dequant_kernel(wi_ref, delta_ref, o_ref):
    o_ref[...] = wi_ref[...].astype(jnp.float32) * delta_ref[...]


def dequant(w_int, delta):
    """w^ = delta * w~  for integer rows [U, d], per-row delta [U]."""
    u, d = w_int.shape
    bu = row_block(u)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(u // bu,),
        in_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bu, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, d), jnp.float32),
        interpret=INTERPRET,
    )(w_int, delta.reshape(u, 1))


def _quant_dr_kernel(w_ref, delta_ref, qn_ref, qp_ref, o_ref):
    x = w_ref[...] / delta_ref[...]
    x = jnp.clip(x, qn_ref[0, 0], qp_ref[0, 0])
    # R_D (Eq. 3): round half towards +inf == floor(x + 0.5).
    o_ref[...] = jnp.floor(x + 0.5).astype(jnp.int32)


def quant_dr(w, delta, qn, qp):
    """Integer codes w~ = R_D(clip(w/delta, qn, qp)) (Eq. 1, deterministic)."""
    u, d = w.shape
    bu = row_block(u)
    return pl.pallas_call(
        _quant_dr_kernel,
        grid=(u // bu,),
        in_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bu, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, d), jnp.int32),
        interpret=INTERPRET,
    )(w, delta.reshape(u, 1), _scalar(qn), _scalar(qp))


def _quant_sr_kernel(w_ref, delta_ref, noise_ref, qn_ref, qp_ref, o_ref):
    x = w_ref[...] / delta_ref[...]
    x = jnp.clip(x, qn_ref[0, 0], qp_ref[0, 0])
    f = jnp.floor(x)
    # R_S (Eq. 4): floor + Bernoulli(frac), with the U[0,1) draw supplied by
    # the caller so the lowered computation stays a pure function.
    o_ref[...] = (f + (noise_ref[...] < (x - f)).astype(x.dtype)).astype(jnp.int32)


def quant_sr(w, delta, noise, qn, qp):
    """Integer codes w~ = R_S(clip(w/delta, qn, qp)) (Eq. 1, stochastic)."""
    u, d = w.shape
    bu = row_block(u)
    return pl.pallas_call(
        _quant_sr_kernel,
        grid=(u // bu,),
        in_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bu, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, d), jnp.int32),
        interpret=INTERPRET,
    )(w, delta.reshape(u, 1), noise, _scalar(qn), _scalar(qp))


def _scalar(v):
    return jnp.asarray(v, dtype=jnp.float32).reshape(1, 1)
