"""Differentiable LSQ fake quantization (paper Eq. 6-7) as Pallas kernels.

This is the kernel that makes ALPT's step-size learning work: Algorithm 1
step 2 runs the forward pass through Q_D(w^{t+1}, delta^t) and needs
d f / d delta. The gradient estimator is LSQ's (Esser et al. 2020), extended
to a per-row (feature-wise) step size:

    dQ/ddelta = qn                    if w/delta <= qn
                qp                    if w/delta >= qp
                R_D(w/delta) - w/delta   otherwise            (Eq. 7)

and the weight gradient uses the straight-through estimator restricted to
the clip range. Both forward and backward bodies are Pallas kernels wired
through jax.custom_vjp, so the whole thing lowers into the train_fq HLO
artifact and runs on the PJRT hot path with no Python.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, row_block


def _fq_fwd_kernel(w_ref, delta_ref, qn_ref, qp_ref, o_ref):
    delta = delta_ref[...]
    x = jnp.clip(w_ref[...] / delta, qn_ref[0, 0], qp_ref[0, 0])
    o_ref[...] = jnp.floor(x + 0.5) * delta


def _fq_bwd_kernel(w_ref, delta_ref, qn_ref, qp_ref, g_ref, dw_ref, dd_ref):
    qn = qn_ref[0, 0]
    qp = qp_ref[0, 0]
    x = w_ref[...] / delta_ref[...]
    g = g_ref[...]
    in_range = (x > qn) & (x < qp)
    dw_ref[...] = g * in_range.astype(g.dtype)
    dq_dd = jnp.where(x <= qn, qn,
                      jnp.where(x >= qp, qp, jnp.floor(x + 0.5) - x))
    dd_ref[...] = jnp.sum(g * dq_dd, axis=1, keepdims=True)


def _scalar(v):
    return jnp.asarray(v, dtype=jnp.float32).reshape(1, 1)


def _fq_forward(w, delta, qn, qp):
    u, d = w.shape
    bu = row_block(u)
    return pl.pallas_call(
        _fq_fwd_kernel,
        grid=(u // bu,),
        in_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bu, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, d), jnp.float32),
        interpret=INTERPRET,
    )(w, delta.reshape(u, 1), _scalar(qn), _scalar(qp))


def _fq_backward(w, delta, qn, qp, g):
    u, d = w.shape
    bu = row_block(u)
    dw, dd = pl.pallas_call(
        _fq_bwd_kernel,
        grid=(u // bu,),
        in_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bu, d), lambda i: (i, 0)),
            pl.BlockSpec((bu, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((u, d), jnp.float32),
            jax.ShapeDtypeStruct((u, 1), jnp.float32),
        ],
        interpret=INTERPRET,
    )(w, delta.reshape(u, 1), _scalar(qn), _scalar(qp), g)
    return dw, dd.reshape(u)


@jax.custom_vjp
def fake_quant(w, delta, qn, qp):
    """Q_D(w, delta) = delta * R_D(clip(w/delta, qn, qp)), differentiable
    w.r.t. w (STE) and delta (Eq. 7). qn/qp get zero cotangents."""
    return _fq_forward(w, delta, qn, qp)


def _vjp_fwd(w, delta, qn, qp):
    return _fq_forward(w, delta, qn, qp), (w, delta, qn, qp)


def _vjp_bwd(res, g):
    w, delta, qn, qp = res
    dw, dd = _fq_backward(w, delta, qn, qp, g)
    return dw, dd, jnp.zeros_like(jnp.asarray(qn, jnp.float32)), \
        jnp.zeros_like(jnp.asarray(qp, jnp.float32))


fake_quant.defvjp(_vjp_fwd, _vjp_bwd)
