"""Shared helpers for the Pallas kernels.

All kernels run `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode lowers the kernel body to plain HLO ops that
any backend runs (see DESIGN.md section 4). The BlockSpec tiling below is
still written TPU-style: row blocks sized for VMEM (~16 MiB budget), grid
over the row dimension, fp32 accumulation.
"""

INTERPRET = True

# Default row-block target. 256 rows x 1248 cols x 4 B = ~1.2 MiB per input
# block — three live blocks stay far below the 16 MiB VMEM budget while
# giving the (8,128)-lane vector unit full tiles at d >= 16.
DEFAULT_BLOCK_ROWS = 256


def row_block(n_rows: int, target: int = DEFAULT_BLOCK_ROWS) -> int:
    """Largest power-of-two-ish divisor of n_rows not exceeding target.

    XLA shapes are static and Pallas grids must tile exactly, so the block
    size has to divide the row count. Falls back to n_rows (single block)
    for awkward sizes — correctness first, the sweep in benches/micro picks
    the fast shape for round sizes.
    """
    if n_rows <= target:
        return n_rows
    b = target
    while b > 1 and n_rows % b != 0:
        b //= 2
    return b if n_rows % b == 0 else n_rows
