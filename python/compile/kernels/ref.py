"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the kernels are tested against (pytest +
hypothesis), and they double as a kernel-free model implementation used to
cross-check the lowered HLO. All functions mirror the paper's equations:

  Eq. 1-2  uniform symmetric quantization  w~ = R(clip(w/D, qn, qp)),
           w^ = D * w~
  Eq. 3    deterministic rounding R_D  (round half towards +inf)
  Eq. 4    stochastic rounding  R_S  (floor + Bernoulli(frac))
  Eq. 6-7  LSQ fake quantization and its step-size gradient estimator
"""

import jax.numpy as jnp


def round_det(x):
    """Paper Eq. 3: floor(x)+1 when frac >= 0.5, floor(x) otherwise."""
    return jnp.floor(x + 0.5)


def round_stoch(x, noise):
    """Paper Eq. 4 with an explicit U[0,1) noise tensor (no RNG state here:
    the caller supplies noise so the op stays a pure function for AOT)."""
    f = jnp.floor(x)
    return f + (noise < (x - f)).astype(x.dtype)


def dequant(w_int, delta):
    """w^ = D * w~ for a [U, d] integer row block with per-row step size."""
    return w_int.astype(jnp.float32) * delta[:, None]


def quant_dr(w, delta, qn, qp):
    """Integer codes via deterministic rounding (Eq. 1 with R_D)."""
    x = jnp.clip(w / delta[:, None], qn, qp)
    return round_det(x).astype(jnp.int32)


def quant_sr(w, delta, noise, qn, qp):
    """Integer codes via stochastic rounding (Eq. 1 with R_S)."""
    x = jnp.clip(w / delta[:, None], qn, qp)
    return round_stoch(x, noise).astype(jnp.int32)


def lsq_fake_quant(w, delta, qn, qp):
    """Eq. 6: w^ = D * R_D(clip(w/D, qn, qp)) with a per-row step size."""
    x = jnp.clip(w / delta[:, None], qn, qp)
    return round_det(x) * delta[:, None]


def lsq_bwd(w, delta, qn, qp, g):
    """Backward of Eq. 6 under LSQ's estimators.

    dw     : straight-through — pass gradient where w/D lies strictly inside
             (qn, qp), zero outside (clipped weights get no weight gradient).
    ddelta : Eq. 7 summed over the row:
               qn                      if w/D <= qn
               qp                      if w/D >= qp
               R_D(w/D) - w/D          otherwise
    """
    x = w / delta[:, None]
    in_range = (x > qn) & (x < qp)
    dw = g * in_range.astype(g.dtype)
    dq_dd = jnp.where(x <= qn, qn,
                      jnp.where(x >= qp, qp, round_det(x) - x))
    ddelta = jnp.sum(g * dq_dd, axis=1)
    return dw, ddelta


def cross_layer(x0, xl, w, b):
    """DCN cross interaction: x_{l+1} = x0 * (x_l . w) + b + x_l."""
    s = xl @ w  # [B]
    return x0 * s[:, None] + b[None, :] + xl


def cross_layer_bwd(x0, xl, w, g):
    """Backward of the cross layer.

    s   = xl @ w
    dx0 = g * s[:, None]
    dxl = g + r[:, None] * w[None, :]   with r = sum_k g[:,k] * x0[:,k]
    dw  = xl^T @ r
    db  = sum_b g
    """
    s = xl @ w
    r = jnp.sum(g * x0, axis=1)
    dx0 = g * s[:, None]
    dxl = g + r[:, None] * w[None, :]
    dw = xl.T @ r
    db = jnp.sum(g, axis=0)
    return dx0, dxl, dw, db
