"""L2: the DCN backbone (Wang et al. 2017) and the AOT-exported step
functions for every training variant the Rust coordinator needs.

All functions are pure and shape-static. Dense parameters travel as one flat
f32[P] vector (layout from configs.param_layout) so the Rust side handles a
single buffer; embedding rows travel as padded per-batch *unique* rows
[U, d] plus an int32 index matrix [B, F] (the coordinator dedups the batch's
features; JAX's gather VJP gives the scatter-add back to unique rows for
free).

Exported variants (see aot.py):
  train_fp   : f32 embeddings in          -> loss, logits, d emb, d dense
  train_lpt  : int32 codes + delta in     -> same (dequant kernel in-graph)
  train_fq   : f32 w + delta + (qn,qp) in -> loss, logits, d w (STE),
               d delta (Eq. 7), d dense   (ALPT Alg. 1 step 2 / QAT-LSQ)
  eval_fp    : f32 embeddings in          -> logits
  eval_lpt   : int32 codes + delta in     -> logits
  quantize   : w, delta, noise, qn, qp    -> int32 codes (SR, Eq. 4)

Dropout (paper: 0.2 on the Criteo MLP) is an explicit mask input of shape
[B, sum(mlp)] holding {0, 1/(1-p)} so the lowered HLO stays deterministic;
the coordinator draws the mask from its own PRNG (ones at eval).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_layout
from .kernels import cross as cross_k
from .kernels import lsq as lsq_k
from .kernels import quantize as quant_k
from .kernels import ref


def unpack_params(cfg: ModelConfig, flat):
    """Flat f32[P] -> dict of named parameter arrays (layout order)."""
    params = {}
    off = 0
    for name, shape, _ in param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def pack_params(cfg: ModelConfig, params):
    """Inverse of unpack_params (used by tests)."""
    leaves = []
    for name, shape, _ in param_layout(cfg):
        leaves.append(params[name].reshape(-1))
    return jnp.concatenate(leaves)


def forward(cfg: ModelConfig, emb_rows, idx, flat_params, mlp_mask,
            use_pallas=True):
    """DCN forward from unique embedding rows to logits [B].

    emb_rows : f32[U, d] unique (dequantized) embedding rows
    idx      : i32[B, F] positions into emb_rows
    mlp_mask : f32[B, sum(mlp)] dropout mask ({0, 1/(1-p)}; ones = no dropout)
    """
    p = unpack_params(cfg, flat_params)
    x = emb_rows[idx]                              # [B, F, d] gather
    x0 = x.reshape(cfg.batch, cfg.input_dim)

    cross_fn = cross_k.cross_layer if use_pallas else ref.cross_layer
    xl = x0
    for i in range(cfg.cross_depth):
        xl = cross_fn(x0, xl, p[f"cross_{i}_w"], p[f"cross_{i}_b"])

    h = x0
    moff = 0
    for i, width in enumerate(cfg.mlp):
        h = jnp.maximum(h @ p[f"mlp_{i}_w"] + p[f"mlp_{i}_b"], 0.0)
        h = h * mlp_mask[:, moff:moff + width]
        moff += width

    out = jnp.concatenate([xl, h], axis=1)
    logits = (out @ p["final_w"]).reshape(-1) + p["final_b"][0]
    return logits


def bce_with_logits(logits, labels):
    """Numerically-stable mean binary cross-entropy."""
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def _loss_fn(cfg, emb_rows, flat_params, idx, labels, mlp_mask, use_pallas):
    logits = forward(cfg, emb_rows, idx, flat_params, mlp_mask, use_pallas)
    return bce_with_logits(logits, labels), logits


def train_fp(cfg: ModelConfig, use_pallas=True):
    """(emb, idx, labels, params, mask) -> (loss, logits, d emb, d params)."""
    def step(emb, idx, labels, flat_params, mlp_mask):
        (loss, logits), (demb, dparams) = jax.value_and_grad(
            _loss_fn, argnums=(1, 2), has_aux=True)(
                cfg, emb, flat_params, idx, labels, mlp_mask, use_pallas)
        return loss, logits, demb, dparams
    return step


def train_lpt(cfg: ModelConfig, use_pallas=True):
    """(codes, delta, idx, labels, params, mask) -> (loss, logits,
    d emb_hat, d params). Gradients are w.r.t. the *dequantized* rows
    (paper Eq. 8: the update applies to w^, requantization is the
    coordinator's job)."""
    dq = quant_k.dequant if use_pallas else ref.dequant

    def step(codes, delta, idx, labels, flat_params, mlp_mask):
        emb_hat = dq(codes, delta)
        (loss, logits), (demb, dparams) = jax.value_and_grad(
            _loss_fn, argnums=(1, 2), has_aux=True)(
                cfg, emb_hat, flat_params, idx, labels, mlp_mask, use_pallas)
        return loss, logits, demb, dparams
    return step


def train_fq(cfg: ModelConfig, use_pallas=True):
    """Fake-quant training step (ALPT Alg. 1 step 2 and QAT-LSQ).

    (w, delta, idx, labels, params, mask, qn, qp) ->
        (loss, logits, d w (STE), d delta (Eq. 7), d params)
    """
    def step(w, delta, idx, labels, flat_params, mlp_mask, qn, qp):
        if use_pallas:
            def inner(w_, delta_, flat_):
                emb_hat = lsq_k.fake_quant(w_, delta_, qn, qp)
                return _loss_fn(cfg, emb_hat, flat_, idx, labels, mlp_mask,
                                use_pallas)
        else:
            # Reference path: same math with the STE expressed via
            # stop_gradient identities.
            def inner(w_, delta_, flat_):
                x = w_ / delta_[:, None]
                inr = ((x > qn) & (x < qp)).astype(w_.dtype)
                dq_dd = jnp.where(x <= qn, qn,
                                  jnp.where(x >= qp, qp,
                                            ref.round_det(x) - x))
                q = ref.lsq_fake_quant(w_, delta_, qn, qp)
                emb_hat = (jax.lax.stop_gradient(q)
                           + inr * (w_ - jax.lax.stop_gradient(w_))
                           + jax.lax.stop_gradient(dq_dd)
                           * (delta_[:, None]
                              - jax.lax.stop_gradient(delta_[:, None])))
                return _loss_fn(cfg, emb_hat, flat_, idx, labels, mlp_mask,
                                use_pallas)

        (loss, logits), (dw, ddelta, dparams) = jax.value_and_grad(
            inner, argnums=(0, 1, 2), has_aux=True)(w, delta, flat_params)
        return loss, logits, dw, ddelta, dparams
    return step


def delta_grad(cfg: ModelConfig, use_pallas=True):
    """Lean ALPT step-2 artifact: only d loss / d delta.

    Same math as train_fq but XLA dead-code-eliminates the dense-parameter
    and weight backward paths plus their host transfers — the §Perf
    optimization that brings ALPT's per-step overhead towards the paper's
    ~1.2x (Table 1 time column).
    """
    full = train_fq(cfg, use_pallas=use_pallas)

    def step(w, delta, idx, labels, flat_params, mlp_mask, qn, qp):
        _, _, _, ddelta, _ = full(w, delta, idx, labels, flat_params,
                                  mlp_mask, qn, qp)
        return (ddelta,)
    return step


def eval_fp(cfg: ModelConfig, use_pallas=True):
    """(emb, idx, params) -> logits (masks = ones: no dropout at eval)."""
    ones = jnp.ones((cfg.batch, cfg.mlp_mask_dim), jnp.float32)

    def step(emb, idx, flat_params):
        return forward(cfg, emb, idx, flat_params, ones, use_pallas)
    return step


def eval_lpt(cfg: ModelConfig, use_pallas=True):
    """(codes, delta, idx, params) -> logits — the int-native serving path."""
    dq = quant_k.dequant if use_pallas else ref.dequant
    ones = jnp.ones((cfg.batch, cfg.mlp_mask_dim), jnp.float32)

    def step(codes, delta, idx, flat_params):
        return forward(cfg, dq(codes, delta), idx, flat_params, ones,
                       use_pallas)
    return step


def quantize_sr(cfg: ModelConfig, use_pallas=True):
    """(w, delta, noise, qn, qp) -> int32 codes. On-device (re)quantization
    used by the serve example to convert an FP table to LPT storage."""
    q = quant_k.quant_sr if use_pallas else ref.quant_sr

    def step(w, delta, noise, qn, qp):
        return q(w, delta, noise, qn, qp)
    return step
