#!/usr/bin/env python3
"""Column-mapping shim: stream a raw Avazu CSV download into the
39-column Criteo-format TSV the `criteo:` reader consumes.

Avazu (Kaggle CTR) ships as CSV with its own layout:

    id,click,hour,C1,banner_pos,site_id,site_domain,site_category,
    app_id,app_domain,app_category,device_id,device_ip,device_model,
    device_type,device_conn_type,C14,...,C21        (24 columns)

The repo's streaming reader (rust/src/data/criteo.rs) expects the Kaggle
Criteo layout instead: `label \\t I1..I13 \\t C1..C26` — 13 numeric then
26 categorical columns, any field possibly empty. This script maps one
to the other, row by row, so a full Avazu download trains with:

    python3 scripts/avazu_to_tsv.py train.csv --out avazu.tsv
    cargo run --release -- train --dataset criteo:avazu.tsv \\
        --method alpt --plan 8 ...

(The output must be a materialized file: the Rust reader re-opens the
path once per epoch plus once for the held-out split, so a one-shot
pipe like `criteo:/dev/stdin` cannot feed it.)

Mapping (documented so the feature space is reproducible):

* label   <- `click` (``--label-default`` fills it for test files that
  lack the column);
* I1      <- hour-of-day parsed from `hour` (YYMMDDHH);
* I2      <- day-of-week (0 = Monday) from the same timestamp;
* I3..I13 <- empty (missing values are data, not errors);
* C1..C21 <- every remaining Avazu column in file order (`C1`,
  `banner_pos`, site/app/device columns, `C14`..`C21`) — they are all
  categorical in Avazu, including the integer-looking ones;
* C22..C26 <- empty.

Only the Python standard library is used; `.gz` inputs stream through
`gzip`. Malformed rows (wrong column count, unparsable hour) are counted
and skipped, mirroring the Rust reader's policy.
"""

import argparse
import csv
import datetime
import gzip
import sys

N_NUMERIC = 13
N_CATEGORICAL = 26
# Avazu columns, in file order, that become categorical features
AVAZU_CATEGORICAL = [
    "C1", "banner_pos", "site_id", "site_domain", "site_category",
    "app_id", "app_domain", "app_category", "device_id", "device_ip",
    "device_model", "device_type", "device_conn_type",
    "C14", "C15", "C16", "C17", "C18", "C19", "C20", "C21",
]
AVAZU_HEADER_TRAIN = ["id", "click", "hour"] + AVAZU_CATEGORICAL
AVAZU_HEADER_TEST = ["id", "hour"] + AVAZU_CATEGORICAL


def open_input(path):
    if path == "-":
        return sys.stdin
    if path.endswith(".gz"):
        return gzip.open(path, "rt", newline="")
    return open(path, newline="")


def convert_row(row, cols, label_default):
    """One Avazu CSV row -> one Criteo-format TSV line, or None."""
    if len(row) != len(cols):
        return None
    rec = dict(zip(cols, row))
    label = rec.get("click", label_default)
    if label not in ("0", "1"):
        return None
    try:
        ts = datetime.datetime.strptime(rec["hour"], "%y%m%d%H")
    except ValueError:
        return None
    numeric = [str(ts.hour), str(ts.weekday())] + [""] * (N_NUMERIC - 2)
    categorical = [rec[c] for c in AVAZU_CATEGORICAL]
    categorical += [""] * (N_CATEGORICAL - len(categorical))
    return "\t".join([label] + numeric + categorical)


def main():
    ap = argparse.ArgumentParser(
        description="Stream an Avazu CSV into Criteo-format TSV "
                    "(39 feature columns)."
    )
    ap.add_argument("input", help="Avazu CSV path, .gz ok, '-' for stdin")
    ap.add_argument("--out", default="-",
                    help="output TSV path (default: stdout)")
    ap.add_argument("--label-default", default="0",
                    help="label for files without a click column "
                         "(e.g. the Kaggle test split); default 0")
    args = ap.parse_args()

    src = open_input(args.input)
    dst = sys.stdout if args.out == "-" else open(args.out, "w")
    reader = csv.reader(src)
    cols = None
    n_ok = n_bad = 0
    for row in reader:
        if cols is None:
            # header row names the layout; headerless files must match
            # the standard train layout
            if row and row[0] == "id":
                lowered = [c.strip() for c in row]
                if lowered != AVAZU_HEADER_TRAIN \
                        and lowered != AVAZU_HEADER_TEST:
                    sys.exit(
                        f"error: unrecognized Avazu header "
                        f"({len(lowered)} columns): {lowered[:6]}..."
                    )
                cols = lowered
                continue
            cols = AVAZU_HEADER_TRAIN
        line = convert_row(row, cols, args.label_default)
        if line is None:
            n_bad += 1
            continue
        print(line, file=dst)
        n_ok += 1
    if dst is not sys.stdout:
        dst.close()
    print(f"converted {n_ok} rows ({n_bad} malformed skipped)",
          file=sys.stderr)
    if n_ok == 0:
        sys.exit("error: no convertible rows found")


if __name__ == "__main__":
    main()
