#!/usr/bin/env python3
"""CI client for the `alpt serve --listen` online scoring server.

Stdlib-only. Drives the full online-serve CI leg:

1. wait for `GET /healthz` to come up;
2. replay the offline-scored requests dumped by
   `alpt serve --ckpt ... --dump-requests N` (JSON lines of
   {"features": [...], "logit": ...}) through `POST /score` and assert
   the HTTP logits match the offline ones;
3. assert malformed bodies get HTTP 400 without killing the server;
4. `POST /reload` onto a second checkpoint while a background thread
   keeps scoring — no request may fail across the swap;
5. check `GET /stats` counters, then `POST /shutdown`.

Usage:
  python3 scripts/http_serve_check.py --addr 127.0.0.1:8091 \
      --requests /tmp/requests.jsonl [--reload-ckpt /tmp/other.ckpt]
"""
import argparse
import json
import sys
import threading
import time
import urllib.error
import urllib.request

TOL = 1e-6


def call(addr, method, path, body=None, timeout=30):
    """One HTTP request; returns (status, parsed-or-raw body)."""
    data = None if body is None else body.encode()
    req = urllib.request.Request(
        f"http://{addr}{path}", data=data, method=method,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read().decode())
    except urllib.error.HTTPError as e:
        try:
            return e.code, json.loads(e.read().decode())
        except Exception:
            return e.code, {}


def wait_healthy(addr, budget_s=60):
    deadline = time.time() + budget_s
    while time.time() < deadline:
        try:
            code, body = call(addr, "GET", "/healthz", timeout=5)
            if code == 200 and body.get("status") == "ok":
                return body
        except Exception:
            pass
        time.sleep(0.5)
    sys.exit(f"FAIL: server at {addr} not healthy within {budget_s}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", required=True)
    ap.add_argument("--requests", required=True,
                    help="JSON-lines file from `alpt serve --dump-requests`")
    ap.add_argument("--reload-ckpt", default=None)
    args = ap.parse_args()

    health = wait_healthy(args.addr)
    print(f"healthy: {health}")

    requests = [json.loads(line) for line in open(args.requests)
                if line.strip()]
    assert requests, "empty requests file"

    # --- offline == online -------------------------------------------
    records = [r["features"] for r in requests]
    code, body = call(args.addr, "POST", "/score",
                      json.dumps({"records": records}))
    assert code == 200, f"score returned {code}: {body}"
    logits = body["logits"]
    assert len(logits) == len(requests), (len(logits), len(requests))
    worst = max(abs(z - r["logit"]) for z, r in zip(logits, requests))
    assert worst <= TOL, \
        f"FAIL: HTTP logits diverge from offline scores (worst {worst})"
    assert all(0.0 <= p <= 1.0 for p in body["probs"])
    print(f"scored {len(requests)} records over HTTP; "
          f"max |http - offline| = {worst:.2e}")

    # --- malformed input ---------------------------------------------
    for bad in ["this is not json", "{\"records\": 42}", "[[1]]"]:
        code, body = call(args.addr, "POST", "/score", bad)
        assert code == 400, f"malformed body {bad!r} -> {code} (want 400)"
    code, _ = call(args.addr, "POST", "/score",
                   json.dumps({"records": [records[0]]}))
    assert code == 200, "server died after malformed input"
    print("malformed bodies -> 400, server alive")

    # --- hot swap under load -----------------------------------------
    if args.reload_ckpt:
        stop = threading.Event()
        failures, scored = [], []

        def hammer():
            while not stop.is_set():
                try:
                    c, _ = call(args.addr, "POST", "/score",
                                json.dumps({"records": [records[0]]}),
                                timeout=30)
                    (scored if c == 200 else failures).append(c)
                except Exception as e:  # noqa: BLE001
                    failures.append(str(e))

        t = threading.Thread(target=hammer)
        t.start()
        while len(scored) < 3:
            time.sleep(0.05)
        code, body = call(args.addr, "POST", "/reload",
                          json.dumps({"ckpt": args.reload_ckpt}))
        assert code == 200, f"reload returned {code}: {body}"
        print(f"reloaded onto {args.reload_ckpt}: {body}")
        seen = len(scored)
        while len(scored) < seen + 3:
            time.sleep(0.05)
        stop.set()
        t.join()
        assert not failures, \
            f"FAIL: {len(failures)} requests failed across the hot swap"
        # still scoring valid logits on the new model
        code, body = call(args.addr, "POST", "/score",
                          json.dumps({"records": [records[0]]}))
        assert code == 200
        print(f"hot swap dropped 0 of {len(scored)} in-flight requests")

    # --- stats + shutdown --------------------------------------------
    code, stats = call(args.addr, "GET", "/stats")
    assert code == 200
    assert stats["requests"] >= 2, stats
    assert stats["records_scored"] >= len(requests), stats
    if args.reload_ckpt:
        assert stats["reloads"] == 1, stats
    print(f"stats: {stats}")

    code, _ = call(args.addr, "POST", "/shutdown")
    assert code == 200
    print("server shut down cleanly")
    print("PASS: online-serve leg")


if __name__ == "__main__":
    main()
