#!/usr/bin/env python3
"""Diff a freshly generated BENCH_micro.json against the committed
baseline and shout (but never fail) when a key row regresses.

Usage:
    python3 scripts/bench_diff.py BASELINE.json CURRENT.json [--warn-pct 20]

Both files use the ``write_report`` schema::

    {"schema_version": 1, "meta": {...}, "benchmarks": [
        {"name": ..., "median_ns": ..., ...}, ...]}

Comparison is on ``median_ns`` (lower is better). Rows present on only
one side are listed informationally. A regression beyond ``--warn-pct``
emits a GitHub Actions ``::warning::`` annotation so it is loud in the
PR checks UI, but the exit code is always 0: shared-runner noise makes
a hard gate flakier than it is useful, and the committed baseline may
have been produced on different hardware. Self-skips (exit 0, note on
stderr) when the baseline file is absent — e.g. the very first PR that
introduces the report.
"""

import argparse
import json
import os
import sys

# Rows that carry the perf contract of the SIMD kernel layer and the
# serving path. Substring match so bit widths / thread counts roll in.
KEY_PREFIXES = [
    "dequant row",
    "packed gather",
    "quantize_row_packed DR",
    "fused quantize_row_packed",
    "LPT-4bit update",
    "LPT-8bit update",
    "engine score",
]


def load_rows(path):
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    rows = {}
    for row in doc.get("benchmarks", []):
        name = row.get("name")
        med = row.get("median_ns")
        if name is not None and isinstance(med, (int, float)) and med > 0:
            rows[name] = float(med)
    return doc.get("meta", {}), rows


def is_key(name):
    return any(name.startswith(p) for p in KEY_PREFIXES)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--warn-pct", type=float, default=20.0,
                    help="warn when median_ns grows by more than this "
                         "percentage (default: 20)")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench_diff: no baseline at {args.baseline}; skipping "
              "(first report?)", file=sys.stderr)
        return 0
    if not os.path.exists(args.current):
        print(f"bench_diff: current report {args.current} missing; "
              "did the bench run?", file=sys.stderr)
        return 0

    base_meta, base = load_rows(args.baseline)
    cur_meta, cur = load_rows(args.current)
    print(f"bench_diff: baseline meta={base_meta} current meta={cur_meta}")
    if base_meta.get("kernel") != cur_meta.get("kernel"):
        print(f"bench_diff: note: kernel differs "
              f"({base_meta.get('kernel')} -> {cur_meta.get('kernel')}); "
              "ratios mix kernel and hardware effects")

    regressions = []
    print(f"{'row':<48} {'base':>12} {'cur':>12} {'ratio':>7}")
    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        ratio = c / b
        flag = ""
        if is_key(name) and ratio > 1.0 + args.warn_pct / 100.0:
            flag = "  <-- REGRESSION"
            regressions.append((name, b, c, ratio))
        print(f"{name:<48} {b:>10.0f}ns {c:>10.0f}ns {ratio:>6.2f}x{flag}")
    for name in sorted(set(base) - set(cur)):
        print(f"{name:<48} {'(dropped from current report)':>34}")
    for name in sorted(set(cur) - set(base)):
        print(f"{name:<48} {'(new row, no baseline)':>34}")

    if regressions:
        for name, b, c, ratio in regressions:
            # GitHub Actions annotation: shows up inline on the PR
            print(f"::warning title=bench regression::{name} median "
                  f"{b:.0f}ns -> {c:.0f}ns ({ratio:.2f}x, threshold "
                  f"{1.0 + args.warn_pct / 100.0:.2f}x)")
        print(f"bench_diff: {len(regressions)} key row(s) regressed "
              f">{args.warn_pct:.0f}% (warning only, not failing CI)",
              file=sys.stderr)
    else:
        print("bench_diff: no key-row regressions beyond "
              f"{args.warn_pct:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
