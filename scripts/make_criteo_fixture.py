#!/usr/bin/env python3
"""Generate the committed Criteo-format fixture
`examples/fixtures/tiny_criteo.tsv`.

Writes a deterministic ~1k-row TSV in the exact Kaggle Criteo layout —
`label \\t I1..I13 \\t C1..C26` — with the statistical properties the
streaming pipeline must handle:

* a latent logistic ground truth, so a trained model reaches a held-out
  AUC well above chance (the CI e2e job asserts the pipeline end to end);
* heavy-tailed integer counts in the numeric columns (log-bucketization
  territory), including occasional small negatives as in the real dump;
* 8-hex-char categorical tokens drawn from per-field pools whose head
  tokens correlate with the label;
* empty fields (~15-20% per column) — missing values are data, not
  errors, in Criteo dumps.

Determinism: a fixed-seed `random.Random`, no environment dependence.

    python3 scripts/make_criteo_fixture.py [--rows 1000] [--seed 7]
"""

import argparse
import math
import os
import random

N_NUMERIC = 13
N_CATEGORICAL = 26


def make_row(rng):
    """One record: (label, 13 numeric strings, 26 categorical strings)."""
    u = rng.gauss(0.0, 1.0)  # latent factor driving label + features
    logit = 1.6 * u - 1.0    # CTR ~ 0.27 at u ~ N(0,1)
    label = 1 if rng.random() < 1.0 / (1.0 + math.exp(-logit)) else 0

    nums = []
    for j in range(N_NUMERIC):
        if rng.random() < 0.15:
            nums.append("")  # missing
            continue
        # heavy-tailed count correlated with the latent factor
        scale = math.exp(0.9 * u + 0.7 * rng.gauss(0.0, 1.0))
        v = int(scale * (1 + 3 * j))
        if j >= 11 and rng.random() < 0.03:
            v = -1  # the real dump carries occasional small negatives
        nums.append(str(v))

    cats = []
    for j in range(N_CATEGORICAL):
        if rng.random() < 0.18:
            cats.append("")  # missing
            continue
        pool = 24 + 6 * j  # per-field vocabulary size
        if j < 8:
            # head fields: token index tracks the latent factor (signal)
            idx = int((u + 3.0) / 6.0 * pool)
            idx = max(0, min(pool - 1, idx + rng.randrange(-1, 2)))
        else:
            # tail fields: Zipf-ish noise
            idx = min(int(rng.paretovariate(1.2)) - 1, pool - 1)
        token = (j * 1_000_003 + idx * 97 + 13) & 0xFFFFFFFF
        cats.append(f"{token:08x}")

    return label, nums, cats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(
        root, "examples", "fixtures", "tiny_criteo.tsv"
    )
    os.makedirs(os.path.dirname(out), exist_ok=True)

    rng = random.Random(args.seed)
    n_pos = 0
    with open(out, "w", encoding="ascii", newline="\n") as f:
        for _ in range(args.rows):
            label, nums, cats = make_row(rng)
            n_pos += label
            f.write("\t".join([str(label)] + nums + cats))
            f.write("\n")

    print(
        f"wrote {out}: {args.rows} rows, ctr {n_pos / args.rows:.3f}, "
        f"{os.path.getsize(out)} bytes"
    )


if __name__ == "__main__":
    main()
