#!/usr/bin/env python3
"""Train the committed serving fixture `examples/fixtures/tiny_lpt8.ckpt`.

The serving eval (rust/src/coordinator/serve.rs) regenerates the `tiny`
synthetic dataset from the checkpoint's experiment seed, so a fixture
only reports a *real* AUC if its model was trained against the same
latent ground truth. This script makes that possible without a Rust
toolchain in the container:

* exact ports of the repo's deterministic generators — `mix64`,
  `Pcg32` (PCG-XSH-RR 64/32) and the stateless pair-interaction hash
  (rust/src/util/rng.rs, rust/src/data/synthetic.rs) — rebuild the
  ground-truth latent weights and field pairs bit-for-bit from the seed
  (both are self-tested against published SplitMix64/PCG32 vectors);
* training *samples* only need the right distribution, not the right
  stream, so Zipf ranks, the per-field rank permutation and Bernoulli
  labels are drawn vectorized with numpy against that ground truth;
* a numpy DCN mirrors rust/src/nn/dcn.rs layer for layer (same cross /
  MLP / head shapes and the same flat parameter layout), trained with
  plain SGD while the embedding table is clamped to the LPT clip range;
* the embedding table is quantized onto the fixed 8-bit LPT grid
  (Δ = clip / 2^{m-1}, codes in [-127, 127]) and written as a version-1
  checkpoint through scripts/make_fixture.py's section writer.

The script refuses to write the fixture unless its own held-out AUC
clears 0.65; rust/tests/ckpt_fixture.rs then re-asserts > 0.60 through
the real Rust reader + engine on the seed-regenerated split, which
fails loudly if the ground-truth port ever drifts from the Rust side.

    python3 scripts/train_fixture.py        # numpy only, ~1 minute
"""

import math
import os
import struct
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from make_fixture import (  # noqa: E402
    BATCH, CROSS_DEPTH, EMB_DIM, FIELDS, KIND_DENSE, KIND_META, KIND_ROWS,
    MAGIC, MLP, N, ROW_BYTES, VERSION, VOCABS, meta_json, n_params, section,
    verify,
)

# experiment echo constants (must agree with make_fixture.experiment_echo)
SEED = 7
CLIP = np.float32(0.1)
BITS = 8
DELTA = CLIP / np.float32(1 << (BITS - 1))  # delta_from_clip, f32
# SyntheticSpec::tiny (rust/src/data/synthetic.rs)
ZIPF_S = 1.1
WEIGHT_STD = 1.2
N_PAIRS = 4
PAIR_STD = 0.6
TARGET_CTR = 0.25
OFFSETS = np.cumsum([0] + VOCABS[:-1])  # exclusive prefix sum (Schema)

# training budget (distribution-matched fresh draws, not the eval split)
N_TRAIN = 60_000
N_EVAL = 10_000
EPOCHS = 4
LR_DENSE = 0.1
LR_EMB = 0.5
MIN_AUC = 0.65

M64 = (1 << 64) - 1


# ---- exact ports of rust/src/util/rng.rs ------------------------------


def mix64(z):
    """SplitMix64 finalizer on Python ints (wrapping u64)."""
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return (z ^ (z >> 31)) & M64


class Pcg32:
    """PCG-XSH-RR 64/32, bit-for-bit the Rust `Pcg32`."""

    def __init__(self, seed, stream):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + mix64(seed)) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & M64
        x = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((x >> rot) | (x << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_u64(self):
        return (self.next_u32() << 32) | self.next_u32()

    def uniform_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        """Lemire's unbiased [0, n) (matches Rust draw-for-draw)."""
        while True:
            x = self.next_u32()
            m = x * n
            lo = m & 0xFFFFFFFF
            if lo >= n or lo >= ((1 << 32) - n) % n:
                return m >> 32

    def normal(self):
        """Box–Muller in f64, cast to f32 (Rust `normal`)."""
        u1 = 1.0 - self.uniform_f64()
        u2 = self.uniform_f64()
        r = math.sqrt(-2.0 * math.log(u1))
        return np.float32(r * math.cos(2.0 * math.pi * u2))

    def normal_scaled(self, mean, std):
        return np.float32(mean) + np.float32(std) * self.normal()


def _selftest():
    """Pin the ports to published reference vectors before trusting them."""
    # SplitMix64(1234567): next() = mix64(state += golden gamma)
    s = (1234567 + 0x9E3779B97F4A7C15) & M64
    assert mix64(s) == 6457827717110365317, "mix64 port broken"
    s = (s + 0x9E3779B97F4A7C15) & M64
    assert mix64(s) == 3203168211198807973, "mix64 port broken"
    # PCG32 demo vector (initstate 42, initseq 54) through the same
    # next_u32 core; the Rust ctor only differs by mixing the seed first
    r = Pcg32.__new__(Pcg32)
    r.state, r.inc = 0, (54 << 1) | 1
    r.next_u32()
    r.state = (r.state + 42) & M64
    r.next_u32()
    got = [r.next_u32() for _ in range(6)]
    assert got == [0xA15C02B7, 0x7B47F409, 0xBA1D3330, 0x83D2F293,
                   0xBFA4784B, 0xCBED606E], f"pcg32 port broken: {got}"


# ---- ground truth (exact latent model, rust/src/data/synthetic.rs) ----


def mix64_np(z):
    """Vectorized mix64 on uint64 arrays."""
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def interaction_np(a, b):
    """Stateless pair weight: hash -> uniforms -> Box–Muller (exact)."""
    h = mix64_np(np.uint64(SEED) ^ ((a << np.uint64(32)) | b))
    u1 = np.maximum((h >> np.uint64(11)).astype(np.float64) * 2.0**-53,
                    1e-12)
    h2 = mix64_np(h ^ np.uint64(0x9E3779B97F4A7C15))
    u2 = (h2 >> np.uint64(11)).astype(np.float64) * 2.0**-53
    return (np.sqrt(-2.0 * np.log(u1))
            * np.cos(2.0 * np.pi * u2)).astype(np.float32)


def ground_truth_weights():
    """Latent per-feature weights + field pairs, bit-for-bit the Rust
    GroundTruth::new draws (Pcg32 streams 0x17EA)."""
    rng = Pcg32(SEED, 0x17EA)
    per_field = np.float32(WEIGHT_STD) / np.sqrt(np.float32(FIELDS))
    weights = np.array(
        [rng.normal_scaled(0.0, per_field) for _ in range(N)],
        dtype=np.float32,
    )
    pairs = []
    while len(pairs) < N_PAIRS:
        a = rng.below(FIELDS)
        b = rng.below(FIELDS)
        if a != b and (min(a, b), max(a, b)) not in pairs:
            pairs.append((min(a, b), max(a, b)))
    return weights, pairs


def gt_logit(weights, pairs, bias, feats):
    """True logit for [n, FIELDS] global-id samples."""
    z = weights[feats].sum(axis=1, dtype=np.float64)
    scale = PAIR_STD / math.sqrt(len(pairs))
    g = feats.astype(np.uint64)
    for a, b in pairs:
        z += scale * interaction_np(g[:, a], g[:, b]).astype(np.float64)
    return z + bias


# ---- distribution-matched sampling (numpy-vectorized) -----------------


def zipf_ranks(nprng, n, size):
    """Zipf(s) ranks over [0, n) by rejection-inversion (same scheme as
    rust Zipf::sample, batch-vectorized with numpy draws)."""
    one_s = 1.0 - ZIPF_S

    def h(x):
        return (np.power(x, one_s) - 1.0) / one_s

    def h_inv(y):
        return np.power(1.0 + y * one_s, 1.0 / one_s)

    h_lo, h_hi = h(0.5), h(n + 0.5)
    out = np.empty(size, dtype=np.int64)
    filled = 0
    while filled < size:
        m = size - filled
        x = h_inv(h_lo + nprng.random(m) * (h_hi - h_lo))
        k = np.clip(np.round(x), 1.0, float(n))
        bucket = np.maximum(h(k + 0.5) - h(k - 0.5), 1e-300)
        acc = nprng.random(m) <= np.power(k, -ZIPF_S) / bucket
        ka = k[acc].astype(np.int64) - 1
        out[filled:filled + ka.size] = ka
        filled += ka.size
    return out


def permute_np(ranks, n, seed):
    """Exact port of synthetic.rs `permute` (bijective cycle-walk)."""
    if n <= 1:
        return np.zeros_like(ranks)
    bits = (n - 1).bit_length()
    mask = np.uint64((1 << bits) - 1)
    keys = [np.uint64(mix64(seed ^ (r * 0xA5A5A5A5))) for r in range(3)]
    shift = np.uint64(max(bits // 2, 1))
    v = ranks.astype(np.uint64)
    pending = np.ones(v.shape, dtype=bool)
    while pending.any():
        w = v[pending]
        for k in keys:
            w ^= (k >> np.uint64(7)) & mask
            w = (w * np.uint64(0x9E3779B9 | 1)) & mask
            w ^= w >> shift
            w &= mask
        v[pending] = w
        pending = v >= np.uint64(n)
    return v.astype(np.int64)


def sample_features(nprng, size):
    """[size, FIELDS] global feature ids from the tiny spec."""
    feats = np.empty((size, FIELDS), dtype=np.int64)
    for f, vocab in enumerate(VOCABS):
        ranks = zipf_ranks(nprng, vocab, size)
        feats[:, f] = OFFSETS[f] + permute_np(ranks, vocab, SEED ^ f)
    return feats


def calibrate_bias(weights, pairs, nprng):
    """Bisect the CTR bias like GroundTruth::new (fresh calibration
    draws; only the constant differs from Rust's by sampling noise)."""
    feats = sample_features(nprng, 20_000)
    raw = gt_logit(weights, pairs, 0.0, feats)
    lo, hi = -10.0, 10.0
    for _ in range(50):
        mid = 0.5 * (lo + hi)
        if np.mean(1.0 / (1.0 + np.exp(-(raw + mid)))) < TARGET_CTR:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# ---- numpy DCN mirroring rust/src/nn/dcn.rs ---------------------------


class DcnParams:
    """Dense parameters in the exact flat layout `param_layout` defines:
    cross w/b pairs, MLP w/b pairs, final_w, final_b."""

    def __init__(self, nprng):
        k = FIELDS * EMB_DIM
        self.cross_w = [np.asarray(nprng.normal(0.0, 0.01, k),
                                   dtype=np.float32)
                        for _ in range(CROSS_DEPTH)]
        self.cross_b = [np.zeros(k, dtype=np.float32)
                        for _ in range(CROSS_DEPTH)]
        self.mlp_w, self.mlp_b = [], []
        prev = k
        for width in MLP:
            a = math.sqrt(6.0 / (prev + width))
            self.mlp_w.append(np.asarray(
                nprng.uniform(-a, a, (prev, width)), dtype=np.float32))
            self.mlp_b.append(np.zeros(width, dtype=np.float32))
            prev = width
        a = math.sqrt(6.0 / (k + prev + 1))
        self.final_w = np.asarray(nprng.uniform(-a, a, k + prev),
                                  dtype=np.float32)
        self.final_b = np.float32(0.0)

    def flat(self):
        parts = []
        for w, b in zip(self.cross_w, self.cross_b):
            parts += [w, b]
        for w, b in zip(self.mlp_w, self.mlp_b):
            parts += [w.reshape(-1), b]
        parts += [self.final_w, np.array([self.final_b], dtype=np.float32)]
        out = np.concatenate(parts).astype(np.float32)
        assert out.size == n_params(), (out.size, n_params())
        return out


def forward(p, emb, feats):
    """Logits + cache for a [B, FIELDS] batch of global ids."""
    b = feats.shape[0]
    k = FIELDS * EMB_DIM
    x0 = emb[feats].reshape(b, k)
    xs = [x0]
    for l in range(CROSS_DEPTH):
        xl = xs[-1]
        s = xl @ p.cross_w[l]
        xs.append(x0 * s[:, None] + p.cross_b[l][None, :] + xl)
    pre, act = [], []
    h = x0
    for i in range(len(MLP)):
        z = h @ p.mlp_w[i] + p.mlp_b[i][None, :]
        pre.append(z)
        h = np.maximum(z, np.float32(0.0))
        act.append(h)
    out = np.concatenate([xs[-1], h], axis=1)
    logits = out @ p.final_w + p.final_b
    return logits, (x0, xs, pre, act, out)


def backward(p, cache, logits, labels):
    """Gradients in the same shapes; mirrors Dcn::backward with an
    all-ones dropout mask."""
    x0, xs, pre, act, out = cache
    b = labels.shape[0]
    k = FIELDS * EMB_DIM
    dlogit = ((1.0 / (1.0 + np.exp(-logits)) - labels)
              / np.float32(b)).astype(np.float32)
    g = DcnParams.__new__(DcnParams)
    g.final_w = out.T @ dlogit
    g.final_b = dlogit.sum()
    dout = dlogit[:, None] * p.final_w[None, :]
    dxl, da = dout[:, :k], dout[:, k:]
    # deep tower
    dx0 = np.zeros_like(x0)
    g.mlp_w = [None] * len(MLP)
    g.mlp_b = [None] * len(MLP)
    for i in reversed(range(len(MLP))):
        dz = da * (pre[i] > 0)
        h_prev = x0 if i == 0 else act[i - 1]
        g.mlp_w[i] = h_prev.T @ dz
        g.mlp_b[i] = dz.sum(axis=0)
        da = dz @ p.mlp_w[i].T
        if i == 0:
            dx0 += da
    # cross tower
    gk = dxl.copy()
    g.cross_w = [None] * CROSS_DEPTH
    g.cross_b = [None] * CROSS_DEPTH
    for l in reversed(range(CROSS_DEPTH)):
        xl = xs[l]
        s = xl @ p.cross_w[l]
        r = (gk * x0).sum(axis=1)
        g.cross_w[l] = xl.T @ r
        g.cross_b[l] = gk.sum(axis=0)
        dx0 += gk * s[:, None]
        gk = gk + r[:, None] * p.cross_w[l][None, :]
    dx0 += gk
    return g, dx0


def sgd(p, g, lr):
    for l in range(CROSS_DEPTH):
        p.cross_w[l] -= lr * g.cross_w[l]
        p.cross_b[l] -= lr * g.cross_b[l]
    for i in range(len(MLP)):
        p.mlp_w[i] -= lr * g.mlp_w[i]
        p.mlp_b[i] -= lr * g.mlp_b[i]
    p.final_w -= lr * g.final_w
    p.final_b -= np.float32(lr * g.final_b)


def auc_of(logits, labels):
    order = np.argsort(logits, kind="stable")
    ranks = np.empty(len(logits), dtype=np.float64)
    ranks[order] = np.arange(1, len(logits) + 1)
    # average ties so the estimate is exact
    sorted_l = logits[order]
    i = 0
    while i < len(sorted_l):
        j = i
        while j + 1 < len(sorted_l) and sorted_l[j + 1] == sorted_l[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = 0.5 * (i + 1 + j + 1)
        i = j + 1
    pos = labels > 0.5
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return (ranks[pos].sum() - n_pos * (n_pos + 1) / 2.0) / (n_pos * n_neg)


def main():
    _selftest()
    weights, pairs = ground_truth_weights()
    nprng = np.random.default_rng(SEED)
    bias = calibrate_bias(weights, pairs, nprng)
    print(f"ground truth: {N} latent weights, pairs {pairs}, "
          f"bias {bias:+.4f}")

    def draw(n):
        feats = sample_features(nprng, n)
        z = gt_logit(weights, pairs, bias, feats)
        labels = (nprng.random(n) < 1.0 / (1.0 + np.exp(-z)))
        return feats, labels.astype(np.float32)

    train_x, train_y = draw(N_TRAIN)
    eval_x, eval_y = draw(N_EVAL)
    ctr = float(train_y.mean())
    assert abs(ctr - TARGET_CTR) < 0.05, f"ctr {ctr} off target"
    bayes = auc_of(gt_logit(weights, pairs, bias, eval_x), eval_y)
    print(f"drew {N_TRAIN} train / {N_EVAL} eval samples, ctr {ctr:.3f}, "
          f"bayes auc {bayes:.4f}")

    emb = np.asarray(nprng.normal(0.0, 0.01, (N, EMB_DIM)),
                     dtype=np.float32)
    params = DcnParams(nprng)
    steps = 0
    for epoch in range(EPOCHS):
        lr_scale = 0.5 ** epoch
        order = nprng.permutation(N_TRAIN)
        losses = []
        for start in range(0, N_TRAIN - BATCH + 1, BATCH):
            idx = order[start:start + BATCH]
            feats, y = train_x[idx], train_y[idx]
            logits, cache = forward(params, emb, feats)
            z = logits.astype(np.float64)
            losses.append(np.mean(np.maximum(z, 0) - z * y
                                  + np.log1p(np.exp(-np.abs(z)))))
            g, dx0 = backward(params, cache, logits, y)
            sgd(params, g, np.float32(LR_DENSE * lr_scale))
            rows = dx0.reshape(BATCH, FIELDS, EMB_DIM)
            np.add.at(emb, feats.reshape(-1),
                      -np.float32(LR_EMB * lr_scale)
                      * rows.reshape(-1, EMB_DIM))
            touched = np.unique(feats)
            emb[touched] = np.clip(emb[touched], -CLIP, CLIP)
            steps += 1
        print(f"epoch {epoch + 1}/{EPOCHS}: loss {np.mean(losses):.5f}")

    # quantize onto the fixed LPT grid and evaluate what will be served
    np.clip(emb, -CLIP, CLIP, out=emb)
    codes = np.clip(np.round(emb / DELTA), -127, 127).astype(np.int64)
    emb_q = (codes.astype(np.float32) * DELTA).astype(np.float32)

    def eval_auc(table):
        logits = np.empty(N_EVAL, dtype=np.float32)
        for start in range(0, N_EVAL, BATCH):
            chunk = eval_x[start:start + BATCH]
            pad = BATCH - chunk.shape[0]
            if pad:
                chunk = np.vstack([chunk, chunk[:pad]])
            out, _ = forward(params, table, chunk)
            logits[start:start + BATCH - pad] = out[:BATCH - pad]
        return auc_of(logits, eval_y)

    auc_fp = eval_auc(emb)
    auc_q = eval_auc(emb_q)
    print(f"held-out auc: fp32 {auc_fp:.4f}, 8-bit quantized {auc_q:.4f} "
          f"(bayes {bayes:.4f})")
    assert auc_q > MIN_AUC, (
        f"trained auc {auc_q:.4f} below {MIN_AUC}; not writing the fixture"
    )

    # write the version-1 checkpoint through the shared section writer
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "examples", "fixtures", "tiny_lpt8.ckpt")
    rows = (codes.reshape(-1) & 0xFF).astype(np.uint8).tobytes()
    assert len(rows) == N * ROW_BYTES
    dense = params.flat().astype("<f4").tobytes()
    sections = [
        section(KIND_META, 0, meta_json(step=steps).encode("utf-8")),
        section(KIND_ROWS, 0, rows),
        section(KIND_DENSE, 0, dense),
    ]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(sections)))
        for s in sections:
            f.write(s)
    verify(path)
    print(f"wrote {path}: {os.path.getsize(path)} bytes, "
          f"step {steps}, quantized auc {auc_q:.4f}")


if __name__ == "__main__":
    main()
