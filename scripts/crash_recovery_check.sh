#!/usr/bin/env bash
# Crash-recovery gate: kill the trainer at every durability failpoint
# mid-save, then prove `--resume` restores the last published state and
# finishes the run byte-identical to an uninterrupted reference run.
#
#   bash scripts/crash_recovery_check.sh
#
# Sites (see rust/src/checkpoint/failpoint.rs): ckpt.section.N,
# ckpt.finish, ckpt.publish, ckpt.published (checkpoint writer);
# journal.reset, journal.append (delta journal); compact.anchor,
# compact.reset (compactor). Actions: crash = abort before the write,
# truncate = half-write + sync + abort (the torn-tail model).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${BIN:-target/release/alpt}
[ -x "$BIN" ] || cargo build --release

WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

TRAIN_ARGS=(--dataset synthetic:tiny --samples 2000 --epochs 1 --seed 7
            --save-every 3 --compact-every 4 --no-runtime --quiet)

echo "== base: train epoch 1 with continuous checkpointing"
"$BIN" train "${TRAIN_ARGS[@]}" --save "$WORK/base.ckpt"

echo "== reference: uninterrupted continuation to epoch 2"
cp "$WORK/base.ckpt" "$WORK/ref.ckpt"
"$BIN" train --resume "$WORK/ref.ckpt" --epochs 2 \
  --save "$WORK/ref.ckpt" --quiet
REF_SHA=$(sha256sum "$WORK/ref.ckpt" | cut -d' ' -f1)

SPECS=(
  ckpt.section.0=crash
  ckpt.section.2=truncate
  ckpt.section.4=crash
  ckpt.finish=crash
  ckpt.finish=truncate
  ckpt.publish=crash
  ckpt.published=crash
  journal.reset=crash
  journal.reset=truncate
  journal.append=crash
  journal.append=truncate
  compact.anchor=crash
  compact.reset=crash
)

for SPEC in "${SPECS[@]}"; do
  CASE="$WORK/case.ckpt"
  rm -f "$CASE" "$CASE.journal" "$CASE.tmp"
  cp "$WORK/base.ckpt" "$CASE"
  echo "== kill at $SPEC"
  if ALPT_FAILPOINT="$SPEC" "$BIN" train --resume "$CASE" --epochs 2 \
       --save "$CASE" --quiet 2>"$WORK/killed.log"; then
    echo "FAIL: $SPEC: the armed run did not die" >&2
    exit 1
  fi
  grep -q failpoint "$WORK/killed.log" || {
    echo "FAIL: $SPEC: the run died without reaching the failpoint" >&2
    cat "$WORK/killed.log" >&2
    exit 1
  }
  "$BIN" train --resume "$CASE" --epochs 2 --save "$CASE" --quiet \
    2>"$WORK/resume.log"
  if [ "$SPEC" = journal.append=truncate ]; then
    # the half-written append must be reported as a salvaged torn tail
    grep -q torn "$WORK/resume.log" || {
      echo "FAIL: $SPEC: resume did not salvage the torn tail" >&2
      cat "$WORK/resume.log" >&2
      exit 1
    }
  fi
  SHA=$(sha256sum "$CASE" | cut -d' ' -f1)
  if [ "$SHA" != "$REF_SHA" ]; then
    echo "FAIL: $SPEC: resumed final checkpoint diverged ($SHA != $REF_SHA)" >&2
    exit 1
  fi
done

echo "PASS: resume was bit-identical after a kill at every failpoint site"
