#!/usr/bin/env python3
"""Checkpoint-writer helpers + untrained bootstrap fixture.

Writes a valid version-1 ALPT checkpoint (see README.md "Checkpoint binary
layout" / rust/src/checkpoint/format.rs) holding an 8-bit LPT table for
the `tiny` synthetic dataset plus a deterministic dense-parameter vector.

Run directly, this writes a *format smoke artifact*: its codes and dense
params follow fixed deterministic patterns, not a trained model, so the
served AUC is chance-level. The *committed* fixture is instead produced
by `scripts/train_fixture.py`, which trains a real model against the
seed's ground truth (numpy only, no Rust toolchain needed) and reuses
this module's section writer; with cargo available the equivalent is:

    cargo run --release -- train --dataset tiny --method lpt-sr --plan 8 \
        --no-runtime --save examples/fixtures/tiny_lpt8.ckpt

The Rust test `fixture_serves_without_training`
(rust/tests/ckpt_fixture.rs) validates every byte of the committed file
against the real reader — including a far-from-chance served AUC, which
an artifact written by *this* script's deterministic patterns fails.
"""

import json
import os
import struct
import zlib

MAGIC = b"ALPTCKPT"
VERSION = 1
KIND_META, KIND_ROWS, KIND_DENSE = 1, 2, 4

# tiny model geometry (rust/src/nn/dcn.rs DcnConfig::tiny / builtin_entry)
FIELDS, EMB_DIM, BATCH, CROSS_DEPTH, MLP = 8, 8, 64, 2, [32, 16]
# tiny synthetic vocabularies (rust/src/data/synthetic.rs SyntheticSpec::tiny)
VOCABS = [2000, 1000, 500, 200, 100, 50, 20, 8]

N = sum(VOCABS)          # 3878 feature rows
D = EMB_DIM              # 8 dims -> 8 bytes/row at 8 bits
ROW_BYTES = D            # 8-bit codes, byte-aligned
SHARD_ROWS = 1 << 16


def f32(x):
    """Round-trip a float through f32 so the JSON echo is f32-exact."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def n_params():
    k = FIELDS * EMB_DIM
    total = CROSS_DEPTH * 2 * k          # cross w+b pairs
    prev = k
    for width in MLP:
        total += prev * width + width    # mlp w+b
        prev = width
    total += (k + prev) + 1              # final_w, final_b
    return total


def experiment_echo():
    # every key experiment_from_json (rust/src/checkpoint/mod.rs) requires
    return {
        "artifacts_dir": "artifacts",
        "bits": 8,
        "clip": f32(0.1),
        "compact_every": 0,
        "dataset": "tiny",
        # u64 seeds are JSON strings (full 64-bit range; numbers only
        # carry 53 bits) — mirrors checkpoint::experiment_to_json
        "dropout_seed": "1234",
        "epochs": 2,
        "grad_scale": "inv_sqrt_bdq",
        "hash_bits": 16,
        "lr_delta": f32(2e-5),
        "lr_dense": f32(1e-3),
        "lr_emb": f32(1e-2),
        "lr_gamma": f32(0.1),
        "lr_milestones": [6, 9],
        "method": "lpt-sr",
        "model": "tiny",
        "n_samples": 20000,
        "numeric_buckets": 40,
        "patience": 0,
        "prefetch_batches": 2,
        "save_every": 0,
        "seed": "7",
        "shuffle_window": 4096,
        "threads": 0,
        "use_runtime": False,
        "vocab_scale": 1.0,
        "wd_delta": f32(5e-8),
        "wd_emb": f32(5e-8),
    }


def meta_json(step=0):
    meta = {
        "aux_len": 0,
        "d": D,
        "experiment": experiment_echo(),
        "format": "alpt-checkpoint",
        "method": "lpt-sr",
        "n": N,
        "n_shards": (N + SHARD_ROWS - 1) // SHARD_ROWS,
        "row_bytes": ROW_BYTES,
        "shard_rows": SHARD_ROWS,
        "step": step,
        "version": VERSION,
    }
    return json.dumps(meta, sort_keys=True, separators=(",", ":"))


def rows_payload():
    """Deterministic 8-bit two's-complement codes, one byte per element."""
    out = bytearray(N * ROW_BYTES)
    for r in range(N):
        for j in range(D):
            code = ((r * 7 + j * 13 + 5) % 255) - 127  # in [-127, 127]
            out[r * ROW_BYTES + j] = code & 0xFF
    return bytes(out)


def dense_payload():
    """Deterministic small dense params in (-0.1, 0.1), f32 LE."""
    vals = []
    for i in range(n_params()):
        u = ((i + 1) * 2654435761) % (1 << 32) / float(1 << 32)
        vals.append((u - 0.5) * 0.2)
    return struct.pack(f"<{len(vals)}f", *vals)


def section(kind, index, payload):
    return (
        struct.pack("<IIQI", kind, index, len(payload),
                    zlib.crc32(payload) & 0xFFFFFFFF)
        + payload
    )


def verify(path):
    """Independent structural re-read of the written file."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "magic"
    version, n_sections = struct.unpack("<II", data[8:16])
    assert version == VERSION, version
    pos, seen, meta = 16, [], None
    for _ in range(n_sections):
        kind, index, length, crc = struct.unpack("<IIQI", data[pos:pos + 20])
        pos += 20
        payload = data[pos:pos + length]
        pos += length
        assert zlib.crc32(payload) & 0xFFFFFFFF == crc, f"crc kind={kind}"
        if kind == KIND_META:
            assert index == 0 and meta is None, "duplicate meta"
            meta = json.loads(payload.decode("utf-8"))
        seen.append((kind, index, length))
    assert pos == len(data), "trailing bytes"
    assert meta is not None, "no meta section"
    assert meta["n"] == N and meta["d"] == D, "meta geometry"
    assert meta["n"] * meta["row_bytes"] == [
        s for s in seen if s[0] == KIND_ROWS
    ][0][2]
    return seen


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_dir = os.path.join(root, "examples", "fixtures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "tiny_lpt8.ckpt")

    sections = [
        section(KIND_META, 0, meta_json().encode("utf-8")),
        section(KIND_ROWS, 0, rows_payload()),
        section(KIND_DENSE, 0, dense_payload()),
    ]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(sections)))
        for s in sections:
            f.write(s)

    seen = verify(path)
    size = os.path.getsize(path)
    print(f"wrote {path}: {size} bytes, sections {seen}")
    print(f"  n={N} d={D} row_bytes={ROW_BYTES} dense={n_params()} params")


if __name__ == "__main__":
    main()
