#!/usr/bin/env bash
# Bench smoke: release build, run the micro bench with a small iteration
# budget, and assert the machine-readable BENCH_micro.json report was
# produced and is well-formed. Wired into ROADMAP.md's tier-1 section:
#
#   bash scripts/bench_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
ALPT_BENCH_QUICK=1 cargo bench --bench micro

test -s BENCH_micro.json || {
    echo "FAIL: BENCH_micro.json missing or empty" >&2
    exit 1
}

if command -v python3 >/dev/null 2>&1; then
    python3 - <<'EOF'
import json

with open("BENCH_micro.json") as f:
    doc = json.load(f)
assert doc["schema_version"] == 1, doc.get("schema_version")
rows = doc["benchmarks"]
assert isinstance(rows, list) and rows, "no benchmark rows"
for row in rows:
    assert row["name"] and row["median_ns"] > 0, row
names = {row["name"] for row in rows}
# the acceptance-critical rows must be present
for needle in ["LPT-4bit update t1", "LPT-8bit update t1",
               "fused quantize_row_packed 4-bit SR"]:
    assert any(needle in n for n in names), f"missing bench row: {needle}"
print(f"bench smoke OK: {len(rows)} rows")
EOF
else
    # minimal structural check without python
    grep -q '"schema_version"' BENCH_micro.json
    grep -q '"benchmarks"' BENCH_micro.json
    grep -q '"median_ns"' BENCH_micro.json
    echo "bench smoke OK (grep check)"
fi
