#!/usr/bin/env bash
# Bench smoke: release build, run the micro bench with a small iteration
# budget, and assert the machine-readable BENCH_micro.json report was
# produced and is well-formed. Wired into ROADMAP.md's tier-1 section and
# the CI workflow (.github/workflows/ci.yml).
#
#   bash scripts/bench_smoke.sh            # full smoke
#   bash scripts/bench_smoke.sh --quick    # CI mode: bench step bounded to <60s
#
# Exit codes are deterministic: 0 = pass or explicit SKIP, 1 = failure.
# Self-skips (exit 0, message on stdout) when no Rust toolchain is
# available, so toolchain-less environments don't report false failures.
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *)
            echo "usage: bash scripts/bench_smoke.sh [--quick]" >&2
            exit 1
            ;;
    esac
done

if ! command -v cargo >/dev/null 2>&1; then
    echo "SKIP: bench smoke needs a Rust toolchain (cargo not found)"
    exit 0
fi

cargo build --release

# The micro bench honours ALPT_BENCH_QUICK by shrinking warmup/iteration
# budgets; --quick additionally hard-bounds the bench *run* to 60s so a
# hung run fails the pipeline instead of stalling it. Compilation is
# done untimed first (a cold runner's bench-profile build would
# otherwise eat the budget).
export ALPT_BENCH_QUICK=1
cargo bench --bench micro --no-run
if [ "$QUICK" = 1 ] && command -v timeout >/dev/null 2>&1; then
    timeout 60 cargo bench --bench micro || {
        status=$?
        if [ "$status" = 124 ]; then
            echo "FAIL: micro bench exceeded the 60s --quick budget" >&2
        else
            echo "FAIL: micro bench exited with status $status" >&2
        fi
        exit 1
    }
else
    cargo bench --bench micro
fi

test -s BENCH_micro.json || {
    echo "FAIL: BENCH_micro.json missing or empty" >&2
    exit 1
}

if command -v python3 >/dev/null 2>&1; then
    python3 - <<'EOF'
import json
import sys

try:
    with open("BENCH_micro.json") as f:
        doc = json.load(f)
except (OSError, ValueError) as e:
    sys.exit(f"FAIL: BENCH_micro.json is malformed: {e}")
if doc.get("schema_version") != 1:
    sys.exit(f"FAIL: bad schema_version {doc.get('schema_version')!r}")
rows = doc.get("benchmarks")
if not isinstance(rows, list) or not rows:
    sys.exit("FAIL: no benchmark rows")
for row in rows:
    if not row.get("name") or not row.get("median_ns", 0) > 0:
        sys.exit(f"FAIL: malformed row {row!r}")
names = {row["name"] for row in rows}
# the acceptance-critical rows must be present
for needle in ["LPT-4bit update t1", "LPT-8bit update t1",
               "fused quantize_row_packed 4-bit SR"]:
    if not any(needle in n for n in names):
        sys.exit(f"FAIL: missing bench row: {needle}")
print(f"bench smoke OK: {len(rows)} rows")
EOF
else
    # minimal structural check without python
    grep -q '"schema_version"' BENCH_micro.json || {
        echo "FAIL: no schema_version in BENCH_micro.json" >&2
        exit 1
    }
    grep -q '"benchmarks"' BENCH_micro.json || {
        echo "FAIL: no benchmarks array in BENCH_micro.json" >&2
        exit 1
    }
    grep -q '"median_ns"' BENCH_micro.json || {
        echo "FAIL: no median_ns rows in BENCH_micro.json" >&2
        exit 1
    }
    echo "bench smoke OK (grep check)"
fi
