//! Crash-recovery integration tests: kill the trainer binary at every
//! durability failpoint and prove that resume never loses the last
//! published state; check that anchor + delta-chain resume is
//! bit-identical to resuming a monolithic checkpoint; and property-test
//! that a single flipped bit anywhere in a checkpoint or journal file
//! surfaces as a precise error (or a clean chain prefix) — never a
//! panic, never a silently different model.
//!
//! The kill matrix drives the real `alpt` binary through
//! `ALPT_FAILPOINT` (see `checkpoint::failpoint`), the same mechanism
//! the CI `crash-recovery` job uses.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use alpt::checkpoint::{journal, journal_path, Checkpoint};
use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{builtin_entry, Trainer};
use alpt::data::batcher::{Batch, StreamBatcher, Tail};
use alpt::data::registry;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("alpt_crash_recovery_tests")
        .join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_alpt")
}

/// One `alpt train` invocation writing continuous checkpoints to
/// `ckpt`. The first (non-resume) form trains epoch 1 from scratch;
/// the resume form continues the run to epoch 2 — the experiment echo
/// carries `save_every`/`compact_every`, so the continuation keeps
/// saving through the same journal machinery.
fn train_cmd(ckpt: &Path, resume: bool, failpoint: Option<&str>) -> Output {
    let mut cmd = Command::new(bin());
    cmd.arg("train");
    if resume {
        cmd.arg("--resume").arg(ckpt).args(["--epochs", "2"]);
    } else {
        cmd.args([
            "--dataset",
            "synthetic:tiny",
            "--samples",
            "2000",
            "--epochs",
            "1",
            "--seed",
            "7",
            "--save-every",
            "3",
            "--compact-every",
            "4",
            "--no-runtime",
        ]);
    }
    cmd.arg("--save").arg(ckpt).arg("--quiet");
    cmd.env_remove("ALPT_FAILPOINT");
    if let Some(spec) = failpoint {
        cmd.env("ALPT_FAILPOINT", spec);
    }
    cmd.output().unwrap()
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// Kill the trainer at every failpoint site mid-save; after each kill,
/// the published checkpoint must still parse, and resuming must finish
/// the run byte-identical to an uninterrupted reference.
#[test]
fn kill_at_every_failpoint_never_loses_published_state() {
    let dir = tmp_dir("kill_matrix");
    let base = dir.join("base.ckpt");
    let out = train_cmd(&base, false, None);
    assert!(out.status.success(), "base run failed: {}", stderr_of(&out));

    // the uninterrupted reference continuation
    let ref_ckpt = dir.join("ref.ckpt");
    std::fs::copy(&base, &ref_ckpt).unwrap();
    let out = train_cmd(&ref_ckpt, true, None);
    assert!(out.status.success(), "ref run failed: {}", stderr_of(&out));
    let want = std::fs::read(&ref_ckpt).unwrap();

    // every site the writer, journal appender, and compactor expose;
    // `truncate` variants leave half-written bytes synced to disk
    let cases = [
        ("ckpt.section.0", "crash"),
        ("ckpt.section.2", "truncate"),
        ("ckpt.section.4", "crash"),
        ("ckpt.finish", "crash"),
        ("ckpt.finish", "truncate"),
        ("ckpt.publish", "crash"),
        ("ckpt.published", "crash"),
        ("journal.reset", "crash"),
        ("journal.reset", "truncate"),
        ("journal.append", "crash"),
        ("journal.append", "truncate"),
        ("compact.anchor", "crash"),
        ("compact.reset", "crash"),
    ];
    for (site, action) in cases {
        let spec = format!("{site}={action}");
        let case =
            dir.join(format!("{}_{action}.ckpt", site.replace('.', "_")));
        std::fs::copy(&base, &case).unwrap();
        std::fs::remove_file(journal_path(&case)).ok();

        let out = train_cmd(&case, true, Some(&spec));
        assert!(
            !out.status.success(),
            "{spec}: the armed run did not die\n{}",
            stderr_of(&out)
        );
        // the published checkpoint survived the kill, whole
        let ckpt = Checkpoint::read(&case).unwrap_or_else(|e| {
            panic!("{spec}: published checkpoint torn by the kill: {e:#}")
        });
        // and whatever journal is on disk reads back cleanly (valid
        // chain, stale leftover, or salvageable torn tail — never an
        // unreadable state)
        let step = ckpt.meta_usize("step").unwrap() as u64;
        let chain = journal::read_chain(&case, ckpt.anchor_id(), step)
            .unwrap_or_else(|e| {
                panic!("{spec}: journal unreadable after the kill: {e:#}")
            });
        if spec == "journal.append=truncate" {
            let chain = chain.expect("torn-append case lost its journal");
            assert!(
                chain.salvaged_bytes > 0,
                "{spec}: expected a salvaged torn tail"
            );
        }

        let out = train_cmd(&case, true, None);
        assert!(
            out.status.success(),
            "{spec}: resume failed: {}",
            stderr_of(&out)
        );
        if spec == "journal.append=truncate" {
            assert!(
                stderr_of(&out).contains("torn"),
                "{spec}: resume did not report the salvaged tail:\n{}",
                stderr_of(&out)
            );
        }
        assert_eq!(
            std::fs::read(&case).unwrap(),
            want,
            "{spec}: resumed run diverged from the uninterrupted reference"
        );
        std::fs::remove_file(&case).ok();
        std::fs::remove_file(journal_path(&case)).ok();
    }
}

/// Shared fixture: a trainer on the streaming tiny dataset plus an
/// iterator of training batches to step it with.
fn trainer_and_batches(
    bits: &str,
) -> (Trainer, impl Iterator<Item = Batch>) {
    let exp = Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::parse(bits).unwrap(),
        model: "tiny".into(),
        dataset: "synthetic:tiny".into(),
        n_samples: 1500,
        use_runtime: false,
        threads: 1,
        ..Experiment::default()
    };
    let entry = builtin_entry(&exp.model).unwrap();
    let n = registry::schema_for(&exp).unwrap().n_features();
    let tr = Trainer::new(exp.clone(), n).unwrap();
    let source = registry::open_source(&exp).unwrap();
    let stream =
        registry::train_epoch_stream(source.as_ref(), &exp, 1).unwrap();
    let batches =
        StreamBatcher::new(stream, entry.fields, entry.batch, Tail::Drop)
            .map(|r| r.unwrap());
    (tr, batches)
}

/// Resuming from anchor + delta chain must land on exactly the state a
/// monolithic full checkpoint of the same moment holds — checked for
/// both the uniform v1 and the grouped mixed-precision v2 formats.
#[test]
fn anchor_plus_chain_resume_is_bit_identical_to_full_resume() {
    for (tag, bits) in [("v1", "8"), ("v2", "f0:4,f1:8,default:2")] {
        let dir = tmp_dir("chain_equiv");
        let chain_path = dir.join(format!("{tag}_chain.ckpt"));
        let full_path = dir.join(format!("{tag}_full.ckpt"));
        std::fs::remove_file(journal_path(&chain_path)).ok();

        let (mut tr, mut batches) = trainer_and_batches(bits);
        for _ in 0..4 {
            for _ in 0..2 {
                tr.step(&batches.next().unwrap(), 1).unwrap();
            }
            tr.continuous_save(&chain_path).unwrap();
        }
        // the same live state, saved monolithically
        tr.save_checkpoint(&full_path).unwrap();

        // precondition: the continuous file really is anchor + deltas
        let ckpt = Checkpoint::read(&chain_path).unwrap();
        let step = ckpt.meta_usize("step").unwrap() as u64;
        let chain = journal::read_chain(&chain_path, ckpt.anchor_id(), step)
            .unwrap()
            .expect("no journal next to the continuous checkpoint");
        assert_eq!(chain.deltas.len(), 3, "{tag}");

        let mut a = Trainer::resume(&chain_path).unwrap();
        let mut b = Trainer::resume(&full_path).unwrap();
        let out_a = dir.join(format!("{tag}_out_a.ckpt"));
        let out_b = dir.join(format!("{tag}_out_b.ckpt"));
        a.save_checkpoint(&out_a).unwrap();
        b.save_checkpoint(&out_b).unwrap();
        assert_eq!(
            std::fs::read(&out_a).unwrap(),
            std::fs::read(&out_b).unwrap(),
            "{tag}: anchor+chain resume diverged from full-checkpoint \
             resume"
        );
        for p in [&chain_path, &full_path, &out_a, &out_b] {
            std::fs::remove_file(p).ok();
            std::fs::remove_file(journal_path(p)).ok();
        }
    }
}

/// Bit positions to flip: every bit of the first 64 bytes (file header
/// + first section/record header), then a deterministic stride across
/// the rest of the file.
fn flip_positions(len: usize) -> Vec<(usize, u8)> {
    let mut v = Vec::new();
    for off in 0..len.min(64) {
        for bit in 0..8u8 {
            v.push((off, bit));
        }
    }
    if len > 64 {
        let tail = len - 64;
        let samples = tail.min(400);
        for i in 0..samples {
            let off = 64 + i * tail / samples;
            v.push((off, (off % 8) as u8));
        }
    }
    v
}

/// Flipping any single bit of a valid checkpoint must make every load
/// fail with an error — magic, version, section-table, and CRC checks
/// leave no byte unguarded — and flipping any single bit of the journal
/// must yield an error or a clean prefix of the original chain. Nothing
/// may panic, and a store under `apply` is never partially mutated
/// (enforced by validate-before-mutate; unit-tested in
/// `checkpoint::journal`).
#[test]
fn single_bitflips_fail_loudly_never_load_garbage() {
    for (tag, bits) in [("v1", "8"), ("v2", "f0:4,f1:8,default:2")] {
        let dir = tmp_dir("bitflip");
        let path = dir.join(format!("{tag}.ckpt"));
        std::fs::remove_file(journal_path(&path)).ok();

        let (mut tr, mut batches) = trainer_and_batches(bits);
        for _ in 0..3 {
            for _ in 0..2 {
                tr.step(&batches.next().unwrap(), 1).unwrap();
            }
            tr.continuous_save(&path).unwrap();
        }

        let ckpt_bytes = std::fs::read(&path).unwrap();
        // what a clean resume of anchor + chain saves back out — the
        // only acceptable result of a flip that still loads (e.g. a bit
        // in the Meta section's unused index field)
        let clean_path = dir.join(format!("{tag}_clean.ckpt"));
        let mut clean_tr = Trainer::resume(&path).unwrap();
        clean_tr.save_checkpoint(&clean_path).unwrap();
        let clean = std::fs::read(&clean_path).unwrap();
        let probe_path = dir.join(format!("{tag}_probe.ckpt"));
        for (off, bit) in flip_positions(ckpt_bytes.len()) {
            let mut damaged = ckpt_bytes.clone();
            damaged[off] ^= 1 << bit;
            std::fs::write(&path, &damaged).unwrap();
            if let Ok(mut resumed) = Trainer::resume(&path) {
                resumed.save_checkpoint(&probe_path).unwrap();
                assert_eq!(
                    std::fs::read(&probe_path).unwrap(),
                    clean,
                    "{tag}: flip at byte {off} bit {bit} loaded as a \
                     *different* model instead of erroring"
                );
            }
        }
        std::fs::write(&path, &ckpt_bytes).unwrap();
        std::fs::remove_file(&clean_path).ok();
        std::fs::remove_file(&probe_path).ok();

        // journal flips: error, or a validated prefix of the real chain
        let ckpt = Checkpoint::read(&path).unwrap();
        let step = ckpt.meta_usize("step").unwrap() as u64;
        let jpath = journal_path(&path);
        let jbytes = std::fs::read(&jpath).unwrap();
        let original = journal::read_chain(&path, ckpt.anchor_id(), step)
            .unwrap()
            .expect("journal missing");
        assert_eq!(original.deltas.len(), 2, "{tag}");
        let encoded: Vec<Vec<u8>> =
            original.deltas.iter().map(|d| d.encode()).collect();
        for (off, bit) in flip_positions(jbytes.len()) {
            let mut damaged = jbytes.clone();
            damaged[off] ^= 1 << bit;
            std::fs::write(&jpath, &damaged).unwrap();
            match journal::read_chain(&path, ckpt.anchor_id(), step) {
                Err(_) => {}
                Ok(None) => {} // rejected whole: run starts from the anchor
                Ok(Some(chain)) => {
                    assert!(
                        chain.deltas.len() <= encoded.len(),
                        "{tag}: flip at {off}.{bit} grew the chain"
                    );
                    for (d, want) in chain.deltas.iter().zip(&encoded) {
                        assert_eq!(
                            &d.encode(),
                            want,
                            "{tag}: flip at byte {off} bit {bit} altered \
                             a delta that still validated"
                        );
                    }
                }
            }
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&jpath).ok();
    }
}
