//! End-to-end tests for budget-driven precision plans: end-of-epoch
//! re-planning migrates the table and resumes bit-identically from a
//! post-migration checkpoint, and hashed/pruned structural group kinds
//! survive the save → resume → serve round trip in the kinded v3 format.

use std::path::PathBuf;

use alpt::checkpoint::Checkpoint;
use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{builtin_entry, serve_checkpoint, Trainer};
use alpt::data::registry;
use alpt::embedding::EmbeddingStore;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_plan_replan_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

fn replan_tiny_exp() -> Experiment {
    Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(2),
        epochs: 2,
        n_samples: 700,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        lr_emb: 0.3,
        ..Experiment::default()
    }
}

#[test]
fn replan_then_mid_epoch_resume_is_bit_identical() {
    // a generous byte budget makes the epoch-1 boundary upgrade the
    // whole 2-bit table to 16 bits; the continuous saves of epoch 2 are
    // therefore post-migration checkpoints, and resuming from the last
    // one must replay the rest of the run bit-for-bit
    let mut exp = Experiment { save_every: 5, ..replan_tiny_exp() };
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();
    let d = builtin_entry("tiny").unwrap().emb_dim;
    exp.replan_budget = n * (2 * d + 4) + 64;

    let ckpt = tmp("replan_mid_epoch.ckpt");
    let mut full = Trainer::new(exp.clone(), n).unwrap();
    let res = full
        .train_stream(source.as_ref(), false, Some(ckpt.as_path()))
        .unwrap();
    assert_eq!(res.epochs_run, 2);
    assert_eq!(
        full.exp.bits.as_uniform(),
        Some(16),
        "boundary replan should have upgraded the table: {}",
        full.exp.bits.key()
    );
    // enough epoch-2 steps that at least one save landed post-migration
    assert!(res.history[1].steps >= 5, "{:?}", res.history[1]);

    let mut resumed = Trainer::resume(&ckpt).unwrap();
    assert_eq!(
        resumed.exp.bits.as_uniform(),
        Some(16),
        "the post-migration plan must be in the checkpoint echo"
    );
    assert_eq!(resumed.epochs_done, 1, "saved mid-epoch-2");
    let source_b = registry::open_source(&resumed.exp).unwrap();
    let res_b =
        resumed.train_stream(source_b.as_ref(), false, None).unwrap();
    assert_eq!(
        gather_all(full.store.as_ref()),
        gather_all(resumed.store.as_ref()),
        "migrated tables diverged after mid-epoch resume"
    );
    assert_eq!(full.dense, resumed.dense, "dense params diverged");
    assert_eq!(
        res_b.history.last().unwrap().val_auc.to_bits(),
        res.history.last().unwrap().val_auc.to_bits(),
        "final val AUC diverged"
    );
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn requantize_on_migrate_is_deterministic() {
    // two identically-seeded runs must migrate to byte-identical tables:
    // the requantize path draws from the per-row StreamKey streams, not
    // from any shared mutable RNG state
    let mut exp = replan_tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();
    let d = builtin_entry("tiny").unwrap().emb_dim;
    exp.replan_budget = n * (2 * d + 4) + 64;

    let run = |exp: &Experiment| {
        let src = registry::open_source(exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        tr.train_stream(src.as_ref(), false, None).unwrap();
        let p = tmp("replan_det.ckpt");
        tr.save_checkpoint(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        (gather_all(tr.store.as_ref()), bytes)
    };
    let (gather_a, bytes_a) = run(&exp);
    let (gather_b, bytes_b) = run(&exp);
    assert_eq!(gather_a, gather_b, "migrated gathers diverged");
    assert_eq!(bytes_a, bytes_b, "migrated checkpoints diverged");
}

#[test]
fn structural_plan_survives_save_resume_serve() {
    // hashed + pruned group kinds round-trip through the kinded v3
    // checkpoint: train → save → resume scores bit-identically → the
    // serving path loads the same file
    let exp = Experiment {
        bits: PrecisionPlan::parse("f0:hash,f1:prune,default:4").unwrap(),
        epochs: 1,
        ..replan_tiny_exp()
    };
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();
    let mut tr = Trainer::new(exp, n).unwrap();
    {
        let gs = tr.store.as_grouped().unwrap();
        assert!(gs.has_structural_groups());
    }
    let res = tr.train_stream(source.as_ref(), false, None).unwrap();
    assert!(res.best_auc.is_finite());

    let ckpt = tmp("structural_roundtrip.ckpt");
    tr.save_checkpoint(&ckpt).unwrap();
    let ck = Checkpoint::read(&ckpt).unwrap();
    assert_eq!(ck.version, 3, "structural groups need the kinded format");

    let mut resumed = Trainer::resume(&ckpt).unwrap();
    {
        let gs = resumed.store.as_grouped().unwrap();
        assert!(gs.has_structural_groups(), "kinds lost on resume");
    }
    let ev_a = tr.evaluate_source(source.as_ref()).unwrap();
    let ev_b = resumed.evaluate_source(source.as_ref()).unwrap();
    assert_eq!(ev_a.auc.to_bits(), ev_b.auc.to_bits(), "AUC diverged");

    let report = serve_checkpoint(&ckpt, 8).unwrap();
    assert_eq!(report.n_features, n);
    assert!(report.auc.is_finite());
    assert!(report.infer_bytes > 0);
    std::fs::remove_file(&ckpt).ok();
}
