//! Online-serving integration tests: concurrent clients against one
//! shared `InferenceEngine` are bit-identical to the serial offline
//! serving path (uniform v1 and mixed-precision v2 checkpoints alike),
//! and the std-only HTTP server survives malformed input, scores
//! identically to the offline path, and hot-swaps checkpoints without
//! dropping in-flight requests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use alpt::checkpoint::journal_path;
use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{builtin_entry, Trainer};
use alpt::data::batcher::{Batch, StreamBatcher, Tail};
use alpt::data::registry;
use alpt::serve::{InferenceEngine, Server, ServerConfig};
use alpt::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_serve_online_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Train-free checkpoint for `method`/`bits` on the streaming tiny
/// dataset (serving only needs a consistent store + dense params).
fn make_ckpt(name: &str, method: Method, bits: &str) -> PathBuf {
    let exp = Experiment {
        method,
        bits: PrecisionPlan::parse(bits).unwrap(),
        model: "tiny".into(),
        dataset: "synthetic:tiny".into(),
        n_samples: 1500,
        use_runtime: false,
        threads: 1,
        ..Experiment::default()
    };
    let n = registry::schema_for(&exp).unwrap().n_features();
    let mut tr = Trainer::new(exp, n).unwrap();
    let path = tmp(name);
    tr.save_checkpoint(&path).unwrap();
    path
}

/// The exact batches the offline `serve_checkpoint` loop scores: the
/// held-out split, deterministic order, padded final batch.
fn val_batches(engine: &InferenceEngine, max: usize) -> Vec<Batch> {
    let exp = engine.exp().clone();
    let source = registry::open_source(&exp).unwrap();
    let stream = registry::val_stream(source.as_ref(), &exp).unwrap();
    StreamBatcher::new(
        stream,
        engine.fields(),
        engine.batch_size(),
        Tail::Pad,
    )
    .take(max)
    .map(|r| r.unwrap())
    .collect()
}

#[test]
fn concurrent_clients_are_bit_identical_to_serial() {
    for (name, method, bits, want_method) in [
        (
            "conc_uniform.ckpt",
            Method::Lpt(RoundingMode::Sr),
            "8",
            "LPT(SR)",
        ),
        (
            "conc_mixed.ckpt",
            Method::Alpt(RoundingMode::Sr),
            "f0:4,f1:8,default:2",
            "ALPT(SR)[mixed]",
        ),
    ] {
        let path = make_ckpt(name, method, bits);
        let engine =
            Arc::new(InferenceEngine::from_checkpoint(&path).unwrap());
        assert_eq!(engine.method_name(), want_method);
        let batches = val_batches(&engine, 4);
        assert!(!batches.is_empty());
        // the serial serve_checkpoint path
        let serial: Vec<Vec<f32>> =
            batches.iter().map(|b| engine.score(b)).collect();
        // N threads, each scoring every batch through the one shared
        // engine, repeatedly — all must match the serial bits
        let n_threads = 6;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let engine = Arc::clone(&engine);
                let batches = &batches;
                let serial = &serial;
                s.spawn(move || {
                    for round in 0..3 {
                        for (i, b) in batches.iter().enumerate() {
                            let got = engine.score(b);
                            assert_eq!(
                                got, serial[i],
                                "{name}: thread {t} round {round} \
                                 batch {i} diverged"
                            );
                        }
                    }
                });
            }
        });
        std::fs::remove_file(&path).ok();
    }
}

// ------------------------------------------------------------- HTTP

/// One raw HTTP/1.1 request over a fresh connection (Connection: close).
fn http(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\
         \r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("status code");
    let payload = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, payload)
}

fn start_server(ckpt: &std::path::Path) -> (String, std::thread::JoinHandle<()>) {
    let mut cfg = ServerConfig::new("127.0.0.1:0", ckpt);
    cfg.workers = 3;
    cfg.max_wait = Duration::from_millis(2);
    let server = Server::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn record_json(features: &[u32]) -> String {
    let ids: Vec<String> =
        features.iter().map(|id| id.to_string()).collect();
    format!("[{}]", ids.join(","))
}

#[test]
fn http_scores_match_offline_and_survives_malformed_bodies() {
    let path = make_ckpt("http_basic.ckpt", Method::Lpt(RoundingMode::Sr), "8");
    let engine = InferenceEngine::from_checkpoint(&path).unwrap();
    let (addr, handle) = start_server(&path);

    // healthz first: the server is up and names the model
    let (code, body) = http(&addr, "GET", "/healthz", "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"ok\""), "{body}");
    assert!(body.contains("LPT(SR)"), "{body}");

    // malformed bodies: HTTP 400, worker survives
    for bad in [
        "not json at all",
        "{\"records\": 7}",
        "[[1,2]]",             // wrong arity for an 8-field model
        "[[1,2,3,4,5,6,7,-1]]", // negative id
        "[[1,2,3,4,5,6,7,99999999]]", // id beyond the table
        "{}",
        "[]",
    ] {
        let (code, body) = http(&addr, "POST", "/score", bad);
        assert_eq!(code, 400, "body {bad:?} -> {body}");
        assert!(body.contains("error"), "{body}");
    }

    // a valid request still scores, and matches the offline engine bits
    let records: Vec<Vec<u32>> = (0..5u32)
        .map(|r| (0..engine.fields() as u32).map(|f| (r + f) % 8).collect())
        .collect();
    let body_json = format!(
        "{{\"records\": [{}]}}",
        records
            .iter()
            .map(|r| record_json(r))
            .collect::<Vec<_>>()
            .join(",")
    );
    let (code, body) = http(&addr, "POST", "/score", &body_json);
    assert_eq!(code, 200, "{body}");
    let parsed = Json::parse(&body).unwrap();
    let logits = parsed.get("logits").unwrap().as_array().unwrap();
    let probs = parsed.get("probs").unwrap().as_array().unwrap();
    assert_eq!(logits.len(), records.len());
    assert_eq!(probs.len(), records.len());
    for (rec, z) in records.iter().zip(logits) {
        let want = engine.score_records(rec).unwrap()[0];
        let got = z.as_f64().unwrap() as f32;
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "HTTP logit diverged from the offline engine"
        );
    }

    // stats reflect the traffic
    let (code, body) = http(&addr, "GET", "/stats", "");
    assert_eq!(code, 200);
    let stats = Json::parse(&body).unwrap();
    assert!(stats.get("requests").unwrap().as_usize().unwrap() >= 1);
    assert!(stats.get("errors").unwrap().as_usize().unwrap() >= 6);
    assert!(
        stats.get("records_scored").unwrap().as_usize().unwrap()
            >= records.len()
    );
    // unknown routes 404
    let (code, _) = http(&addr, "GET", "/nope", "");
    assert_eq!(code, 404);

    let (code, _) = http(&addr, "POST", "/shutdown", "");
    assert_eq!(code, 200);
    handle.join().unwrap();
    std::fs::remove_file(&path).ok();
}

#[test]
fn reload_hot_swaps_without_dropping_requests() {
    // v1 uniform checkpoint live, v2 mixed-precision checkpoint swapped
    // in — zero-downtime across checkpoint format versions
    let a = make_ckpt("reload_a.ckpt", Method::Lpt(RoundingMode::Sr), "8");
    let b = make_ckpt(
        "reload_b.ckpt",
        Method::Alpt(RoundingMode::Sr),
        "f0:4,f1:8,default:2",
    );
    let engine_b = InferenceEngine::from_checkpoint(&b).unwrap();
    let (addr, handle) = start_server(&a);

    let record: Vec<u32> =
        (0..engine_b.fields() as u32).map(|f| f % 8).collect();
    let body = format!("[{}]", record_json(&record));

    // background clients hammer /score while the swap happens
    let stop = Arc::new(AtomicBool::new(false));
    let failures = Arc::new(AtomicUsize::new(0));
    let scored = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..3 {
            let (stop, failures, scored) = (
                Arc::clone(&stop),
                Arc::clone(&failures),
                Arc::clone(&scored),
            );
            let (addr, body) = (addr.clone(), body.clone());
            s.spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let (code, _) = http(&addr, "POST", "/score", &body);
                    if code == 200 {
                        scored.fetch_add(1, Ordering::SeqCst);
                    } else {
                        failures.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }

        // let the clients get going, then swap under them
        while scored.load(Ordering::SeqCst) < 5 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let reload_body = format!("{{\"ckpt\": {:?}}}", b.display().to_string());
        let (code, resp) = http(&addr, "POST", "/reload", &reload_body);
        assert_eq!(code, 200, "{resp}");
        assert!(resp.contains("ALPT(SR)[mixed]"), "{resp}");
        // and keep scoring on the new model for a bit
        let after_swap = scored.load(Ordering::SeqCst);
        while scored.load(Ordering::SeqCst) < after_swap + 5 {
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::SeqCst);
    });
    assert_eq!(
        failures.load(Ordering::SeqCst),
        0,
        "requests failed across the hot swap"
    );

    // the live engine is now B: HTTP scores match engine B's bits
    let (code, resp) = http(&addr, "POST", "/score", &body);
    assert_eq!(code, 200);
    let want = engine_b.score_records(&record).unwrap()[0];
    let got = Json::parse(&resp)
        .unwrap()
        .get("logits")
        .unwrap()
        .as_array()
        .unwrap()[0]
        .as_f64()
        .unwrap() as f32;
    assert_eq!(got.to_bits(), want.to_bits());

    // reload of a missing file: 409, live engine untouched, and the
    // failure is counted instead of swallowed
    let (code, resp) =
        http(&addr, "POST", "/reload", "{\"ckpt\": \"/nonexistent.ckpt\"}");
    assert_eq!(code, 409, "{resp}");
    let (code, resp) = http(&addr, "GET", "/stats", "");
    assert_eq!(code, 200);
    let stats = Json::parse(&resp).unwrap();
    assert_eq!(stats.get("reloads").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        stats.get("reload_failures").unwrap().as_usize().unwrap(),
        1
    );

    let (code, _) = http(&addr, "POST", "/shutdown", "");
    assert_eq!(code, 200);
    handle.join().unwrap();
    std::fs::remove_file(&a).ok();
    std::fs::remove_file(&b).ok();
}

#[test]
fn watch_folds_growing_delta_chain_without_dropping_requests() {
    // A continuous-training run publishes one full anchor and then only
    // appends CRC-chained deltas. `--watch` must pick up every append
    // (the checkpoint file itself never changes mtime), fold the chain,
    // and swap with zero dropped requests.
    let path = tmp("watch_chain.ckpt");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(journal_path(&path)).ok();

    let exp = Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::parse("8").unwrap(),
        model: "tiny".into(),
        dataset: "synthetic:tiny".into(),
        n_samples: 1500,
        use_runtime: false,
        threads: 1,
        ..Experiment::default()
    };
    let entry = builtin_entry(&exp.model).unwrap();
    let n = registry::schema_for(&exp).unwrap().n_features();
    let mut tr = Trainer::new(exp.clone(), n).unwrap();
    let source = registry::open_source(&exp).unwrap();
    let stream =
        registry::train_epoch_stream(source.as_ref(), &exp, 1).unwrap();
    let mut batches =
        StreamBatcher::new(stream, entry.fields, entry.batch, Tail::Drop)
            .map(|r| r.unwrap());
    let mut advance = |tr: &mut Trainer| {
        for _ in 0..2 {
            tr.step(&batches.next().unwrap(), 1).unwrap();
        }
    };

    // anchor: the first continuous save is a full checkpoint + journal
    advance(&mut tr);
    tr.continuous_save(&path).unwrap();
    assert!(journal_path(&path).exists());

    let mut cfg = ServerConfig::new("127.0.0.1:0", &path);
    cfg.workers = 3;
    cfg.max_wait = Duration::from_millis(2);
    cfg.watch = Some(Duration::from_millis(20));
    let server = Server::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let record: Vec<u32> =
        (0..entry.fields as u32).map(|f| f % 8).collect();
    let body = format!("[{}]", record_json(&record));

    let stop = Arc::new(AtomicBool::new(false));
    let failures = Arc::new(AtomicUsize::new(0));
    let scored = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..3 {
            let (stop, failures, scored) = (
                Arc::clone(&stop),
                Arc::clone(&failures),
                Arc::clone(&scored),
            );
            let (addr, body) = (addr.clone(), body.clone());
            s.spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let (code, _) = http(&addr, "POST", "/score", &body);
                    if code == 200 {
                        scored.fetch_add(1, Ordering::SeqCst);
                    } else {
                        failures.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }
        while scored.load(Ordering::SeqCst) < 5 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // grow the chain under load: each save appends one delta (the
        // anchor file itself is never rewritten below compact_every)
        for _ in 0..3 {
            advance(&mut tr);
            tr.continuous_save(&path).unwrap();
            std::thread::sleep(Duration::from_millis(60));
        }
        // the watcher must converge on the full chain's bits
        let want = InferenceEngine::from_checkpoint(&path)
            .unwrap()
            .score_records(&record)
            .unwrap()[0];
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let (code, resp) = http(&addr, "POST", "/score", &body);
            assert_eq!(code, 200, "{resp}");
            let got = Json::parse(&resp)
                .unwrap()
                .get("logits")
                .unwrap()
                .as_array()
                .unwrap()[0]
                .as_f64()
                .unwrap() as f32;
            if got.to_bits() == want.to_bits() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "watcher never folded the delta chain: live {got}, \
                 chain {want}"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        stop.store(true, Ordering::SeqCst);
    });
    assert_eq!(
        failures.load(Ordering::SeqCst),
        0,
        "requests failed while the delta chain grew under --watch"
    );

    // the fresh load folded the whole chain, not just the anchor
    let engine = InferenceEngine::from_checkpoint(&path).unwrap();
    assert_eq!(engine.deltas_folded(), 3);
    let (code, resp) = http(&addr, "GET", "/stats", "");
    assert_eq!(code, 200);
    let stats = Json::parse(&resp).unwrap();
    assert!(
        stats.get("reloads").unwrap().as_usize().unwrap() >= 1,
        "{resp}"
    );
    assert_eq!(
        stats.get("reload_failures").unwrap().as_usize().unwrap(),
        0,
        "{resp}"
    );

    let (code, _) = http(&addr, "POST", "/shutdown", "");
    assert_eq!(code, 200);
    handle.join().unwrap();
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(journal_path(&path)).ok();
}
