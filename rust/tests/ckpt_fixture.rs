//! Validates the committed serving fixture against the real checkpoint
//! reader: geometry, CRC-checked sections, grid-aligned gather values,
//! a full inference pass, a far-from-chance served AUC (the fixture is
//! *trained* — scripts/train_fixture.py), and the save→load→save
//! byte-identity contract.
//!
//! Skips (with a note) only when the fixture file is absent; a present
//! but malformed fixture is a hard failure.

use std::path::PathBuf;

use alpt::checkpoint::{
    dense_params, load_store, save_store, Checkpoint, SectionKind,
};
use alpt::config::{Method, RoundingMode};
use alpt::coordinator::{builtin_entry, serve_with_engine};
use alpt::serve::InferenceEngine;
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::data::Schema;
use alpt::embedding::EmbeddingStore;
use alpt::nn::Dcn;
use alpt::quant::delta_from_clip;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("examples/fixtures/tiny_lpt8.ckpt")
}

#[test]
fn fixture_serves_without_training() {
    let path = fixture_path();
    if !path.exists() {
        eprintln!(
            "skipping: no committed fixture (run \
             `python3 scripts/train_fixture.py`)"
        );
        return;
    }

    let ckpt = Checkpoint::read(&path).expect("fixture must parse");
    let (store, exp) = load_store(&ckpt).expect("fixture store must load");

    // the committed fixture is deliberately written as a version-1
    // (pre-precision-plan) file: v1 loads as a single-group uniform plan
    assert_eq!(ckpt.version, 1);
    assert!(store.as_grouped().is_none(), "v1 loads as a single group");

    // geometry pins: the tiny synthetic schema and the tiny model config
    assert_eq!(exp.method, Method::Lpt(RoundingMode::Sr));
    assert_eq!(exp.bits, alpt::config::PrecisionPlan::uniform(8));
    assert_eq!(exp.model, "tiny");
    assert!(!exp.use_runtime, "fixture must be runtime-free");
    let spec = SyntheticSpec::tiny(exp.seed);
    let n_features = Schema::new(spec.vocabs.clone()).n_features();
    assert_eq!(store.n_features(), n_features);
    let entry = builtin_entry(&exp.model).unwrap();
    assert_eq!(store.dim(), entry.emb_dim);
    let dense = dense_params(&ckpt).expect("fixture must hold dense params");
    assert_eq!(dense.len(), entry.n_params);

    // every gathered value sits on the fixed-Δ LPT grid
    let bw = exp.bit_width().unwrap();
    let delta = delta_from_clip(exp.clip, bw);
    let ids: Vec<u32> = (0..64).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    for &v in &out {
        let x = v / delta;
        assert!(
            (x - x.round()).abs() < 1e-4,
            "gathered value {v} off the Δ={delta} grid"
        );
        assert!(x.abs() <= 128.0, "code magnitude out of 8-bit range");
    }

    // one full inference batch through the Rust nn path — no training
    let ds = generate(&spec, 2000);
    let dcn = Dcn::new(entry.dcn_config());
    let batch = Batcher::new(&ds, entry.batch, None, false)
        .next()
        .expect("at least one batch");
    let (umax, d) = (entry.umax, entry.emb_dim);
    let mut emb = vec![0.0f32; umax * d];
    let n_u = batch.unique.len();
    store.gather(&batch.unique, &mut emb[..n_u * d]);
    let logits = dcn.infer(&emb, &batch.idx, &dense);
    assert_eq!(logits.len(), entry.batch);
    assert!(logits.iter().all(|x| x.is_finite()), "non-finite logits");

    // the fixture is trained against the seed's ground truth
    // (scripts/train_fixture.py ports the latent model bit-for-bit), so
    // the engine must score real — not chance-level — AUC over the
    // eval split serve.rs regenerates from the checkpoint's own seed
    let engine = InferenceEngine::from_checkpoint(&path)
        .expect("fixture must load into the engine");
    let report = serve_with_engine(&engine, usize::MAX)
        .expect("fixture must serve the seed-regenerated split");
    assert!(
        report.auc > 0.6,
        "fixture serves chance-level auc {:.4}: the committed \
         checkpoint is not a trained model (regenerate it with \
         `python3 scripts/train_fixture.py`)",
        report.auc
    );

    // save→load→save through the Rust writer is byte-identical
    let dir = std::env::temp_dir().join("alpt_fixture_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("fixture.1.ckpt");
    let p2 = dir.join("fixture.2.ckpt");
    save_store(&p1, store.as_ref(), &exp).unwrap();

    // uniform-plan equivalence anchor: the fixture is written in the
    // pre-precision-plan v1 shape, so the re-saved file's header version
    // and raw row payloads must match the committed bytes exactly —
    // uniform checkpoints did not change shape across the refactor
    let resaved = Checkpoint::read(&p1).unwrap();
    assert_eq!(resaved.version, ckpt.version, "uniform files stay v1");
    let old_rows = ckpt.sections_of(SectionKind::Rows);
    let new_rows = resaved.sections_of(SectionKind::Rows);
    assert_eq!(old_rows.len(), new_rows.len());
    for (a, b) in old_rows.iter().zip(&new_rows) {
        assert_eq!(a.index, b.index);
        assert_eq!(
            a.payload, b.payload,
            "row payloads diverged from the pre-refactor fixture"
        );
    }
    let ck1 = Checkpoint::read(&p1).unwrap();
    let (store2, exp2) = load_store(&ck1).unwrap();
    save_store(&p2, store2.as_ref(), &exp2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "save→load→save changed bytes"
    );
    // and the re-saved store still gathers identically to the fixture's
    let mut out2 = vec![0.0f32; ids.len() * store.dim()];
    store2.gather(&ids, &mut out2);
    assert_eq!(out, out2);
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}
