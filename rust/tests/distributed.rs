//! End-to-end tests for distributed parameter-server training: a
//! coordinator plus N `run_worker` shards over loopback TCP must be
//! bit-identical to the single-process run (gathers, checkpoint bytes,
//! served logits), fail loudly when a worker dies mid-epoch, and
//! reshard checkpoints N → M transparently.

use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::thread::JoinHandle;

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{
    run_worker, sample_requests, RpcConfig, Trainer, WorkerHub, WorkerOpts,
};
use alpt::data::registry;
use alpt::embedding::{EmbeddingStore, UpdateHp};
use alpt::quant::{lsq_delta_grad_row, BitWidth};
use alpt::util::rng::Pcg32;
use anyhow::Result;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_distributed_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn tiny_exp() -> Experiment {
    Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        n_samples: 600,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        lr_emb: 0.3,
        ..Experiment::default()
    }
}

fn test_cfg() -> RpcConfig {
    RpcConfig {
        timeout_ms: 60_000,
        accept_timeout_ms: 60_000,
        ..RpcConfig::default()
    }
}

/// Spawn `n` worker serve loops connecting to `addr`; `die_after[i]`
/// injects a crash after that many UPDATE frames.
fn spawn_workers(
    addr: &str,
    n: usize,
    die_after: &[Option<u64>],
) -> Vec<JoinHandle<Result<()>>> {
    (0..n)
        .map(|i| {
            let opts = WorkerOpts {
                connect: addr.to_string(),
                idle_timeout_ms: 60_000,
                connect_retries: 200,
                retry_delay_ms: 25,
                die_after_updates: die_after.get(i).copied().flatten(),
                ..WorkerOpts::default()
            };
            std::thread::spawn(move || run_worker(&opts))
        })
        .collect()
}

/// Bind a port-0 hub, spawn `workers` healthy workers against it, and
/// attach them to `tr`.
fn attach(tr: &mut Trainer, workers: usize) -> Vec<JoinHandle<Result<()>>> {
    let hub = WorkerHub::bind("127.0.0.1:0", test_cfg()).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let handles = spawn_workers(&addr, workers, &[]);
    tr.attach_workers_hub(hub, workers).unwrap();
    handles
}

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

fn shutdown_and_join(tr: Trainer, handles: Vec<JoinHandle<Result<()>>>) {
    tr.store.as_remote().unwrap().shutdown().unwrap();
    drop(tr);
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// The tentpole contract: `--workers 2` is bit-identical to the
/// single-process run — the rows two shards serve at attach time, the
/// final checkpoint file, and the logits served from it.
#[test]
fn two_workers_train_bit_identical_to_single_process() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    let p_single = tmp("single.ckpt");
    let single_init;
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        single_init = gather_all(tr.store.as_ref());
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_single).unwrap();
    }

    let p_dist = tmp("dist2.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        let handles = attach(&mut tr, 2);
        assert!(tr.store.as_remote().is_some(), "store was not swapped");
        // the sharded table serves exactly the rows the local one held
        assert_eq!(
            gather_all(tr.store.as_ref()),
            single_init,
            "gather through two shards diverged from the local table"
        );
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_dist).unwrap();
        shutdown_and_join(tr, handles);
    }

    assert_eq!(
        std::fs::read(&p_single).unwrap(),
        std::fs::read(&p_dist).unwrap(),
        "2-worker checkpoint is not byte-identical to single-process"
    );
    // byte equality already implies this; assert the user-visible form
    let a = sample_requests(&p_single, 8).unwrap();
    let b = sample_requests(&p_dist, 8).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.features, y.features);
        assert_eq!(x.logit.to_bits(), y.logit.to_bits());
    }
    std::fs::remove_file(&p_single).ok();
    std::fs::remove_file(&p_dist).ok();
}

/// The overlap acceptance matrix: pipelined (the default) and
/// `--no-overlap` (synchronous) runs at 1, 2 and 3 workers must all
/// produce a checkpoint byte-identical to the single-process file —
/// batch-ahead pipelining changes the wire schedule, never the math.
#[test]
fn overlap_matrix_bit_identical_across_worker_counts() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    let p_single = tmp("matrix_single.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_single).unwrap();
    }
    let reference = std::fs::read(&p_single).unwrap();
    std::fs::remove_file(&p_single).ok();

    for workers in [1usize, 2, 3] {
        for overlap in [true, false] {
            let p = tmp(&format!("matrix_{workers}w_ovl{overlap}.ckpt"));
            let source = registry::open_source(&exp).unwrap();
            let mut tr = Trainer::new(exp.clone(), n).unwrap();
            tr.set_rpc_overlap(overlap);
            let handles = attach(&mut tr, workers);
            tr.train_stream(source.as_ref(), false, None).unwrap();
            tr.save_checkpoint(&p).unwrap();
            shutdown_and_join(tr, handles);
            assert_eq!(
                std::fs::read(&p).unwrap(),
                reference,
                "{workers}-worker run (overlap={overlap}) is not \
                 byte-identical to single-process"
            );
            std::fs::remove_file(&p).ok();
        }
    }
}

/// The shared per-row hyperparameters / second pass the direct
/// store-level tests below drive `update` with (the trainer normally
/// supplies these from the model).
fn test_hp() -> UpdateHp {
    UpdateHp {
        lr_emb: 0.1,
        wd_emb: 0.0,
        lr_delta: 1e-3,
        wd_delta: 0.0,
        grad_scale: 1.0,
        lr_scale: 1.0,
    }
}

fn eq7_second_pass(
) -> impl FnMut(&[f32], &[f32], &[BitWidth]) -> Result<Vec<f32>> {
    move |w_new: &[f32], delta: &[f32], bws: &[BitWidth]| {
        let d = w_new.len() / delta.len();
        let ups = vec![1.0f32; d];
        Ok(delta
            .iter()
            .enumerate()
            .map(|(i, &dl)| {
                lsq_delta_grad_row(&w_new[i * d..(i + 1) * d], dl, bws[i],
                                   &ups)
            })
            .collect())
    }
}

/// Regression for the `deltas_for` cache-miss branch: when `update`
/// runs for a batch the gather cache no longer holds, the store takes
/// the fanned-out aux-only round trip — and the result must still be
/// bit-identical to a local store doing the same update.
#[test]
fn update_after_cache_eviction_matches_local_store() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();
    let mut tr_local = Trainer::new(exp.clone(), n).unwrap();
    let mut tr_remote = Trainer::new(exp.clone(), n).unwrap();
    let handles = attach(&mut tr_remote, 2);
    let d = tr_local.store.dim();

    let ids_b: Vec<u32> = vec![0, 1, 2, 3, 5, 8, 13, 21];
    let ids_a: Vec<u32> = vec![4, 6, 7];

    let mut emb_l = vec![0.0f32; ids_b.len() * d];
    tr_local.store.gather(&ids_b, &mut emb_l);
    let mut emb_r = vec![0.0f32; ids_b.len() * d];
    tr_remote.store.gather(&ids_b, &mut emb_r);
    assert_eq!(emb_l, emb_r, "remote gather diverged before the update");

    // evict batch B from the remote gather cache so the update's
    // deltas_for(B) misses and must take the aux round trip
    let mut scratch = vec![0.0f32; ids_a.len() * d];
    tr_remote.store.gather(&ids_a, &mut scratch);

    let grads: Vec<f32> = (0..ids_b.len() * d)
        .map(|i| ((i % 7) as f32 - 3.0) * 0.01)
        .collect();
    let hp = test_hp();
    let mut sp = eq7_second_pass();
    let mut rng_l = Pcg32::seeded(77);
    let mut rng_r = Pcg32::seeded(77);
    tr_local
        .store
        .update(&ids_b, &emb_l, &grads, &hp, &mut rng_l, &mut sp)
        .unwrap();
    tr_remote
        .store
        .update(&ids_b, &emb_r, &grads, &hp, &mut rng_r, &mut sp)
        .unwrap();

    let mut after_l = vec![0.0f32; ids_b.len() * d];
    tr_local.store.gather(&ids_b, &mut after_l);
    let mut after_r = vec![0.0f32; ids_b.len() * d];
    tr_remote.store.gather(&ids_b, &mut after_r);
    assert_eq!(
        after_l.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        after_r.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "cache-miss update diverged from the local store"
    );
    shutdown_and_join(tr_remote, handles);
}

/// A worker dying with pipelined frames in flight — its UPDATE unacked
/// and the batch-ahead GATHER already sent — must surface as a loud
/// failure at the next settle (the drain finds the Err frame or the
/// closed socket), never as a hang or silently wrong data.
#[test]
fn worker_death_with_inflight_prefetch_fails_loudly() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();
    let mut tr = Trainer::new(exp.clone(), n).unwrap();
    let hub = WorkerHub::bind("127.0.0.1:0", test_cfg()).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    // shard 0 dies when its second UPDATE frame arrives
    let handles = spawn_workers(&addr, 2, &[Some(1), None]);
    tr.attach_workers_hub(hub, 2).unwrap();

    let d = tr.store.dim();
    let ids: Vec<u32> = (0..16u32).collect();
    let hp = test_hp();
    let mut sp = eq7_second_pass();
    let mut rng = Pcg32::seeded(3);
    let grads = vec![0.01f32; ids.len() * d];

    // round 1 survives: pipelined UPDATE + prefetch, settled by the
    // next gather
    let mut emb = vec![0.0f32; ids.len() * d];
    tr.store.gather(&ids, &mut emb);
    tr.store
        .update(&ids, &emb, &grads, &hp, &mut rng, &mut sp)
        .unwrap();
    tr.store.prefetch_ids(&ids);

    // round 2 trips shard 0's failpoint with the prefetch in flight;
    // the failure must surface by the end of round 3's settle
    let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let mut out = vec![0.0f32; ids.len() * d];
        tr.store.gather(&ids, &mut out);
        tr.store
            .update(&ids, &out, &grads, &hp, &mut rng, &mut sp)
            .unwrap();
        tr.store.prefetch_ids(&ids);
        let mut out2 = vec![0.0f32; ids.len() * d];
        tr.store.gather(&ids, &mut out2);
    }));
    assert!(
        outcome.is_err(),
        "worker death with in-flight prefetches did not fail the run"
    );

    drop(tr); // best-effort shutdown releases the survivor
    let results: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        results[0].is_err(),
        "the rigged worker should report its injected crash"
    );
    assert!(results[1].is_ok(), "the healthy worker should exit cleanly");
}

/// A worker crashing mid-epoch must fail the run loudly (no hang, no
/// silently-wrong model), and the last published checkpoint must still
/// resume.
#[test]
fn worker_death_mid_epoch_fails_loudly_and_checkpoint_survives() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    // a clean run publishes the checkpoint the operator falls back to
    let p = tmp("death_base.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p).unwrap();
    }

    // resume it, attach two workers — one rigged to die after 3 updates
    let mut tr = Trainer::resume(&p).unwrap();
    tr.exp.epochs = tr.epochs_done + 1;
    let hub = WorkerHub::bind("127.0.0.1:0", test_cfg()).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let handles = spawn_workers(&addr, 2, &[Some(3), None]);
    tr.attach_workers_hub(hub, 2).unwrap();

    let source = registry::open_source(&tr.exp).unwrap();
    let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
        tr.train_stream(source.as_ref(), false, None)
    }));
    assert!(
        !matches!(outcome, Ok(Ok(_))),
        "training kept going after a worker died mid-epoch"
    );
    drop(tr); // best-effort shutdown releases the survivor
    let results: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        results[0].is_err(),
        "the rigged worker should report its injected crash"
    );

    // the previously published checkpoint is intact and trains on
    let mut back = Trainer::resume(&p).unwrap();
    back.exp.epochs = back.epochs_done + 1;
    let source = registry::open_source(&back.exp).unwrap();
    let res = back.train_stream(source.as_ref(), false, None).unwrap();
    assert_eq!(res.epochs_run, 1);
    std::fs::remove_file(&p).ok();
}

/// Checkpoints persist rows in canonical global order, so a table
/// trained on N workers reshards onto M (or onto one process) without
/// the file changing: attach-then-save is a byte no-op, and continuing
/// training on 3 workers matches the single-process continuation.
#[test]
fn checkpoint_reshards_n_to_m_byte_identically() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    // epoch 1 on two workers
    let p_base = tmp("reshard_base.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        let handles = attach(&mut tr, 2);
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_base).unwrap();
        shutdown_and_join(tr, handles);
    }

    // resume on 3 workers: an immediate save must not move a byte
    let p_resharded = tmp("reshard_3w.ckpt");
    let p_cont3 = tmp("reshard_cont3.ckpt");
    {
        let mut tr = Trainer::resume(&p_base).unwrap();
        tr.exp.epochs = tr.epochs_done + 1;
        let handles = attach(&mut tr, 3);
        tr.save_checkpoint(&p_resharded).unwrap();
        assert_eq!(
            std::fs::read(&p_base).unwrap(),
            std::fs::read(&p_resharded).unwrap(),
            "resharding 2 -> 3 workers changed the checkpoint"
        );
        let source = registry::open_source(&tr.exp).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_cont3).unwrap();
        shutdown_and_join(tr, handles);
    }

    // the single-process continuation of the same file
    let p_cont1 = tmp("reshard_cont1.ckpt");
    {
        let mut tr = Trainer::resume(&p_base).unwrap();
        tr.exp.epochs = tr.epochs_done + 1;
        let source = registry::open_source(&tr.exp).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_cont1).unwrap();
    }
    assert_eq!(
        std::fs::read(&p_cont3).unwrap(),
        std::fs::read(&p_cont1).unwrap(),
        "training on 3 workers diverged from the single-process \
         continuation of the same checkpoint"
    );
    for p in [&p_base, &p_resharded, &p_cont3, &p_cont1] {
        std::fs::remove_file(p).ok();
    }
}
