//! End-to-end tests for distributed parameter-server training: a
//! coordinator plus N `run_worker` shards over loopback TCP must be
//! bit-identical to the single-process run (gathers, checkpoint bytes,
//! served logits), fail loudly when a worker dies mid-epoch, and
//! reshard checkpoints N → M transparently.

use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::thread::JoinHandle;

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{
    run_worker, sample_requests, RpcConfig, Trainer, WorkerHub, WorkerOpts,
};
use alpt::data::registry;
use alpt::embedding::EmbeddingStore;
use anyhow::Result;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_distributed_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn tiny_exp() -> Experiment {
    Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        n_samples: 600,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        lr_emb: 0.3,
        ..Experiment::default()
    }
}

fn test_cfg() -> RpcConfig {
    RpcConfig {
        timeout_ms: 60_000,
        accept_timeout_ms: 60_000,
        ..RpcConfig::default()
    }
}

/// Spawn `n` worker serve loops connecting to `addr`; `die_after[i]`
/// injects a crash after that many UPDATE frames.
fn spawn_workers(
    addr: &str,
    n: usize,
    die_after: &[Option<u64>],
) -> Vec<JoinHandle<Result<()>>> {
    (0..n)
        .map(|i| {
            let opts = WorkerOpts {
                connect: addr.to_string(),
                idle_timeout_ms: 60_000,
                connect_retries: 200,
                retry_delay_ms: 25,
                die_after_updates: die_after.get(i).copied().flatten(),
                ..WorkerOpts::default()
            };
            std::thread::spawn(move || run_worker(&opts))
        })
        .collect()
}

/// Bind a port-0 hub, spawn `workers` healthy workers against it, and
/// attach them to `tr`.
fn attach(tr: &mut Trainer, workers: usize) -> Vec<JoinHandle<Result<()>>> {
    let hub = WorkerHub::bind("127.0.0.1:0", test_cfg()).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let handles = spawn_workers(&addr, workers, &[]);
    tr.attach_workers_hub(hub, workers).unwrap();
    handles
}

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

fn shutdown_and_join(tr: Trainer, handles: Vec<JoinHandle<Result<()>>>) {
    tr.store.as_remote().unwrap().shutdown().unwrap();
    drop(tr);
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// The tentpole contract: `--workers 2` is bit-identical to the
/// single-process run — the rows two shards serve at attach time, the
/// final checkpoint file, and the logits served from it.
#[test]
fn two_workers_train_bit_identical_to_single_process() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    let p_single = tmp("single.ckpt");
    let single_init;
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        single_init = gather_all(tr.store.as_ref());
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_single).unwrap();
    }

    let p_dist = tmp("dist2.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        let handles = attach(&mut tr, 2);
        assert!(tr.store.as_remote().is_some(), "store was not swapped");
        // the sharded table serves exactly the rows the local one held
        assert_eq!(
            gather_all(tr.store.as_ref()),
            single_init,
            "gather through two shards diverged from the local table"
        );
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_dist).unwrap();
        shutdown_and_join(tr, handles);
    }

    assert_eq!(
        std::fs::read(&p_single).unwrap(),
        std::fs::read(&p_dist).unwrap(),
        "2-worker checkpoint is not byte-identical to single-process"
    );
    // byte equality already implies this; assert the user-visible form
    let a = sample_requests(&p_single, 8).unwrap();
    let b = sample_requests(&p_dist, 8).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.features, y.features);
        assert_eq!(x.logit.to_bits(), y.logit.to_bits());
    }
    std::fs::remove_file(&p_single).ok();
    std::fs::remove_file(&p_dist).ok();
}

/// A worker crashing mid-epoch must fail the run loudly (no hang, no
/// silently-wrong model), and the last published checkpoint must still
/// resume.
#[test]
fn worker_death_mid_epoch_fails_loudly_and_checkpoint_survives() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    // a clean run publishes the checkpoint the operator falls back to
    let p = tmp("death_base.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p).unwrap();
    }

    // resume it, attach two workers — one rigged to die after 3 updates
    let mut tr = Trainer::resume(&p).unwrap();
    tr.exp.epochs = tr.epochs_done + 1;
    let hub = WorkerHub::bind("127.0.0.1:0", test_cfg()).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let handles = spawn_workers(&addr, 2, &[Some(3), None]);
    tr.attach_workers_hub(hub, 2).unwrap();

    let source = registry::open_source(&tr.exp).unwrap();
    let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
        tr.train_stream(source.as_ref(), false, None)
    }));
    assert!(
        !matches!(outcome, Ok(Ok(_))),
        "training kept going after a worker died mid-epoch"
    );
    drop(tr); // best-effort shutdown releases the survivor
    let results: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        results[0].is_err(),
        "the rigged worker should report its injected crash"
    );

    // the previously published checkpoint is intact and trains on
    let mut back = Trainer::resume(&p).unwrap();
    back.exp.epochs = back.epochs_done + 1;
    let source = registry::open_source(&back.exp).unwrap();
    let res = back.train_stream(source.as_ref(), false, None).unwrap();
    assert_eq!(res.epochs_run, 1);
    std::fs::remove_file(&p).ok();
}

/// Checkpoints persist rows in canonical global order, so a table
/// trained on N workers reshards onto M (or onto one process) without
/// the file changing: attach-then-save is a byte no-op, and continuing
/// training on 3 workers matches the single-process continuation.
#[test]
fn checkpoint_reshards_n_to_m_byte_identically() {
    let exp = tiny_exp();
    let n = registry::open_source(&exp).unwrap().schema().n_features();

    // epoch 1 on two workers
    let p_base = tmp("reshard_base.ckpt");
    {
        let source = registry::open_source(&exp).unwrap();
        let mut tr = Trainer::new(exp.clone(), n).unwrap();
        let handles = attach(&mut tr, 2);
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_base).unwrap();
        shutdown_and_join(tr, handles);
    }

    // resume on 3 workers: an immediate save must not move a byte
    let p_resharded = tmp("reshard_3w.ckpt");
    let p_cont3 = tmp("reshard_cont3.ckpt");
    {
        let mut tr = Trainer::resume(&p_base).unwrap();
        tr.exp.epochs = tr.epochs_done + 1;
        let handles = attach(&mut tr, 3);
        tr.save_checkpoint(&p_resharded).unwrap();
        assert_eq!(
            std::fs::read(&p_base).unwrap(),
            std::fs::read(&p_resharded).unwrap(),
            "resharding 2 -> 3 workers changed the checkpoint"
        );
        let source = registry::open_source(&tr.exp).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_cont3).unwrap();
        shutdown_and_join(tr, handles);
    }

    // the single-process continuation of the same file
    let p_cont1 = tmp("reshard_cont1.ckpt");
    {
        let mut tr = Trainer::resume(&p_base).unwrap();
        tr.exp.epochs = tr.epochs_done + 1;
        let source = registry::open_source(&tr.exp).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        tr.save_checkpoint(&p_cont1).unwrap();
    }
    assert_eq!(
        std::fs::read(&p_cont3).unwrap(),
        std::fs::read(&p_cont1).unwrap(),
        "training on 3 workers diverged from the single-process \
         continuation of the same checkpoint"
    );
    for p in [&p_base, &p_resharded, &p_cont3, &p_cont1] {
        std::fs::remove_file(p).ok();
    }
}
