//! End-to-end tests for the streaming Criteo pipeline: the committed TSV
//! fixture streams cleanly, trains through `Trainer::train_stream`,
//! checkpoints and serves; the prefetching batcher and a mid-epoch
//! resume are bit-identical to the uninterrupted serial run.
//!
//! Skips (with a note) only when the TSV fixture is absent; a present but
//! broken fixture is a hard failure.

use std::path::PathBuf;

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{serve_checkpoint, Trainer};
use alpt::data::registry::{self, DataSource, RecordStream};
use alpt::embedding::EmbeddingStore;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("examples/fixtures/tiny_criteo.tsv")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_criteo_pipeline_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn criteo_exp() -> Experiment {
    Experiment {
        dataset: format!("criteo:{}", fixture_path().display()),
        model: "criteo".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        patience: 0,
        use_runtime: false,
        threads: 1,
        hash_bits: 8,
        shuffle_window: 256,
        prefetch_batches: 2,
        wd_emb: 1e-5,
        ..Experiment::default()
    }
}

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

#[test]
fn fixture_streams_every_record() {
    let path = fixture_path();
    if !path.exists() {
        eprintln!(
            "skipping: no committed fixture (run \
             `python3 scripts/make_criteo_fixture.py`)"
        );
        return;
    }
    let exp = criteo_exp();
    let source = registry::open_source(&exp).unwrap();
    let schema = source.schema().clone();
    assert_eq!(schema.n_fields(), 39);
    let mut stream = source.stream().unwrap();
    let mut out = vec![0u32; 39];
    let mut n = 0usize;
    let mut positives = 0usize;
    while let Some(label) = stream.next_record(&mut out).unwrap() {
        n += 1;
        positives += label as usize;
        for (f, &g) in out.iter().enumerate() {
            assert_eq!(schema.field_of(g), f, "record {n}: bad field id");
        }
    }
    assert_eq!(n, 1000, "fixture must stream all 1000 rows");
    // the fixture's CTR is ~0.33; anything near that proves labels parse
    assert!(
        (200..=500).contains(&positives),
        "positives={positives} out of range"
    );
}

#[test]
fn criteo_trains_checkpoints_and_serves() {
    let path = fixture_path();
    if !path.exists() {
        eprintln!("skipping: no committed fixture");
        return;
    }
    let exp = criteo_exp();
    let source = registry::open_source(&exp).unwrap();
    let n_features = source.schema().n_features();
    let mut trainer = Trainer::new(exp, n_features).unwrap();
    let res = trainer.train_stream(source.as_ref(), false, None).unwrap();
    assert_eq!(res.epochs_run, 1);
    assert!(res.history[0].steps > 0, "no training steps ran");
    assert!(res.best_auc.is_finite() && res.best_logloss.is_finite());

    let ckpt = tmp("criteo_e2e.ckpt");
    trainer.save_checkpoint(&ckpt).unwrap();

    // resumed trainer evaluates identically on the held-out split
    let mut resumed = Trainer::resume(&ckpt).unwrap();
    assert_eq!(resumed.epochs_done, 1);
    let ev_a = trainer.evaluate_source(source.as_ref()).unwrap();
    let ev_b = resumed.evaluate_source(source.as_ref()).unwrap();
    assert_eq!(ev_a.auc.to_bits(), ev_b.auc.to_bits());
    assert_eq!(ev_a.samples, ev_b.samples);
    assert!(ev_a.samples > 50, "holdout too small: {}", ev_a.samples);

    // and the serve path streams the same held-out split from the file
    let report = serve_checkpoint(&ckpt, 8).unwrap();
    assert_eq!(report.method, "ALPT(SR)");
    assert_eq!(report.n_features, n_features);
    assert!(report.auc.is_finite());
    assert_eq!(report.requests, ev_a.samples);
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn prefetch_and_serial_training_are_bit_identical() {
    // synthetic streaming source: small and fast, same code path as files
    let base = Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Lpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        n_samples: 1200,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 128,
        lr_emb: 0.3,
        ..Experiment::default()
    };
    let mut results = Vec::new();
    for prefetch in [0usize, 3] {
        let exp =
            Experiment { prefetch_batches: prefetch, ..base.clone() };
        let source = registry::open_source(&exp).unwrap();
        let n = source.schema().n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        tr.train_stream(source.as_ref(), false, None).unwrap();
        results.push((gather_all(tr.store.as_ref()), tr.dense.clone()));
    }
    assert_eq!(
        results[0].0, results[1].0,
        "prefetched table diverged from serial"
    );
    assert_eq!(
        results[0].1, results[1].1,
        "prefetched dense params diverged from serial"
    );
}

#[test]
fn mid_epoch_resume_continues_bit_identically() {
    let exp = Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        epochs: 1,
        n_samples: 700,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        save_every: 5, // ~9 full batches of 64 in the train split
        lr_emb: 0.3,
        ..Experiment::default()
    };
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();

    // uninterrupted run, checkpointing mid-epoch every 5 steps
    let ckpt = tmp("mid_epoch.ckpt");
    let mut full = Trainer::new(exp.clone(), n).unwrap();
    let res = full
        .train_stream(source.as_ref(), false, Some(ckpt.as_path()))
        .unwrap();
    let steps_full = res.history[0].steps;
    // the file on disk holds the *last* every-5-steps save of the epoch
    let last_save = (steps_full / 5) * 5;
    assert!(last_save >= 5, "too few steps ({steps_full}) to save mid-epoch");

    let mut resumed = Trainer::resume(&ckpt).unwrap();
    assert_eq!(resumed.epochs_done, 0);
    assert_eq!(resumed.stream_records_done, (last_save * 64) as u64);
    // sources are rebuilt identically from the experiment echo
    let source_b = registry::open_source(&resumed.exp).unwrap();
    let res_b = resumed
        .train_stream(source_b.as_ref(), false, None)
        .unwrap();
    assert_eq!(res_b.epochs_run, 1);
    assert_eq!(
        res_b.history[0].steps,
        steps_full - last_save,
        "resume must finish only the remaining steps"
    );
    assert_eq!(
        gather_all(full.store.as_ref()),
        gather_all(resumed.store.as_ref()),
        "embedding tables diverged after mid-epoch resume"
    );
    assert_eq!(full.dense, resumed.dense, "dense params diverged");
    assert_eq!(
        res_b.history[0].val_auc.to_bits(),
        res.history[0].val_auc.to_bits(),
        "val AUC diverged"
    );
    assert_eq!(
        full.early_stop, resumed.early_stop,
        "early-stop bookkeeping diverged"
    );
    assert_eq!(res_b.best_auc.to_bits(), res.best_auc.to_bits());
    std::fs::remove_file(&ckpt).ok();
}
