//! End-to-end tests for per-field mixed-precision plans: a grouped store
//! trains through the streaming trainer, checkpoints in the format-v2
//! grouped layout, resumes bit-identically (including mid-epoch), and
//! serves — plus the Criteo-fixture leg mirroring the CI
//! `--bits cat:4,num:8` job.

use std::path::PathBuf;

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::{serve_checkpoint, Trainer};
use alpt::data::registry;
use alpt::embedding::EmbeddingStore;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("alpt_mixed_precision_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn criteo_fixture() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("examples/fixtures/tiny_criteo.tsv")
}

fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
    let ids: Vec<u32> = (0..store.n_features() as u32).collect();
    let mut out = vec![0.0f32; ids.len() * store.dim()];
    store.gather(&ids, &mut out);
    out
}

fn mixed_tiny_exp() -> Experiment {
    Experiment {
        dataset: "synthetic:tiny".into(),
        model: "tiny".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::parse("f0:4,f1:8,default:2").unwrap(),
        epochs: 1,
        n_samples: 700,
        patience: 0,
        use_runtime: false,
        threads: 1,
        shuffle_window: 64,
        prefetch_batches: 2,
        lr_emb: 0.3,
        ..Experiment::default()
    }
}

#[test]
fn mixed_plan_mid_epoch_resume_is_bit_identical() {
    // the grouped-store counterpart of the uniform mid-epoch-resume
    // contract: a v2 checkpoint restores every group's packed rows,
    // learned deltas and the shared SR step counter exactly
    let exp = Experiment { save_every: 5, ..mixed_tiny_exp() };
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();

    let ckpt = tmp("mixed_mid_epoch.ckpt");
    let mut full = Trainer::new(exp.clone(), n).unwrap();
    assert!(
        full.store.as_grouped().is_some(),
        "mixed plan must build a grouped store"
    );
    let res = full
        .train_stream(source.as_ref(), false, Some(ckpt.as_path()))
        .unwrap();
    let steps_full = res.history[0].steps;
    let last_save = (steps_full / 5) * 5;
    assert!(last_save >= 5, "too few steps ({steps_full}) to save");

    let mut resumed = Trainer::resume(&ckpt).unwrap();
    assert_eq!(resumed.exp.bits, exp.bits, "plan survives the echo");
    assert_eq!(resumed.epochs_done, 0);
    let source_b = registry::open_source(&resumed.exp).unwrap();
    let res_b =
        resumed.train_stream(source_b.as_ref(), false, None).unwrap();
    assert_eq!(res_b.history[0].steps, steps_full - last_save);
    assert_eq!(
        gather_all(full.store.as_ref()),
        gather_all(resumed.store.as_ref()),
        "grouped tables diverged after mid-epoch resume"
    );
    assert_eq!(full.dense, resumed.dense, "dense params diverged");
    assert_eq!(
        res_b.history[0].val_auc.to_bits(),
        res.history[0].val_auc.to_bits(),
        "val AUC diverged"
    );
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn mixed_checkpoint_save_resume_save_is_byte_identical() {
    let exp = mixed_tiny_exp();
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();
    let mut tr = Trainer::new(exp, n).unwrap();
    // a few real steps so packed rows, deltas and counters are non-trivial
    tr.train_stream(source.as_ref(), false, None).unwrap();
    let p1 = tmp("mixed_roundtrip.1.ckpt");
    let p2 = tmp("mixed_roundtrip.2.ckpt");
    tr.save_checkpoint(&p1).unwrap();
    let mut resumed = Trainer::resume(&p1).unwrap();
    resumed.save_checkpoint(&p2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "mixed save→resume→save changed bytes"
    );
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn mixed_criteo_plan_trains_and_serves_above_chance() {
    // the CI `--bits cat:4,num:8` leg in test form: 4-bit categorical
    // tables + 8-bit numeric tables over the committed fixture
    let path = criteo_fixture();
    if !path.exists() {
        eprintln!("skipping: no committed Criteo fixture");
        return;
    }
    let exp = Experiment {
        dataset: format!("criteo:{}", path.display()),
        model: "criteo".into(),
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::parse("cat:4,num:8").unwrap(),
        epochs: 2,
        patience: 0,
        use_runtime: false,
        threads: 1,
        hash_bits: 8,
        shuffle_window: 256,
        prefetch_batches: 2,
        wd_emb: 1e-5,
        ..Experiment::default()
    };
    let source = registry::open_source(&exp).unwrap();
    let n = source.schema().n_features();
    let mut trainer = Trainer::new(exp, n).unwrap();
    {
        let gs = trainer.store.as_grouped().unwrap();
        assert_eq!(gs.n_groups(), 2);
        assert_eq!(gs.group_bits(0), 4);
        assert_eq!(gs.group_bits(1), 8);
        // 26 categorical fields of 2^8 rows; 13 numeric of 40 buckets
        assert_eq!(gs.group_rows(0), 26 * 256);
        assert_eq!(gs.group_rows(1), 13 * 40);
    }
    let res = trainer.train_stream(source.as_ref(), false, None).unwrap();
    assert_eq!(res.epochs_run, 2);
    assert!(
        res.best_auc > 0.5,
        "mixed-plan held-out AUC at chance: {}",
        res.best_auc
    );

    let ckpt = tmp("mixed_criteo.ckpt");
    trainer.save_checkpoint(&ckpt).unwrap();
    let mut resumed = Trainer::resume(&ckpt).unwrap();
    let ev_a = trainer.evaluate_source(source.as_ref()).unwrap();
    let ev_b = resumed.evaluate_source(source.as_ref()).unwrap();
    assert_eq!(ev_a.auc.to_bits(), ev_b.auc.to_bits());

    let report = serve_checkpoint(&ckpt, 8).unwrap();
    assert_eq!(report.method, "ALPT(SR)[mixed]");
    assert_eq!(report.n_features, n);
    assert!(report.auc.is_finite());
    // the mixed table ships smaller than the uniform-8 one would
    let uniform8_bytes = n * 16 + n * 4; // 8-bit codes + f32 Δ per row
    assert!(
        report.infer_bytes < uniform8_bytes,
        "mixed table not smaller: {} vs {uniform8_bytes}",
        report.infer_bytes
    );
    std::fs::remove_file(&ckpt).ok();
}
