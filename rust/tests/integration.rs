//! Cross-layer integration tests: the AOT HLO artifacts (L1 Pallas + L2
//! JAX, compiled through PJRT) against the pure-Rust nn implementation on
//! identical inputs, and end-to-end training through the runtime.
//!
//! These tests require `make artifacts`; they skip (with a note) when the
//! manifest is absent so `cargo test` stays green on a fresh checkout.

use std::path::PathBuf;

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::Trainer;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::nn::Dcn;
use alpt::quant::{lsq_delta_grad_row, BitWidth};
use alpt::runtime::{lit_f32, lit_i32, lit_scalar, to_f32, to_scalar_f32,
                    Runtime};
use alpt::util::rng::Pcg32;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

struct Fixture {
    rt: Runtime,
    dcn: Dcn,
    umax: usize,
    d: usize,
    b: usize,
    f: usize,
    mmd: usize,
    emb: Vec<f32>,
    idx: Vec<i32>,
    labels: Vec<u8>,
    labels_f: Vec<f32>,
    params: Vec<f32>,
    mask: Vec<f32>,
}

fn fixture(seed: u64) -> Fixture {
    let rt = Runtime::load(&artifacts_dir()).unwrap();
    let entry = rt.entry("tiny").unwrap().clone();
    let (umax, d, b, f, mmd) = (entry.umax, entry.emb_dim, entry.batch,
                                entry.fields, entry.mlp_mask_dim);
    let mut rng = Pcg32::seeded(seed);
    let dcn = Dcn::new(entry.dcn_config());
    let params = entry.init_params(&mut rng);
    let emb: Vec<f32> =
        (0..umax * d).map(|_| rng.normal_scaled(0.0, 0.1)).collect();
    let idx: Vec<i32> =
        (0..b * f).map(|_| rng.below(umax as u32) as i32).collect();
    let labels: Vec<u8> = (0..b).map(|_| rng.bernoulli(0.3) as u8).collect();
    let labels_f: Vec<f32> = labels.iter().map(|&x| x as f32).collect();
    let mask = vec![1.0f32; b * mmd];
    Fixture { rt, dcn, umax, d, b, f, mmd, emb, idx, labels, labels_f,
              params, mask }
}

fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        let diff = (x - y).abs();
        if diff > worst {
            worst = diff;
        }
        assert!(
            diff <= tol,
            "{what}[{i}]: {x} vs {y} (diff {diff}, tol {tol})"
        );
    }
    eprintln!("  {what}: max |diff| = {worst:.3e} over {} elems", a.len());
}

/// The headline integration check: loss, logits, embedding grads and
/// dense-parameter grads from the PJRT-executed HLO must match the Rust
/// nn implementation on the same inputs.
#[test]
fn hlo_train_fp_matches_rust_nn() {
    require_artifacts!();
    let mut fx = fixture(11);
    let outs = fx
        .rt
        .exec(
            "tiny",
            "train_fp",
            &[
                lit_f32(&fx.emb, &[fx.umax as i64, fx.d as i64]).unwrap(),
                lit_i32(&fx.idx, &[fx.b as i64, fx.f as i64]).unwrap(),
                lit_f32(&fx.labels_f, &[fx.b as i64]).unwrap(),
                lit_f32(&fx.params, &[fx.params.len() as i64]).unwrap(),
                lit_f32(&fx.mask, &[fx.b as i64, fx.mmd as i64]).unwrap(),
            ],
        )
        .unwrap();
    let loss_hlo = to_scalar_f32(&outs[0]).unwrap();
    let logits_hlo = to_f32(&outs[1]).unwrap();
    let demb_hlo = to_f32(&outs[2]).unwrap();
    let dparams_hlo = to_f32(&outs[3]).unwrap();

    let out = fx.dcn.train_step(&fx.emb, &fx.idx, &fx.labels, &fx.params,
                                &fx.mask, fx.umax);
    assert!((loss_hlo - out.loss).abs() < 1e-5,
            "loss: {loss_hlo} vs {}", out.loss);
    assert_close(&logits_hlo, &out.logits, 1e-5, 1e-4, "logits");
    assert_close(&demb_hlo, &out.d_emb, 1e-6, 1e-3, "d_emb");
    assert_close(&dparams_hlo, &out.d_params, 1e-6, 2e-3, "d_params");
}

/// train_lpt = dequant-in-graph + train_fp: must agree with feeding the
/// dequantized rows to the Rust nn.
#[test]
fn hlo_train_lpt_matches_rust_nn_on_dequantized() {
    require_artifacts!();
    let mut fx = fixture(13);
    let mut rng = Pcg32::seeded(99);
    let codes: Vec<i32> =
        (0..fx.umax * fx.d).map(|_| rng.below(255) as i32 - 128).collect();
    let delta: Vec<f32> =
        (0..fx.umax).map(|_| rng.uniform_in(1e-3, 0.01)).collect();
    let emb_hat: Vec<f32> = (0..fx.umax * fx.d)
        .map(|i| codes[i] as f32 * delta[i / fx.d])
        .collect();

    let outs = fx
        .rt
        .exec(
            "tiny",
            "train_lpt",
            &[
                lit_i32(&codes, &[fx.umax as i64, fx.d as i64]).unwrap(),
                lit_f32(&delta, &[fx.umax as i64]).unwrap(),
                lit_i32(&fx.idx, &[fx.b as i64, fx.f as i64]).unwrap(),
                lit_f32(&fx.labels_f, &[fx.b as i64]).unwrap(),
                lit_f32(&fx.params, &[fx.params.len() as i64]).unwrap(),
                lit_f32(&fx.mask, &[fx.b as i64, fx.mmd as i64]).unwrap(),
            ],
        )
        .unwrap();
    let loss_hlo = to_scalar_f32(&outs[0]).unwrap();
    let demb_hlo = to_f32(&outs[2]).unwrap();

    let out = fx.dcn.train_step(&emb_hat, &fx.idx, &fx.labels, &fx.params,
                                &fx.mask, fx.umax);
    assert!((loss_hlo - out.loss).abs() < 1e-5);
    assert_close(&demb_hlo, &out.d_emb, 1e-6, 1e-3, "d_emb (lpt)");
}

/// train_fq's Δ gradient must equal the Rust Eq. 7 reduction applied to
/// the gradients at the fake-quantized weights.
#[test]
fn hlo_train_fq_delta_grads_match_eq7() {
    require_artifacts!();
    let mut fx = fixture(17);
    let mut rng = Pcg32::seeded(5);
    let delta: Vec<f32> =
        (0..fx.umax).map(|_| rng.uniform_in(2e-3, 8e-3)).collect();
    let bw = BitWidth::B8;
    let (qn, qp) = (bw.qn() as f32, bw.qp() as f32);

    let outs = fx
        .rt
        .exec(
            "tiny",
            "train_fq",
            &[
                lit_f32(&fx.emb, &[fx.umax as i64, fx.d as i64]).unwrap(),
                lit_f32(&delta, &[fx.umax as i64]).unwrap(),
                lit_i32(&fx.idx, &[fx.b as i64, fx.f as i64]).unwrap(),
                lit_f32(&fx.labels_f, &[fx.b as i64]).unwrap(),
                lit_f32(&fx.params, &[fx.params.len() as i64]).unwrap(),
                lit_f32(&fx.mask, &[fx.b as i64, fx.mmd as i64]).unwrap(),
                lit_scalar(qn),
                lit_scalar(qp),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 5);
    let ddelta_hlo = to_f32(&outs[3]).unwrap();

    // Rust replication: fake-quant forward, nn backward, Eq. 7 reduce.
    let mut emb_q = vec![0.0f32; fx.umax * fx.d];
    for i in 0..fx.umax {
        for j in 0..fx.d {
            let x = (fx.emb[i * fx.d + j] / delta[i]).clamp(qn, qp);
            emb_q[i * fx.d + j] = (x + 0.5).floor() * delta[i];
        }
    }
    let out = fx.dcn.train_step(&emb_q, &fx.idx, &fx.labels, &fx.params,
                                &fx.mask, fx.umax);
    let ddelta_rust: Vec<f32> = (0..fx.umax)
        .map(|i| {
            lsq_delta_grad_row(
                &fx.emb[i * fx.d..(i + 1) * fx.d],
                delta[i],
                bw,
                &out.d_emb[i * fx.d..(i + 1) * fx.d],
            )
        })
        .collect();
    assert_close(&ddelta_hlo, &ddelta_rust, 2e-6, 2e-3, "d_delta");
}

/// eval artifacts agree with the nn forward.
#[test]
fn hlo_eval_matches_rust_infer() {
    require_artifacts!();
    let mut fx = fixture(19);
    let outs = fx
        .rt
        .exec(
            "tiny",
            "eval_fp",
            &[
                lit_f32(&fx.emb, &[fx.umax as i64, fx.d as i64]).unwrap(),
                lit_i32(&fx.idx, &[fx.b as i64, fx.f as i64]).unwrap(),
                lit_f32(&fx.params, &[fx.params.len() as i64]).unwrap(),
            ],
        )
        .unwrap();
    let logits_hlo = to_f32(&outs[0]).unwrap();
    let logits_rust = fx.dcn.infer(&fx.emb, &fx.idx, &fx.params);
    assert_close(&logits_hlo, &logits_rust, 1e-5, 1e-4, "eval logits");
}

/// End-to-end: train tiny ALPT(SR) through the PJRT runtime and confirm
/// learning happens (loss falls, AUC beats random) and that the runtime
/// and nn paths land in the same ballpark.
#[test]
fn runtime_training_learns_and_matches_nn_path() {
    require_artifacts!();
    let spec = SyntheticSpec::tiny(21);
    let ds = generate(&spec, 6000);
    let (train, val, _) = ds.split((0.8, 0.1, 0.1), 3);

    let exp = |use_runtime: bool| Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        model: "tiny".into(),
        epochs: 2,
        use_runtime,
        lr_emb: 0.5,
        lr_delta: 1e-4,
        patience: 0,
        artifacts_dir: artifacts_dir().to_str().unwrap().to_string(),
        ..Experiment::default()
    };

    let mut tr_rt = Trainer::new(exp(true), ds.schema.n_features()).unwrap();
    assert!(tr_rt.uses_runtime());
    let res_rt = tr_rt.train(&train, &val, false).unwrap();
    eprintln!("runtime path: auc={:.4} logloss={:.5}", res_rt.best_auc,
              res_rt.best_logloss);
    assert!(res_rt.best_auc > 0.60, "auc={}", res_rt.best_auc);
    let h = &res_rt.history;
    assert!(h.last().unwrap().mean_loss < h.first().unwrap().mean_loss
            || h.len() == 1);

    let mut tr_nn = Trainer::new(exp(false), ds.schema.n_features()).unwrap();
    let res_nn = tr_nn.train(&train, &val, false).unwrap();
    eprintln!("nn path:      auc={:.4} logloss={:.5}", res_nn.best_auc,
              res_nn.best_logloss);
    // same data, same seeds, SR noise differs only through execution
    // rounding: the two paths must agree to training noise
    assert!((res_rt.best_auc - res_nn.best_auc).abs() < 0.03,
            "paths diverged: {} vs {}", res_rt.best_auc, res_nn.best_auc);
}

/// FP through the runtime should comfortably beat heavily-quantized 2-bit
/// LPT(DR) — the qualitative Table 1 / Table 2 ordering.
#[test]
fn runtime_fp_beats_2bit_lpt_dr() {
    require_artifacts!();
    let spec = SyntheticSpec::tiny(23);
    let ds = generate(&spec, 6000);
    let (train, val, _) = ds.split((0.8, 0.1, 0.1), 3);
    let base = Experiment {
        model: "tiny".into(),
        epochs: 2,
        lr_emb: 0.5,
        patience: 0,
        artifacts_dir: artifacts_dir().to_str().unwrap().to_string(),
        ..Experiment::default()
    };
    let mut fp = Trainer::new(
        Experiment { method: Method::Fp, ..base.clone() },
        ds.schema.n_features(),
    )
    .unwrap();
    let r_fp = fp.train(&train, &val, false).unwrap();
    let mut lpt = Trainer::new(
        Experiment {
            method: Method::Lpt(RoundingMode::Dr),
            bits: PrecisionPlan::uniform(2),
            clip: 0.1,
            ..base
        },
        ds.schema.n_features(),
    )
    .unwrap();
    let r_lpt = lpt.train(&train, &val, false).unwrap();
    eprintln!("fp auc={:.4}  lpt2(dr) auc={:.4}", r_fp.best_auc,
              r_lpt.best_auc);
    assert!(r_fp.best_auc > r_lpt.best_auc,
            "expected FP > 2-bit LPT(DR): {} vs {}", r_fp.best_auc,
            r_lpt.best_auc);
}
