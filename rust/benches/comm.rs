//! Communication bench — the paper's §1 motivation quantified: per-epoch
//! leader↔worker traffic of a sharded embedding table, by method and bit
//! width, plus the analytical cost model cross-checked against measured
//! bytes from the real RPC frame encoder (`coordinator::net`) and
//! sharded-gather scaling over the real row partition.

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::net::{self, GatherReq, GatherResp, Op, UpdateReq};
use alpt::coordinator::sharding::step_comm;
use alpt::coordinator::{
    run_worker, CommStats, RowPartition, RpcConfig, WorkerHub, WorkerOpts,
};
use alpt::data::batcher::{Batch, Batcher};
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::embedding::{
    build_store, EmbeddingStore, Persistable, RemoteStore, UpdateHp,
};
use alpt::quant::BitWidth;
use alpt::util::bench::{fmt_rate, Bencher};
use alpt::util::json::Json;
use alpt::util::rng::Pcg32;
use anyhow::Result;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn alpt8_exp() -> Experiment {
    Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        use_runtime: false,
        threads: 1,
        ..Experiment::default()
    }
}

fn main() {
    let quick =
        std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1");
    let n_samples = if quick { 20_000 } else { 100_000 };
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, n_samples);
    let dim = 16;
    println!(
        "=== comm: avazu-syn, {} samples, {} features, d={dim}, B=256 ===",
        ds.n_samples(),
        ds.schema.n_features()
    );

    // traffic per epoch by method (analytical model)
    println!("\nper-epoch traffic (embedding rows down, f32 grads up):");
    println!(
        "  {:<12} {:>5} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "method", "bits", "down MB", "up MB", "total MB", "@10Gbps",
        "vs FP"
    );
    let mut fp_total = 0u64;
    for (method, bits) in [
        (Method::Fp, 32u32),
        (Method::Lsq, 8),
        (Method::Lpt(RoundingMode::Sr), 16),
        (Method::Lpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 4),
        (Method::Alpt(RoundingMode::Sr), 2),
    ] {
        let mut total = CommStats::default();
        for b in Batcher::new(&ds, 256, Some(1), true) {
            total.add(&step_comm(method, bits, dim, &b));
        }
        if method == Method::Fp {
            fp_total = total.total_bytes();
        }
        println!(
            "  {:<12} {:>5} {:>10.1} {:>10.1} {:>10.1} {:>8.2}s {:>8.2}x",
            method.name(),
            bits,
            total.bytes_down as f64 / 1e6,
            total.bytes_up as f64 / 1e6,
            total.total_bytes() as f64 / 1e6,
            total.seconds_at(10.0),
            fp_total as f64 / total.total_bytes() as f64
        );
    }

    // the model vs the wire: encode the real GATHER/UPDATE frames the
    // distributed path would send for each batch and count their bytes
    println!(
        "\nmodel vs measured wire bytes (ALPT 8-bit, 4 shards, real \
         frames incl. 16B header+CRC per frame):"
    );
    let exp = alpt8_exp();
    let n = ds.schema.n_features();
    let mut rng = Pcg32::seeded(7);
    let store = build_store(&exp, n, dim, &mut rng).expect("store");
    let row_bytes =
        store.ckpt_row_bytes().expect("packed store") as u32;
    let part = RowPartition::new(n, 4);
    let batches: Vec<_> = Batcher::new(&ds, 256, Some(1), true)
        .take(if quick { 50 } else { 200 })
        .collect();
    let mut model = CommStats::default();
    let mut measured = 0u64;
    let mut frames = 0u64;
    let mut rowbuf = vec![0u8; row_bytes as usize];
    for b in &batches {
        model.add(&step_comm(exp.method, 8, dim, b));
        for (_, globals) in part.split(&b.unique) {
            if globals.is_empty() {
                continue;
            }
            let k = globals.len();
            // coordinator -> worker: which rows
            let req = GatherReq { aux_only: false, ids: globals.clone() };
            measured +=
                net::encode_frame(Op::Gather, 0, 0, &req.encode()).len()
                    as u64;
            // worker -> coordinator: packed rows + Δ aux
            let mut rows = Vec::with_capacity(k * row_bytes as usize);
            for &g in &globals {
                store
                    .save_rows(g as usize, &mut rowbuf)
                    .expect("row payload");
                rows.extend_from_slice(&rowbuf);
            }
            let resp =
                GatherResp { row_bytes, rows, aux: vec![0.01; k] };
            measured += net::encode_frame(
                Op::Gather,
                net::FLAG_RESPONSE,
                0,
                &resp.encode(),
            )
            .len() as u64;
            // coordinator -> worker: f32 grads + dΔ; worker acks empty
            let upd = UpdateReq {
                step: 0,
                draw: 0,
                hp: [0.0; 6],
                ids: globals,
                grads: vec![0.0; k * dim],
                d_delta: vec![0.0; k],
            };
            measured +=
                net::encode_frame(Op::Update, 0, 0, &upd.encode()).len()
                    as u64;
            measured += net::encode_frame(
                Op::Update,
                net::FLAG_RESPONSE,
                0,
                &[],
            )
            .len() as u64;
            frames += 4;
        }
    }
    println!(
        "  {} steps, {} rows: model {:.2} MB, wire {:.2} MB over {} \
         frames (+{:.1}% framing/ids overhead)",
        model.steps,
        model.rows_moved,
        model.total_bytes() as f64 / 1e6,
        measured as f64 / 1e6,
        frames,
        100.0 * (measured as f64 / model.total_bytes() as f64 - 1.0)
    );

    // sharded gather scaling over the real partition: per-shard stores,
    // split the batch, gather locals, scatter into batch positions
    println!("\nsharded gather throughput (ALPT-8bit shards, in-process):");
    for workers in [1usize, 2, 4, 8] {
        let part = RowPartition::new(n, workers);
        let shards: Vec<_> = (0..workers)
            .map(|s| {
                let mut rng = Pcg32::seeded(100 + s as u64);
                build_store(&exp, part.shard_rows(s).max(1), dim, &mut rng)
                    .expect("shard store")
            })
            .collect();
        let mut out = vec![0.0f32; 256 * 24 * dim];
        let mut scratch = vec![0.0f32; 256 * 24 * dim];
        let t0 = Instant::now();
        let mut rows = 0u64;
        for b in &batches {
            let out = &mut out[..b.unique.len() * dim];
            for (s, (positions, globals)) in
                part.split(&b.unique).into_iter().enumerate()
            {
                if globals.is_empty() {
                    continue;
                }
                let locals: Vec<u32> =
                    globals.iter().map(|&g| part.local_of(g)).collect();
                let scratch = &mut scratch[..locals.len() * dim];
                shards[s].gather(&locals, scratch);
                for (k, &pos) in positions.iter().enumerate() {
                    out[pos * dim..(pos + 1) * dim]
                        .copy_from_slice(&scratch[k * dim..(k + 1) * dim]);
                }
            }
            rows += b.unique.len() as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {workers} workers: {rows} rows in {:>7.1} ms  ({})",
            dt * 1e3,
            fmt_rate(rows as f64 / dt)
        );
    }
    // the tentpole measured end to end: real run_worker shards over
    // loopback TCP, driven through RemoteStore in its three schedules —
    // serial (one blocking round trip per shard in turn), fan-out
    // (parallel shard round trips, wall-clock = max over shards), and
    // pipelined (fan-out + the next batch's GATHER sent right behind
    // this batch's UPDATE frames). Same math in all three; only the
    // wire schedule changes.
    println!(
        "\ndistributed RPC gather+update over loopback (ALPT-8bit, \
         B=256):"
    );
    let rpc_batches =
        &batches[..batches.len().min(if quick { 20 } else { 60 })];
    let total_rows: f64 =
        rpc_batches.iter().map(|b| b.unique.len() as f64).sum();
    let max_k = rpc_batches
        .iter()
        .map(|b| b.unique.len())
        .max()
        .unwrap_or(0)
        * dim;
    let mut out = vec![0.0f32; max_k];
    let grads: Vec<f32> = (0..max_k)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.002)
        .collect();
    let hp = UpdateHp {
        lr_emb: 0.05,
        wd_emb: 0.0,
        lr_delta: 1e-4,
        wd_delta: 0.0,
        grad_scale: 1.0,
        lr_scale: 1.0,
    };
    let mut b = Bencher {
        warmup: Duration::from_millis(if quick { 0 } else { 50 }),
        target: Duration::from_millis(if quick { 1 } else { 400 }),
        samples: if quick { 1 } else { 8 },
        rows: Vec::new(),
    };
    for workers in [1usize, 2, 4] {
        let (mut store, handles) =
            attach_loopback(&exp, n, dim, workers);
        let mut rng = Pcg32::seeded(9 + workers as u64);
        for (cfg_name, fan, overlap) in [
            ("serial", false, false),
            ("fan-out", true, false),
            ("pipelined", true, true),
        ] {
            store.set_fan_out(fan);
            store.set_overlap(overlap);
            let name =
                format!("RPC gather+update {cfg_name} {workers}sh");
            b.bench_units(&name, Some(total_rows), || {
                rpc_pass(
                    &mut store,
                    rpc_batches,
                    dim,
                    overlap,
                    &hp,
                    &mut rng,
                    &mut out,
                    &grads,
                );
            });
        }
        store.shutdown().expect("worker shutdown");
        drop(store);
        for h in handles {
            h.join().expect("worker thread").expect("worker exit");
        }
    }
    merge_micro_report(&b, quick);

    println!(
        "\nshape check (paper §1/§2.3): traffic scales with the bit width \
         — 8-bit ALPT cuts total bytes ~2.4x vs FP (uplink stays f32), \
         the downlink alone shrinks ~3.2x at d=16, and real framing adds \
         only a few percent on top of the model."
    );
}

/// Bind a port-0 hub, spawn `workers` live `run_worker` serve loops
/// against it, and attach a [`RemoteStore`] seeded from a fresh local
/// table.
fn attach_loopback(
    exp: &Experiment,
    n: usize,
    dim: usize,
    workers: usize,
) -> (RemoteStore, Vec<JoinHandle<Result<()>>>) {
    let mut rng = Pcg32::seeded(42);
    let local = build_store(exp, n, dim, &mut rng).expect("local store");
    let cfg = RpcConfig {
        timeout_ms: 60_000,
        accept_timeout_ms: 60_000,
        ..RpcConfig::default()
    };
    let hub = WorkerHub::bind("127.0.0.1:0", cfg).expect("bind hub");
    let addr = hub.local_addr().expect("hub addr").to_string();
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let opts = WorkerOpts {
                connect: addr.clone(),
                idle_timeout_ms: 60_000,
                connect_retries: 200,
                retry_delay_ms: 25,
                ..WorkerOpts::default()
            };
            std::thread::spawn(move || run_worker(&opts))
        })
        .collect();
    let store = RemoteStore::attach(local.as_ref(), exp, hub, workers)
        .expect("attach workers");
    (store, handles)
}

/// One training-shaped pass: gather + update per batch, with the
/// batch-ahead GATHER issued behind the UPDATE frames when `pipelined`.
/// Ends on an epoch barrier so the timing covers full completion of
/// every in-flight frame, not just the sends.
#[allow(clippy::too_many_arguments)]
fn rpc_pass(
    store: &mut RemoteStore,
    batches: &[Batch],
    dim: usize,
    pipelined: bool,
    hp: &UpdateHp,
    rng: &mut Pcg32,
    out: &mut [f32],
    grads: &[f32],
) {
    let mut zero_sp = |_w: &[f32],
                       dl: &[f32],
                       _: &[BitWidth]|
     -> Result<Vec<f32>> { Ok(vec![0.0f32; dl.len()]) };
    for (i, batch) in batches.iter().enumerate() {
        let k = batch.unique.len() * dim;
        store.gather(&batch.unique, &mut out[..k]);
        store
            .update(&batch.unique, &out[..k], &grads[..k], hp, rng,
                    &mut zero_sp)
            .expect("rpc update");
        if pipelined {
            if let Some(next) = batches.get(i + 1) {
                store.prefetch_ids(&next.unique);
            }
        }
    }
    store.barrier().expect("drain barrier");
}

/// Merge this bench's rows into `BENCH_micro.json` without disturbing
/// the micro bench's rows (`scripts/bench_smoke.sh` asserts on those):
/// read the existing report if present, drop any stale `RPC
/// gather+update` rows, append the fresh ones, and rewrite the
/// document. Run `cargo bench --bench micro` first for a full report.
fn merge_micro_report(b: &Bencher, quick: bool) {
    let path = std::path::Path::new("BENCH_micro.json");
    let fresh = match b.to_json() {
        Json::Array(rows) => rows,
        _ => unreachable!("to_json returns an array"),
    };
    let prior = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok());
    let mut kept: Vec<Json> = prior
        .as_ref()
        .and_then(|doc| doc.get("benchmarks").ok())
        .and_then(|rows| rows.as_array().ok())
        .map(|rows| {
            rows.iter()
                .filter(|row| {
                    row.get("name")
                        .ok()
                        .and_then(|n| n.as_str().ok())
                        .map(|n| !n.starts_with("RPC gather+update"))
                        .unwrap_or(true)
                })
                .cloned()
                .collect()
        })
        .unwrap_or_default();
    let n_kept = kept.len();
    kept.extend(fresh);
    let meta = prior
        .as_ref()
        .and_then(|doc| doc.get("meta").ok())
        .cloned()
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("bench", Json::str("comm")),
                ("quick", Json::Bool(quick)),
            ])
        });
    let doc = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("meta", meta),
        ("benchmarks", Json::Array(kept)),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!(
            "\n[merged {} RPC rows into BENCH_micro.json alongside {} \
             existing rows]",
            b.rows.len(),
            n_kept
        ),
        Err(e) => {
            eprintln!("failed to write BENCH_micro.json: {e}");
            std::process::exit(1);
        }
    }
}
