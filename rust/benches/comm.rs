//! Communication bench — the paper's §1 motivation quantified: per-epoch
//! leader↔worker traffic of a sharded embedding table, by method and bit
//! width, plus the analytical cost model cross-checked against measured
//! bytes from the real RPC frame encoder (`coordinator::net`) and
//! sharded-gather scaling over the real row partition.

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::net::{self, GatherReq, GatherResp, Op, UpdateReq};
use alpt::coordinator::sharding::step_comm;
use alpt::coordinator::{CommStats, RowPartition};
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::embedding::{build_store, EmbeddingStore, Persistable};
use alpt::util::bench::fmt_rate;
use alpt::util::rng::Pcg32;
use std::time::Instant;

fn alpt8_exp() -> Experiment {
    Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        use_runtime: false,
        threads: 1,
        ..Experiment::default()
    }
}

fn main() {
    let quick =
        std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1");
    let n_samples = if quick { 20_000 } else { 100_000 };
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, n_samples);
    let dim = 16;
    println!(
        "=== comm: avazu-syn, {} samples, {} features, d={dim}, B=256 ===",
        ds.n_samples(),
        ds.schema.n_features()
    );

    // traffic per epoch by method (analytical model)
    println!("\nper-epoch traffic (embedding rows down, f32 grads up):");
    println!(
        "  {:<12} {:>5} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "method", "bits", "down MB", "up MB", "total MB", "@10Gbps",
        "vs FP"
    );
    let mut fp_total = 0u64;
    for (method, bits) in [
        (Method::Fp, 32u32),
        (Method::Lsq, 8),
        (Method::Lpt(RoundingMode::Sr), 16),
        (Method::Lpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 4),
        (Method::Alpt(RoundingMode::Sr), 2),
    ] {
        let mut total = CommStats::default();
        for b in Batcher::new(&ds, 256, Some(1), true) {
            total.add(&step_comm(method, bits, dim, &b));
        }
        if method == Method::Fp {
            fp_total = total.total_bytes();
        }
        println!(
            "  {:<12} {:>5} {:>10.1} {:>10.1} {:>10.1} {:>8.2}s {:>8.2}x",
            method.name(),
            bits,
            total.bytes_down as f64 / 1e6,
            total.bytes_up as f64 / 1e6,
            total.total_bytes() as f64 / 1e6,
            total.seconds_at(10.0),
            fp_total as f64 / total.total_bytes() as f64
        );
    }

    // the model vs the wire: encode the real GATHER/UPDATE frames the
    // distributed path would send for each batch and count their bytes
    println!(
        "\nmodel vs measured wire bytes (ALPT 8-bit, 4 shards, real \
         frames incl. 16B header+CRC per frame):"
    );
    let exp = alpt8_exp();
    let n = ds.schema.n_features();
    let mut rng = Pcg32::seeded(7);
    let store = build_store(&exp, n, dim, &mut rng).expect("store");
    let row_bytes =
        store.ckpt_row_bytes().expect("packed store") as u32;
    let part = RowPartition::new(n, 4);
    let batches: Vec<_> = Batcher::new(&ds, 256, Some(1), true)
        .take(if quick { 50 } else { 200 })
        .collect();
    let mut model = CommStats::default();
    let mut measured = 0u64;
    let mut frames = 0u64;
    let mut rowbuf = vec![0u8; row_bytes as usize];
    for b in &batches {
        model.add(&step_comm(exp.method, 8, dim, b));
        for (_, globals) in part.split(&b.unique) {
            if globals.is_empty() {
                continue;
            }
            let k = globals.len();
            // coordinator -> worker: which rows
            let req = GatherReq { aux_only: false, ids: globals.clone() };
            measured +=
                net::encode_frame(Op::Gather, 0, 0, &req.encode()).len()
                    as u64;
            // worker -> coordinator: packed rows + Δ aux
            let mut rows = Vec::with_capacity(k * row_bytes as usize);
            for &g in &globals {
                store
                    .save_rows(g as usize, &mut rowbuf)
                    .expect("row payload");
                rows.extend_from_slice(&rowbuf);
            }
            let resp =
                GatherResp { row_bytes, rows, aux: vec![0.01; k] };
            measured += net::encode_frame(
                Op::Gather,
                net::FLAG_RESPONSE,
                0,
                &resp.encode(),
            )
            .len() as u64;
            // coordinator -> worker: f32 grads + dΔ; worker acks empty
            let upd = UpdateReq {
                step: 0,
                draw: 0,
                hp: [0.0; 6],
                ids: globals,
                grads: vec![0.0; k * dim],
                d_delta: vec![0.0; k],
            };
            measured +=
                net::encode_frame(Op::Update, 0, 0, &upd.encode()).len()
                    as u64;
            measured += net::encode_frame(
                Op::Update,
                net::FLAG_RESPONSE,
                0,
                &[],
            )
            .len() as u64;
            frames += 4;
        }
    }
    println!(
        "  {} steps, {} rows: model {:.2} MB, wire {:.2} MB over {} \
         frames (+{:.1}% framing/ids overhead)",
        model.steps,
        model.rows_moved,
        model.total_bytes() as f64 / 1e6,
        measured as f64 / 1e6,
        frames,
        100.0 * (measured as f64 / model.total_bytes() as f64 - 1.0)
    );

    // sharded gather scaling over the real partition: per-shard stores,
    // split the batch, gather locals, scatter into batch positions
    println!("\nsharded gather throughput (ALPT-8bit shards, in-process):");
    for workers in [1usize, 2, 4, 8] {
        let part = RowPartition::new(n, workers);
        let shards: Vec<_> = (0..workers)
            .map(|s| {
                let mut rng = Pcg32::seeded(100 + s as u64);
                build_store(&exp, part.shard_rows(s).max(1), dim, &mut rng)
                    .expect("shard store")
            })
            .collect();
        let mut out = vec![0.0f32; 256 * 24 * dim];
        let mut scratch = vec![0.0f32; 256 * 24 * dim];
        let t0 = Instant::now();
        let mut rows = 0u64;
        for b in &batches {
            let out = &mut out[..b.unique.len() * dim];
            for (s, (positions, globals)) in
                part.split(&b.unique).into_iter().enumerate()
            {
                if globals.is_empty() {
                    continue;
                }
                let locals: Vec<u32> =
                    globals.iter().map(|&g| part.local_of(g)).collect();
                let scratch = &mut scratch[..locals.len() * dim];
                shards[s].gather(&locals, scratch);
                for (k, &pos) in positions.iter().enumerate() {
                    out[pos * dim..(pos + 1) * dim]
                        .copy_from_slice(&scratch[k * dim..(k + 1) * dim]);
                }
            }
            rows += b.unique.len() as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {workers} workers: {rows} rows in {:>7.1} ms  ({})",
            dt * 1e3,
            fmt_rate(rows as f64 / dt)
        );
    }
    println!(
        "\nshape check (paper §1/§2.3): traffic scales with the bit width \
         — 8-bit ALPT cuts total bytes ~2.4x vs FP (uplink stays f32), \
         the downlink alone shrinks ~3.2x at d=16, and real framing adds \
         only a few percent on top of the model."
    );
}
