//! Communication bench — the paper's §1 motivation quantified: per-epoch
//! leader↔worker traffic of a sharded embedding table, by method and bit
//! width, plus parallel sharded-gather scaling.

use alpt::config::{Experiment, Method, PrecisionPlan, RoundingMode};
use alpt::coordinator::sharding::{step_comm, ShardedStore};
use alpt::coordinator::CommStats;
use alpt::data::batcher::Batcher;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::util::bench::fmt_rate;
use std::time::Instant;

fn main() {
    let quick =
        std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1");
    let n_samples = if quick { 20_000 } else { 100_000 };
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, n_samples);
    let dim = 16;
    println!(
        "=== comm: avazu-syn, {} samples, {} features, d={dim}, B=256 ===",
        ds.n_samples(),
        ds.schema.n_features()
    );

    // traffic per epoch by method
    println!("\nper-epoch traffic (embedding rows down, f32 grads up):");
    println!(
        "  {:<12} {:>5} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "method", "bits", "down MB", "up MB", "total MB", "@10Gbps",
        "vs FP"
    );
    let mut fp_total = 0u64;
    for (method, bits) in [
        (Method::Fp, 32u32),
        (Method::Lsq, 8),
        (Method::Lpt(RoundingMode::Sr), 16),
        (Method::Lpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 8),
        (Method::Alpt(RoundingMode::Sr), 4),
        (Method::Alpt(RoundingMode::Sr), 2),
    ] {
        let mut total = CommStats::default();
        for b in Batcher::new(&ds, 256, Some(1), true) {
            total.add(&step_comm(method, bits, dim, &b));
        }
        if method == Method::Fp {
            fp_total = total.total_bytes();
        }
        println!(
            "  {:<12} {:>5} {:>10.1} {:>10.1} {:>10.1} {:>8.2}s {:>8.2}x",
            method.name(),
            bits,
            total.bytes_down as f64 / 1e6,
            total.bytes_up as f64 / 1e6,
            total.total_bytes() as f64 / 1e6,
            total.seconds_at(10.0),
            fp_total as f64 / total.total_bytes() as f64
        );
    }

    // parallel gather scaling over worker counts
    println!("\nsharded parallel gather throughput (ALPT-8bit shards):");
    let exp = Experiment {
        method: Method::Alpt(RoundingMode::Sr),
        bits: PrecisionPlan::uniform(8),
        use_runtime: false,
        ..Experiment::default()
    };
    let batches: Vec<_> = Batcher::new(&ds, 256, Some(1), true)
        .take(if quick { 50 } else { 200 })
        .collect();
    for workers in [1usize, 2, 4, 8] {
        let mut sharded =
            ShardedStore::new(&exp, ds.schema.n_features(), dim, workers)
                .expect("shards");
        let mut out = vec![0.0f32; 256 * 24 * dim];
        let t0 = Instant::now();
        let mut rows = 0u64;
        for b in &batches {
            sharded.gather(&b.unique, &mut out[..b.unique.len() * dim]);
            rows += b.unique.len() as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {workers} workers: {rows} rows in {:>7.1} ms  ({})",
            dt * 1e3,
            fmt_rate(rows as f64 / dt)
        );
    }
    println!(
        "\nshape check (paper §1/§2.3): traffic scales with the bit width \
         — 8-bit ALPT cuts total bytes ~2.4x vs FP (uplink stays f32), \
         and the downlink alone shrinks ~3.2x at d=16."
    );
}
