//! Hot-path microbenchmarks (§Perf): table gather/dequant by bit width,
//! SR/DR quantization, batch dedup, AUC, the Rust-nn training step, and
//! PJRT artifact execution latency.
//!
//! Output feeds EXPERIMENTS.md §Perf; JSON mirror in results/micro.json.

use alpt::config::{Experiment, Method, RoundingMode};
use alpt::coordinator::Trainer;
use alpt::data::batcher::{make_batch, Batcher};
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::embedding::{AlptStore, EmbeddingStore, FpStore, LptStore};
use alpt::nn::{Dcn, DcnConfig};
use alpt::quant::{quantize_row, BitWidth, PackedTable, Rounding};
use alpt::util::bench::{section, Bencher};
use alpt::util::rng::Pcg32;

fn main() {
    let quick =
        std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut b = if quick {
        let mut b = Bencher::new();
        b.target = std::time::Duration::from_millis(200);
        b.samples = 5;
        b
    } else {
        Bencher::new()
    };
    let mut rng = Pcg32::seeded(1);

    // ------------------------------------------------ packed table access
    section("packed table: read_row_dequant (rows/s), d=16");
    let d = 16;
    let n = 100_000;
    for bits in [2u32, 4, 8, 16] {
        let bw = BitWidth::from_bits(bits).unwrap();
        let mut t = PackedTable::new(n, d, bw);
        let mut codes = vec![0i32; d];
        for r in 0..n {
            for (j, c) in codes.iter_mut().enumerate() {
                *c = (((r * 31 + j * 7) % 255) as i32) - 128;
                *c = (*c).clamp(bw.qn(), bw.qp());
            }
            t.write_row(r, &codes);
        }
        let mut out = vec![0.0f32; d];
        let mut row = 0usize;
        b.bench_units(&format!("dequant row {bits}-bit"), Some(1.0), || {
            row = (row + 97) % n;
            t.read_row_dequant(row, 0.01, &mut out);
            std::hint::black_box(&out);
        });
    }

    // ------------------------------------------------------- quantization
    section("quantize rows (elems/s), d=16");
    let w: Vec<f32> = (0..d).map(|i| (i as f32 - 8.0) * 0.003).collect();
    let mut codes = vec![0i32; d];
    for (name, rounding) in [("DR", Rounding::Deterministic),
                             ("SR", Rounding::Stochastic)] {
        b.bench_units(&format!("quantize_row 8-bit {name}"),
                      Some(d as f64), || {
            quantize_row(&w, 0.01, BitWidth::B8, rounding, &mut rng,
                         &mut codes);
            std::hint::black_box(&codes);
        });
    }

    // --------------------------------------------------- store gathers
    section("store gather: 144 unique rows x d=16 (rows/s)");
    let ids: Vec<u32> = (0..144u32).map(|i| i * 613 % 100_000).collect();
    let mut out = vec![0.0f32; ids.len() * d];
    let mut rng2 = Pcg32::seeded(2);
    let fp = FpStore::init(n, d, &mut rng2);
    b.bench_units("FP gather", Some(ids.len() as f64), || {
        fp.gather(&ids, &mut out);
        std::hint::black_box(&out);
    });
    let lpt = LptStore::init(n, d, BitWidth::B8, 0.1, Rounding::Stochastic,
                             &mut rng2);
    b.bench_units("LPT-8bit gather (unpack+dequant)",
                  Some(ids.len() as f64), || {
        lpt.gather(&ids, &mut out);
        std::hint::black_box(&out);
    });
    let alpt_store =
        AlptStore::init(n, d, BitWidth::B2, Rounding::Stochastic, &mut rng2);
    b.bench_units("ALPT-2bit gather (unpack+dequant)",
                  Some(ids.len() as f64), || {
        alpt_store.gather(&ids, &mut out);
        std::hint::black_box(&out);
    });

    // ------------------------------------------------------------- dedup
    section("batch dedup (samples/s), avazu-syn B=256");
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, 10_000);
    let rows: Vec<usize> = (0..256).collect();
    b.bench_units("make_batch B=256 F=24", Some(256.0), || {
        let batch = make_batch(&ds, &rows, 256);
        std::hint::black_box(batch.n_unique());
    });

    // --------------------------------------------------------------- auc
    section("metrics (elems/s)");
    let mut rng3 = Pcg32::seeded(3);
    let scores: Vec<f32> = (0..100_000).map(|_| rng3.uniform_f32()).collect();
    let labels: Vec<u8> =
        (0..100_000).map(|_| rng3.bernoulli(0.2) as u8).collect();
    b.bench_units("auc n=100k", Some(100_000.0), || {
        std::hint::black_box(alpt::metrics::auc(&scores, &labels));
    });

    // --------------------------------------------------- rust-nn step
    section("rust-nn DCN train step (tiny geometry)");
    let cfg = DcnConfig::tiny();
    let dcn = Dcn::new(cfg.clone());
    let mut rng4 = Pcg32::seeded(4);
    let params = cfg.init_params(&mut rng4);
    let umax = cfg.batch * cfg.fields;
    let emb: Vec<f32> =
        (0..umax * cfg.emb_dim).map(|_| rng4.normal_scaled(0.0, 0.1)).collect();
    let idx: Vec<i32> = (0..cfg.batch * cfg.fields)
        .map(|_| rng4.below(umax as u32) as i32)
        .collect();
    let labels4: Vec<u8> =
        (0..cfg.batch).map(|_| rng4.bernoulli(0.3) as u8).collect();
    let mask = vec![1.0f32; cfg.batch * cfg.mlp_mask_dim()];
    b.bench_units("nn train_step tiny (samples/s)",
                  Some(cfg.batch as f64), || {
        let o = dcn.train_step(&emb, &idx, &labels4, &params, &mask, umax);
        std::hint::black_box(o.loss);
    });

    // --------------------------------------------- PJRT step latency
    let have_artifacts =
        std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        section("full coordinator step through PJRT (tiny, samples/s)");
        let spec = SyntheticSpec::tiny(5);
        let tiny_ds = generate(&spec, 4_000);
        for (method, label) in [
            (Method::Fp, "step FP (train_fp)"),
            (Method::Lpt(RoundingMode::Sr), "step LPT-SR (train_lpt)"),
            (Method::Alpt(RoundingMode::Sr),
             "step ALPT-SR (train_lpt + train_fq)"),
        ] {
            let exp = Experiment {
                method,
                model: "tiny".into(),
                use_runtime: true,
                ..Experiment::default()
            };
            let mut tr = Trainer::new(exp, tiny_ds.schema.n_features())
                .expect("trainer");
            let batches: Vec<_> =
                Batcher::new(&tiny_ds, tr.entry.batch, Some(1), true)
                    .take(8)
                    .collect();
            let mut i = 0;
            let bsz = tr.entry.batch as f64;
            b.bench_units(label, Some(bsz), || {
                let batch = &batches[i % batches.len()];
                i += 1;
                let o = tr.step(batch, 1).expect("step");
                std::hint::black_box(o.loss);
            });
        }
        section("eval step through PJRT (tiny)");
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            model: "tiny".into(),
            use_runtime: true,
            ..Experiment::default()
        };
        let mut tr =
            Trainer::new(exp, tiny_ds.schema.n_features()).expect("trainer");
        let (_, val, _) = tiny_ds.split((0.8, 0.1, 0.1), 1);
        b.bench_units("evaluate 400 samples (eval_lpt)", Some(400.0), || {
            let ev = tr.evaluate(&val).expect("eval");
            std::hint::black_box(ev.auc);
        });
    } else {
        eprintln!("(skipping PJRT benches: run `make artifacts`)");
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/micro.json", b.to_json().to_string()).ok();
    println!("\n[saved results/micro.json]");
}
