//! Hot-path microbenchmarks (§Perf): packed-table row ops (word-at-a-time
//! unpack, fused quantize→pack), the SIMD kernel matrix (every available
//! kernel vs the scalar oracle, with bit-identity asserted in-loop),
//! counter-RNG stream throughput, serial vs sharded store gather/update
//! at every bit width, the budget planner, batch dedup, AUC, the Rust-nn
//! training step, and PJRT artifact execution latency.
//!
//! Output feeds ROADMAP.md §Performance; machine-readable mirror in
//! `BENCH_micro.json` at the repo root (cross-PR perf trajectory) plus
//! the legacy `results/micro.json`. Quick mode: `ALPT_BENCH_QUICK=1`.

use alpt::config::{
    Experiment, FieldKind, Method, PrecisionPlan, RoundingMode,
};
use alpt::coordinator::Trainer;
use alpt::data::batcher::{make_batch, Batcher};
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::data::Schema;
use alpt::embedding::{
    AlptStore, EmbeddingStore, FpStore, GroupedStore, LptStore, UpdateHp,
};
use alpt::nn::{Dcn, DcnConfig};
use alpt::quant::{kernels, quantize_row, BitWidth, PackedTable, Rounding};
use alpt::util::bench::{section, Bencher};
use alpt::util::json::Json;
use alpt::util::rng::{Pcg32, StreamKey};
use alpt::util::threadpool::default_threads;
use anyhow::Result;

const ALL_BITS: [u32; 4] = [2, 4, 8, 16];

fn bench_hp() -> UpdateHp {
    UpdateHp {
        lr_emb: 0.05,
        wd_emb: 1e-6,
        lr_delta: 1e-4,
        wd_delta: 1e-6,
        grad_scale: 1.0,
        lr_scale: 1.0,
    }
}

fn main() {
    let quick =
        std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut b = if quick {
        let mut b = Bencher::new();
        b.target = std::time::Duration::from_millis(200);
        b.samples = 5;
        b
    } else {
        Bencher::new()
    };
    let mut rng = Pcg32::seeded(1);
    let n_threads = default_threads();

    // ------------------------------------------------------- counter rng
    section("counter-based RNG streams (draws/s)");
    {
        let draws_per_row = 16usize;
        let mut acc = 0u32;
        let mut seq = Pcg32::seeded(7);
        b.bench_units("sequential Pcg32 16 draws",
                      Some(draws_per_row as f64), || {
            for _ in 0..draws_per_row {
                acc = acc.wrapping_add(seq.next_u32());
            }
            std::hint::black_box(acc);
        });
        let key = StreamKey::for_step(7, 3);
        let mut row = 0u64;
        b.bench_units("stream_for row setup + 16 draws",
                      Some(draws_per_row as f64), || {
            row = row.wrapping_add(1);
            let mut r = key.row_rng(row);
            for _ in 0..draws_per_row {
                acc = acc.wrapping_add(r.next_u32());
            }
            std::hint::black_box(acc);
        });
    }

    // ------------------------------------------------ packed table access
    section("packed table: row ops, d=16 (rows/s)");
    let d = 16;
    let n = 100_000;
    for bits in ALL_BITS {
        let bw = BitWidth::from_bits(bits).unwrap();
        let mut t = PackedTable::new(n, d, bw);
        let mut codes = vec![0i32; d];
        for r in 0..n {
            for (j, c) in codes.iter_mut().enumerate() {
                *c = (((r * 31 + j * 7) % 255) as i32) - 128;
                *c = (*c).clamp(bw.qn(), bw.qp());
            }
            t.write_row(r, &codes);
        }
        let mut out = vec![0.0f32; d];
        let mut row = 0usize;
        b.bench_units(&format!("dequant row {bits}-bit"), Some(1.0), || {
            row = (row + 97) % n;
            t.read_row_dequant(row, 0.01, &mut out);
            std::hint::black_box(&out);
        });
        let mut iout = vec![0i32; d];
        b.bench_units(&format!("read_row codes {bits}-bit"), Some(1.0),
                      || {
            row = (row + 97) % n;
            t.read_row(row, &mut iout);
            std::hint::black_box(&iout);
        });
        b.bench_units(&format!("write_row {bits}-bit"), Some(1.0), || {
            row = (row + 97) % n;
            t.write_row(row, &codes);
            std::hint::black_box(&t);
        });
    }

    // --------------------------------------- quantize: scalar vs fused
    section("quantize one row, d=16: scalar set() vs word write_row vs \
             fused quantize_row_packed");
    let w: Vec<f32> = (0..d).map(|i| (i as f32 - 8.0) * 0.003).collect();
    for bits in ALL_BITS {
        let bw = BitWidth::from_bits(bits).unwrap();
        let delta = 0.01f32;
        let mut t = PackedTable::new(4, d, bw);
        let mut codes = vec![0i32; d];
        b.bench_units(&format!("quantize+set scalar {bits}-bit SR"),
                      Some(d as f64), || {
            quantize_row(&w, delta, bw, Rounding::Stochastic, &mut rng,
                         &mut codes);
            for (col, &c) in codes.iter().enumerate() {
                t.set(1, col, c);
            }
            std::hint::black_box(&t);
        });
        b.bench_units(&format!("quantize+write_row word {bits}-bit SR"),
                      Some(d as f64), || {
            quantize_row(&w, delta, bw, Rounding::Stochastic, &mut rng,
                         &mut codes);
            t.write_row(1, &codes);
            std::hint::black_box(&t);
        });
        b.bench_units(&format!("fused quantize_row_packed {bits}-bit SR"),
                      Some(d as f64), || {
            t.quantize_row_packed(1, &w, delta, Rounding::Stochastic,
                                  &mut rng);
            std::hint::black_box(&t);
        });
    }

    // ------------------- SIMD kernel matrix: scalar oracle vs vectorized
    section(&format!(
        "SIMD kernel matrix, d=16 (rows/s): dequant / batched gather / \
         DR quantize per kernel (active = {})",
        kernels::active().name()
    ));
    {
        let kernel_list = kernels::available();
        for bits in ALL_BITS {
            let bw = BitWidth::from_bits(bits).unwrap();
            let mut t = PackedTable::new(n, d, bw);
            let mut codes = vec![0i32; d];
            for r in 0..n {
                for (j, c) in codes.iter_mut().enumerate() {
                    *c = ((((r * 31 + j * 7) % 255) as i32) - 128)
                        .clamp(bw.qn(), bw.qp());
                }
                t.write_row(r, &codes);
            }
            let mut out = vec![0.0f32; d];
            let mut want = vec![0.0f32; d];
            for &k in &kernel_list {
                kernels::dequant_row(
                    kernels::Kernel::Scalar,
                    t.raw_rows(11, 1),
                    d,
                    bits,
                    0.01,
                    &mut want,
                );
                kernels::dequant_row(
                    k, t.raw_rows(11, 1), d, bits, 0.01, &mut out,
                );
                assert_eq!(
                    want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{} dequant diverged from scalar at {bits}-bit",
                    k.name()
                );
                let mut row = 0usize;
                b.bench_units(
                    &format!("dequant row {bits}-bit [{}]", k.name()),
                    Some(1.0),
                    || {
                        row = (row + 97) % n;
                        kernels::dequant_row(
                            k,
                            t.raw_rows(row, 1),
                            d,
                            bits,
                            0.01,
                            &mut out,
                        );
                        std::hint::black_box(&out);
                    },
                );
            }
            // the acceptance rows: batched gather + fused DR quantize
            // at the paper's serving widths
            if bits == 4 || bits == 8 {
                let kids: Vec<u32> =
                    (0..4096u32).map(|i| (i * 131) % n as u32).collect();
                let mut kout = vec![0.0f32; kids.len() * d];
                let mut kwant = vec![0.0f32; kids.len() * d];
                t.gather_dequant_with(
                    kernels::Kernel::Scalar,
                    &kids,
                    |_| 0.01,
                    &mut kwant,
                );
                for &k in &kernel_list {
                    t.gather_dequant_with(k, &kids, |_| 0.01, &mut kout);
                    assert_eq!(
                        kwant
                            .iter()
                            .map(|x| x.to_bits())
                            .collect::<Vec<_>>(),
                        kout.iter()
                            .map(|x| x.to_bits())
                            .collect::<Vec<_>>(),
                        "{} gather diverged from scalar at {bits}-bit",
                        k.name()
                    );
                    b.bench_units(
                        &format!(
                            "packed gather 4096x16 {bits}-bit [{}]",
                            k.name()
                        ),
                        Some(kids.len() as f64),
                        || {
                            t.gather_dequant_with(
                                k,
                                &kids,
                                |_| 0.01,
                                &mut kout,
                            );
                            std::hint::black_box(&kout);
                        },
                    );
                }
                let qw: Vec<f32> = (0..d)
                    .map(|i| (i as f32 - 8.0) * 0.003)
                    .collect();
                for &k in &kernel_list {
                    b.bench_units(
                        &format!(
                            "quantize_row_packed DR {bits}-bit [{}]",
                            k.name()
                        ),
                        Some(d as f64),
                        || {
                            t.quantize_row_packed_with(
                                k,
                                1,
                                &qw,
                                0.01,
                                Rounding::Deterministic,
                                &mut rng,
                            );
                            std::hint::black_box(&t);
                        },
                    );
                }
            }
        }
    }

    // ------------------------------- store gather: serial vs sharded
    section(&format!(
        "store gather: 4096 unique rows x d=16, t1 vs t{n_threads} (rows/s)"
    ));
    let gids: Vec<u32> =
        (0..4096u32).map(|i| i * 17).collect(); // strictly increasing: unique
    let mut gout = vec![0.0f32; gids.len() * d];
    let mut rng2 = Pcg32::seeded(2);
    {
        let mut fp = FpStore::init(n, d, &mut rng2);
        fp.set_threads(1);
        b.bench_units("FP gather t1", Some(gids.len() as f64), || {
            fp.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
        fp.set_threads(0);
        b.bench_units(&format!("FP gather t{n_threads}"),
                      Some(gids.len() as f64), || {
            fp.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
    }
    for bits in ALL_BITS {
        let bw = BitWidth::from_bits(bits).unwrap();
        let mut lpt = LptStore::init(n, d, bw, 0.1, Rounding::Stochastic,
                                     &mut rng2);
        lpt.set_threads(1);
        let mut serial_out = vec![0.0f32; gids.len() * d];
        lpt.gather(&gids, &mut serial_out);
        b.bench_units(&format!("LPT-{bits}bit gather t1"),
                      Some(gids.len() as f64), || {
            lpt.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
        lpt.set_threads(0);
        b.bench_units(&format!("LPT-{bits}bit gather t{n_threads}"),
                      Some(gids.len() as f64), || {
            lpt.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
        assert_eq!(serial_out, gout,
                   "sharded gather must be bit-identical to serial");
    }

    // ------------------------------- store update: serial vs sharded
    section(&format!(
        "store update: 4096 unique rows x d=16, t1 vs t{n_threads} (rows/s)"
    ));
    let grads: Vec<f32> = (0..gids.len() * d)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.01)
        .collect();
    let hp = bench_hp();
    let mut nop_sp = |_: &[f32],
                      _: &[f32],
                      _: &[BitWidth]|
     -> Result<Vec<f32>> { unreachable!() };
    for bits in [4u32, 8] {
        let bw = BitWidth::from_bits(bits).unwrap();
        let mut lpt = LptStore::init(n, d, bw, 0.1, Rounding::Stochastic,
                                     &mut rng2);
        let mut what = vec![0.0f32; gids.len() * d];
        lpt.gather(&gids, &mut what);
        lpt.set_threads(1);
        b.bench_units(&format!("LPT-{bits}bit update t1"),
                      Some(gids.len() as f64), || {
            lpt.update(&gids, &what, &grads, &hp, &mut rng2, &mut nop_sp)
                .unwrap();
        });
        lpt.set_threads(0);
        b.bench_units(&format!("LPT-{bits}bit update t{n_threads}"),
                      Some(gids.len() as f64), || {
            lpt.update(&gids, &what, &grads, &hp, &mut rng2, &mut nop_sp)
                .unwrap();
        });
    }
    let mut zero_sp = |_w: &[f32],
                       dl: &[f32],
                       _: &[BitWidth]|
     -> Result<Vec<f32>> { Ok(vec![0.0f32; dl.len()]) };
    for bits in [4u32, 8] {
        let bw = BitWidth::from_bits(bits).unwrap();
        let mut alpt_store =
            AlptStore::init(n, d, bw, Rounding::Stochastic, &mut rng2);
        let mut what = vec![0.0f32; gids.len() * d];
        alpt_store.gather(&gids, &mut what);
        alpt_store.set_threads(1);
        b.bench_units(&format!("ALPT-{bits}bit update t1 (zero-cost sp)"),
                      Some(gids.len() as f64), || {
            alpt_store
                .update(&gids, &what, &grads, &hp, &mut rng2, &mut zero_sp)
                .unwrap();
        });
        alpt_store.set_threads(0);
        b.bench_units(
            &format!("ALPT-{bits}bit update t{n_threads} (zero-cost sp)"),
            Some(gids.len() as f64),
            || {
                alpt_store
                    .update(&gids, &what, &grads, &hp, &mut rng2,
                            &mut zero_sp)
                    .unwrap();
            },
        );
    }

    // ------------------- mixed-precision grouped store (precision plan)
    section(&format!(
        "grouped mixed-precision store (num:4,cat:8 plan): 4096 rows x \
         d=16, t1 vs t{n_threads} (rows/s)"
    ));
    {
        // two equal halves: a 4-bit "numeric" group and an 8-bit
        // "categorical" one, same row ids as the LPT rows above
        let mixed_exp = Experiment {
            method: Method::Lpt(RoundingMode::Sr),
            bits: PrecisionPlan::parse("num:4,cat:8").unwrap(),
            threads: 1,
            use_runtime: false,
            ..Experiment::default()
        };
        let schema =
            Schema::new(vec![(n / 2) as u32, (n - n / 2) as u32]);
        let kinds = [FieldKind::Numeric, FieldKind::Categorical];
        let mut grouped = GroupedStore::from_plan(
            &mixed_exp, &schema, &kinds, n, d, &mut rng2,
        )
        .expect("grouped store");
        grouped.set_threads(1);
        let mut serial_out = vec![0.0f32; gids.len() * d];
        grouped.gather(&gids, &mut serial_out);
        b.bench_units("mixed-{4,8}bit gather t1",
                      Some(gids.len() as f64), || {
            grouped.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
        grouped.set_threads(0);
        b.bench_units(&format!("mixed-{{4,8}}bit gather t{n_threads}"),
                      Some(gids.len() as f64), || {
            grouped.gather(&gids, &mut gout);
            std::hint::black_box(&gout);
        });
        assert_eq!(serial_out, gout,
                   "grouped sharded gather must be bit-identical to serial");
        let mut what = vec![0.0f32; gids.len() * d];
        grouped.gather(&gids, &mut what);
        grouped.set_threads(1);
        b.bench_units("mixed-{4,8}bit update t1",
                      Some(gids.len() as f64), || {
            grouped
                .update(&gids, &what, &grads, &hp, &mut rng2, &mut nop_sp)
                .unwrap();
        });
        grouped.set_threads(0);
        b.bench_units(&format!("mixed-{{4,8}}bit update t{n_threads}"),
                      Some(gids.len() as f64), || {
            grouped
                .update(&gids, &what, &grads, &hp, &mut rng2, &mut nop_sp)
                .unwrap();
        });
        // ALPT flavour: learned per-row deltas in both groups
        let alpt_exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            ..mixed_exp.clone()
        };
        let mut alpt_grouped = GroupedStore::from_plan(
            &alpt_exp, &schema, &kinds, n, d, &mut rng2,
        )
        .expect("grouped alpt store");
        alpt_grouped.gather(&gids, &mut what);
        alpt_grouped.set_threads(1);
        b.bench_units("mixed-{4,8}bit ALPT update t1 (zero-cost sp)",
                      Some(gids.len() as f64), || {
            alpt_grouped
                .update(&gids, &what, &grads, &hp, &mut rng2,
                        &mut zero_sp)
                .unwrap();
        });
        alpt_grouped.set_threads(0);
        b.bench_units(
            &format!(
                "mixed-{{4,8}}bit ALPT update t{n_threads} (zero-cost sp)"
            ),
            Some(gids.len() as f64),
            || {
                alpt_grouped
                    .update(&gids, &what, &grads, &hp, &mut rng2,
                            &mut zero_sp)
                    .unwrap();
            },
        );
    }

    // --------------------------------------------------- budget planner
    section("budget planner: plan_for_budget, criteo-like geometry \
             (plans/s)");
    {
        use alpt::analysis::{plan_for_budget, static_field_scores};
        // 39 fields with vocabs spanning 4 orders of magnitude, a
        // mid-range budget so the greedy loop runs several upgrade
        // rounds before settling
        let vocabs: Vec<u32> =
            (0..39u32).map(|f| 1u32 << (2 + (f % 18))).collect();
        let scores = static_field_scores(&vocabs);
        let total: u64 = vocabs.iter().map(|&v| v as u64).sum();
        let budget = total * 12;
        b.bench_units("plan_for_budget 39 fields d=16", Some(1.0), || {
            let p = plan_for_budget(&vocabs, &scores, 16, true, budget,
                                    true)
                .expect("mid-range budget is feasible");
            std::hint::black_box(p.bytes);
        });
    }

    // ------------------------------- shared inference engine throughput
    section(&format!(
        "InferenceEngine::score (tiny LPT-8 ckpt, B=64): t1 vs \
         t{n_threads} concurrent clients (req/s)"
    ));
    {
        use alpt::serve::InferenceEngine;
        use std::sync::Arc;

        let exp = Experiment {
            method: Method::Lpt(RoundingMode::Sr),
            model: "tiny".into(),
            dataset: "tiny".into(),
            n_samples: 4_000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let spec = SyntheticSpec::tiny(exp.seed);
        let ds = generate(&spec, exp.n_samples);
        let mut tr = Trainer::new(exp, ds.schema.n_features())
            .expect("bench trainer");
        let ckpt = std::env::temp_dir().join("alpt_bench_engine.ckpt");
        tr.save_checkpoint(&ckpt).expect("bench checkpoint");
        let engine = Arc::new(
            InferenceEngine::from_checkpoint(&ckpt).expect("bench engine"),
        );
        std::fs::remove_file(&ckpt).ok();
        let batches: Vec<_> =
            Batcher::new(&ds, engine.batch_size(), Some(1), true)
                .take(8)
                .collect();
        let bsz = engine.batch_size() as f64;
        let serial: Vec<Vec<f32>> =
            batches.iter().map(|b| engine.score(b)).collect();
        let mut i = 0usize;
        b.bench_units("engine score t1", Some(bsz), || {
            let batch = &batches[i % batches.len()];
            i += 1;
            std::hint::black_box(engine.score(batch));
        });
        // one iteration = n_threads concurrent clients, one batch each,
        // all through the one shared engine (&self — no locks)
        b.bench_units(
            &format!("engine score t{n_threads}"),
            Some(bsz * n_threads as f64),
            || {
                std::thread::scope(|s| {
                    for t in 0..n_threads {
                        let engine = Arc::clone(&engine);
                        let batch = &batches[t % batches.len()];
                        s.spawn(move || {
                            std::hint::black_box(engine.score(batch));
                        });
                    }
                });
            },
        );
        // saturation headline: same shape, but report whole requests
        // per second (one request = one B=64 batch) with every core
        // busy — the number a capacity planner actually provisions on
        b.bench_units(
            &format!("engine score saturation t{n_threads} (req/s)"),
            Some(n_threads as f64),
            || {
                std::thread::scope(|s| {
                    for t in 0..n_threads {
                        let engine = Arc::clone(&engine);
                        let batch = &batches[t % batches.len()];
                        s.spawn(move || {
                            std::hint::black_box(engine.score(batch));
                        });
                    }
                });
            },
        );
        // concurrent scoring must stay bit-identical to the serial pass
        let threaded: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = batches
                .iter()
                .map(|batch| {
                    let engine = Arc::clone(&engine);
                    s.spawn(move || engine.score(batch))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(serial, threaded,
                   "threaded engine scoring must be bit-identical");
    }

    // ------------------------------------------------------------- dedup
    section("batch dedup (samples/s), avazu-syn B=256");
    let spec = SyntheticSpec::avazu(3);
    let ds = generate(&spec, 10_000);
    let rows: Vec<usize> = (0..256).collect();
    b.bench_units("make_batch B=256 F=24", Some(256.0), || {
        let batch = make_batch(&ds, &rows, 256);
        std::hint::black_box(batch.n_unique());
    });

    // --------------------------------------------------------------- auc
    section("metrics (elems/s)");
    let mut rng3 = Pcg32::seeded(3);
    let scores: Vec<f32> = (0..100_000).map(|_| rng3.uniform_f32()).collect();
    let labels: Vec<u8> =
        (0..100_000).map(|_| rng3.bernoulli(0.2) as u8).collect();
    b.bench_units("auc n=100k", Some(100_000.0), || {
        std::hint::black_box(alpt::metrics::auc(&scores, &labels));
    });

    // --------------------------------------------------- rust-nn step
    section("rust-nn DCN train step (tiny geometry)");
    let cfg = DcnConfig::tiny();
    let dcn = Dcn::new(cfg.clone());
    let mut rng4 = Pcg32::seeded(4);
    let params = cfg.init_params(&mut rng4);
    let umax = cfg.batch * cfg.fields;
    let emb: Vec<f32> =
        (0..umax * cfg.emb_dim).map(|_| rng4.normal_scaled(0.0, 0.1)).collect();
    let idx: Vec<i32> = (0..cfg.batch * cfg.fields)
        .map(|_| rng4.below(umax as u32) as i32)
        .collect();
    let labels4: Vec<u8> =
        (0..cfg.batch).map(|_| rng4.bernoulli(0.3) as u8).collect();
    let mask = vec![1.0f32; cfg.batch * cfg.mlp_mask_dim()];
    b.bench_units("nn train_step tiny (samples/s)",
                  Some(cfg.batch as f64), || {
        let o = dcn.train_step(&emb, &idx, &labels4, &params, &mask, umax);
        std::hint::black_box(o.loss);
    });

    // --------------------------------------------- PJRT step latency
    let have_artifacts =
        std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        section("full coordinator step through PJRT (tiny, samples/s)");
        let spec = SyntheticSpec::tiny(5);
        let tiny_ds = generate(&spec, 4_000);
        for (method, label) in [
            (Method::Fp, "step FP (train_fp)"),
            (Method::Lpt(RoundingMode::Sr), "step LPT-SR (train_lpt)"),
            (Method::Alpt(RoundingMode::Sr),
             "step ALPT-SR (train_lpt + train_fq)"),
        ] {
            let exp = Experiment {
                method,
                model: "tiny".into(),
                use_runtime: true,
                ..Experiment::default()
            };
            let mut tr = Trainer::new(exp, tiny_ds.schema.n_features())
                .expect("trainer");
            let batches: Vec<_> =
                Batcher::new(&tiny_ds, tr.entry.batch, Some(1), true)
                    .take(8)
                    .collect();
            let mut i = 0;
            let bsz = tr.entry.batch as f64;
            b.bench_units(label, Some(bsz), || {
                let batch = &batches[i % batches.len()];
                i += 1;
                let o = tr.step(batch, 1).expect("step");
                std::hint::black_box(o.loss);
            });
        }
        section("eval step through PJRT (tiny)");
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            model: "tiny".into(),
            use_runtime: true,
            ..Experiment::default()
        };
        let mut tr =
            Trainer::new(exp, tiny_ds.schema.n_features()).expect("trainer");
        let (_, val, _) = tiny_ds.split((0.8, 0.1, 0.1), 1);
        b.bench_units("evaluate 400 samples (eval_lpt)", Some(400.0), || {
            let ev = tr.evaluate(&val).expect("eval");
            std::hint::black_box(ev.auc);
        });
    } else {
        eprintln!("(skipping PJRT benches: run `make artifacts`)");
    }

    // ------------------------------------------------------------ output
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/micro.json", b.to_json().to_string()).ok();
    let meta = vec![
        ("bench", Json::str("micro")),
        ("quick", Json::Bool(quick)),
        ("threads_avail", Json::num(n_threads as f64)),
        ("kernel", Json::str(kernels::active().name())),
    ];
    match b.write_report(std::path::Path::new("BENCH_micro.json"), meta) {
        Ok(()) => println!(
            "\n[saved BENCH_micro.json + results/micro.json]"
        ),
        Err(e) => {
            // a stale report must not pass bench_smoke.sh silently
            eprintln!("failed to write BENCH_micro.json: {e}");
            std::process::exit(1);
        }
    }
}
