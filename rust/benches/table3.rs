//! Table 3 — scalability: larger embedding dimension (d = 32) and more
//! categorical features (lower OOV threshold ⇒ ~2× vocab), 8-bit.
//!
//! Paper shape: ALPT(SR) stays lossless (≥ FP) in both settings; LPT(SR)
//! trails slightly.

use alpt::config::{Method, RoundingMode};
use alpt::experiments::{
    base_experiment, dataset_for, print_table, run_cell, save_cells,
    GridScale,
};

fn main() {
    let scale = GridScale::from_env();
    println!("=== Table 3: d=32 and larger vocab (8-bit) ===");
    let methods = [
        Method::Fp,
        Method::Lpt(RoundingMode::Sr),
        Method::Alpt(RoundingMode::Sr),
    ];
    let mut all = Vec::new();
    for dataset in ["avazu", "criteo"] {
        // setting A: d = 32
        {
            let mut base = base_experiment(dataset, &scale);
            base.model = format!("{dataset}_d32");
            let ds = dataset_for(&base).expect("dataset");
            let mut cells = Vec::new();
            for method in methods {
                let mut exp = base.clone();
                exp.method = method;
                match run_cell(&exp, &ds, false) {
                    Ok(c) => {
                        println!(
                            "  [{dataset} d=32] {:<10} auc {:.4}  \
                             logloss {:.5}",
                            c.method, c.auc, c.logloss
                        );
                        cells.push(c);
                    }
                    Err(e) => eprintln!("  {method:?} failed: {e:#}"),
                }
            }
            print_table(&format!("Table 3 — {dataset}-syn, d=32"), &cells);
            all.extend(cells);
        }
        // setting B: ~2x vocabulary ("threshold lowered")
        {
            let mut base = base_experiment(dataset, &scale);
            base.vocab_scale = 2.0;
            let ds = dataset_for(&base).expect("dataset");
            let mut cells = Vec::new();
            for method in methods {
                let mut exp = base.clone();
                exp.method = method;
                match run_cell(&exp, &ds, false) {
                    Ok(c) => {
                        println!(
                            "  [{dataset} vocab x2] {:<10} auc {:.4}  \
                             logloss {:.5}",
                            c.method, c.auc, c.logloss
                        );
                        cells.push(c);
                    }
                    Err(e) => eprintln!("  {method:?} failed: {e:#}"),
                }
            }
            print_table(
                &format!("Table 3 — {dataset}-syn, vocab x2"),
                &cells,
            );
            all.extend(cells);
        }
    }
    save_cells("table3", &all).ok();
}
