//! Table 1 — overall performance of ALPT vs every baseline at 8 bits on
//! the Avazu-like and Criteo-like synthetic datasets: AUC, Logloss,
//! epochs × time, training & inference compression ratios.
//!
//! Paper shape to reproduce: ALPT(SR) ≈ FP ≈ LSQ ≈ PACT on accuracy (ALPT
//! losslessly best-in-class), LPT(SR)/Hashing/Pruning clearly behind,
//! LPT(DR) far behind; ALPT at 3.2× train & infer compression vs QAT's 1×
//! train.
//!
//! `ALPT_BENCH_QUICK=1 cargo bench --bench table1` for the fast variant.

use alpt::experiments::{
    base_experiment, dataset_for, print_table, run_cell, save_cells,
    table1_methods, GridScale,
};

fn main() {
    let scale = GridScale::from_env();
    println!(
        "=== Table 1: overall performance (8-bit) — {} samples, {} epochs \
         max ===",
        scale.samples, scale.epochs
    );
    let mut all = Vec::new();
    for dataset in ["avazu", "criteo"] {
        let base = base_experiment(dataset, &scale);
        let ds = dataset_for(&base).expect("dataset");
        println!(
            "\n--- {dataset}-syn: {} samples, {} features ---",
            ds.n_samples(),
            ds.schema.n_features()
        );
        let mut cells = Vec::new();
        for (method, bits) in table1_methods() {
            let mut exp = base.clone();
            exp.method = method;
            // storage fmt knob; 32 means fp/hash/prune, which ignore it
            exp.bits = alpt::config::PrecisionPlan::uniform(
                if bits == 32 { 8 } else { bits },
            );
            let cell = match run_cell(&exp, &ds, false) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("  {method:?} failed: {e:#}");
                    continue;
                }
            };
            println!(
                "  {:<10} auc {:.4}  logloss {:.5}  ({} x {:.1}s)",
                cell.method, cell.auc, cell.logloss, cell.epochs,
                cell.secs_per_epoch
            );
            cells.push(cell);
        }
        print_table(&format!("Table 1 — {dataset}-syn (8-bit)"), &cells);
        all.extend(cells);
    }
    save_cells("table1", &all).ok();

    // headline assertions, printed not panicking (bench, not test)
    let get = |ds: &str, m: &str| {
        all.iter()
            .find(|c| c.dataset == ds && c.method == m)
            .map(|c| c.auc)
    };
    for ds in ["avazu", "criteo"] {
        if let (Some(fp), Some(alpt), Some(lpt_sr), Some(lpt_dr)) = (
            get(ds, "FP"),
            get(ds, "ALPT(SR)"),
            get(ds, "LPT(SR)"),
            get(ds, "LPT(DR)"),
        ) {
            println!(
                "\n[{ds}] shape check: FP {fp:.4} vs ALPT(SR) {alpt:.4} \
                 (gap {:+.4}; paper: ~0) | LPT SR {lpt_sr:.4} > DR \
                 {lpt_dr:.4}: {}",
                fp - alpt,
                lpt_sr > lpt_dr
            );
        }
    }
}
