//! Figure 3 — the synthetic convex experiment: parameter distributions of
//! FP / LPT-DR / LPT-SR at t ∈ {10, 100, 1000} (panels a–c) and the count
//! of parameters whose update DR erases, |η∇f| < Δ/2, over time (panel d).

use alpt::analysis::{run_convex, ConvexMode, ConvexSpec};
use alpt::util::json::Json;

fn main() {
    let spec = ConvexSpec::default();
    println!(
        "=== Figure 3: f(w) = (w-0.5)^2, {} params, delta = {}, eta = {} \
         ===\n",
        spec.n_params, spec.delta, spec.eta0
    );

    // panels (a)-(c): distributions at the paper's snapshots
    let record = [10usize, 100, 1000];
    let mut json_rows = Vec::new();
    for mode in [ConvexMode::FullPrecision, ConvexMode::LptDr,
                 ConvexMode::LptSr] {
        let snaps = run_convex(&spec, mode, 1000, &record);
        println!("--- {} ---", mode.name());
        for s in &snaps {
            println!(
                "  t={:<5} mean obj {:.3e}  stalled {:>4}/{}  |{}|",
                s.iteration,
                s.mean_obj,
                s.stalled,
                spec.n_params,
                s.histogram.sparkline()
            );
            json_rows.push(Json::obj(vec![
                ("mode", Json::str(mode.name())),
                ("t", Json::num(s.iteration as f64)),
                ("mean_obj", Json::num(s.mean_obj)),
                ("stalled", Json::num(s.stalled as f64)),
                (
                    "hist",
                    Json::Array(
                        s.histogram
                            .counts
                            .iter()
                            .map(|&c| Json::num(c as f64))
                            .collect(),
                    ),
                ),
            ]));
        }
        println!();
    }

    // panel (d): DR stall counter over a fine time grid
    let grid: Vec<usize> = (1..=100).map(|i| i * 10).collect();
    let snaps = run_convex(&spec, ConvexMode::LptDr, 1000, &grid);
    println!("--- (d) DR stalled-parameter count ---");
    let mut curve = Vec::new();
    for s in snaps.iter().step_by(10) {
        println!("  t={:<5} stalled {:>4}", s.iteration, s.stalled);
        curve.push(Json::arr_f64(&[s.iteration as f64, s.stalled as f64]));
    }
    std::fs::create_dir_all("results").ok();
    let doc = Json::obj(vec![
        ("panels_abc", Json::Array(json_rows)),
        ("panel_d", Json::Array(curve)),
    ]);
    std::fs::write("results/fig3.json", doc.to_string()).ok();
    println!("\n[saved results/fig3.json]");
    println!(
        "shape check (paper): SR final obj << DR final obj; DR stalled \
         saturates at {}.",
        spec.n_params
    );
}
