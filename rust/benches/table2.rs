//! Table 2 — accuracy of the quantization methods at smaller bit widths
//! (2- and 4-bit) on both datasets.
//!
//! Paper shape: everything degrades as bits shrink; ALPT(SR) > LPT(SR)
//! at every width (biggest gap at 2-bit); LSQ (full-precision master
//! weights) holds up best at 2-bit; PACT collapses at 2-bit.

use alpt::config::{Method, RoundingMode};
use alpt::experiments::{
    base_experiment, dataset_for, print_table, run_cell, save_cells,
    GridScale,
};

fn main() {
    let scale = GridScale::from_env();
    println!("=== Table 2: smaller bit widths (2/4-bit) ===");
    let methods = [
        (Method::Pact, "PACT"),
        (Method::Lsq, "LSQ"),
        (Method::Lpt(RoundingMode::Sr), "LPT(SR)"),
        (Method::Alpt(RoundingMode::Sr), "ALPT(SR)"),
    ];
    let mut all = Vec::new();
    for dataset in ["avazu", "criteo"] {
        let base = base_experiment(dataset, &scale);
        let ds = dataset_for(&base).expect("dataset");
        for bits in [2u32, 4] {
            let mut cells = Vec::new();
            for (method, _) in methods {
                let mut exp = base.clone();
                exp.method = method;
                exp.bits = alpt::config::PrecisionPlan::uniform(bits);
                // paper: clip 0.1 at 2/4-bit for LPT; smaller step-size
                // weight decay for ALPT
                exp.clip = 0.1;
                if matches!(method, Method::Alpt(_)) {
                    exp.wd_delta =
                        if dataset == "avazu" { 0.0 } else { 1e-6 };
                }
                match run_cell(&exp, &ds, false) {
                    Ok(c) => {
                        println!(
                            "  [{dataset} {bits}-bit] {:<10} auc {:.4}  \
                             logloss {:.5}",
                            c.method, c.auc, c.logloss
                        );
                        cells.push(c);
                    }
                    Err(e) => eprintln!("  {method:?} failed: {e:#}"),
                }
            }
            print_table(
                &format!("Table 2 — {dataset}-syn @ {bits}-bit"),
                &cells,
            );
            all.extend(cells);
        }
    }
    save_cells("table2", &all).ok();

    let get = |ds: &str, m: &str, b: u32| {
        all.iter()
            .find(|c| c.dataset == ds && c.method == m && c.bits == b)
            .map(|c| c.auc)
    };
    for ds in ["avazu", "criteo"] {
        for b in [2u32, 4] {
            if let (Some(alpt), Some(lpt)) =
                (get(ds, "ALPT(SR)", b), get(ds, "LPT(SR)", b))
            {
                println!(
                    "[{ds} {b}-bit] ALPT {alpt:.4} vs LPT {lpt:.4} \
                     (paper: ALPT consistently higher) -> {}",
                    if alpt > lpt { "OK" } else { "MISS" }
                );
            }
        }
    }
}
