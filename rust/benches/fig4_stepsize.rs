//! Figure 4 — AUC under different step-size learning rates × gradient
//! scaling factors for ALPT(SR) on the Avazu-like dataset.
//!
//! Paper shape: the three scaling factors {1, 1/√(dq), 1/√(bdq)} give
//! near-identical accuracy at a given LR, while the LR itself matters a
//! lot (interacting with the step-size weight decay).

use alpt::config::{Method, RoundingMode};
use alpt::experiments::{base_experiment, dataset_for, run_cell, GridScale};
use alpt::quant::GradScale;
use alpt::util::json::Json;

fn main() {
    let scale = GridScale::from_env();
    println!("=== Figure 4: step-size LR x gradient scaling (ALPT-SR, \
              8-bit, avazu-syn) ===\n");
    let mut base = base_experiment("avazu", &scale);
    // keep the figure tractable: half the table-size budget
    base.n_samples = (scale.samples / 2).max(10_000);
    base.method = Method::Alpt(RoundingMode::Sr);
    let ds = dataset_for(&base).expect("dataset");

    let lrs = [2e-6f32, 2e-5, 2e-4, 2e-3];
    let scales = [
        (GradScale::One, "g=1"),
        (GradScale::InvSqrtDq, "g=1/sqrt(dq)"),
        (GradScale::InvSqrtBdq, "g=1/sqrt(bdq)"),
    ];
    println!(
        "{:<16} {}",
        "lr_delta",
        lrs.iter()
            .map(|l| format!("{l:>10.0e}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    let mut rows = Vec::new();
    for (gs, gs_name) in scales {
        let mut line = format!("{gs_name:<16}");
        let mut aucs = Vec::new();
        for &lr in &lrs {
            let mut exp = base.clone();
            exp.grad_scale = gs;
            exp.lr_delta = lr;
            let auc = match run_cell(&exp, &ds, false) {
                Ok(c) => c.auc,
                Err(e) => {
                    eprintln!("cell failed: {e:#}");
                    f64::NAN
                }
            };
            line.push_str(&format!(" {auc:>10.4}"));
            aucs.push(auc);
        }
        println!("{line}");
        rows.push(Json::obj(vec![
            ("scale", Json::str(gs_name)),
            ("lrs", Json::arr_f64(&lrs.map(|x| x as f64))),
            ("aucs", Json::arr_f64(&aucs)),
        ]));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write(
        "results/fig4.json",
        Json::Array(rows).to_string(),
    )
    .ok();
    println!("\n[saved results/fig4.json]");
    println!(
        "shape check (paper): rows (scaling factors) nearly identical per \
         column; columns (LR) vary much more."
    );
}
