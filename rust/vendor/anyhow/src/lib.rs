//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crate registry, so the repo vendors the
//! subset of `anyhow`'s API the codebase actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error state is a flattened message chain
//! (outermost context first); `{e}` prints the outermost message, `{e:#}`
//! the full `a: b: c` chain, and `{e:?}` the anyhow-style
//! "Caused by:" report.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: StdError>(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The same coherence trick the real crate uses: `Error` itself does not
// implement `std::error::Error`, so this blanket impl cannot overlap the
// reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::from_std(err)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result`. Implemented for any error convertible into [`Error`], which
/// covers both std errors and `Error` itself.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("Condition failed: `", ::std::stringify!($cond), "`"),
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_std_and_anyhow_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let r2: Result<()> = Err(anyhow!("inner {}", 7));
        let e2 = r2.with_context(|| "step").unwrap_err();
        assert_eq!(format!("{e2:#}"), "step: inner 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f(x: i32) -> Result<()> {
            ensure!(x == 0);
            Ok(())
        }
        let msg = format!("{}", f(1).unwrap_err());
        assert!(msg.contains("Condition failed"), "{msg}");
    }
}
