//! Dense linear-algebra primitives for the Rust DCN path.
//!
//! Shapes are `(rows, cols)` over flat `&[f32]` row-major buffers. The
//! matmul kernels use the cache-friendly i–k–j loop order with an
//! accumulate-into-output contract (callers zero or seed the output).

/// `c[m,n] += a[m,k] @ b[k,n]`
pub fn matmul_nn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in a_row.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in c_row.iter_mut().zip(b_row) {
                *cj += aik * bj;
            }
        }
    }
}

/// `c[m,n] += a[p,m]^T @ b[p,n]` (used for weight grads `dW = h^T dz`)
pub fn matmul_tn(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    p: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), p * m);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(c.len(), m * n);
    for row in 0..p {
        let a_row = &a[row * m..(row + 1) * m];
        let b_row = &b[row * n..(row + 1) * n];
        for (i, &ai) in a_row.iter().enumerate() {
            if ai == 0.0 {
                continue;
            }
            let c_row = &mut c[i * n..(i + 1) * n];
            for (cj, &bj) in c_row.iter_mut().zip(b_row) {
                *cj += ai * bj;
            }
        }
    }
}

/// `c[m,n] += a[m,p] @ b[n,p]^T` (used for input grads `dx = dz @ W^T`)
pub fn matmul_nt(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    p: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), n * p);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * p..(i + 1) * p];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (j, cj) in c_row.iter_mut().enumerate() {
            let b_row = &b[j * p..(j + 1) * p];
            let mut acc = 0.0f32;
            for (&av, &bv) in a_row.iter().zip(b_row) {
                acc += av * bv;
            }
            *cj += acc;
        }
    }
}

/// Row-wise dot products: `out[i] = a[i,:] . v`
pub fn rowdot(a: &[f32], v: &[f32], out: &mut [f32], m: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        let mut acc = 0.0f32;
        for (&x, &y) in row.iter().zip(v) {
            acc += x * y;
        }
        out[i] = acc;
    }
}

/// In-place ReLU, returning the mask application to a paired grad later is
/// the caller's job (they keep the pre-activation).
pub fn relu(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Add `b` broadcast over rows: `x[i,:] += b`.
pub fn add_bias(x: &mut [f32], b: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        for (v, &bj) in x[i * n..(i + 1) * n].iter_mut().zip(b) {
            *v += bj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul_nn(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        check("nn/tn/nt consistency", 40, |g| {
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = g.vec_normal(m * k, 1.0);
            let b = g.vec_normal(k * n, 1.0);
            let want = naive_nn(&a, &b, m, k, n);

            let mut c = vec![0.0; m * n];
            matmul_nn(&a, &b, &mut c, m, k, n);
            // a^T with a stored as [k, m]: transpose a manually
            let mut at = vec![0.0; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            let mut c2 = vec![0.0; m * n];
            matmul_tn(&at, &b, &mut c2, k, m, n);
            // b^T stored as [n, k]
            let mut bt = vec![0.0; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            let mut c3 = vec![0.0; m * n];
            matmul_nt(&a, &bt, &mut c3, m, k, n);

            for (idx, &w) in want.iter().enumerate() {
                for (which, got) in
                    [(&c, "nn"), (&c2, "tn"), (&c3, "nt")].iter().map(
                        |(v, s)| (*s, v[idx]),
                    )
                {
                    if (got - w).abs() > 1e-4 * (1.0 + w.abs()) {
                        return Err(format!(
                            "{which} mismatch at {idx}: {got} vs {w}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rowdot_matches() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = [1.0, -1.0];
        let mut out = [0.0; 3];
        rowdot(&a, &v, &mut out, 3, 2);
        assert_eq!(out, [-1.0, -1.0, -1.0]);
    }

    #[test]
    fn relu_and_bias() {
        let mut x = vec![-1.0, 2.0, -3.0, 4.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 4.0]);
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, vec![10.0, 22.0, 10.0, 24.0]);
    }

    #[test]
    fn sigmoid_bounds() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(30.0) > 0.999_999);
        assert!(sigmoid(-30.0) < 1e-6);
    }
}
