//! DCN (Deep & Cross Network) forward/backward in Rust, mirroring
//! `python/compile/model.py` layer for layer.

use super::ops;

/// Model geometry; identical fields to the Python `ModelConfig` and the
//  manifest entries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DcnConfig {
    pub fields: usize,
    pub emb_dim: usize,
    pub batch: usize,
    pub cross_depth: usize,
    pub mlp: Vec<usize>,
}

impl DcnConfig {
    pub fn tiny() -> Self {
        Self { fields: 8, emb_dim: 8, batch: 64, cross_depth: 2,
               mlp: vec![32, 16] }
    }

    pub fn input_dim(&self) -> usize {
        self.fields * self.emb_dim
    }

    pub fn mlp_mask_dim(&self) -> usize {
        self.mlp.iter().sum()
    }

    /// Dense parameter layout: (name, rows, cols, init) in flat order —
    /// must match `configs.param_layout` in Python.
    pub fn param_layout(&self) -> Vec<(String, usize, usize, Init)> {
        let k = self.input_dim();
        let mut layout = Vec::new();
        for i in 0..self.cross_depth {
            layout.push((format!("cross_{i}_w"), k, 1, Init::Normal));
            layout.push((format!("cross_{i}_b"), k, 1, Init::Zero));
        }
        let mut prev = k;
        for (i, &w) in self.mlp.iter().enumerate() {
            layout.push((format!("mlp_{i}_w"), prev, w, Init::Xavier));
            layout.push((format!("mlp_{i}_b"), w, 1, Init::Zero));
            prev = w;
        }
        layout.push(("final_w".into(), k + prev, 1, Init::Xavier));
        layout.push(("final_b".into(), 1, 1, Init::Zero));
        layout
    }

    pub fn n_params(&self) -> usize {
        self.param_layout().iter().map(|(_, r, c, _)| r * c).sum()
    }

    /// Initialize a flat parameter vector per the layout's init spec
    /// (Xavier-uniform for matrices, N(0, 0.01) for cross vectors, zeros
    /// for biases) — the same scheme `python/tests` and the manifest use.
    pub fn init_params(&self, rng: &mut crate::util::rng::Pcg32) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        for (_, rows, cols, init) in self.param_layout() {
            let n = rows * cols;
            match init {
                Init::Xavier => {
                    let a = (6.0 / (rows + cols) as f32).sqrt();
                    out.extend((0..n).map(|_| rng.uniform_in(-a, a)));
                }
                Init::Normal => {
                    out.extend((0..n).map(|_| rng.normal_scaled(0.0, 0.01)));
                }
                Init::Zero => out.extend(std::iter::repeat(0.0).take(n)),
            }
        }
        out
    }
}

/// Parameter initializer kinds (manifest `init` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    Xavier,
    Normal,
    Zero,
}

/// Offsets of each named parameter in the flat vector.
fn offsets(cfg: &DcnConfig) -> Vec<(String, usize, usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    for (name, r, c, _) in cfg.param_layout() {
        out.push((name, off, r, c));
        off += r * c;
    }
    out
}

/// Forward-pass activations kept for the backward pass.
pub struct Cache {
    x0: Vec<f32>,            // [B, K]
    cross_xs: Vec<Vec<f32>>, // inputs to each cross layer + final output
    mlp_pre: Vec<Vec<f32>>,  // pre-ReLU activations per MLP layer
    mlp_act: Vec<Vec<f32>>,  // post-ReLU+mask activations
    out: Vec<f32>,           // [B, K + last]
    logits: Vec<f32>,        // [B]
    mask: Vec<f32>,          // dropout mask copy
}

/// Training-step output (mirrors the `train_*` artifact outputs).
pub struct TrainOutput {
    pub loss: f32,
    pub logits: Vec<f32>,
    pub d_emb: Vec<f32>,    // [U, d]
    pub d_params: Vec<f32>, // [P]
}

/// The Rust DCN engine.
pub struct Dcn {
    pub cfg: DcnConfig,
    offs: Vec<(String, usize, usize, usize)>,
}

impl Dcn {
    pub fn new(cfg: DcnConfig) -> Self {
        let offs = offsets(&cfg);
        Self { cfg, offs }
    }

    fn param<'a>(&self, params: &'a [f32], name: &str) -> &'a [f32] {
        let (_, off, r, c) = self
            .offs
            .iter()
            .find(|(n, ..)| n == name)
            .unwrap_or_else(|| panic!("no param {name}"));
        &params[*off..off + r * c]
    }

    fn param_mut<'a>(
        &self,
        params: &'a mut [f32],
        name: &str,
    ) -> &'a mut [f32] {
        let (_, off, r, c) = self
            .offs
            .iter()
            .find(|(n, ..)| n == name)
            .unwrap_or_else(|| panic!("no param {name}"));
        &mut params[*off..off + r * c]
    }

    /// Forward from unique embedding rows; returns logits and the cache.
    ///
    /// `emb`: `[U, d]` unique rows, `idx`: `[B, F]` positions into emb,
    /// `mask`: `[B, mlp_mask_dim]` dropout mask ({0, 1/(1-p)}).
    pub fn forward(
        &self,
        emb: &[f32],
        idx: &[i32],
        params: &[f32],
        mask: &[f32],
    ) -> Cache {
        let cfg = &self.cfg;
        let (b, f, d, k) = (cfg.batch, cfg.fields, cfg.emb_dim, cfg.input_dim());
        assert_eq!(idx.len(), b * f);
        assert_eq!(mask.len(), b * cfg.mlp_mask_dim());

        // gather -> x0 [B, K]
        let mut x0 = vec![0.0f32; b * k];
        for bi in 0..b {
            for fi in 0..f {
                let u = idx[bi * f + fi] as usize;
                x0[bi * k + fi * d..bi * k + (fi + 1) * d]
                    .copy_from_slice(&emb[u * d..(u + 1) * d]);
            }
        }

        // cross tower
        let mut cross_xs = vec![x0.clone()];
        let mut s = vec![0.0f32; b];
        for l in 0..cfg.cross_depth {
            let w = self.param(params, &format!("cross_{l}_w"));
            let bias = self.param(params, &format!("cross_{l}_b"));
            let xl = cross_xs.last().unwrap();
            ops::rowdot(xl, w, &mut s, b, k);
            let mut next = vec![0.0f32; b * k];
            for bi in 0..b {
                for j in 0..k {
                    next[bi * k + j] =
                        x0[bi * k + j] * s[bi] + bias[j] + xl[bi * k + j];
                }
            }
            cross_xs.push(next);
        }

        // deep tower
        let mut mlp_pre = Vec::with_capacity(cfg.mlp.len());
        let mut mlp_act = Vec::with_capacity(cfg.mlp.len());
        let mut h = x0.clone();
        let mut prev = k;
        let mut moff = 0usize;
        for (i, &width) in cfg.mlp.iter().enumerate() {
            let w = self.param(params, &format!("mlp_{i}_w"));
            let bias = self.param(params, &format!("mlp_{i}_b"));
            let mut z = vec![0.0f32; b * width];
            ops::matmul_nn(&h, w, &mut z, b, prev, width);
            ops::add_bias(&mut z, bias, b, width);
            mlp_pre.push(z.clone());
            ops::relu(&mut z);
            // dropout mask slice
            for bi in 0..b {
                for j in 0..width {
                    z[bi * width + j] *=
                        mask[bi * cfg.mlp_mask_dim() + moff + j];
                }
            }
            mlp_act.push(z.clone());
            h = z;
            prev = width;
            moff += width;
        }

        // head
        let last = *cfg.mlp.last().unwrap();
        let xl = cross_xs.last().unwrap();
        let mut out = vec![0.0f32; b * (k + last)];
        for bi in 0..b {
            out[bi * (k + last)..bi * (k + last) + k]
                .copy_from_slice(&xl[bi * k..(bi + 1) * k]);
            out[bi * (k + last) + k..(bi + 1) * (k + last)]
                .copy_from_slice(&h[bi * last..(bi + 1) * last]);
        }
        let wf = self.param(params, "final_w");
        let bf = self.param(params, "final_b")[0];
        let mut logits = vec![0.0f32; b];
        ops::rowdot(&out, wf, &mut logits, b, k + last);
        for z in logits.iter_mut() {
            *z += bf;
        }

        Cache { x0, cross_xs, mlp_pre, mlp_act, out, logits,
                mask: mask.to_vec() }
    }

    /// Mean BCE loss from cached logits.
    pub fn loss(&self, cache: &Cache, labels: &[u8]) -> f32 {
        let b = self.cfg.batch;
        let mut total = 0.0f64;
        for (&z, &y) in cache.logits.iter().zip(labels) {
            let z = z as f64;
            total += z.max(0.0) - z * (y as f64) + (-z.abs()).exp().ln_1p();
        }
        (total / b as f64) as f32
    }

    /// Backward pass: gradients w.r.t. unique embedding rows and the flat
    /// dense parameter vector.
    pub fn backward(
        &self,
        cache: &Cache,
        idx: &[i32],
        labels: &[u8],
        params: &[f32],
        n_unique: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let (b, f, d, k) = (cfg.batch, cfg.fields, cfg.emb_dim, cfg.input_dim());
        let last = *cfg.mlp.last().unwrap();
        let mut d_params = vec![0.0f32; params.len()];

        // d loss / d logit = (sigmoid(z) - y) / B
        let mut dlogit = vec![0.0f32; b];
        for i in 0..b {
            dlogit[i] = (ops::sigmoid(cache.logits[i]) - labels[i] as f32)
                / b as f32;
        }

        // head
        {
            let wf = self.param(params, "final_w").to_vec();
            let dwf = self.param_mut(&mut d_params, "final_w");
            // dWf[j] = sum_b out[b,j] * dlogit[b]
            for bi in 0..b {
                let row = &cache.out
                    [bi * (k + last)..(bi + 1) * (k + last)];
                for (j, &o) in row.iter().enumerate() {
                    dwf[j] += o * dlogit[bi];
                }
            }
            let dbf = self.param_mut(&mut d_params, "final_b");
            dbf[0] = dlogit.iter().sum();
            let _ = wf;
        }
        let wf = self.param(params, "final_w");
        let mut dout = vec![0.0f32; b * (k + last)];
        for bi in 0..b {
            for j in 0..k + last {
                dout[bi * (k + last) + j] = dlogit[bi] * wf[j];
            }
        }

        // split: cross grad + deep grad
        let mut dxl = vec![0.0f32; b * k];
        let mut da = vec![0.0f32; b * last];
        for bi in 0..b {
            dxl[bi * k..(bi + 1) * k].copy_from_slice(
                &dout[bi * (k + last)..bi * (k + last) + k],
            );
            da[bi * last..(bi + 1) * last].copy_from_slice(
                &dout[bi * (k + last) + k..(bi + 1) * (k + last)],
            );
        }

        // deep tower backward
        let mut dx0 = vec![0.0f32; b * k];
        {
            let mut moff_ends = Vec::new();
            let mut acc = 0;
            for &w in &cfg.mlp {
                moff_ends.push(acc);
                acc += w;
            }
            let mut da_cur = da;
            for i in (0..cfg.mlp.len()).rev() {
                let width = cfg.mlp[i];
                let prev_dim =
                    if i == 0 { k } else { cfg.mlp[i - 1] };
                let moff = moff_ends[i];
                // through mask and relu
                let mut dz = vec![0.0f32; b * width];
                for bi in 0..b {
                    for j in 0..width {
                        let m = cache.mask
                            [bi * cfg.mlp_mask_dim() + moff + j];
                        let pre = cache.mlp_pre[i][bi * width + j];
                        dz[bi * width + j] = da_cur[bi * width + j]
                            * m
                            * if pre > 0.0 { 1.0 } else { 0.0 };
                    }
                }
                let h_prev: &[f32] = if i == 0 {
                    &cache.x0
                } else {
                    &cache.mlp_act[i - 1]
                };
                // dW = h_prev^T dz ; db = sum dz ; da_prev = dz @ W^T
                {
                    let dw =
                        self.param_mut(&mut d_params, &format!("mlp_{i}_w"));
                    ops::matmul_tn(h_prev, &dz, dw, b, prev_dim, width);
                }
                {
                    let db =
                        self.param_mut(&mut d_params, &format!("mlp_{i}_b"));
                    for bi in 0..b {
                        for j in 0..width {
                            db[j] += dz[bi * width + j];
                        }
                    }
                }
                let w = self.param(params, &format!("mlp_{i}_w"));
                let mut da_prev = vec![0.0f32; b * prev_dim];
                ops::matmul_nt(&dz, w, &mut da_prev, b, width, prev_dim);
                if i == 0 {
                    for (o, &v) in dx0.iter_mut().zip(&da_prev) {
                        *o += v;
                    }
                } else {
                    da_cur = da_prev;
                }
            }
        }

        // cross tower backward (see kernels/ref.py cross_layer_bwd)
        {
            let mut g = dxl;
            let mut s = vec![0.0f32; b];
            for l in (0..cfg.cross_depth).rev() {
                let w = self.param(params, &format!("cross_{l}_w")).to_vec();
                let xl = &cache.cross_xs[l];
                ops::rowdot(xl, &w, &mut s, b, k);
                // r[bi] = sum_j g[bi,j] * x0[bi,j]
                let mut r = vec![0.0f32; b];
                for bi in 0..b {
                    let mut acc = 0.0f32;
                    for j in 0..k {
                        acc += g[bi * k + j] * cache.x0[bi * k + j];
                    }
                    r[bi] = acc;
                }
                {
                    let dw =
                        self.param_mut(&mut d_params, &format!("cross_{l}_w"));
                    for bi in 0..b {
                        for j in 0..k {
                            dw[j] += xl[bi * k + j] * r[bi];
                        }
                    }
                }
                {
                    let db =
                        self.param_mut(&mut d_params, &format!("cross_{l}_b"));
                    for bi in 0..b {
                        for j in 0..k {
                            db[j] += g[bi * k + j];
                        }
                    }
                }
                // dx0 += g * s ; g_next = g + r ⊗ w
                let mut g_next = vec![0.0f32; b * k];
                for bi in 0..b {
                    for j in 0..k {
                        dx0[bi * k + j] += g[bi * k + j] * s[bi];
                        g_next[bi * k + j] =
                            g[bi * k + j] + r[bi] * w[j];
                    }
                }
                g = g_next;
            }
            // the chain bottoms out at x0
            for (o, &v) in dx0.iter_mut().zip(&g) {
                *o += v;
            }
        }

        // scatter-add x0 grads back to unique embedding rows
        let mut d_emb = vec![0.0f32; n_unique * d];
        for bi in 0..b {
            for fi in 0..f {
                let u = idx[bi * f + fi] as usize;
                for j in 0..d {
                    d_emb[u * d + j] += dx0[bi * k + fi * d + j];
                }
            }
        }

        (d_emb, d_params)
    }

    /// Full training step (forward + loss + backward), mirroring the
    /// `train_fp` artifact contract.
    pub fn train_step(
        &self,
        emb: &[f32],
        idx: &[i32],
        labels: &[u8],
        params: &[f32],
        mask: &[f32],
        n_unique: usize,
    ) -> TrainOutput {
        let cache = self.forward(emb, idx, params, mask);
        let loss = self.loss(&cache, labels);
        let (d_emb, d_params) =
            self.backward(&cache, idx, labels, params, n_unique);
        TrainOutput { loss, logits: cache.logits, d_emb, d_params }
    }

    /// Inference: logits only (mask of ones).
    pub fn infer(&self, emb: &[f32], idx: &[i32], params: &[f32]) -> Vec<f32> {
        let ones = vec![1.0f32; self.cfg.batch * self.cfg.mlp_mask_dim()];
        self.forward(emb, idx, params, &ones).logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup() -> (Dcn, Vec<f32>, Vec<f32>, Vec<i32>, Vec<u8>, Vec<f32>, usize) {
        let cfg = DcnConfig {
            fields: 3,
            emb_dim: 4,
            batch: 8,
            cross_depth: 2,
            mlp: vec![10, 6],
        };
        let n_unique = 12;
        let mut rng = Pcg32::seeded(5);
        let dcn = Dcn::new(cfg.clone());
        let params = cfg.init_params(&mut rng);
        let emb: Vec<f32> = (0..n_unique * cfg.emb_dim)
            .map(|_| rng.normal_scaled(0.0, 0.2))
            .collect();
        let idx: Vec<i32> = (0..cfg.batch * cfg.fields)
            .map(|_| rng.below(n_unique as u32) as i32)
            .collect();
        let labels: Vec<u8> =
            (0..cfg.batch).map(|_| rng.bernoulli(0.4) as u8).collect();
        let mask = vec![1.0f32; cfg.batch * cfg.mlp_mask_dim()];
        (dcn, params, emb, idx, labels, mask, n_unique)
    }

    #[test]
    fn layout_matches_python_counts() {
        // tiny config: counted from configs.param_layout
        let cfg = DcnConfig::tiny();
        let k = 64;
        let expect = 2 * (k + k)       // cross w+b, depth 2
            + (k * 32 + 32) + (32 * 16 + 16)  // mlp
            + (k + 16)                 // final w: (k+last) x 1
            + 1;                       // final b
        assert_eq!(cfg.n_params(), expect);
    }

    #[test]
    fn forward_shapes_and_finite() {
        let (dcn, params, emb, idx, _labels, mask, _u) = setup();
        let cache = dcn.forward(&emb, &idx, &params, &mask);
        assert_eq!(cache.logits.len(), 8);
        assert!(cache.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn loss_matches_metrics_formula() {
        let (dcn, params, emb, idx, labels, mask, _u) = setup();
        let cache = dcn.forward(&emb, &idx, &params, &mask);
        let want = crate::metrics::logloss_from_logits(
            &cache.logits,
            &labels,
        ) as f32;
        assert!((dcn.loss(&cache, &labels) - want).abs() < 1e-6);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let (dcn, mut params, mut emb, idx, labels, mask, n_unique) = setup();
        let out = dcn.train_step(&emb, &idx, &labels, &params, &mask,
                                 n_unique);
        let eps = 3e-3f32;
        let mut rng = Pcg32::seeded(17);

        // a few random parameter coordinates
        for _ in 0..6 {
            let i = rng.below_usize(params.len());
            let orig = params[i];
            params[i] = orig + eps;
            let up = dcn
                .train_step(&emb, &idx, &labels, &params, &mask, n_unique)
                .loss;
            params[i] = orig - eps;
            let dn = dcn
                .train_step(&emb, &idx, &labels, &params, &mask, n_unique)
                .loss;
            params[i] = orig;
            let fd = (up - dn) / (2.0 * eps);
            let an = out.d_params[i];
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs(),
                "param {i}: fd={fd} analytic={an}"
            );
        }

        // a few embedding coordinates
        for _ in 0..6 {
            let i = rng.below_usize(emb.len());
            let orig = emb[i];
            emb[i] = orig + eps;
            let up = dcn
                .train_step(&emb, &idx, &labels, &params, &mask, n_unique)
                .loss;
            emb[i] = orig - eps;
            let dn = dcn
                .train_step(&emb, &idx, &labels, &params, &mask, n_unique)
                .loss;
            emb[i] = orig;
            let fd = (up - dn) / (2.0 * eps);
            let an = out.d_emb[i];
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs(),
                "emb {i}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn dropout_mask_zeroes_grad_flow() {
        let (dcn, params, emb, idx, labels, _mask, n_unique) = setup();
        let zero_mask = vec![0.0f32; dcn.cfg.batch * dcn.cfg.mlp_mask_dim()];
        let out =
            dcn.train_step(&emb, &idx, &labels, &params, &zero_mask, n_unique);
        // with the deep tower masked out, mlp weight grads must be zero
        let layout = dcn.cfg.param_layout();
        let mut off = 0;
        for (name, r, c, _) in layout {
            let g = &out.d_params[off..off + r * c];
            if name.starts_with("mlp_") && name.ends_with("_w") {
                assert!(g.iter().all(|&x| x == 0.0), "{name} grads nonzero");
            }
            off += r * c;
        }
    }

    #[test]
    fn training_reduces_loss_rust_path() {
        let (dcn, mut params, mut emb, _idx, _labels, mask, n_unique) =
            setup();
        let mut rng = Pcg32::seeded(23);
        // learnable rule: label = 1 if unique row 0 appears in field 0
        let mut first = f32::NAN;
        let mut last = 0.0;
        for step in 0..120 {
            let idx: Vec<i32> = (0..dcn.cfg.batch * dcn.cfg.fields)
                .map(|_| rng.below(n_unique as u32) as i32)
                .collect();
            let labels: Vec<u8> = (0..dcn.cfg.batch)
                .map(|bi| (idx[bi * dcn.cfg.fields] == 0) as u8)
                .collect();
            let out =
                dcn.train_step(&emb, &idx, &labels, &params, &mask, n_unique);
            for (p, g) in params.iter_mut().zip(&out.d_params) {
                *p -= 0.3 * g;
            }
            for (e, g) in emb.iter_mut().zip(&out.d_emb) {
                *e -= 2.0 * g;
            }
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(
            last < first - 0.1,
            "loss did not decrease: {first} -> {last}"
        );
    }
}
