//! Pure-Rust DCN forward/backward — a PJRT-free twin of the L2 JAX model.
//!
//! Three jobs: (1) integration tests pin the AOT HLO's loss/gradients
//! against this implementation on identical inputs; (2) a CPU fallback
//! compute path for environments without the PJRT shared library; (3) a
//! baseline for the §Perf comparisons. The parameter layout, math and
//! even reduction order choices mirror `python/compile/model.py` (layout
//! from `configs.param_layout`).

pub mod dcn;
pub mod ops;

pub use dcn::{Dcn, DcnConfig, TrainOutput};
