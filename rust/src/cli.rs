//! Tiny argument parser (offline: no `clap`). Supports subcommands,
//! `--flag`, `--key value` / `--key=value`, and positional arguments, with
//! generated usage text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = program name is
    /// NOT expected). `known_flags` lists boolean options that take no
    /// value; everything else starting with `--` consumes one.
    pub fn parse_tokens(
        tokens: &[String],
        expect_subcommand: bool,
        known_flags: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        if expect_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    args.subcommand = Some(it.next().unwrap().clone());
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            args.options.insert(name.to_string(), v.clone());
                        }
                        _ => bail!("option --{name} expects a value"),
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping the program name).
    pub fn from_env(expect_subcommand: bool, known_flags: &[&str]) -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Self::parse_tokens(&tokens, expect_subcommand, known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad value for --{name}: {s}")),
        }
    }
}

/// Parse a byte-size option value (`65536`, `64k`, `48m`, `2g`; binary
/// multiples, case-insensitive). One grammar for every size-taking flag
/// (`--budget`, `--replan-budget`, `--max-frame`); errors name the flag
/// so the user knows which one to fix.
pub fn parse_bytes(flag: &str, s: &str) -> Result<u64> {
    crate::config::parse_byte_budget(s).map_err(|e| {
        anyhow::anyhow!("bad value for --{flag}: {e} (expected e.g. 64k, 48m, 2g)")
    })
}

/// Parse a `HOST:PORT` option value and return it in normalized
/// `host:port` form. One grammar for every address-taking flag
/// (`serve --listen`, `train --listen-worker`, `worker --connect`);
/// errors name the flag.
pub fn parse_host_port(flag: &str, s: &str) -> Result<String> {
    let s = s.trim();
    let Some((host, port)) = s.rsplit_once(':') else {
        bail!("bad value for --{flag}: {s:?} (expected HOST:PORT, e.g. 127.0.0.1:4700)");
    };
    if host.is_empty() {
        bail!("bad value for --{flag}: {s:?} has an empty host");
    }
    let port: u16 = port.parse().map_err(|_| {
        anyhow::anyhow!("bad value for --{flag}: {s:?} has a bad port (expected 1-65535)")
    })?;
    Ok(format!("{host}:{port}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse_tokens(
            &toks("train --method alpt-sr --bits=4 --quick file.toml"),
            true,
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("method"), Some("alpt-sr"));
        assert_eq!(a.get("bits"), Some("4"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(
            Args::parse_tokens(&toks("--method"), false, &[]).is_err()
        );
        assert!(Args::parse_tokens(&toks("--a --b"), false, &[]).is_err());
    }

    #[test]
    fn get_parse_defaults() {
        let a = Args::parse_tokens(&toks("--bits 4"), false, &[]).unwrap();
        assert_eq!(a.get_parse::<u32>("bits", 8).unwrap(), 4);
        assert_eq!(a.get_parse::<u32>("epochs", 15).unwrap(), 15);
        assert!(a.get_parse::<u32>("bits", 8).is_ok());
        let b =
            Args::parse_tokens(&toks("--bits four"), false, &[]).unwrap();
        assert!(b.get_parse::<u32>("bits", 8).is_err());
    }

    #[test]
    fn get_parse_handles_precision_plans() {
        // `--bits` values flow through FromStr, so plan strings work
        // anywhere a width did
        use crate::config::PrecisionPlan;
        let a = Args::parse_tokens(&toks("--bits cat:4,num:8"), false, &[])
            .unwrap();
        let plan: PrecisionPlan =
            a.get_parse("bits", PrecisionPlan::uniform(8)).unwrap();
        assert_eq!(plan, PrecisionPlan::parse("cat:4,num:8").unwrap());
        let b = Args::parse_tokens(&toks("--bits cat:banana"), false, &[])
            .unwrap();
        assert!(b
            .get_parse::<PrecisionPlan>("bits", PrecisionPlan::uniform(8))
            .is_err());
    }

    #[test]
    fn parse_bytes_shared_grammar() {
        assert_eq!(parse_bytes("max-frame", "65536").unwrap(), 65536);
        assert_eq!(parse_bytes("budget", "64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("budget", "48M").unwrap(), 48 << 20);
        assert_eq!(parse_bytes("replan-budget", "2g").unwrap(), 2 << 30);
        let err = parse_bytes("max-frame", "lots").unwrap_err().to_string();
        assert!(err.contains("--max-frame"), "error names the flag: {err}");
    }

    #[test]
    fn parse_host_port_shared_grammar() {
        assert_eq!(
            parse_host_port("listen", "127.0.0.1:4700").unwrap(),
            "127.0.0.1:4700"
        );
        assert_eq!(
            parse_host_port("connect", " localhost:80 ").unwrap(),
            "localhost:80"
        );
        for bad in ["no-port", ":4700", "host:", "host:99999", "host:abc"] {
            let err =
                parse_host_port("listen-worker", bad).unwrap_err().to_string();
            assert!(
                err.contains("--listen-worker"),
                "error names the flag: {err}"
            );
        }
    }

    #[test]
    fn no_subcommand_when_dashes_first() {
        let a = Args::parse_tokens(&toks("--x 1 pos"), true, &[]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["pos"]);
    }
}
