//! Tiny argument parser (offline: no `clap`). Supports subcommands,
//! `--flag`, `--key value` / `--key=value`, and positional arguments, with
//! generated usage text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = program name is
    /// NOT expected). `known_flags` lists boolean options that take no
    /// value; everything else starting with `--` consumes one.
    pub fn parse_tokens(
        tokens: &[String],
        expect_subcommand: bool,
        known_flags: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        if expect_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    args.subcommand = Some(it.next().unwrap().clone());
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            args.options.insert(name.to_string(), v.clone());
                        }
                        _ => bail!("option --{name} expects a value"),
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping the program name).
    pub fn from_env(expect_subcommand: bool, known_flags: &[&str]) -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Self::parse_tokens(&tokens, expect_subcommand, known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad value for --{name}: {s}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse_tokens(
            &toks("train --method alpt-sr --bits=4 --quick file.toml"),
            true,
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("method"), Some("alpt-sr"));
        assert_eq!(a.get("bits"), Some("4"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(
            Args::parse_tokens(&toks("--method"), false, &[]).is_err()
        );
        assert!(Args::parse_tokens(&toks("--a --b"), false, &[]).is_err());
    }

    #[test]
    fn get_parse_defaults() {
        let a = Args::parse_tokens(&toks("--bits 4"), false, &[]).unwrap();
        assert_eq!(a.get_parse::<u32>("bits", 8).unwrap(), 4);
        assert_eq!(a.get_parse::<u32>("epochs", 15).unwrap(), 15);
        assert!(a.get_parse::<u32>("bits", 8).is_ok());
        let b =
            Args::parse_tokens(&toks("--bits four"), false, &[]).unwrap();
        assert!(b.get_parse::<u32>("bits", 8).is_err());
    }

    #[test]
    fn get_parse_handles_precision_plans() {
        // `--bits` values flow through FromStr, so plan strings work
        // anywhere a width did
        use crate::config::PrecisionPlan;
        let a = Args::parse_tokens(&toks("--bits cat:4,num:8"), false, &[])
            .unwrap();
        let plan: PrecisionPlan =
            a.get_parse("bits", PrecisionPlan::uniform(8)).unwrap();
        assert_eq!(plan, PrecisionPlan::parse("cat:4,num:8").unwrap());
        let b = Args::parse_tokens(&toks("--bits cat:banana"), false, &[])
            .unwrap();
        assert!(b
            .get_parse::<PrecisionPlan>("bits", PrecisionPlan::uniform(8))
            .is_err());
    }

    #[test]
    fn no_subcommand_when_dashes_first() {
        let a = Args::parse_tokens(&toks("--x 1 pos"), true, &[]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["pos"]);
    }
}
