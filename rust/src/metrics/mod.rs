//! Evaluation metrics for CTR prediction: exact AUC (tie-aware
//! Mann–Whitney), logloss, and calibration — the paper reports AUC and
//! Logloss (§4.1; +0.001 AUC is considered significant).

/// Exact ROC-AUC via the rank-sum formulation with average ranks for ties.
///
/// Returns 0.5 for degenerate inputs (all-one or all-zero labels).
pub fn auc(scores: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l != 0).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });

    // average ranks over tied groups; accumulate rank sum of positives
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0usize;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len()
            && scores[order[j + 1]] == scores[order[i]]
        {
            j += 1;
        }
        // ranks are 1-based: group spans ranks (i+1)..=(j+1)
        let avg_rank = (i + 1 + j + 1) as f64 / 2.0;
        for &k in &order[i..=j] {
            if labels[k] != 0 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let n_pos_f = n_pos as f64;
    let n_neg_f = n_neg as f64;
    (rank_sum_pos - n_pos_f * (n_pos_f + 1.0) / 2.0) / (n_pos_f * n_neg_f)
}

/// Mean binary cross-entropy from *logits* (numerically stable; mirrors
/// `model.bce_with_logits` in L2).
pub fn logloss_from_logits(logits: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    if logits.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    for (&z, &y) in logits.iter().zip(labels) {
        let z = z as f64;
        let y = y as f64;
        total += z.max(0.0) - z * y + (-z.abs()).exp().ln_1p();
    }
    total / logits.len() as f64
}

/// sigmoid for score conversion.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Calibration: mean predicted CTR / empirical CTR (1.0 = perfectly
/// calibrated on average).
pub fn calibration(logits: &[f32], labels: &[u8]) -> f64 {
    if logits.is_empty() {
        return 1.0;
    }
    let pred: f64 =
        logits.iter().map(|&z| sigmoid(z) as f64).sum::<f64>();
    let actual: f64 = labels.iter().map(|&y| y as f64).sum::<f64>();
    if actual == 0.0 {
        return f64::INFINITY;
    }
    pred / actual
}

/// Accumulates logits/labels across eval batches, then computes metrics
/// once at the end (AUC needs the full score set).
#[derive(Default)]
pub struct EvalAccumulator {
    logits: Vec<f32>,
    labels: Vec<u8>,
}

impl EvalAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// `valid` limits to the un-padded prefix of the final batch.
    pub fn push(&mut self, logits: &[f32], labels: &[u8], valid: usize) {
        self.logits.extend_from_slice(&logits[..valid]);
        self.labels.extend_from_slice(&labels[..valid]);
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn auc(&self) -> f64 {
        auc(&self.logits, &self.labels)
    }

    pub fn logloss(&self) -> f64 {
        logloss_from_logits(&self.logits, &self.labels)
    }

    pub fn calibration(&self) -> f64 {
        calibration(&self.logits, &self.labels)
    }
}

/// Fixed-memory streaming AUC: scores are bucketed through the sigmoid
/// into [`StreamingAuc::BUCKETS`] per-class histogram bins, and AUC is
/// the rank-sum over the histogram with the standard half-credit
/// treatment of within-bin ties. The approximation error is bounded by
/// the bin width (1/BUCKETS in probability space) — with 4096 bins it
/// sits far below the 0.001-AUC significance level the paper uses —
/// while state stays at 64 KiB no matter how long the eval stream is.
pub struct StreamingAuc {
    pos: Vec<u64>,
    neg: Vec<u64>,
}

impl StreamingAuc {
    pub const BUCKETS: usize = 4096;

    pub fn new() -> Self {
        Self {
            pos: vec![0; Self::BUCKETS],
            neg: vec![0; Self::BUCKETS],
        }
    }

    pub fn push(&mut self, logit: f32, label: u8) {
        let p = sigmoid(logit) as f64;
        let b = ((p * Self::BUCKETS as f64) as usize)
            .min(Self::BUCKETS - 1);
        if label != 0 {
            self.pos[b] += 1;
        } else {
            self.neg[b] += 1;
        }
    }

    /// Returns 0.5 for degenerate inputs, like [`auc`].
    pub fn auc(&self) -> f64 {
        let n_pos: u64 = self.pos.iter().sum();
        let n_neg: u64 = self.neg.iter().sum();
        if n_pos == 0 || n_neg == 0 {
            return 0.5;
        }
        let mut wins = 0.0f64;
        let mut neg_below = 0.0f64;
        for (p, n) in self.pos.iter().zip(&self.neg) {
            let (p, n) = (*p as f64, *n as f64);
            wins += p * (neg_below + 0.5 * n);
            neg_below += n;
        }
        wins / (n_pos as f64 * n_neg as f64)
    }
}

impl Default for StreamingAuc {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounded-memory eval accumulator for streaming datasets: histogram AUC
/// plus exact running logloss. The streaming counterpart of
/// [`EvalAccumulator`].
#[derive(Default)]
pub struct StreamingEval {
    auc: StreamingAuc,
    loss_sum: f64,
    n: usize,
}

impl StreamingEval {
    pub fn new() -> Self {
        Self::default()
    }

    /// `valid` limits to the un-padded prefix of the final batch.
    pub fn push(&mut self, logits: &[f32], labels: &[u8], valid: usize) {
        for (&z, &y) in logits[..valid].iter().zip(&labels[..valid]) {
            self.auc.push(z, y);
            let z = z as f64;
            self.loss_sum +=
                z.max(0.0) - z * y as f64 + (-z.abs()).exp().ln_1p();
        }
        self.n += valid;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn auc(&self) -> f64 {
        self.auc.auc()
    }

    pub fn logloss(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.loss_sum / self.n as f64
        }
    }
}

/// Fixed-memory, thread-safe latency histogram: geometric buckets from
/// 1 µs up (ratio [`LatencyHistogram::GROWTH`]), `AtomicU64` counters so
/// many server workers can [`LatencyHistogram::record_ms`] concurrently
/// with no lock on the request path. Percentiles interpolate inside the
/// matched bucket, so the relative error is bounded by the bucket ratio
/// (~10%) — plenty for p50/p95/p99 reporting, while state stays at a few
/// KiB no matter how many requests are recorded.
pub struct LatencyHistogram {
    buckets: Vec<std::sync::atomic::AtomicU64>,
    count: std::sync::atomic::AtomicU64,
    sum_us: std::sync::atomic::AtomicU64,
    max_us: std::sync::atomic::AtomicU64,
}

impl LatencyHistogram {
    /// Geometric bucket growth factor.
    pub const GROWTH: f64 = 1.1;
    /// 1.1^360 µs ≈ 8e8 s — covers any latency this crate can observe.
    pub const BUCKETS: usize = 360;

    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(Self::BUCKETS);
        buckets
            .resize_with(Self::BUCKETS, || std::sync::atomic::AtomicU64::new(0));
        Self {
            buckets,
            count: std::sync::atomic::AtomicU64::new(0),
            sum_us: std::sync::atomic::AtomicU64::new(0),
            max_us: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        // bucket i covers [GROWTH^i, GROWTH^{i+1}) µs; everything below
        // 1 µs lands in bucket 0
        if us <= 1 {
            return 0;
        }
        (((us as f64).ln() / Self::GROWTH.ln()) as usize)
            .min(Self::BUCKETS - 1)
    }

    /// Record one observation, in milliseconds (sub-µs clamps to 1 µs).
    pub fn record_ms(&self, ms: f64) {
        use std::sync::atomic::Ordering::Relaxed;
        let us = (ms * 1e3).max(1.0).round() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
        self.max_us.fetch_max(us, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Sum of all recorded latencies in milliseconds.
    pub fn total_ms(&self) -> f64 {
        self.sum_us.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_ms() / n as f64
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_us.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e3
    }

    /// Approximate percentile in milliseconds, `q` in [0, 100]
    /// (0.0 when nothing was recorded). Linear interpolation inside the
    /// matched geometric bucket.
    pub fn percentile_ms(&self, q: f64) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 100.0) / 100.0 * n as f64).max(1.0);
        let mut seen = 0.0f64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Relaxed) as f64;
            if c == 0.0 {
                continue;
            }
            if seen + c >= rank {
                let lo = Self::GROWTH.powi(i as i32);
                let hi = lo * Self::GROWTH;
                let frac = ((rank - seen) / c).clamp(0.0, 1.0);
                return (lo + (hi - lo) * frac) / 1e3;
            }
            seen += c;
        }
        self.max_ms()
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn auc_perfect_and_inverted() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        assert_eq!(auc(&scores, &labels), 1.0);
        let inv = [1, 1, 0, 0];
        assert_eq!(auc(&scores, &inv), 0.0);
    }

    #[test]
    fn auc_known_value() {
        // hand-computed: pairs (pos > neg): scores pos {0.8, 0.4},
        // neg {0.5, 0.3}. correct pairs: (0.8>0.5),(0.8>0.3),(0.4>0.3)=3 of 4
        let scores = [0.8, 0.5, 0.4, 0.3];
        let labels = [1, 0, 1, 0];
        assert!((auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auc_ties_average() {
        // one tie between a pos and a neg counts half
        let scores = [0.5, 0.5];
        let labels = [1, 0];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_is_half() {
        assert_eq!(auc(&[0.3, 0.7], &[1, 1]), 0.5);
        assert_eq!(auc(&[0.3, 0.7], &[0, 0]), 0.5);
    }

    #[test]
    fn auc_matches_pair_counting() {
        check("auc == exhaustive pair count", 60, |g| {
            let n = g.usize_in(2, 60);
            let scores: Vec<f32> =
                (0..n).map(|_| (g.usize_in(0, 9) as f32) / 10.0).collect();
            let labels: Vec<u8> = (0..n).map(|_| g.bool() as u8).collect();
            let n_pos = labels.iter().filter(|&&l| l == 1).count();
            if n_pos == 0 || n_pos == n {
                return Ok(());
            }
            let mut wins = 0.0f64;
            let mut pairs = 0.0f64;
            for i in 0..n {
                for j in 0..n {
                    if labels[i] == 1 && labels[j] == 0 {
                        pairs += 1.0;
                        if scores[i] > scores[j] {
                            wins += 1.0;
                        } else if scores[i] == scores[j] {
                            wins += 0.5;
                        }
                    }
                }
            }
            let want = wins / pairs;
            let got = auc(&scores, &labels);
            if (got - want).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("got {got} want {want}"))
            }
        });
    }

    #[test]
    fn logloss_matches_direct() {
        let logits = [0.0f32, 2.0, -1.0];
        let labels = [1u8, 0, 1];
        let mut want = 0.0f64;
        for (&z, &y) in logits.iter().zip(&labels) {
            let p = 1.0 / (1.0 + (-(z as f64)).exp());
            want -= if y == 1 { p.ln() } else { (1.0 - p).ln() };
        }
        want /= 3.0;
        assert!((logloss_from_logits(&logits, &labels) - want).abs() < 1e-9);
    }

    #[test]
    fn logloss_extreme_logits_finite() {
        let l = logloss_from_logits(&[40.0, -40.0], &[0, 1]);
        assert!(l.is_finite() && l > 10.0);
        let good = logloss_from_logits(&[40.0, -40.0], &[1, 0]);
        assert!(good >= 0.0 && good < 1e-6);
    }

    #[test]
    fn accumulator_respects_valid() {
        let mut acc = EvalAccumulator::new();
        acc.push(&[1.0, 2.0, 3.0], &[1, 0, 1], 2);
        assert_eq!(acc.len(), 2);
        acc.push(&[0.5], &[0], 1);
        assert_eq!(acc.len(), 3);
        assert!(acc.auc() > 0.0);
    }

    #[test]
    fn streaming_auc_tracks_exact_auc() {
        let mut rng = Pcg32::seeded(11);
        let n = 30_000;
        let logits: Vec<f32> =
            (0..n).map(|_| rng.normal_scaled(0.0, 1.5)).collect();
        let labels: Vec<u8> = logits
            .iter()
            .map(|&z| rng.bernoulli(sigmoid(0.8 * z)) as u8)
            .collect();
        let exact = auc(&logits, &labels);
        let mut streaming = StreamingAuc::new();
        for (&z, &y) in logits.iter().zip(&labels) {
            streaming.push(z, y);
        }
        let approx = streaming.auc();
        assert!(
            (approx - exact).abs() < 5e-4,
            "streaming {approx} vs exact {exact}"
        );
    }

    #[test]
    fn streaming_auc_degenerate_is_half() {
        let mut s = StreamingAuc::new();
        assert_eq!(s.auc(), 0.5);
        s.push(0.3, 1);
        s.push(2.0, 1);
        assert_eq!(s.auc(), 0.5);
    }

    #[test]
    fn streaming_auc_perfect_separation() {
        let mut s = StreamingAuc::new();
        for i in 0..50 {
            s.push(-4.0 - (i as f32) * 0.1, 0);
            s.push(4.0 + (i as f32) * 0.1, 1);
        }
        assert!(s.auc() > 0.999, "auc={}", s.auc());
    }

    #[test]
    fn streaming_eval_matches_batch_metrics() {
        let logits = [0.4f32, -1.2, 2.0, 0.0, -0.3, 1.1];
        let labels = [1u8, 0, 1, 0, 1, 0];
        let mut acc = StreamingEval::new();
        // push in two chunks, the second with a padded tail
        acc.push(&logits[..3], &labels[..3], 3);
        acc.push(&logits[3..], &labels[3..], 3);
        assert_eq!(acc.len(), 6);
        let exact_ll = logloss_from_logits(&logits, &labels);
        assert!((acc.logloss() - exact_ll).abs() < 1e-12);
        let exact_auc = auc(&logits, &labels);
        assert!((acc.auc() - exact_auc).abs() < 2e-3);
        // `valid` masks padding
        let mut masked = StreamingEval::new();
        masked.push(&logits, &labels, 4);
        assert_eq!(masked.len(), 4);
    }

    #[test]
    fn latency_histogram_percentiles_track_exact() {
        let h = LatencyHistogram::new();
        // 1..=1000 ms uniformly: p50 ≈ 500, p95 ≈ 950, p99 ≈ 990
        for i in 1..=1000 {
            h.record_ms(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.total_ms() - 500_500.0).abs() < 1.0);
        assert!((h.mean_ms() - 500.5).abs() < 0.1);
        for (q, want) in [(50.0, 500.0), (95.0, 950.0), (99.0, 990.0)] {
            let got = h.percentile_ms(q);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.12, "p{q}: got {got}, want ~{want}");
        }
        assert_eq!(h.max_ms(), 1000.0);
        assert!(h.percentile_ms(100.0) <= h.max_ms() * 1.11);
    }

    #[test]
    fn latency_histogram_empty_and_tiny_values() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ms(50.0), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
        // sub-µs values clamp to the 1 µs floor instead of panicking
        h.record_ms(0.0);
        h.record_ms(1e-9);
        assert_eq!(h.count(), 2);
        assert!(h.percentile_ms(50.0) > 0.0);
        assert!(h.percentile_ms(50.0) < 0.01);
    }

    #[test]
    fn latency_histogram_concurrent_records() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..500 {
                        h.record_ms((t * 500 + i) as f64 * 0.01 + 0.001);
                    }
                });
            }
        });
        assert_eq!(h.count(), 2000);
        assert!(h.percentile_ms(50.0) > 0.0);
        assert!(h.total_ms() > 0.0);
    }

    #[test]
    fn calibration_sane() {
        let mut rng = Pcg32::seeded(4);
        let n = 20_000;
        // perfectly calibrated: y ~ Bernoulli(sigmoid(z))
        let logits: Vec<f32> =
            (0..n).map(|_| rng.normal_scaled(0.0, 1.0)).collect();
        let labels: Vec<u8> = logits
            .iter()
            .map(|&z| rng.bernoulli(sigmoid(z)) as u8)
            .collect();
        let c = calibration(&logits, &labels);
        assert!((c - 1.0).abs() < 0.05, "calibration={c}");
    }
}
