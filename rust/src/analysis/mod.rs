//! Offline analyses: the paper's synthetic convex experiment (§3.1,
//! Figure 3) and the budgeted precision planner behind `auto:<bytes>`
//! plans and `alpt plan --budget`.
//!
//! Convex experiment — minimize f(w) = (w − 0.5)² for 1000 independent
//! parameters under full-precision SGD vs LPT with deterministic /
//! stochastic rounding. Expected shape (Theorems 1–2, Remark 1): SR
//! tracks the FP trajectory, DR stalls as soon as every update satisfies
//! |η∇f| < Δ/2 and the parameter distribution freezes away from the
//! optimum.
//!
//! Budget planner — see [`plan_for_budget`].

use crate::config::{FieldSel, GroupKind, PrecisionPlan};
use crate::data::Schema;
use crate::quant::{round_dr, round_sr, BitWidth};
use crate::util::rng::Pcg32;
use crate::util::stats::Histogram;
use anyhow::{bail, ensure, Result};

/// Training mode for the convex experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvexMode {
    FullPrecision,
    LptDr,
    LptSr,
}

impl ConvexMode {
    pub fn name(self) -> &'static str {
        match self {
            ConvexMode::FullPrecision => "FP",
            ConvexMode::LptDr => "DR",
            ConvexMode::LptSr => "SR",
        }
    }
}

/// Experiment settings. Paper values: 1000 params uniform in [0,1],
/// Δ = 0.01, m = 8, target 0.5.
///
/// On the learning rate: the paper states η = 1, but with f = (w−0.5)²
/// that makes plain SGD the exact reflection w ↦ 1−w (no convergence for
/// *any* variant), and η = 1/√t hits a degenerate exact-convergence step
/// at t = 4 — the published setup is under-specified. We use a small
/// constant η (default 0.052) where Remark 1 manifests cleanly: DR erases
/// every update once |η∇f| < Δ/2, i.e. freezes parameters anywhere within
/// radius Δ/(4η) ≈ 0.048 of the optimum, while SR (unbiased) walks to the
/// O(Δ²) floor and FP contracts geometrically to 0. (0.052 rather than
/// 0.05 so grid-aligned distances never hit the erase threshold exactly.)
#[derive(Clone, Debug)]
pub struct ConvexSpec {
    pub n_params: usize,
    pub target: f32,
    pub delta: f32,
    pub bits: BitWidth,
    pub eta0: f32,
    pub seed: u64,
    /// Decay LR like η/√t (the Theorem 1–2 schedule) instead of constant.
    pub sqrt_decay: bool,
}

impl Default for ConvexSpec {
    fn default() -> Self {
        Self {
            n_params: 1000,
            target: 0.5,
            delta: 0.01,
            bits: BitWidth::B8,
            eta0: 0.052,
            seed: 7,
            sqrt_decay: false,
        }
    }
}

/// Snapshot of the experiment at one recorded iteration.
#[derive(Clone, Debug)]
pub struct ConvexSnapshot {
    pub iteration: usize,
    pub mode: ConvexMode,
    pub mean_obj: f64,
    /// Number of params whose update DR would erase: |η∇f| < Δ/2
    /// (Figure 3d's curve).
    pub stalled: usize,
    pub histogram: Histogram,
}

/// Run the experiment, snapshotting at `record_at` iterations.
pub fn run_convex(
    spec: &ConvexSpec,
    mode: ConvexMode,
    iterations: usize,
    record_at: &[usize],
) -> Vec<ConvexSnapshot> {
    let mut rng = Pcg32::new(spec.seed, 0xC0);
    // identical inits across modes (fresh stream per run)
    let mut w: Vec<f32> =
        (0..spec.n_params).map(|_| rng.uniform_f32()).collect();
    let mut out = Vec::new();
    let qn = spec.bits.qn() as f32;
    let qp = spec.bits.qp() as f32;

    for t in 1..=iterations {
        let eta = if spec.sqrt_decay {
            spec.eta0 / (t as f32).sqrt()
        } else {
            spec.eta0
        };
        let mut stalled = 0usize;
        for wi in w.iter_mut() {
            let grad = 2.0 * (*wi - spec.target);
            if (eta * grad).abs() < spec.delta / 2.0 {
                stalled += 1;
            }
            let updated = *wi - eta * grad;
            *wi = match mode {
                ConvexMode::FullPrecision => updated,
                ConvexMode::LptDr => {
                    let x = (updated / spec.delta).clamp(qn, qp);
                    round_dr(x) * spec.delta
                }
                ConvexMode::LptSr => {
                    let x = (updated / spec.delta).clamp(qn, qp);
                    round_sr(x, rng.uniform_f32()) * spec.delta
                }
            };
        }
        if record_at.contains(&t) {
            let mut hist = Histogram::new(
                spec.target as f64 - 0.15,
                spec.target as f64 + 0.15,
                60,
            );
            let mut obj = 0.0f64;
            for &wi in &w {
                hist.push(wi as f64);
                let d = (wi - spec.target) as f64;
                obj += d * d;
            }
            out.push(ConvexSnapshot {
                iteration: t,
                mode,
                mean_obj: obj / spec.n_params as f64,
                stalled,
                histogram: hist,
            });
        }
    }
    out
}

// ------------------------------------------------------- budget planner

/// The packed widths the planner climbs through, cheapest first.
pub const PLAN_WIDTHS: [u32; 4] = [2, 4, 8, 16];

/// What [`plan_for_budget`] decided: the emitted plan, its predicted
/// inference footprint under the same cost model the greedy search used,
/// and the raw per-field assignments in field order.
#[derive(Clone, Debug)]
pub struct BudgetPlan {
    pub plan: PrecisionPlan,
    /// Predicted inference bytes of `plan` ([`plan_bytes`]); ≤ the budget
    /// whenever `plan_for_budget` succeeds.
    pub bytes: u64,
    /// Per-field [`GroupKind`] assignment, indexed by field.
    pub kinds: Vec<GroupKind>,
}

/// Predicted inference footprint, in bytes, of a per-field assignment.
///
/// Matches each store's `infer_bytes` accounting:
///
/// * packed width `b`: `rows · ceil(d·b/8)` code bytes, plus 4 bytes per
///   row of learned Δ under ALPT, or one shared 4-byte Δ per distinct
///   width group under LPT;
/// * `hash`: the quotient–remainder tables at remainder 2 —
///   `(2 + ceil(rows/2)) · d` f32s;
/// * `prune`: the schedule's steady state (R_x = 0.5 → half the dense
///   weights survive), `rows · d · 2` bytes. Early in the ramp the live
///   table is bigger; the budget is a shipping target, not a transient
///   training bound.
pub fn plan_bytes(
    kinds: &[GroupKind],
    vocabs: &[u32],
    dim: usize,
    is_alpt: bool,
) -> u64 {
    let d = dim as u64;
    let mut total = 0u64;
    let mut width_mask = 0u32;
    for (kind, &vocab) in kinds.iter().zip(vocabs) {
        let rows = vocab as u64;
        total += match kind {
            GroupKind::Bits(b) => {
                width_mask |= b; // widths are distinct powers of two
                let row_bytes = (d * *b as u64).div_ceil(8);
                rows * row_bytes + if is_alpt { rows * 4 } else { 0 }
            }
            GroupKind::Hashed => (2 + rows.div_ceil(2)) * d * 4,
            GroupKind::Pruned => rows * d * 2,
        };
    }
    if !is_alpt {
        total += width_mask.count_ones() as u64 * 4;
    }
    total
}

/// Mean access count per allocated row, field by field — the hotness
/// score [`plan_for_budget`] ranks on. Fields whose traffic concentrates
/// on a small vocabulary score high (every row is hot); long-tail fields
/// score low (most rows are cold). A field nobody touched scores 0.
pub fn field_scores_from_counts(
    counts: &[u32],
    schema: &Schema,
) -> Vec<f64> {
    (0..schema.n_fields())
        .map(|f| {
            let lo = schema.offsets[f] as usize;
            let hi = lo + schema.vocabs[f] as usize;
            let total: u64 =
                counts[lo..hi].iter().map(|&c| c as u64).sum();
            total as f64 / schema.vocabs[f] as f64
        })
        .collect()
}

/// The data-free fallback ranking (used to materialize `auto:<bytes>`
/// before any batch has run): under a uniform-traffic assumption each
/// field's per-row heat is inversely proportional to its vocabulary.
pub fn static_field_scores(vocabs: &[u32]) -> Vec<f64> {
    vocabs.iter().map(|&v| 1.0 / v as f64).collect()
}

/// Resolve a byte budget into a concrete per-field precision plan.
///
/// Deterministic greedy: every field starts at 2-bit (fields with score
/// 0 start `prune`d when `allow_structural` — nobody reads them, so the
/// dense-but-masked group costs quality nothing), then fields are
/// upgraded 2→4→8→16 one width per round in hotness order
/// ([`field_scores_from_counts`]; ties broken by field index) for as
/// long as the predicted footprint stays within `budget`. Zero-score
/// fields are never upgraded. `allow_structural` is off on the online
/// re-planning path, where a structural group would block future
/// migrations (shared parameters cannot be requantized row-by-row).
///
/// Errors when even the cheapest all-2-bit assignment overflows the
/// budget, naming the minimum feasible size.
pub fn plan_for_budget(
    vocabs: &[u32],
    scores: &[f64],
    dim: usize,
    is_alpt: bool,
    budget: u64,
    allow_structural: bool,
) -> Result<BudgetPlan> {
    ensure!(!vocabs.is_empty(), "no fields to plan");
    ensure!(
        vocabs.len() == scores.len(),
        "planner got {} fields but {} scores",
        vocabs.len(),
        scores.len()
    );
    ensure!(budget > 0, "budget must be positive");

    let n = vocabs.len();
    let mut kinds: Vec<GroupKind> = scores
        .iter()
        .map(|&s| {
            if allow_structural && s <= 0.0 {
                GroupKind::Pruned
            } else {
                GroupKind::Bits(2)
            }
        })
        .collect();

    // A pruned group still ships half its dense f32s — 8x a 2-bit row —
    // so under a tight budget untouched fields fall back to 2-bit codes,
    // biggest field first.
    let mut bytes = plan_bytes(&kinds, vocabs, dim, is_alpt);
    if bytes > budget {
        let mut pruned: Vec<usize> = (0..n)
            .filter(|&f| kinds[f] == GroupKind::Pruned)
            .collect();
        pruned.sort_by_key(|&f| std::cmp::Reverse(vocabs[f]));
        for f in pruned {
            if bytes <= budget {
                break;
            }
            kinds[f] = GroupKind::Bits(2);
            bytes = plan_bytes(&kinds, vocabs, dim, is_alpt);
        }
    }
    if bytes > budget {
        bail!(
            "budget of {budget} bytes cannot hold even an all-2-bit plan \
             for this geometry ({n} fields, dim {dim}: minimum {bytes} \
             bytes); raise the budget or shrink the embedding dim"
        );
    }

    // hotness order: score descending, field index breaking ties
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    loop {
        let mut upgraded = false;
        for &f in &order {
            if scores[f] <= 0.0 {
                continue;
            }
            let GroupKind::Bits(b) = kinds[f] else { continue };
            if b >= 16 {
                continue;
            }
            let mut trial = kinds.clone();
            trial[f] = GroupKind::Bits(b * 2);
            let trial_bytes = plan_bytes(&trial, vocabs, dim, is_alpt);
            if trial_bytes <= budget {
                kinds = trial;
                bytes = trial_bytes;
                upgraded = true;
            }
        }
        if !upgraded {
            break;
        }
    }

    // Emit the most-common width as the plan default (ties to the wider
    // width) and one fN rule per field that differs — the compactest
    // spelling that round-trips through the plan grammar.
    let mut default_bits = 0u32;
    let mut best = 0usize;
    for &width in &PLAN_WIDTHS {
        let c = kinds
            .iter()
            .filter(|k| **k == GroupKind::Bits(width))
            .count();
        if c > 0 && c >= best {
            best = c;
            default_bits = width;
        }
    }
    if default_bits == 0 {
        default_bits = 8; // all-structural plan: default backs nothing
    }
    let rules: Vec<(FieldSel, GroupKind)> = (0..n)
        .filter(|&f| kinds[f] != GroupKind::Bits(default_bits))
        .map(|f| (FieldSel::Field(f), kinds[f]))
        .collect();
    let plan = PrecisionPlan::from_rules(rules, default_bits);
    Ok(BudgetPlan { plan, bytes, kinds })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn final_obj(mode: ConvexMode, iters: usize) -> f64 {
        let spec = ConvexSpec::default();
        run_convex(&spec, mode, iters, &[iters])[0].mean_obj
    }

    #[test]
    fn fp_converges_to_target() {
        assert!(final_obj(ConvexMode::FullPrecision, 1000) < 1e-8);
    }

    #[test]
    fn sr_tracks_fp_dr_stalls() {
        // the paper's headline qualitative result
        let sr = final_obj(ConvexMode::LptSr, 1000);
        let dr = final_obj(ConvexMode::LptDr, 1000);
        assert!(
            dr > 5.0 * sr.max(1e-9),
            "DR should stall above SR: dr={dr} sr={sr}"
        );
        // SR reaches the quantization floor: O(delta^2)
        assert!(sr < 1e-3, "sr={sr}");
    }

    #[test]
    fn dr_stall_counter_saturates() {
        // remark 1: once |eta*grad| < delta/2 for everything, DR freezes
        let spec = ConvexSpec::default();
        let snaps =
            run_convex(&spec, ConvexMode::LptDr, 1000, &[10, 500, 1000]);
        let last = snaps.last().unwrap();
        assert_eq!(last.stalled, spec.n_params, "all params stalled");
        // and the objective no longer improves once frozen
        assert!((snaps[1].mean_obj - snaps[2].mean_obj).abs() < 1e-12);
    }

    #[test]
    fn snapshots_at_requested_iterations() {
        let spec = ConvexSpec::default();
        let snaps = run_convex(&spec, ConvexMode::LptSr, 1000,
                               &[10, 100, 1000]);
        assert_eq!(
            snaps.iter().map(|s| s.iteration).collect::<Vec<_>>(),
            vec![10, 100, 1000]
        );
        for s in &snaps {
            assert_eq!(s.histogram.total() as usize, spec.n_params);
        }
    }

    // ------------------------------------------------- budget planner

    #[test]
    fn planner_respects_budget_and_ranks_by_heat() {
        let vocabs = [16u32, 4096, 256];
        let scores = [50.0, 0.01, 3.0];
        let budget = 12_000u64;
        let got =
            plan_for_budget(&vocabs, &scores, 8, false, budget, false)
                .unwrap();
        assert!(got.bytes <= budget, "{} > {budget}", got.bytes);
        assert_eq!(
            got.bytes,
            plan_bytes(&got.kinds, &vocabs, 8, false),
            "reported bytes disagree with the cost model"
        );
        let width =
            |f: usize| got.kinds[f].bits().expect("packed assignment");
        assert!(
            width(0) >= width(2) && width(2) >= width(1),
            "heat order violated: {:?}",
            got.kinds
        );
        // the emitted grammar round-trips to the same plan
        let reparsed = PrecisionPlan::parse(&got.plan.key()).unwrap();
        assert_eq!(reparsed, got.plan);
    }

    #[test]
    fn planner_is_deterministic() {
        let vocabs = [40u32, 1000, 8, 300];
        let scores = [1.0, 0.2, 9.0, 0.2];
        let a = plan_for_budget(&vocabs, &scores, 16, true, 40_000, true)
            .unwrap();
        let b = plan_for_budget(&vocabs, &scores, 16, true, 40_000, true)
            .unwrap();
        assert_eq!(a.kinds, b.kinds);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn zero_score_fields_prune_only_when_structural_is_allowed() {
        let vocabs = [100u32, 100];
        let scores = [1.0, 0.0];
        let strict =
            plan_for_budget(&vocabs, &scores, 8, false, 1 << 20, false)
                .unwrap();
        assert!(strict.kinds.iter().all(|k| !k.is_structural()));
        // the cold field is never upgraded past the 2-bit floor
        assert_eq!(strict.kinds[1], GroupKind::Bits(2));

        let loose =
            plan_for_budget(&vocabs, &scores, 8, false, 1 << 20, true)
                .unwrap();
        assert_eq!(loose.kinds[1], GroupKind::Pruned);
        assert_eq!(loose.kinds[0], GroupKind::Bits(16), "budget is ample");
    }

    #[test]
    fn tight_budget_downgrades_pruned_fields_to_codes() {
        // pruned = rows*d*2 bytes; 2-bit = rows*d/4: only the downgrade
        // fits this budget
        let vocabs = [1000u32, 1000];
        let scores = [1.0, 0.0];
        let dim = 8;
        let all2 = plan_bytes(
            &[GroupKind::Bits(2), GroupKind::Bits(2)],
            &vocabs,
            dim,
            false,
        );
        let got = plan_for_budget(
            &vocabs, &scores, dim, false, all2 + 16, true,
        )
        .unwrap();
        assert_eq!(got.kinds[1], GroupKind::Bits(2));
        assert!(got.bytes <= all2 + 16);
    }

    #[test]
    fn infeasible_budget_names_the_minimum() {
        let err = plan_for_budget(&[1 << 20], &[1.0], 32, false, 64, false)
            .unwrap_err()
            .to_string();
        assert!(err.contains("all-2-bit"), "{err}");
        assert!(err.contains("minimum"), "{err}");
    }

    #[test]
    fn alpt_plans_charge_the_per_row_delta() {
        let kinds = [GroupKind::Bits(4), GroupKind::Bits(4)];
        let vocabs = [100u32, 50];
        let lpt = plan_bytes(&kinds, &vocabs, 8, false);
        let alpt = plan_bytes(&kinds, &vocabs, 8, true);
        assert_eq!(alpt, lpt - 4 + 150 * 4); // shared Δ out, row Δs in
    }

    #[test]
    fn count_scores_average_per_row_traffic() {
        let schema = Schema::new(vec![2, 3]);
        // field 0 rows hit [4, 0]; field 1 rows hit [1, 1, 1]
        let counts = [4u32, 0, 1, 1, 1];
        let scores = field_scores_from_counts(&counts, &schema);
        assert_eq!(scores, vec![2.0, 1.0]);
        let stat = static_field_scores(&[2, 4]);
        assert_eq!(stat, vec![0.5, 0.25]);
    }
}
