//! The paper's synthetic convex experiment (§3.1, Figure 3): minimize
//! f(w) = (w − 0.5)² for 1000 independent parameters under full-precision
//! SGD vs LPT with deterministic / stochastic rounding.
//!
//! Expected shape (Theorems 1–2, Remark 1): SR tracks the FP trajectory,
//! DR stalls as soon as every update satisfies |η∇f| < Δ/2 and the
//! parameter distribution freezes away from the optimum.

use crate::quant::{round_dr, round_sr, BitWidth};
use crate::util::rng::Pcg32;
use crate::util::stats::Histogram;

/// Training mode for the convex experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvexMode {
    FullPrecision,
    LptDr,
    LptSr,
}

impl ConvexMode {
    pub fn name(self) -> &'static str {
        match self {
            ConvexMode::FullPrecision => "FP",
            ConvexMode::LptDr => "DR",
            ConvexMode::LptSr => "SR",
        }
    }
}

/// Experiment settings. Paper values: 1000 params uniform in [0,1],
/// Δ = 0.01, m = 8, target 0.5.
///
/// On the learning rate: the paper states η = 1, but with f = (w−0.5)²
/// that makes plain SGD the exact reflection w ↦ 1−w (no convergence for
/// *any* variant), and η = 1/√t hits a degenerate exact-convergence step
/// at t = 4 — the published setup is under-specified. We use a small
/// constant η (default 0.052) where Remark 1 manifests cleanly: DR erases
/// every update once |η∇f| < Δ/2, i.e. freezes parameters anywhere within
/// radius Δ/(4η) ≈ 0.048 of the optimum, while SR (unbiased) walks to the
/// O(Δ²) floor and FP contracts geometrically to 0. (0.052 rather than
/// 0.05 so grid-aligned distances never hit the erase threshold exactly.)
#[derive(Clone, Debug)]
pub struct ConvexSpec {
    pub n_params: usize,
    pub target: f32,
    pub delta: f32,
    pub bits: BitWidth,
    pub eta0: f32,
    pub seed: u64,
    /// Decay LR like η/√t (the Theorem 1–2 schedule) instead of constant.
    pub sqrt_decay: bool,
}

impl Default for ConvexSpec {
    fn default() -> Self {
        Self {
            n_params: 1000,
            target: 0.5,
            delta: 0.01,
            bits: BitWidth::B8,
            eta0: 0.052,
            seed: 7,
            sqrt_decay: false,
        }
    }
}

/// Snapshot of the experiment at one recorded iteration.
#[derive(Clone, Debug)]
pub struct ConvexSnapshot {
    pub iteration: usize,
    pub mode: ConvexMode,
    pub mean_obj: f64,
    /// Number of params whose update DR would erase: |η∇f| < Δ/2
    /// (Figure 3d's curve).
    pub stalled: usize,
    pub histogram: Histogram,
}

/// Run the experiment, snapshotting at `record_at` iterations.
pub fn run_convex(
    spec: &ConvexSpec,
    mode: ConvexMode,
    iterations: usize,
    record_at: &[usize],
) -> Vec<ConvexSnapshot> {
    let mut rng = Pcg32::new(spec.seed, 0xC0);
    // identical inits across modes (fresh stream per run)
    let mut w: Vec<f32> =
        (0..spec.n_params).map(|_| rng.uniform_f32()).collect();
    let mut out = Vec::new();
    let qn = spec.bits.qn() as f32;
    let qp = spec.bits.qp() as f32;

    for t in 1..=iterations {
        let eta = if spec.sqrt_decay {
            spec.eta0 / (t as f32).sqrt()
        } else {
            spec.eta0
        };
        let mut stalled = 0usize;
        for wi in w.iter_mut() {
            let grad = 2.0 * (*wi - spec.target);
            if (eta * grad).abs() < spec.delta / 2.0 {
                stalled += 1;
            }
            let updated = *wi - eta * grad;
            *wi = match mode {
                ConvexMode::FullPrecision => updated,
                ConvexMode::LptDr => {
                    let x = (updated / spec.delta).clamp(qn, qp);
                    round_dr(x) * spec.delta
                }
                ConvexMode::LptSr => {
                    let x = (updated / spec.delta).clamp(qn, qp);
                    round_sr(x, rng.uniform_f32()) * spec.delta
                }
            };
        }
        if record_at.contains(&t) {
            let mut hist = Histogram::new(
                spec.target as f64 - 0.15,
                spec.target as f64 + 0.15,
                60,
            );
            let mut obj = 0.0f64;
            for &wi in &w {
                hist.push(wi as f64);
                let d = (wi - spec.target) as f64;
                obj += d * d;
            }
            out.push(ConvexSnapshot {
                iteration: t,
                mode,
                mean_obj: obj / spec.n_params as f64,
                stalled,
                histogram: hist,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn final_obj(mode: ConvexMode, iters: usize) -> f64 {
        let spec = ConvexSpec::default();
        run_convex(&spec, mode, iters, &[iters])[0].mean_obj
    }

    #[test]
    fn fp_converges_to_target() {
        assert!(final_obj(ConvexMode::FullPrecision, 1000) < 1e-8);
    }

    #[test]
    fn sr_tracks_fp_dr_stalls() {
        // the paper's headline qualitative result
        let sr = final_obj(ConvexMode::LptSr, 1000);
        let dr = final_obj(ConvexMode::LptDr, 1000);
        assert!(
            dr > 5.0 * sr.max(1e-9),
            "DR should stall above SR: dr={dr} sr={sr}"
        );
        // SR reaches the quantization floor: O(delta^2)
        assert!(sr < 1e-3, "sr={sr}");
    }

    #[test]
    fn dr_stall_counter_saturates() {
        // remark 1: once |eta*grad| < delta/2 for everything, DR freezes
        let spec = ConvexSpec::default();
        let snaps =
            run_convex(&spec, ConvexMode::LptDr, 1000, &[10, 500, 1000]);
        let last = snaps.last().unwrap();
        assert_eq!(last.stalled, spec.n_params, "all params stalled");
        // and the objective no longer improves once frozen
        assert!((snaps[1].mean_obj - snaps[2].mean_obj).abs() < 1e-12);
    }

    #[test]
    fn snapshots_at_requested_iterations() {
        let spec = ConvexSpec::default();
        let snaps = run_convex(&spec, ConvexMode::LptSr, 1000,
                               &[10, 100, 1000]);
        assert_eq!(
            snaps.iter().map(|s| s.iteration).collect::<Vec<_>>(),
            vec![10, 100, 1000]
        );
        for s in &snaps {
            assert_eq!(s.histogram.total() as usize, spec.n_params);
        }
    }
}
