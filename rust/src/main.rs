//! `alpt` — the command-line launcher.
//!
//! ```text
//! alpt train   --dataset avazu --method alpt-sr --plan 8 [--config f.toml]
//! alpt train   --dataset criteo:path/to/train.tsv --method alpt --plan 8
//! alpt plan    --dataset criteo:train.tsv --budget 64m   # budgeted plan
//! alpt gen     --dataset criteo --samples 100000 --out data.ds
//! alpt train   --dataset tiny --workers 2 --listen-worker 127.0.0.1:4700
//! alpt worker  --connect 127.0.0.1:4700   # one embedding shard, run N of these
//! alpt convex                      # the Figure-3 synthetic experiment
//! alpt info                        # artifact manifest + environment
//! ```

use alpt::cli::Args;
use alpt::config::{Experiment, Method};
use alpt::coordinator::Trainer;
use alpt::data::registry::{self, DataSource, DatasetSpec};
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::data::Dataset;
use anyhow::{bail, Context, Result};

const USAGE: &str = "\
alpt — Adaptive Low-Precision Training for CTR embeddings (AAAI 2023)

USAGE:
  alpt train  [--config FILE]
              [--dataset avazu|criteo|tiny|synthetic[:NAME]|criteo:FILE.tsv]
              [--method fp|lpt-sr|lpt-dr|alpt-sr|alpt-dr|lsq|pact|hashing|pruning]
              [--plan 2|4|8|16 | --plan cat:4,num:8 | --plan f3:2,default:8
               | --plan f0:hash,f2:prune,default:8 | --plan auto:BYTES]
              [--replan-budget BYTES]  (re-derive a budgeted plan from each
               epoch's access counts and migrate rows at the boundary)
              [--epochs N] [--samples N] [--seed N]
              [--model NAME] [--no-runtime]
              [--hash-bits N] [--numeric-buckets N] [--shuffle-window N]
              [--prefetch-batches N] [--save-every STEPS]
              [--compact-every DELTAS]  (fold the delta journal into a
               fresh full checkpoint after this many deltas, 64)
              [--save FILE.ckpt] [--resume FILE.ckpt]
              [--workers N]  (shard the embedding table across N `alpt
               worker` processes; bit-identical to single-process)
              [--listen-worker HOST:PORT]  (worker registration address,
               127.0.0.1:4700)
              [--no-overlap]  (disable batch-ahead RPC pipelining; the
               synchronous schedule — checkpoints are identical either way)
              [--rpc-timeout-ms MS] [--max-frame BYTES[k|m|g]]
              [--connect-retries N] [--retry-delay-ms MS]
  alpt worker [--connect HOST:PORT]  (serve one embedding shard to a
               coordinator started with --workers; 127.0.0.1:4700)
              [--idle-timeout-ms MS]  (exit if the coordinator goes
               silent this long, 600000)
              [--max-frame BYTES[k|m|g]] [--connect-retries N]
              [--retry-delay-ms MS]
  alpt plan   --budget BYTES[k|m|g]  (derive a per-field precision plan
               whose predicted inference footprint fits the budget)
              [--dataset ...] [--method ...] [--model NAME]
              [--sample N]  (train records scanned for access counts, 1M)
              [--out FILE]  (write the bare plan string to FILE)
  alpt serve  --ckpt FILE.ckpt [--batches N]     (no training: load + serve)
              [--listen HOST:PORT]  (online HTTP scoring server: POST /score,
               GET /healthz, GET /stats, POST /reload, POST /shutdown)
              [--workers N] [--wait-ms MS] [--queue-cap N]
              [--watch] [--watch-ms MS]  (poll the ckpt file and hot-swap
               on change; --watch-ms sets the poll/debounce period, 1000)
              [--dump-requests N]   (print held-out records + offline logits
               as JSON lines — the HTTP protocol's ground truth)
  alpt gen    --dataset NAME --samples N --out FILE.ds
  alpt convex                                    (Figure-3 experiment)
  alpt info                                      (manifest + environment)

Datasets: plain names are in-memory synthetic specs; `criteo:FILE.tsv`
streams a Criteo-format TSV (label + 13 numeric + 26 categorical columns)
from disk with on-the-fly feature hashing — see README.md \"Datasets\".

Precision plans: `--plan` takes one width for every field, a per-field
plan (`cat:4,num:8`, `f3:2,f7:16,default:8`, structural kinds `hash` /
`prune`), or a budget directive (`auto:BYTES`) resolved by the planner;
`--bits` is a deprecated alias with the same grammar — see README.md
\"Precision plans\" and \"Budgeted precision plans\".
";

fn main() -> Result<()> {
    let args =
        Args::from_env(
            true,
            &["no-runtime", "no-overlap", "quiet", "help", "watch"],
        )?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("train") => train(&args),
        Some("worker") => worker(&args),
        Some("serve") => serve(&args),
        Some("plan") => plan(&args),
        Some("gen") => gen(&args),
        Some("convex") => {
            convex();
            Ok(())
        }
        Some("info") => info(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn build_experiment(args: &Args) -> Result<Experiment> {
    let mut exp = if let Some(path) = args.get("config") {
        let doc = alpt::config::toml::TomlDoc::parse_file(
            std::path::Path::new(path),
        )
        .with_context(|| format!("reading {path}"))?;
        Experiment::from_toml(&doc)?
    } else {
        Experiment::default()
    };
    if let Some(ds) = args.get("dataset") {
        exp = exp.with_dataset_defaults(ds);
    }
    if let Some(m) = args.get("method") {
        exp.method = Method::parse(m)?;
    }
    if let Some(m) = args.get("model") {
        exp.model = m.to_string();
    }
    if args.get("bits").is_some() {
        // once per process: retry loops and multi-experiment drivers
        // shouldn't drown real output in the same line
        static BITS_DEPRECATED: std::sync::Once = std::sync::Once::new();
        BITS_DEPRECATED.call_once(|| {
            eprintln!(
                "warning: --bits is deprecated; use --plan (same grammar)"
            );
        });
    }
    exp.bits = args.get_parse("bits", exp.bits.clone())?;
    exp.bits = args.get_parse("plan", exp.bits.clone())?;
    if let Some(b) = args.get("replan-budget") {
        exp.replan_budget = alpt::cli::parse_bytes("replan-budget", b)? as usize;
    }
    exp.epochs = args.get_parse("epochs", exp.epochs)?;
    exp.seed = args.get_parse("seed", exp.seed)?;
    exp.n_samples = args.get_parse("samples", exp.n_samples)?;
    exp.hash_bits = args.get_parse("hash-bits", exp.hash_bits)?;
    exp.numeric_buckets =
        args.get_parse("numeric-buckets", exp.numeric_buckets)?;
    exp.shuffle_window =
        args.get_parse("shuffle-window", exp.shuffle_window)?;
    exp.prefetch_batches =
        args.get_parse("prefetch-batches", exp.prefetch_batches)?;
    exp.save_every = args.get_parse("save-every", exp.save_every)?;
    exp.compact_every =
        args.get_parse("compact-every", exp.compact_every)?;
    if args.flag("no-runtime") {
        exp.use_runtime = false;
    }
    Ok(exp)
}

fn make_spec(exp: &Experiment) -> Result<SyntheticSpec> {
    match DatasetSpec::parse(&exp.dataset) {
        DatasetSpec::Synthetic(name)
        | DatasetSpec::SyntheticStream(name) => {
            SyntheticSpec::for_dataset(&name, exp.seed, exp.vocab_scale)
        }
        DatasetSpec::CriteoFile(path) => {
            bail!("{} streams from disk (no synthetic spec)", path.display())
        }
    }
}

fn train(args: &Args) -> Result<()> {
    // --resume warm-starts every piece of training state from a
    // checkpoint; the experiment configuration comes from the file's
    // metadata echo, so other config flags are ignored (a fresh run with
    // different settings should start from `alpt train` instead).
    let mut trainer = if let Some(ckpt) = args.get("resume") {
        let mut trainer = Trainer::resume(std::path::Path::new(ckpt))?;
        // --epochs may raise the budget of a finished run; everything
        // else comes from the echo
        trainer.exp.epochs =
            args.get_parse("epochs", trainer.exp.epochs)?;
        println!(
            "resumed {} from {ckpt} ({} epochs done, budget {})",
            trainer.store.method_name(),
            trainer.epochs_done,
            trainer.exp.epochs
        );
        trainer
    } else {
        let exp = build_experiment(args)?;
        let n_features = registry::schema_for(&exp)?.n_features();
        Trainer::new(exp, n_features)?
    };
    // --workers shards the embedding table across remote processes.
    // Worker layout is CLI-level state (never in the experiment or the
    // checkpoint), so fresh runs, resumes, and reshards all attach here.
    let n_workers: usize = args.get_parse("workers", 0usize)?;
    if n_workers > 0 {
        let listen = alpt::cli::parse_host_port(
            "listen-worker",
            args.get_or("listen-worker", "127.0.0.1:4700"),
        )?;
        let d = alpt::coordinator::RpcConfig::default();
        let cfg = alpt::coordinator::RpcConfig {
            timeout_ms: args.get_parse("rpc-timeout-ms", d.timeout_ms)?,
            connect_retries: args
                .get_parse("connect-retries", d.connect_retries)?,
            retry_delay_ms: args
                .get_parse("retry-delay-ms", d.retry_delay_ms)?,
            max_frame: match args.get("max-frame") {
                Some(s) => alpt::cli::parse_bytes("max-frame", s)?,
                None => d.max_frame,
            },
            ..d
        };
        trainer.set_rpc_overlap(!args.flag("no-overlap"));
        trainer.attach_workers(&listen, n_workers, cfg)?;
    }
    let exp = trainer.exp.clone();
    if DatasetSpec::parse(&exp.dataset).is_streaming() {
        return train_streaming(&mut trainer, args);
    }
    let spec = make_spec(&exp)?;
    println!("generating {} samples of {}...", exp.n_samples, spec.name);
    let ds = generate(&spec, exp.n_samples);
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
    println!(
        "training {} (bits {}) on {} [{} runtime]",
        trainer.store.method_name(),
        exp.bits,
        spec.name,
        if trainer.uses_runtime() { "PJRT" } else { "rust-nn" }
    );
    let res = trainer.train(&train, &val, !args.flag("quiet"))?;
    let ev = trainer.evaluate(&test)?;
    println!(
        "\n{}: test auc {:.4}  logloss {:.5}  compress {:.1}x train / \
         {:.1}x infer  ({:.1}s/epoch)",
        res.method,
        ev.auc,
        ev.logloss,
        res.train_compression,
        res.infer_compression,
        res.seconds_per_epoch
    );
    if let Some(path) = args.get("save") {
        trainer.save_checkpoint(std::path::Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    if let Some(remote) = trainer.store.as_remote() {
        print_rpc_latency(remote);
        remote.shutdown()?;
    }
    Ok(())
}

/// Per-shard RPC latency lines for the train report: one line per
/// worker, covering every response-bearing wave (gathers, update
/// acks/drains, barriers, checkpoint reads) since attach. A shard
/// whose p99 stands out is the straggler bounding the fan-out.
fn print_rpc_latency(remote: &alpt::embedding::RemoteStore) {
    for (shard, h) in remote.rpc_latency().iter().enumerate() {
        if h.count() == 0 {
            continue;
        }
        println!(
            "  rpc shard {shard}: {} waves  mean {:.2} ms  p50 {:.2} ms  \
             p99 {:.2} ms  max {:.2} ms",
            h.count(),
            h.mean_ms(),
            h.percentile_ms(50.0),
            h.percentile_ms(99.0),
            h.max_ms()
        );
    }
}

/// The streaming training path (`criteo:<path>` / `synthetic[:name]`):
/// epochs stream from the source with a deterministic holdout split;
/// reported metrics come from the held-out split rather than a third
/// test partition.
fn train_streaming(trainer: &mut Trainer, args: &Args) -> Result<()> {
    let exp = trainer.exp.clone();
    let source = registry::open_source(&exp)?;
    println!(
        "streaming {}: {} fields, {} feature rows (hash_bits {}, \
         window {}, prefetch {})",
        source.name(),
        source.schema().n_fields(),
        source.schema().n_features(),
        exp.hash_bits,
        exp.shuffle_window,
        exp.prefetch_batches
    );
    println!(
        "training {} (bits {}) [{} runtime]",
        trainer.store.method_name(),
        exp.bits,
        if trainer.uses_runtime() { "PJRT" } else { "rust-nn" }
    );
    let save_path = args.get("save").map(std::path::Path::new);
    if save_path.is_none() && exp.save_every > 0 {
        if args.get("save-every").is_some() {
            // explicitly requested this invocation: refusing beats
            // silently writing no checkpoints for hours
            bail!(
                "--save-every {} needs --save FILE.ckpt to write the \
                 mid-stream checkpoints to",
                exp.save_every
            );
        }
        // inherited from a config file / resume echo: warn and run
        eprintln!(
            "warning: save_every {} is set but no --save path was \
             given; mid-stream checkpoints are disabled",
            exp.save_every
        );
    }
    let res =
        trainer.train_stream(source.as_ref(), !args.flag("quiet"), save_path)?;
    // train_stream already evaluated the held-out split after the final
    // epoch and the model has not changed since; re-evaluate only when
    // no epoch ran (e.g. resuming an already-finished run)
    let (auc, logloss) = match res.history.last() {
        Some(r) => (r.val_auc, r.val_logloss),
        None => {
            let ev = trainer.evaluate_source(source.as_ref())?;
            (ev.auc, ev.logloss)
        }
    };
    for w in source.warnings() {
        eprintln!("warning: {w}");
    }
    println!(
        "\n{}: held-out auc {auc:.4}  logloss {logloss:.5}  compress \
         {:.1}x train / {:.1}x infer  ({:.1}s/epoch)",
        res.method,
        res.train_compression,
        res.infer_compression,
        res.seconds_per_epoch
    );
    if let Some(path) = save_path {
        trainer.save_checkpoint(path)?;
        println!("checkpoint saved to {}", path.display());
    }
    if let Some(remote) = trainer.store.as_remote() {
        print_rpc_latency(remote);
        remote.shutdown()?;
    }
    Ok(())
}

/// `alpt worker --connect HOST:PORT`: host one shard of the embedding
/// table for a coordinator started with `--workers N`. Blocks until the
/// coordinator sends SHUTDOWN (clean exit) or the connection dies
/// (nonzero exit — the coordinator notices the same way).
fn worker(args: &Args) -> Result<()> {
    use alpt::cli::{parse_bytes, parse_host_port};
    use alpt::coordinator::{run_worker, WorkerOpts};

    // fault-injection hook (used by the CI kill leg): crash after
    // serving this many UPDATE frames
    let die_after_updates = match std::env::var("ALPT_WORKER_DIE_AFTER") {
        Ok(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad ALPT_WORKER_DIE_AFTER {v:?} (expected a count)")
        })?),
        Err(_) => None,
    };
    let d = WorkerOpts::default();
    let opts = WorkerOpts {
        connect: parse_host_port(
            "connect",
            args.get_or("connect", "127.0.0.1:4700"),
        )?,
        idle_timeout_ms: args
            .get_parse("idle-timeout-ms", d.idle_timeout_ms)?,
        max_frame: match args.get("max-frame") {
            Some(s) => parse_bytes("max-frame", s)?,
            None => d.max_frame,
        },
        connect_retries: args
            .get_parse("connect-retries", d.connect_retries)?,
        retry_delay_ms: args.get_parse("retry-delay-ms", d.retry_delay_ms)?,
        die_after_updates,
    };
    run_worker(&opts)
}

/// `alpt plan --budget BYTES`: the offline half of budgeted precision
/// planning. Streams the dataset's training split once, tallying per-row
/// access counts, ranks fields by mean per-row traffic, and greedily
/// assigns bit widths (hot fields wide, cold fields 2-bit, untouched
/// fields pruned) until the predicted inference footprint fills the
/// budget. Prints the plan string — feed it back to `alpt train --plan`
/// (or write it to a file with `--out`).
fn plan(args: &Args) -> Result<()> {
    use alpt::analysis::{field_scores_from_counts, plan_for_budget};
    use alpt::data::registry::RecordStream;

    let exp = build_experiment(args)?;
    let budget = match args.get("budget") {
        Some(s) => alpt::cli::parse_bytes("budget", s)?,
        None => exp.bits.auto_budget().ok_or_else(|| {
            anyhow::anyhow!(
                "plan requires --budget BYTES (or --plan auto:BYTES)"
            )
        })?,
    };
    if !exp.method.trains_quantized() {
        bail!(
            "plan picks per-field bit widths, which only \
             quantized-training methods use; method {} has no packed \
             table (use --method lpt/alpt)",
            exp.method.key()
        );
    }
    let schema = registry::schema_for(&exp)?;
    let entry = alpt::coordinator::builtin_entry(&exp.model)?;

    // one pass over the training split (the same records epoch 1 sees),
    // counting how often each embedding row is touched
    let source = registry::open_source(&exp)?;
    let mut stream =
        registry::train_epoch_stream(source.as_ref(), &exp, 1)?;
    let cap: u64 = args.get_parse("sample", 1_000_000u64)?;
    let mut counts = vec![0u32; schema.n_features()];
    let mut buf = vec![0u32; schema.n_fields()];
    let mut seen = 0u64;
    while seen < cap {
        match stream.next_record(&mut buf)? {
            None => break,
            Some(_) => {
                for &id in &buf {
                    let c = &mut counts[id as usize];
                    *c = c.saturating_add(1);
                }
                seen += 1;
            }
        }
    }
    for w in source.warnings() {
        eprintln!("warning: {w}");
    }
    if seen == 0 {
        bail!(
            "the training split of {} produced no records to count",
            source.name()
        );
    }

    let scores = field_scores_from_counts(&counts, &schema);
    let is_alpt =
        matches!(exp.method, Method::Alpt(_));
    let report = plan_for_budget(
        &schema.vocabs,
        &scores,
        entry.emb_dim,
        is_alpt,
        budget,
        true,
    )?;
    println!(
        "scanned {seen} train records over {} fields ({} feature rows, \
         dim {})",
        schema.n_fields(),
        schema.n_features(),
        entry.emb_dim
    );
    for (f, kind) in report.kinds.iter().enumerate() {
        println!(
            "  f{f}: vocab {:>8}  score {:>10.3}  -> {}",
            schema.vocabs[f],
            scores[f],
            kind.key()
        );
    }
    println!("plan: {}", report.plan.key());
    println!(
        "predicted inference bytes: {} / budget {budget} ({:.1}%)",
        report.bytes,
        100.0 * report.bytes as f64 / budget as f64
    );
    assert!(report.bytes <= budget, "planner exceeded its budget");
    if let Some(out) = args.get("out") {
        std::fs::write(out, format!("{}\n", report.plan.key()))
            .with_context(|| format!("writing {out}"))?;
        println!("plan written to {out}");
    }
    Ok(())
}

/// Load a checkpoint and serve CTR requests from it through the shared
/// `InferenceEngine` — no training step anywhere. Three modes: the
/// offline batch-eval report (default), `--dump-requests N` (JSON lines
/// of held-out records + their offline logits), and `--listen HOST:PORT`
/// (the online HTTP scoring server with micro-batching and `/reload`
/// hot-swap).
fn serve(args: &Args) -> Result<()> {
    use alpt::coordinator::{sample_requests, serve_checkpoint};

    let path = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("serve requires --ckpt FILE.ckpt"))?;
    let ckpt = std::path::Path::new(path);

    if let Some(n) = args.get("dump-requests") {
        let n: usize = n
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --dump-requests {n:?}"))?;
        for r in sample_requests(ckpt, n)? {
            let features = alpt::util::json::Json::Array(
                r.features
                    .iter()
                    .map(|&id| alpt::util::json::Json::num(id as f64))
                    .collect(),
            );
            let line = alpt::util::json::Json::obj(vec![
                ("features", features),
                ("logit", alpt::util::json::Json::num(r.logit as f64)),
            ]);
            println!("{}", line.to_string());
        }
        return Ok(());
    }

    if let Some(listen) = args.get("listen") {
        let listen = alpt::cli::parse_host_port("listen", listen)?;
        return serve_http(args, &listen, ckpt);
    }

    let max_batches = args.get_parse("batches", usize::MAX)?;
    let report = serve_checkpoint(ckpt, max_batches)?;
    println!(
        "loaded {} checkpoint: {} rows x {} dims, {} KB table \
         ({:.1}x smaller than fp32)",
        report.method,
        report.n_features,
        report.dim,
        report.infer_bytes / 1024,
        report.fp_bytes as f64 / report.infer_bytes as f64
    );
    println!(
        "served {} requests in {} batches: auc {:.4}, p50 {:.2} ms, \
         p95 {:.2} ms, p99 {:.2} ms, {:.0} req/s",
        report.requests,
        report.batches(),
        report.auc,
        report.p50_ms(),
        report.p95_ms(),
        report.p99_ms(),
        report.requests_per_sec()
    );
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    Ok(())
}

/// `alpt serve --listen HOST:PORT`: block on the online scoring server
/// until `POST /shutdown`.
fn serve_http(
    args: &Args,
    listen: &str,
    ckpt: &std::path::Path,
) -> Result<()> {
    use alpt::serve::{Server, ServerConfig};

    let mut cfg = ServerConfig::new(listen, ckpt);
    cfg.workers = args.get_parse("workers", cfg.workers)?;
    cfg.max_wait = std::time::Duration::from_millis(
        args.get_parse("wait-ms", cfg.max_wait.as_millis() as u64)?,
    );
    cfg.queue_cap = args.get_parse("queue-cap", cfg.queue_cap)?;
    if args.flag("watch") {
        cfg.watch = Some(std::time::Duration::from_millis(
            args.get_parse("watch-ms", 1000u64)?,
        ));
    }
    let server = Server::bind(cfg)?;
    let engine = server.engine_handle().current();
    println!(
        "serving {} ({} rows x {} dims, batch {}) on http://{}",
        engine.method_name(),
        engine.n_features(),
        engine.dim(),
        engine.batch_size(),
        server.local_addr()?
    );
    println!(
        "endpoints: POST /score  GET /healthz  GET /stats  POST /reload  \
         POST /shutdown"
    );
    server.run()
}

fn gen(args: &Args) -> Result<()> {
    let exp = build_experiment(args)?;
    let spec = make_spec(&exp)?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("gen requires --out FILE.ds"))?;
    println!("generating {} samples of {}...", exp.n_samples, spec.name);
    let ds = generate(&spec, exp.n_samples);
    ds.write(std::path::Path::new(out))?;
    println!(
        "wrote {out}: {} samples, {} fields, {} features, ctr {:.4}",
        ds.n_samples(),
        ds.n_fields(),
        ds.schema.n_features(),
        ds.ctr()
    );
    // round-trip sanity
    let back = Dataset::read(std::path::Path::new(out))?;
    assert_eq!(back.n_samples(), ds.n_samples());
    Ok(())
}

fn convex() {
    use alpt::analysis::{run_convex, ConvexMode, ConvexSpec};
    let spec = ConvexSpec::default();
    for mode in [ConvexMode::FullPrecision, ConvexMode::LptDr,
                 ConvexMode::LptSr] {
        let snaps = run_convex(&spec, mode, 1000, &[10, 100, 1000]);
        println!("--- {} ---", mode.name());
        for s in &snaps {
            println!(
                "  t={:<5} mean obj {:.3e}  stalled {:>4}  |{}|",
                s.iteration,
                s.mean_obj,
                s.stalled,
                s.histogram.sparkline()
            );
        }
    }
}

fn info(args: &Args) -> Result<()> {
    println!("alpt {}", alpt::version());
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts-dir", "artifacts"),
    );
    match alpt::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts in {}:", dir.display());
            for (name, entry) in &rt.manifest.configs {
                println!(
                    "  {name}: F={} d={} B={} cross={} mlp={:?} P={} \
                     ({} variants)",
                    entry.fields,
                    entry.emb_dim,
                    entry.batch,
                    entry.cross_depth,
                    entry.mlp,
                    entry.n_params,
                    entry.artifacts.len()
                );
            }
        }
        Err(e) => println!("no runtime: {e:#}"),
    }
    Ok(())
}
