//! `alpt` — the command-line launcher.
//!
//! ```text
//! alpt train   --dataset avazu --method alpt-sr --bits 8 [--config f.toml]
//! alpt gen     --dataset criteo --samples 100000 --out data.ds
//! alpt convex                      # the Figure-3 synthetic experiment
//! alpt info                        # artifact manifest + environment
//! ```

use alpt::cli::Args;
use alpt::config::{Experiment, Method};
use alpt::coordinator::Trainer;
use alpt::data::synthetic::{generate, SyntheticSpec};
use alpt::data::Dataset;
use anyhow::{bail, Context, Result};

const USAGE: &str = "\
alpt — Adaptive Low-Precision Training for CTR embeddings (AAAI 2023)

USAGE:
  alpt train  [--config FILE] [--dataset avazu|criteo|tiny]
              [--method fp|lpt-sr|lpt-dr|alpt-sr|alpt-dr|lsq|pact|hashing|pruning]
              [--bits 2|4|8|16] [--epochs N] [--samples N] [--seed N]
              [--model NAME] [--no-runtime]
  alpt gen    --dataset NAME --samples N --out FILE.ds
  alpt convex                                    (Figure-3 experiment)
  alpt info                                      (manifest + environment)
";

fn main() -> Result<()> {
    let args = Args::from_env(true, &["no-runtime", "quiet", "help"])?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("train") => train(&args),
        Some("gen") => gen(&args),
        Some("convex") => {
            convex();
            Ok(())
        }
        Some("info") => info(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn build_experiment(args: &Args) -> Result<Experiment> {
    let mut exp = if let Some(path) = args.get("config") {
        let doc = alpt::config::toml::TomlDoc::parse_file(
            std::path::Path::new(path),
        )
        .with_context(|| format!("reading {path}"))?;
        Experiment::from_toml(&doc)?
    } else {
        Experiment::default()
    };
    if let Some(ds) = args.get("dataset") {
        exp = exp.with_dataset_defaults(ds);
    }
    if let Some(m) = args.get("method") {
        exp.method = Method::parse(m)?;
    }
    if let Some(m) = args.get("model") {
        exp.model = m.to_string();
    }
    exp.bits = args.get_parse("bits", exp.bits)?;
    exp.epochs = args.get_parse("epochs", exp.epochs)?;
    exp.seed = args.get_parse("seed", exp.seed)?;
    exp.n_samples = args.get_parse("samples", exp.n_samples)?;
    if args.flag("no-runtime") {
        exp.use_runtime = false;
    }
    Ok(exp)
}

fn make_spec(exp: &Experiment) -> Result<SyntheticSpec> {
    Ok(match exp.dataset.as_str() {
        "avazu" => SyntheticSpec::avazu(exp.seed),
        "criteo" => SyntheticSpec::criteo(exp.seed),
        "tiny" => SyntheticSpec::tiny(exp.seed),
        other => bail!("unknown dataset {other:?}"),
    })
}

fn train(args: &Args) -> Result<()> {
    let exp = build_experiment(args)?;
    let spec = make_spec(&exp)?;
    println!("generating {} samples of {}...", exp.n_samples, spec.name);
    let ds = generate(&spec, exp.n_samples);
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
    let mut trainer = Trainer::new(exp.clone(), ds.schema.n_features())?;
    println!(
        "training {} ({} bits) on {} [{} runtime]",
        trainer.store.method_name(),
        exp.bits,
        spec.name,
        if trainer.uses_runtime() { "PJRT" } else { "rust-nn" }
    );
    let res = trainer.train(&train, &val, !args.flag("quiet"))?;
    let ev = trainer.evaluate(&test)?;
    println!(
        "\n{}: test auc {:.4}  logloss {:.5}  compress {:.1}x train / \
         {:.1}x infer  ({:.1}s/epoch)",
        res.method,
        ev.auc,
        ev.logloss,
        res.train_compression,
        res.infer_compression,
        res.seconds_per_epoch
    );
    Ok(())
}

fn gen(args: &Args) -> Result<()> {
    let exp = build_experiment(args)?;
    let spec = make_spec(&exp)?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("gen requires --out FILE.ds"))?;
    println!("generating {} samples of {}...", exp.n_samples, spec.name);
    let ds = generate(&spec, exp.n_samples);
    ds.write(std::path::Path::new(out))?;
    println!(
        "wrote {out}: {} samples, {} fields, {} features, ctr {:.4}",
        ds.n_samples(),
        ds.n_fields(),
        ds.schema.n_features(),
        ds.ctr()
    );
    // round-trip sanity
    let back = Dataset::read(std::path::Path::new(out))?;
    assert_eq!(back.n_samples(), ds.n_samples());
    Ok(())
}

fn convex() {
    use alpt::analysis::{run_convex, ConvexMode, ConvexSpec};
    let spec = ConvexSpec::default();
    for mode in [ConvexMode::FullPrecision, ConvexMode::LptDr,
                 ConvexMode::LptSr] {
        let snaps = run_convex(&spec, mode, 1000, &[10, 100, 1000]);
        println!("--- {} ---", mode.name());
        for s in &snaps {
            println!(
                "  t={:<5} mean obj {:.3e}  stalled {:>4}  |{}|",
                s.iteration,
                s.mean_obj,
                s.stalled,
                s.histogram.sparkline()
            );
        }
    }
}

fn info(args: &Args) -> Result<()> {
    println!("alpt {}", alpt::version());
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts-dir", "artifacts"),
    );
    match alpt::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts in {}:", dir.display());
            for (name, entry) in &rt.manifest.configs {
                println!(
                    "  {name}: F={} d={} B={} cross={} mlp={:?} P={} \
                     ({} variants)",
                    entry.fields,
                    entry.emb_dim,
                    entry.batch,
                    entry.cross_depth,
                    entry.mlp,
                    entry.n_params,
                    entry.artifacts.len()
                );
            }
        }
        Err(e) => println!("no runtime: {e:#}"),
    }
    Ok(())
}
