//! Uniform symmetric quantization (paper §2.1, Eq. 1–4) and the learned
//! step-size machinery (Eq. 6–7).
//!
//! This module is the Rust twin of `python/compile/kernels/`: the same
//! math runs (a) here, on the table-update path, and (b) as Pallas kernels
//! inside the AOT HLO on the model-execution path. Integration tests pin
//! the two against each other.

pub mod kernels;
pub mod packed;

pub use kernels::Kernel;
pub use packed::{PackedTable, RowWriter};

use crate::util::rng::Pcg32;

/// Quantization bit width. `qn = -2^{m-1}`, `qp = 2^{m-1} - 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BitWidth {
    B2,
    B4,
    B8,
    B16,
}

impl BitWidth {
    pub fn from_bits(bits: u32) -> Option<Self> {
        match bits {
            2 => Some(Self::B2),
            4 => Some(Self::B4),
            8 => Some(Self::B8),
            16 => Some(Self::B16),
            _ => None,
        }
    }

    pub fn bits(self) -> u32 {
        match self {
            Self::B2 => 2,
            Self::B4 => 4,
            Self::B8 => 8,
            Self::B16 => 16,
        }
    }

    /// Most negative code `-2^{m-1}`.
    pub fn qn(self) -> i32 {
        -(1 << (self.bits() - 1))
    }

    /// Most positive code `2^{m-1} - 1`.
    pub fn qp(self) -> i32 {
        (1 << (self.bits() - 1)) - 1
    }

    /// `q = 2^{m-1} - 1` in the paper's gradient-scale formula.
    pub fn q(self) -> f32 {
        self.qp() as f32
    }
}

/// Rounding mode (paper Eq. 3 vs Eq. 4). The paper's central theory result
/// (Theorems 1–2) is that SR converges strictly better than DR in LPT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    Deterministic,
    Stochastic,
}

/// R_D (Eq. 3): round half towards +inf — identical to the Pallas kernel's
/// `floor(x + 0.5)`.
#[inline]
pub fn round_dr(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// R_S (Eq. 4): floor + Bernoulli(frac) with an explicit U[0,1) draw.
#[inline]
pub fn round_sr(x: f32, u: f32) -> f32 {
    let f = x.floor();
    f + ((u < x - f) as u32 as f32)
}

/// Quantize one weight to its integer code (Eq. 1).
#[inline]
pub fn quantize_dr(w: f32, delta: f32, bw: BitWidth) -> i32 {
    let x = (w / delta).clamp(bw.qn() as f32, bw.qp() as f32);
    round_dr(x) as i32
}

/// Quantize one weight with stochastic rounding.
#[inline]
pub fn quantize_sr(w: f32, delta: f32, bw: BitWidth, u: f32) -> i32 {
    let x = (w / delta).clamp(bw.qn() as f32, bw.qp() as f32);
    round_sr(x, u) as i32
}

/// De-quantize a code (Eq. 2).
#[inline]
pub fn dequantize(code: i32, delta: f32) -> f32 {
    code as f32 * delta
}

/// Quantize a row in place into `codes` (one rng draw per element for SR).
pub fn quantize_row(
    w: &[f32],
    delta: f32,
    bw: BitWidth,
    rounding: Rounding,
    rng: &mut Pcg32,
    codes: &mut [i32],
) {
    debug_assert_eq!(w.len(), codes.len());
    match rounding {
        Rounding::Deterministic => {
            for (c, &x) in codes.iter_mut().zip(w) {
                *c = quantize_dr(x, delta, bw);
            }
        }
        Rounding::Stochastic => {
            for (c, &x) in codes.iter_mut().zip(w) {
                *c = quantize_sr(x, delta, bw, rng.uniform_f32());
            }
        }
    }
}

/// De-quantize a row of codes into `out`.
pub fn dequantize_row(codes: &[i32], delta: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * delta;
    }
}

/// LSQ's step-size gradient estimator (Eq. 7) for one element:
/// `d Q_D(w)/d delta`.
#[inline]
pub fn lsq_delta_grad_elem(w: f32, delta: f32, bw: BitWidth) -> f32 {
    let qn = bw.qn() as f32;
    let qp = bw.qp() as f32;
    let x = w / delta;
    if x <= qn {
        qn
    } else if x >= qp {
        qp
    } else {
        round_dr(x) - x
    }
}

/// `d f / d delta` for one row: sum of upstream grads times Eq. 7. Exactly
/// the reduction the Pallas LSQ backward kernel performs.
pub fn lsq_delta_grad_row(
    w: &[f32],
    delta: f32,
    bw: BitWidth,
    upstream: &[f32],
) -> f32 {
    debug_assert_eq!(w.len(), upstream.len());
    w.iter()
        .zip(upstream)
        .map(|(&wi, &g)| g * lsq_delta_grad_elem(wi, delta, bw))
        .sum()
}

/// STE weight gradient through Q_D: pass inside the open clip interval,
/// zero outside (matches the Pallas LSQ backward).
pub fn ste_weight_grad_row(
    w: &[f32],
    delta: f32,
    bw: BitWidth,
    upstream: &[f32],
    out: &mut [f32],
) {
    let qn = bw.qn() as f32;
    let qp = bw.qp() as f32;
    for ((o, &wi), &g) in out.iter_mut().zip(w).zip(upstream) {
        let x = wi / delta;
        *o = if x > qn && x < qp { g } else { 0.0 };
    }
}

/// LSQ-style step-size initialization: `2 * E|w| / sqrt(qp)` over the row
/// (Esser et al. 2020), with a floor to keep Δ positive for all-zero rows.
pub fn init_delta(w: &[f32], bw: BitWidth) -> f32 {
    let mean_abs =
        w.iter().map(|x| x.abs()).sum::<f32>() / w.len().max(1) as f32;
    let d = 2.0 * mean_abs / (bw.q()).sqrt();
    d.max(1e-8)
}

/// Fixed step size from a clipping value (vanilla-LPT style; the paper
/// tunes clip ∈ {1, 0.1, 0.01, 0.001}): Δ = clip / 2^{m-1}.
pub fn delta_from_clip(clip: f32, bw: BitWidth) -> f32 {
    clip / (1 << (bw.bits() - 1)) as f32
}

/// Paper §3.2: gradient scale `g` options for the step-size update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradScale {
    One,
    /// `1/sqrt(d*q)`
    InvSqrtDq,
    /// `1/sqrt(b*d*q)` (the paper's default)
    InvSqrtBdq,
}

impl GradScale {
    /// Stable config token — the inverse of the `grad_scale` parser in
    /// `Experiment::apply`, used by the checkpoint metadata echo.
    pub fn key(self) -> &'static str {
        match self {
            GradScale::One => "one",
            GradScale::InvSqrtDq => "inv_sqrt_dq",
            GradScale::InvSqrtBdq => "inv_sqrt_bdq",
        }
    }

    pub fn value(self, batch: usize, dim: usize, bw: BitWidth) -> f32 {
        match self {
            GradScale::One => 1.0,
            GradScale::InvSqrtDq => 1.0 / (dim as f32 * bw.q()).sqrt(),
            GradScale::InvSqrtBdq => {
                1.0 / (batch as f32 * dim as f32 * bw.q()).sqrt()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn bitwidth_ranges() {
        assert_eq!(BitWidth::B2.qn(), -2);
        assert_eq!(BitWidth::B2.qp(), 1);
        assert_eq!(BitWidth::B4.qn(), -8);
        assert_eq!(BitWidth::B4.qp(), 7);
        assert_eq!(BitWidth::B8.qn(), -128);
        assert_eq!(BitWidth::B8.qp(), 127);
        assert_eq!(BitWidth::B16.qn(), -32768);
        assert_eq!(BitWidth::B16.qp(), 32767);
        assert_eq!(BitWidth::from_bits(8), Some(BitWidth::B8));
        assert_eq!(BitWidth::from_bits(3), None);
    }

    #[test]
    fn round_dr_ties_up() {
        assert_eq!(round_dr(0.5), 1.0);
        assert_eq!(round_dr(-0.5), 0.0);
        assert_eq!(round_dr(-1.5), -1.0);
        assert_eq!(round_dr(1.49), 1.0);
        assert_eq!(round_dr(1.5), 2.0);
    }

    #[test]
    fn round_sr_extremes() {
        // u = 0.99…: round down unless frac > u; u = 0: always up for frac>0
        assert_eq!(round_sr(1.3, 0.99), 1.0);
        assert_eq!(round_sr(1.3, 0.0), 2.0);
        assert_eq!(round_sr(2.0, 0.5), 2.0); // integer stays put
    }

    #[test]
    fn dr_quantization_error_bounded() {
        check("|dequant(quant_dr(w)) - w| <= delta/2 in range", 300, |g| {
            let bw = *g.pick(&[BitWidth::B4, BitWidth::B8, BitWidth::B16]);
            let delta = g.f32_in(1e-4, 0.1);
            // keep w strictly inside the representable range
            let lim = delta * (bw.qp() as f32 - 1.0);
            let w = g.f32_in(-lim, lim);
            let c = quantize_dr(w, delta, bw);
            let err = (dequantize(c, delta) - w).abs();
            if err <= delta / 2.0 + 1e-6 {
                Ok(())
            } else {
                Err(format!("w={w} delta={delta} err={err}"))
            }
        });
    }

    #[test]
    fn sr_quantization_error_bounded_by_delta() {
        check("|dequant(quant_sr(w)) - w| < delta in range", 300, |g| {
            let bw = BitWidth::B8;
            let delta = g.f32_in(1e-4, 0.1);
            let lim = delta * (bw.qp() as f32 - 1.0);
            let w = g.f32_in(-lim, lim);
            let u = g.f32_in(0.0, 1.0);
            let c = quantize_sr(w, delta, bw, u);
            let err = (dequantize(c, delta) - w).abs();
            if err < delta + 1e-6 {
                Ok(())
            } else {
                Err(format!("w={w} delta={delta} err={err}"))
            }
        });
    }

    #[test]
    fn codes_stay_in_range() {
        check("codes within [qn, qp] even for huge w", 300, |g| {
            let bw = *g.pick(&[
                BitWidth::B2,
                BitWidth::B4,
                BitWidth::B8,
                BitWidth::B16,
            ]);
            let delta = g.f32_in(1e-4, 0.01);
            let w = g.f32_in(-100.0, 100.0);
            let u = g.f32_in(0.0, 1.0);
            for c in [quantize_dr(w, delta, bw), quantize_sr(w, delta, bw, u)]
            {
                if c < bw.qn() || c > bw.qp() {
                    return Err(format!("code {c} out of range for {bw:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sr_unbiased_statistically() {
        let mut rng = Pcg32::seeded(99);
        let bw = BitWidth::B8;
        let delta = 0.01f32;
        let w = 0.0234f32; // frac(w/delta) = 0.34
        let n = 200_000;
        let mean: f64 = (0..n)
            .map(|_| {
                dequantize(quantize_sr(w, delta, bw, rng.uniform_f32()), delta)
                    as f64
            })
            .sum::<f64>()
            / n as f64;
        // SE = delta * sqrt(p(1-p)/n) ≈ 1.06e-5; allow 5 sigma
        assert!((mean - w as f64).abs() < 6e-5, "mean={mean}");
    }

    #[test]
    fn lsq_grad_matches_eq7() {
        let bw = BitWidth::B4; // qn=-8, qp=7
        let delta = 0.1;
        // clipped low
        assert_eq!(lsq_delta_grad_elem(-5.0, delta, bw), -8.0);
        // clipped high
        assert_eq!(lsq_delta_grad_elem(5.0, delta, bw), 7.0);
        // in range: R_D(x) - x with x = 3.4 -> 3 - 3.4 = -0.4
        let g = lsq_delta_grad_elem(0.34, delta, bw);
        assert!((g - (-0.4)).abs() < 1e-5, "g={g}");
    }

    #[test]
    fn lsq_row_grad_is_weighted_sum() {
        let bw = BitWidth::B8;
        let w = [0.0234f32, -0.0711, 0.5];
        let ups = [1.0f32, 2.0, -1.0];
        let delta = 0.01;
        let want: f32 = w
            .iter()
            .zip(&ups)
            .map(|(&wi, &g)| g * lsq_delta_grad_elem(wi, delta, bw))
            .sum();
        assert_eq!(lsq_delta_grad_row(&w, delta, bw, &ups), want);
    }

    #[test]
    fn ste_masks_clipped() {
        let bw = BitWidth::B4;
        let delta = 0.1;
        let w = [0.0, 0.79, -0.85, 0.3];
        let ups = [1.0, 1.0, 1.0, 1.0];
        let mut out = [0.0f32; 4];
        ste_weight_grad_row(&w, delta, bw, &ups, &mut out);
        assert_eq!(out, [1.0, 0.0, 0.0, 1.0]); // 0.79/0.1=7.9 >= qp -> 0
    }

    #[test]
    fn init_delta_positive_and_scales() {
        let w = [0.1f32, -0.2, 0.3, -0.4];
        let d8 = init_delta(&w, BitWidth::B8);
        let d2 = init_delta(&w, BitWidth::B2);
        assert!(d8 > 0.0 && d2 > 0.0);
        assert!(d2 > d8, "lower bit width needs a larger step");
        assert!(init_delta(&[0.0; 4], BitWidth::B8) >= 1e-8);
    }

    #[test]
    fn grad_scale_values() {
        let s = GradScale::InvSqrtBdq.value(256, 16, BitWidth::B8);
        assert!((s - 1.0 / (256.0f32 * 16.0 * 127.0).sqrt()).abs() < 1e-9);
        assert_eq!(GradScale::One.value(7, 5, BitWidth::B2), 1.0);
    }

    #[test]
    fn quantize_dequantize_roundtrip_row() {
        let mut rng = Pcg32::seeded(3);
        let w: Vec<f32> = (0..64).map(|_| rng.normal_scaled(0.0, 0.05)).collect();
        let delta = init_delta(&w, BitWidth::B8);
        let mut codes = vec![0i32; 64];
        quantize_row(&w, delta, BitWidth::B8, Rounding::Deterministic,
                     &mut rng, &mut codes);
        let mut back = vec![0.0f32; 64];
        dequantize_row(&codes, delta, &mut back);
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= delta, "a={a} b={b} delta={delta}");
        }
    }
}
