//! Bit-packed integer storage for quantized embedding tables.
//!
//! This is where the paper's memory saving physically happens: the whole
//! [n_features × dim] table lives as m-bit two's-complement codes packed
//! into `u8` words (4:1 ratio at 8 bits vs f32, 16:1 at 2 bits), plus one
//! f32 step size per feature row. Only the rows referenced by the current
//! batch are expanded to f32 — and only transiently.
//!
//! Layout: row-major, rows padded to a whole byte so row accesses never
//! straddle feature boundaries; padding bits are kept zero by every write
//! path. Row readers and writers process *whole bytes at a time* — each
//! sub-byte code is extracted with a constant shift/mask pair instead of
//! a per-element position branch — and the fused
//! [`PackedTable::quantize_row_packed`] quantizes f32 weights straight
//! into packed bytes, skipping the i32 scratch round-trip entirely.
//! [`RowWriter`] extends the same write paths to concurrent per-row use
//! from the sharded update engine.
//!
//! The row hot paths (unpack, dequantize, deterministic quantize→pack,
//! and the batched [`PackedTable::gather_dequant`]) dispatch through
//! [`super::kernels`] to SIMD implementations picked once per process;
//! the byte-wise kernels at the bottom of this file are the scalar
//! reference every SIMD kernel is property-tested against, bit for bit.
//!
//! A table's bit width is per *table*, not per process: the
//! mixed-precision grouped store packs each precision group into its own
//! `PackedTable`, so one model can mix 2/4/8/16-bit sub-tables while
//! every kernel here stays width-specialized.

use super::kernels::{self, Kernel};
use super::{quantize_dr, quantize_sr, BitWidth, Rounding};
use crate::util::rng::Pcg32;
use anyhow::{ensure, Result};

/// Packed `[rows × dim]` table of m-bit signed integer codes.
#[derive(Clone, Debug)]
pub struct PackedTable {
    bits: u32,
    rows: usize,
    dim: usize,
    row_bytes: usize,
    data: Vec<u8>,
}

impl PackedTable {
    pub fn new(rows: usize, dim: usize, bw: BitWidth) -> Self {
        let bits = bw.bits();
        let row_bytes = (dim * bits as usize).div_ceil(8);
        Self { bits, rows, dim, row_bytes, data: vec![0u8; rows * row_bytes] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn bit_width(&self) -> BitWidth {
        BitWidth::from_bits(self.bits).unwrap()
    }

    /// Raw bit count per code (`bit_width().bits()` without the enum
    /// round-trip).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Bytes per (byte-padded) row.
    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    /// Raw packed storage (row-major, `row_bytes` per row).
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Bytes of backing storage (the compression-ratio numerator).
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }

    /// Read one element (sign-extended). Scalar reference path — the
    /// word-at-a-time row ops are property-tested against it.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> i32 {
        debug_assert!(row < self.rows && col < self.dim);
        let base = row * self.row_bytes;
        match self.bits {
            8 => self.data[base + col] as i8 as i32,
            16 => {
                let o = base + col * 2;
                i16::from_le_bytes([self.data[o], self.data[o + 1]]) as i32
            }
            4 => {
                let byte = self.data[base + col / 2];
                let nib = if col % 2 == 0 { byte & 0xF } else { byte >> 4 };
                ((nib as i32) << 28) >> 28
            }
            2 => {
                let byte = self.data[base + col / 4];
                let two = (byte >> ((col % 4) * 2)) & 0b11;
                ((two as i32) << 30) >> 30
            }
            _ => unreachable!(),
        }
    }

    /// Write one element. `v` must be within the bit width's range.
    /// Scalar reference path (see [`PackedTable::get`]).
    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: i32) {
        debug_assert!(row < self.rows && col < self.dim);
        let bw = BitWidth::from_bits(self.bits).unwrap();
        debug_assert!(
            v >= bw.qn() && v <= bw.qp(),
            "code {v} out of range for {} bits",
            self.bits
        );
        let base = row * self.row_bytes;
        match self.bits {
            8 => self.data[base + col] = v as i8 as u8,
            16 => {
                let o = base + col * 2;
                let b = (v as i16).to_le_bytes();
                self.data[o] = b[0];
                self.data[o + 1] = b[1];
            }
            4 => {
                let o = base + col / 2;
                let nib = (v as u8) & 0xF;
                if col % 2 == 0 {
                    self.data[o] = (self.data[o] & 0xF0) | nib;
                } else {
                    self.data[o] = (self.data[o] & 0x0F) | (nib << 4);
                }
            }
            2 => {
                let o = base + col / 4;
                let shift = (col % 4) * 2;
                let two = (v as u8) & 0b11;
                self.data[o] =
                    (self.data[o] & !(0b11 << shift)) | (two << shift);
            }
            _ => unreachable!(),
        }
    }

    #[inline]
    fn row_slice(&self, row: usize) -> &[u8] {
        debug_assert!(row < self.rows);
        let base = row * self.row_bytes;
        &self.data[base..base + self.row_bytes]
    }

    #[inline]
    fn row_slice_mut(&mut self, row: usize) -> &mut [u8] {
        debug_assert!(row < self.rows);
        let base = row * self.row_bytes;
        &mut self.data[base..base + self.row_bytes]
    }

    /// Unpack a whole row into `out` as i32 codes (SIMD-dispatched).
    pub fn read_row(&self, row: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.dim);
        kernels::unpack_row(
            kernels::active(),
            self.row_slice(row),
            self.dim,
            self.bits,
            out,
        );
    }

    /// Unpack a row straight to de-quantized f32 (`code * delta`) — the
    /// gather hot path, dispatched to the process-wide SIMD kernel
    /// (bit-identical to the scalar reference; see [`super::kernels`]).
    pub fn read_row_dequant(&self, row: usize, delta: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        kernels::dequant_row(
            kernels::active(),
            self.row_slice(row),
            self.dim,
            self.bits,
            delta,
            out,
        );
    }

    /// Batched gather: dequantize the rows named by `ids` into `out`
    /// (`ids.len() × dim`), with a per-id step size from `delta_of` and
    /// software prefetch of upcoming row pointers — gathers are random
    /// access over a table far larger than cache, so each row's bytes
    /// are requested [`Self::PREFETCH_AHEAD`] iterations early.
    pub fn gather_dequant(
        &self,
        ids: &[u32],
        delta_of: impl Fn(u32) -> f32,
        out: &mut [f32],
    ) {
        self.gather_dequant_with(kernels::active(), ids, delta_of, out)
    }

    /// How many rows ahead [`PackedTable::gather_dequant`] prefetches.
    /// At dim 16 × 4-bit a row is 8 bytes, so ~8 rows ≈ one cache-miss
    /// latency of decode work in flight.
    pub const PREFETCH_AHEAD: usize = 8;

    /// [`PackedTable::gather_dequant`] pinned to one kernel — the
    /// bench/property-test entry point.
    pub fn gather_dequant_with(
        &self,
        k: Kernel,
        ids: &[u32],
        delta_of: impl Fn(u32) -> f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), ids.len() * self.dim);
        if self.dim == 0 {
            return;
        }
        for (i, (&id, row)) in
            ids.iter().zip(out.chunks_mut(self.dim)).enumerate()
        {
            if let Some(&ahead) = ids.get(i + Self::PREFETCH_AHEAD) {
                self.prefetch_row(ahead as usize);
            }
            kernels::dequant_row(
                k,
                self.row_slice(id as usize),
                self.dim,
                self.bits,
                delta_of(id),
                row,
            );
        }
    }

    /// Dequantize rows `0..n` in order, one per-row Δ each — the
    /// wire-byte decode path of the distributed gather cache, where the
    /// batch's packed rows were staged contiguously. One kernel
    /// dispatch for the whole batch; no software prefetch (sequential
    /// reads stream through the hardware prefetcher).
    pub fn dequant_rows(&self, n: usize, deltas: &[f32], out: &mut [f32]) {
        debug_assert!(n <= self.rows && n <= deltas.len());
        debug_assert_eq!(out.len(), n * self.dim);
        if self.dim == 0 {
            return;
        }
        let k = kernels::active();
        for (i, row) in out.chunks_mut(self.dim).enumerate() {
            kernels::dequant_row(
                k,
                self.row_slice(i),
                self.dim,
                self.bits,
                deltas[i],
                row,
            );
        }
    }

    /// Hint the CPU to pull `row`'s first cache line — a no-op outside
    /// x86_64 (aarch64 has no stable prefetch intrinsic; its hardware
    /// prefetcher plus the small row footprint cover the gap).
    #[inline]
    pub fn prefetch_row(&self, row: usize) {
        debug_assert!(row < self.rows);
        #[cfg(target_arch = "x86_64")]
        // Safety: prefetch is a hint; it cannot fault even on a bad
        // address, and the pointer is in-bounds by the assert above.
        unsafe {
            use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch::<_MM_HINT_T0>(
                self.data.as_ptr().add(row * self.row_bytes) as *const i8,
            );
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = row;
    }

    /// Pack a row of i32 codes (whole bytes at a time; padding bits in the
    /// final byte are written as zero).
    pub fn write_row(&mut self, row: usize, codes: &[i32]) {
        debug_assert_eq!(codes.len(), self.dim);
        let (dim, bits) = (self.dim, self.bits);
        pack_codes(self.row_slice_mut(row), dim, bits, codes);
    }

    /// Fused quantize→pack: quantize the f32 row `w` (Eq. 1 with Eq. 3/4
    /// rounding) straight into this row's packed bytes, skipping the i32
    /// scratch round-trip. Stochastic draws come from `rng`, one per
    /// element in column order — identical order (hence identical codes)
    /// to `quantize_row` + `write_row` on the same generator state.
    pub fn quantize_row_packed(
        &mut self,
        row: usize,
        w: &[f32],
        delta: f32,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) {
        self.quantize_row_packed_with(
            kernels::active(),
            row,
            w,
            delta,
            rounding,
            rng,
        );
    }

    /// [`PackedTable::quantize_row_packed`] pinned to one kernel — the
    /// bench/property-test entry point. Only deterministic rounding is
    /// vectorized; SR always runs the scalar column-order draw loop, so
    /// every kernel consumes `rng` identically.
    #[allow(clippy::too_many_arguments)]
    pub fn quantize_row_packed_with(
        &mut self,
        k: Kernel,
        row: usize,
        w: &[f32],
        delta: f32,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) {
        debug_assert_eq!(w.len(), self.dim);
        let (dim, bits) = (self.dim, self.bits);
        let bw = self.bit_width();
        quantize_into(k, self.row_slice_mut(row), dim, bits, bw, w,
                      delta, rounding, rng);
    }

    /// Raw packed bytes of rows `[lo, lo + count)` — the checkpoint
    /// serialization path. Verbatim storage bytes: round-tripping them
    /// through [`PackedTable::load_raw_rows`] is bit-identical by
    /// construction (no dequantize/requantize).
    pub fn raw_rows(&self, lo: usize, count: usize) -> &[u8] {
        debug_assert!(lo + count <= self.rows);
        &self.data[lo * self.row_bytes..(lo + count) * self.row_bytes]
    }

    /// Copy the raw packed bytes of rows `[lo, lo + dst.len()/row_bytes)`
    /// into `dst` — the bounds-checked counterpart of
    /// [`PackedTable::raw_rows`] used by the store checkpoint hooks.
    pub fn save_raw_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        ensure!(
            dst.len() % self.row_bytes == 0,
            "row payload of {} bytes is not a multiple of {} bytes/row",
            dst.len(),
            self.row_bytes
        );
        let count = dst.len() / self.row_bytes;
        ensure!(
            lo + count <= self.rows,
            "rows [{lo}, {}) exceed the {}-row table",
            lo + count,
            self.rows
        );
        dst.copy_from_slice(self.raw_rows(lo, count));
        Ok(())
    }

    /// Restore rows `[lo, lo + src.len()/row_bytes)` from bytes produced
    /// by [`PackedTable::raw_rows`]. Validates that the padding bits of
    /// every ragged row are zero — the invariant all write paths
    /// maintain — so a doctored file cannot smuggle in out-of-contract
    /// storage.
    pub fn load_raw_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        ensure!(
            src.len() % self.row_bytes == 0,
            "row payload of {} bytes is not a multiple of {} bytes/row",
            src.len(),
            self.row_bytes
        );
        let count = src.len() / self.row_bytes;
        ensure!(
            lo + count <= self.rows,
            "rows [{lo}, {}) exceed the {}-row table",
            lo + count,
            self.rows
        );
        let pad_bits = self.row_bytes * 8 - self.dim * self.bits as usize;
        if pad_bits > 0 {
            for (r, row) in src.chunks_exact(self.row_bytes).enumerate() {
                let last = row[self.row_bytes - 1];
                ensure!(
                    last >> (8 - pad_bits) == 0,
                    "row {}: padding bits set ({last:#010b})",
                    lo + r
                );
            }
        }
        self.data[lo * self.row_bytes..lo * self.row_bytes + src.len()]
            .copy_from_slice(src);
        Ok(())
    }

    /// Shared handle for writing *disjoint* rows from multiple threads —
    /// the sharded `update` path. Borrows the table mutably for its whole
    /// lifetime, so no other access can race it; safety within the handle
    /// reduces to callers never targeting the same row concurrently.
    pub fn row_writer(&mut self) -> RowWriter<'_> {
        RowWriter {
            data: self.data.as_mut_ptr(),
            rows: self.rows,
            dim: self.dim,
            row_bytes: self.row_bytes,
            bits: self.bits,
            _marker: std::marker::PhantomData,
        }
    }
}

/// Concurrent per-row write handle produced by
/// [`PackedTable::row_writer`]. `Send + Sync`: every method takes `&self`
/// and is `unsafe fn`, with the contract that concurrent calls target
/// disjoint rows (rows never share bytes — they are byte-padded).
pub struct RowWriter<'a> {
    data: *mut u8,
    rows: usize,
    dim: usize,
    row_bytes: usize,
    bits: u32,
    _marker: std::marker::PhantomData<&'a mut [u8]>,
}

unsafe impl Send for RowWriter<'_> {}
unsafe impl Sync for RowWriter<'_> {}

impl RowWriter<'_> {
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_slice_mut(&self, row: usize) -> &mut [u8] {
        debug_assert!(row < self.rows);
        std::slice::from_raw_parts_mut(
            self.data.add(row * self.row_bytes),
            self.row_bytes,
        )
    }

    /// Pack `codes` into `row`.
    ///
    /// # Safety
    /// No concurrent call (on this writer) may target the same `row`.
    pub unsafe fn write_row(&self, row: usize, codes: &[i32]) {
        debug_assert_eq!(codes.len(), self.dim);
        pack_codes(self.row_slice_mut(row), self.dim, self.bits, codes);
    }

    /// Fused quantize→pack into `row` (see
    /// [`PackedTable::quantize_row_packed`]).
    ///
    /// # Safety
    /// No concurrent call (on this writer) may target the same `row`.
    pub unsafe fn quantize_row_packed(
        &self,
        row: usize,
        w: &[f32],
        delta: f32,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) {
        debug_assert_eq!(w.len(), self.dim);
        let bw = BitWidth::from_bits(self.bits).unwrap();
        quantize_into(kernels::active(), self.row_slice_mut(row),
                      self.dim, self.bits, bw, w, delta, rounding, rng);
    }
}

// ------------------------------------------------- byte-wise row kernels
//
// The scalar reference kernels. `super::kernels` dispatches to these for
// `Kernel::Scalar` (and property-tests every SIMD kernel against them),
// which is why they are `pub(crate)` rather than private.

/// Unpack `dim` sign-extended codes from a byte-padded row.
pub(crate) fn unpack_codes(
    src: &[u8],
    dim: usize,
    bits: u32,
    out: &mut [i32],
) {
    match bits {
        8 => {
            for (o, &b) in out.iter_mut().zip(src) {
                *o = b as i8 as i32;
            }
        }
        16 => {
            for (o, pair) in out.iter_mut().zip(src.chunks_exact(2)) {
                *o = i16::from_le_bytes([pair[0], pair[1]]) as i32;
            }
        }
        4 => {
            let full = dim / 2;
            let (head, tail) = out.split_at_mut(full * 2);
            for (o2, &b) in head.chunks_exact_mut(2).zip(&src[..full]) {
                o2[0] = ((b as i32) << 28) >> 28;
                o2[1] = ((b as i32) << 24) >> 28;
            }
            if let [last] = tail {
                *last = ((src[full] as i32) << 28) >> 28;
            }
        }
        2 => {
            let full = dim / 4;
            let (head, tail) = out.split_at_mut(full * 4);
            for (o4, &b) in head.chunks_exact_mut(4).zip(&src[..full]) {
                let b = b as i32;
                o4[0] = (b << 30) >> 30;
                o4[1] = (b << 28) >> 30;
                o4[2] = (b << 26) >> 30;
                o4[3] = (b << 24) >> 30;
            }
            for (k, o) in tail.iter_mut().enumerate() {
                *o = ((src[full] as i32) << (30 - 2 * k as i32)) >> 30;
            }
        }
        _ => unreachable!(),
    }
}

/// Dequantize `dim` codes from a byte-padded row: `out[c] = code * delta`.
pub(crate) fn dequant_codes(
    src: &[u8],
    dim: usize,
    bits: u32,
    delta: f32,
    out: &mut [f32],
) {
    match bits {
        8 => {
            for (o, &b) in out.iter_mut().zip(src) {
                *o = (b as i8 as f32) * delta;
            }
        }
        16 => {
            for (o, pair) in out.iter_mut().zip(src.chunks_exact(2)) {
                *o = i16::from_le_bytes([pair[0], pair[1]]) as f32
                    * delta;
            }
        }
        4 => {
            let full = dim / 2;
            let (head, tail) = out.split_at_mut(full * 2);
            for (o2, &b) in head.chunks_exact_mut(2).zip(&src[..full])
            {
                o2[0] = (((b as i32) << 28) >> 28) as f32 * delta;
                o2[1] = (((b as i32) << 24) >> 28) as f32 * delta;
            }
            if let [last] = tail {
                *last = (((src[full] as i32) << 28) >> 28) as f32
                    * delta;
            }
        }
        2 => {
            let full = dim / 4;
            let (head, tail) = out.split_at_mut(full * 4);
            for (o4, &b) in head.chunks_exact_mut(4).zip(&src[..full])
            {
                let b = b as i32;
                o4[0] = ((b << 30) >> 30) as f32 * delta;
                o4[1] = ((b << 28) >> 30) as f32 * delta;
                o4[2] = ((b << 26) >> 30) as f32 * delta;
                o4[3] = ((b << 24) >> 30) as f32 * delta;
            }
            for (k, o) in tail.iter_mut().enumerate() {
                *o = (((src[full] as i32) << (30 - 2 * k as i32))
                    >> 30) as f32
                    * delta;
            }
        }
        _ => unreachable!(),
    }
}

/// Pack `dim` codes into a byte-padded row; padding bits end up zero.
pub(crate) fn pack_codes(
    dst: &mut [u8],
    dim: usize,
    bits: u32,
    codes: &[i32],
) {
    #[cfg(debug_assertions)]
    {
        let bw = BitWidth::from_bits(bits).unwrap();
        for &c in codes {
            debug_assert!(
                c >= bw.qn() && c <= bw.qp(),
                "code {c} out of range for {bits} bits"
            );
        }
    }
    match bits {
        8 => {
            for (d, &c) in dst.iter_mut().zip(codes) {
                *d = c as i8 as u8;
            }
        }
        16 => {
            for (d2, &c) in dst.chunks_exact_mut(2).zip(codes) {
                d2.copy_from_slice(&(c as i16).to_le_bytes());
            }
        }
        4 => {
            let full = dim / 2;
            for (d, c2) in
                dst[..full].iter_mut().zip(codes.chunks_exact(2))
            {
                *d = (c2[0] as u8 & 0x0F) | ((c2[1] as u8) << 4);
            }
            if dim % 2 == 1 {
                dst[full] = codes[dim - 1] as u8 & 0x0F;
            }
        }
        2 => {
            let full = dim / 4;
            for (d, c4) in
                dst[..full].iter_mut().zip(codes.chunks_exact(4))
            {
                *d = (c4[0] as u8 & 0b11)
                    | ((c4[1] as u8 & 0b11) << 2)
                    | ((c4[2] as u8 & 0b11) << 4)
                    | ((c4[3] as u8 & 0b11) << 6);
            }
            if dim % 4 != 0 {
                let mut b = 0u8;
                for (k, &c) in codes[full * 4..].iter().enumerate() {
                    b |= (c as u8 & 0b11) << (2 * k);
                }
                dst[full] = b;
            }
        }
        _ => unreachable!(),
    }
}

/// Quantize `w` and pack in one pass. SR draws happen in column order so
/// the result is bit-identical to `quantize_row` + `write_row` run on the
/// same generator state. DR has no draws, so it is free to vectorize:
/// it routes through `kernels::quantize_dr_row` for the chosen kernel,
/// while SR always runs the scalar draw loop (any kernel, same bytes,
/// same final generator state).
#[allow(clippy::too_many_arguments)]
#[inline]
fn quantize_into(
    k: Kernel,
    dst: &mut [u8],
    dim: usize,
    bits: u32,
    bw: BitWidth,
    w: &[f32],
    delta: f32,
    rounding: Rounding,
    rng: &mut Pcg32,
) {
    match rounding {
        Rounding::Deterministic => {
            kernels::quantize_dr_row(k, dst, dim, bits, bw, w, delta)
        }
        Rounding::Stochastic => {
            pack_with(dst, dim, bits, w, &mut |x| {
                quantize_sr(x, delta, bw, rng.uniform_f32())
            })
        }
    }
}

/// Scalar fused deterministic quantize→pack — the oracle
/// `kernels::quantize_dr_row` reduces to for `Kernel::Scalar` and
/// property-tests the SIMD kernels against.
pub(crate) fn quantize_dr_codes(
    dst: &mut [u8],
    dim: usize,
    bits: u32,
    bw: BitWidth,
    w: &[f32],
    delta: f32,
) {
    pack_with(dst, dim, bits, w, &mut |x| quantize_dr(x, delta, bw));
}

/// Byte-wise packing driven by a per-element `code` closure, evaluated in
/// strict column order (SR draw order must match the serial reference).
#[inline]
fn pack_with(
    dst: &mut [u8],
    dim: usize,
    bits: u32,
    w: &[f32],
    code: &mut impl FnMut(f32) -> i32,
) {
    match bits {
        8 => {
            for (d, &x) in dst.iter_mut().zip(w) {
                *d = code(x) as i8 as u8;
            }
        }
        16 => {
            for (d2, &x) in dst.chunks_exact_mut(2).zip(w) {
                d2.copy_from_slice(&(code(x) as i16).to_le_bytes());
            }
        }
        4 => {
            let full = dim / 2;
            for (d, x2) in dst[..full].iter_mut().zip(w.chunks_exact(2)) {
                let lo = code(x2[0]) as u8 & 0x0F;
                let hi = (code(x2[1]) as u8) << 4;
                *d = lo | hi;
            }
            if dim % 2 == 1 {
                dst[full] = code(w[dim - 1]) as u8 & 0x0F;
            }
        }
        2 => {
            let full = dim / 4;
            for (d, x4) in dst[..full].iter_mut().zip(w.chunks_exact(4)) {
                let c0 = code(x4[0]) as u8 & 0b11;
                let c1 = code(x4[1]) as u8 & 0b11;
                let c2 = code(x4[2]) as u8 & 0b11;
                let c3 = code(x4[3]) as u8 & 0b11;
                *d = c0 | (c1 << 2) | (c2 << 4) | (c3 << 6);
            }
            if dim % 4 != 0 {
                let mut b = 0u8;
                for (k, &x) in w[full * 4..].iter().enumerate() {
                    b |= (code(x) as u8 & 0b11) << (2 * k);
                }
                dst[full] = b;
            }
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_row;
    use crate::util::prop::{check, Gen};

    const ALL_WIDTHS: [BitWidth; 4] =
        [BitWidth::B2, BitWidth::B4, BitWidth::B8, BitWidth::B16];

    fn roundtrip_prop(bw: BitWidth) {
        check(
            &format!("packed roundtrip {}bit", bw.bits()),
            120,
            move |g: &mut Gen| {
                let rows = g.usize_in(1, 40);
                let dim = g.usize_in(1, 33);
                let mut t = PackedTable::new(rows, dim, bw);
                let mut want = vec![0i32; rows * dim];
                for r in 0..rows {
                    for c in 0..dim {
                        let v = g.i32_in(bw.qn(), bw.qp());
                        t.set(r, c, v);
                        want[r * dim + c] = v;
                    }
                }
                for r in 0..rows {
                    let mut row = vec![0i32; dim];
                    t.read_row(r, &mut row);
                    for c in 0..dim {
                        if t.get(r, c) != want[r * dim + c]
                            || row[c] != want[r * dim + c]
                        {
                            return Err(format!(
                                "mismatch at ({r},{c}): got {} / {} want {}",
                                t.get(r, c),
                                row[c],
                                want[r * dim + c]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn roundtrip_2bit() {
        roundtrip_prop(BitWidth::B2);
    }

    #[test]
    fn roundtrip_4bit() {
        roundtrip_prop(BitWidth::B4);
    }

    #[test]
    fn roundtrip_8bit() {
        roundtrip_prop(BitWidth::B8);
    }

    #[test]
    fn roundtrip_16bit() {
        roundtrip_prop(BitWidth::B16);
    }

    #[test]
    fn word_row_ops_match_scalar_reference() {
        // write_row (word path) must agree element-wise with set/get (the
        // scalar reference), for every width and odd/even dim.
        check("write_row/read_row vs set/get", 160, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 37);
            let rows = g.usize_in(1, 8);
            let r = g.usize_in(0, rows - 1);
            let codes: Vec<i32> = (0..dim)
                .map(|_| g.i32_in(bw.qn(), bw.qp()))
                .collect();

            let mut word = PackedTable::new(rows, dim, bw);
            word.write_row(r, &codes);
            let mut scalar = PackedTable::new(rows, dim, bw);
            for (c, &v) in codes.iter().enumerate() {
                scalar.set(r, c, v);
            }

            for c in 0..dim {
                if word.get(r, c) != codes[c] {
                    return Err(format!(
                        "write_row broke col {c}: {} vs {}",
                        word.get(r, c),
                        codes[c]
                    ));
                }
            }
            let mut back = vec![0i32; dim];
            word.read_row(r, &mut back);
            if back != codes {
                return Err(format!("read_row mismatch: {back:?}"));
            }
            let mut deq = vec![0.0f32; dim];
            let delta = 0.25f32;
            word.read_row_dequant(r, delta, &mut deq);
            for c in 0..dim {
                let want = codes[c] as f32 * delta;
                if deq[c] != want {
                    return Err(format!(
                        "dequant mismatch col {c}: {} vs {want}",
                        deq[c]
                    ));
                }
            }
            if word.bytes() != scalar.bytes() {
                return Err("byte layout differs from scalar sets".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fused_quantize_matches_scalar_pipeline() {
        // quantize_row_packed == quantize_row + write_row, bit for bit,
        // for DR and (same rng state) SR.
        check("fused quantize+pack vs scalar", 120, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 37);
            let delta = g.f32_in(1e-3, 0.1);
            let w: Vec<f32> = (0..dim).map(|_| g.f32_normal(0.05)).collect();
            let seed = g.u32_any() as u64;
            for rounding in [Rounding::Deterministic, Rounding::Stochastic] {
                let mut rng_a = Pcg32::seeded(seed);
                let mut rng_b = Pcg32::seeded(seed);
                let mut fused = PackedTable::new(2, dim, bw);
                fused.quantize_row_packed(1, &w, delta, rounding,
                                          &mut rng_a);
                let mut codes = vec![0i32; dim];
                quantize_row(&w, delta, bw, rounding, &mut rng_b,
                             &mut codes);
                let mut scalar = PackedTable::new(2, dim, bw);
                scalar.write_row(1, &codes);
                if fused.bytes() != scalar.bytes() {
                    return Err(format!(
                        "fused != scalar for {rounding:?} {}bit dim={dim}",
                        bw.bits()
                    ));
                }
                // identical draw counts: generators must end in the same
                // state
                if rng_a.next_u32() != rng_b.next_u32() {
                    return Err("rng state diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn padding_bits_stay_zero_for_odd_dims() {
        // rows whose dim is not a multiple of codes-per-byte must keep
        // their padding bits zero after every write path, and writes must
        // stay inside row_bytes.
        check("padding bits zero", 120, |g: &mut Gen| {
            let bw = *g.pick(&[BitWidth::B2, BitWidth::B4]);
            let cpb = (8 / bw.bits()) as usize;
            // force a ragged tail
            let dim = {
                let d = g.usize_in(1, 29);
                if d % cpb == 0 {
                    d + 1
                } else {
                    d
                }
            };
            let rows = g.usize_in(1, 6);
            let mut t = PackedTable::new(rows, dim, bw);
            let mut rng = Pcg32::seeded(g.u32_any() as u64);
            for r in 0..rows {
                match g.usize_in(0, 2) {
                    0 => {
                        let codes: Vec<i32> = (0..dim)
                            .map(|_| g.i32_in(bw.qn(), bw.qp()))
                            .collect();
                        t.write_row(r, &codes);
                    }
                    1 => {
                        let w: Vec<f32> =
                            (0..dim).map(|_| g.f32_normal(0.1)).collect();
                        t.quantize_row_packed(r, &w, 0.01,
                                              Rounding::Stochastic,
                                              &mut rng);
                    }
                    _ => {
                        for c in 0..dim {
                            t.set(r, c, g.i32_in(bw.qn(), bw.qp()));
                        }
                    }
                }
            }
            let used_bits = dim * bw.bits() as usize;
            let pad_bits = t.row_bytes() * 8 - used_bits;
            assert!(pad_bits > 0 && pad_bits < 8);
            for r in 0..rows {
                let last = t.bytes()[r * t.row_bytes() + t.row_bytes() - 1];
                let pad = last >> (8 - pad_bits);
                if pad != 0 {
                    return Err(format!(
                        "row {r}: padding bits set ({last:#010b}, \
                         {}bit dim={dim})",
                        bw.bits()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn row_writer_matches_serial_writes() {
        // concurrent disjoint-row writes through RowWriter must produce
        // exactly the bytes serial write_row produces.
        let bw = BitWidth::B4;
        let (rows, dim) = (64, 11);
        let codes: Vec<Vec<i32>> = (0..rows)
            .map(|r| {
                (0..dim)
                    .map(|c| {
                        ((r * 7 + c * 3) as i32 % 16) - 8
                    })
                    .map(|v| v.clamp(bw.qn(), bw.qp()))
                    .collect()
            })
            .collect();
        let mut serial = PackedTable::new(rows, dim, bw);
        for (r, row_codes) in codes.iter().enumerate() {
            serial.write_row(r, row_codes);
        }
        let mut parallel = PackedTable::new(rows, dim, bw);
        {
            let writer = parallel.row_writer();
            crate::util::threadpool::parallel_ranges(rows, 4, 1, |range| {
                for r in range {
                    // Safety: ranges are disjoint, one writer per row.
                    unsafe { writer.write_row(r, &codes[r]) };
                }
            });
        }
        assert_eq!(serial.bytes(), parallel.bytes());
    }

    #[test]
    fn storage_is_packed() {
        // 1000 rows x 16 dims
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B8).storage_bytes(),
            16_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B4).storage_bytes(),
            8_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B2).storage_bytes(),
            4_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B16).storage_bytes(),
            32_000
        );
        // odd dim pads to byte boundary per row
        assert_eq!(
            PackedTable::new(10, 3, BitWidth::B2).storage_bytes(),
            10 // 3*2=6 bits -> 1 byte per row
        );
    }

    #[test]
    fn rows_are_independent() {
        let mut t = PackedTable::new(3, 5, BitWidth::B4);
        t.write_row(1, &[-8, 7, 0, -1, 3]);
        let mut row0 = vec![9i32; 5];
        t.read_row(0, &mut row0);
        assert_eq!(row0, vec![0; 5]);
        let mut row1 = vec![0i32; 5];
        t.read_row(1, &mut row1);
        assert_eq!(row1, vec![-8, 7, 0, -1, 3]);
        // writing row 1 again (all widths of tail) must leave rows 0 and 2
        // untouched: row writes stay within row_bytes
        t.write_row(1, &[7, -8, 1, -2, -1]);
        let mut row2 = vec![0i32; 5];
        t.read_row(2, &mut row2);
        assert_eq!(row2, vec![0; 5]);
        t.read_row(0, &mut row0);
        assert_eq!(row0, vec![0; 5]);
    }

    #[test]
    fn dequant_row_matches_scalar() {
        let mut t = PackedTable::new(2, 7, BitWidth::B8);
        t.write_row(0, &[-128, -1, 0, 1, 2, 64, 127]);
        let mut out = vec![0.0f32; 7];
        t.read_row_dequant(0, 0.5, &mut out);
        assert_eq!(out, vec![-64.0, -0.5, 0.0, 0.5, 1.0, 32.0, 63.5]);
    }

    #[test]
    fn raw_rows_roundtrip_and_padding_guard() {
        check("raw_rows roundtrip", 80, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let rows = g.usize_in(2, 20);
            let dim = g.usize_in(1, 19);
            let mut src = PackedTable::new(rows, dim, bw);
            for r in 0..rows {
                let codes: Vec<i32> =
                    (0..dim).map(|_| g.i32_in(bw.qn(), bw.qp())).collect();
                src.write_row(r, codes.as_slice());
            }
            let lo = g.usize_in(0, rows - 1);
            let count = g.usize_in(1, rows - lo);
            let bytes = src.raw_rows(lo, count).to_vec();
            let mut dst = PackedTable::new(rows, dim, bw);
            dst.load_raw_rows(lo, &bytes)
                .map_err(|e| format!("load failed: {e:#}"))?;
            if dst.raw_rows(lo, count) != src.raw_rows(lo, count) {
                return Err("restored bytes differ".into());
            }
            // rows outside [lo, lo+count) stay zeroed
            let mut codes = vec![0i32; dim];
            for r in 0..rows {
                if r < lo || r >= lo + count {
                    dst.read_row(r, &mut codes);
                    if codes.iter().any(|&c| c != 0) {
                        return Err(format!("row {r} disturbed"));
                    }
                }
            }
            Ok(())
        });

        // misaligned payloads and out-of-range targets are rejected on
        // both directions
        let mut t = PackedTable::new(4, 3, BitWidth::B4);
        assert!(t.load_raw_rows(0, &[0u8; 3]).is_err()); // 2 bytes/row
        assert!(t.load_raw_rows(3, &[0u8; 4]).is_err()); // past the end
        assert!(t.save_raw_rows(0, &mut [0u8; 3]).is_err());
        assert!(t.save_raw_rows(3, &mut [0u8; 4]).is_err());
        assert!(t.save_raw_rows(1, &mut [0u8; 4]).is_ok());
        // padding bits set -> rejected (3 nibbles used, 1 pad nibble)
        assert!(t.load_raw_rows(0, &[0x11, 0xF1]).is_err());
        assert!(t.load_raw_rows(0, &[0x11, 0x01]).is_ok());
    }

    #[test]
    fn negative_codes_sign_extend() {
        for bw in ALL_WIDTHS {
            let mut t = PackedTable::new(1, 2, bw);
            t.set(0, 0, bw.qn());
            t.set(0, 1, -1);
            assert_eq!(t.get(0, 0), bw.qn(), "{bw:?}");
            assert_eq!(t.get(0, 1), -1, "{bw:?}");
        }
    }
}
