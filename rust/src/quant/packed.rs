//! Bit-packed integer storage for quantized embedding tables.
//!
//! This is where the paper's memory saving physically happens: the whole
//! [n_features × dim] table lives as m-bit two's-complement codes packed
//! into `u8` words (4:1 ratio at 8 bits vs f32, 16:1 at 2 bits), plus one
//! f32 step size per feature row. Only the rows referenced by the current
//! batch are expanded to f32 — and only transiently.
//!
//! Layout: row-major, rows padded to a whole byte so row accesses never
//! straddle feature boundaries (keeps row loads branch-light and makes
//! per-row parallel updates safe).

use super::BitWidth;

/// Packed `[rows × dim]` table of m-bit signed integer codes.
#[derive(Clone, Debug)]
pub struct PackedTable {
    bits: u32,
    rows: usize,
    dim: usize,
    row_bytes: usize,
    data: Vec<u8>,
}

impl PackedTable {
    pub fn new(rows: usize, dim: usize, bw: BitWidth) -> Self {
        let bits = bw.bits();
        let row_bytes = (dim * bits as usize).div_ceil(8);
        Self { bits, rows, dim, row_bytes, data: vec![0u8; rows * row_bytes] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn bit_width(&self) -> BitWidth {
        BitWidth::from_bits(self.bits).unwrap()
    }

    /// Bytes of backing storage (the compression-ratio numerator).
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }

    /// Read one element (sign-extended).
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> i32 {
        debug_assert!(row < self.rows && col < self.dim);
        let base = row * self.row_bytes;
        match self.bits {
            8 => self.data[base + col] as i8 as i32,
            16 => {
                let o = base + col * 2;
                i16::from_le_bytes([self.data[o], self.data[o + 1]]) as i32
            }
            4 => {
                let byte = self.data[base + col / 2];
                let nib = if col % 2 == 0 { byte & 0xF } else { byte >> 4 };
                ((nib as i32) << 28) >> 28
            }
            2 => {
                let byte = self.data[base + col / 4];
                let two = (byte >> ((col % 4) * 2)) & 0b11;
                ((two as i32) << 30) >> 30
            }
            _ => unreachable!(),
        }
    }

    /// Write one element. `v` must be within the bit width's range.
    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: i32) {
        debug_assert!(row < self.rows && col < self.dim);
        let bw = BitWidth::from_bits(self.bits).unwrap();
        debug_assert!(
            v >= bw.qn() && v <= bw.qp(),
            "code {v} out of range for {} bits",
            self.bits
        );
        let base = row * self.row_bytes;
        match self.bits {
            8 => self.data[base + col] = v as i8 as u8,
            16 => {
                let o = base + col * 2;
                let b = (v as i16).to_le_bytes();
                self.data[o] = b[0];
                self.data[o + 1] = b[1];
            }
            4 => {
                let o = base + col / 2;
                let nib = (v as u8) & 0xF;
                if col % 2 == 0 {
                    self.data[o] = (self.data[o] & 0xF0) | nib;
                } else {
                    self.data[o] = (self.data[o] & 0x0F) | (nib << 4);
                }
            }
            2 => {
                let o = base + col / 4;
                let shift = (col % 4) * 2;
                let two = (v as u8) & 0b11;
                self.data[o] =
                    (self.data[o] & !(0b11 << shift)) | (two << shift);
            }
            _ => unreachable!(),
        }
    }

    /// Unpack a whole row into `out` as i32 codes.
    pub fn read_row(&self, row: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.dim);
        let base = row * self.row_bytes;
        match self.bits {
            8 => {
                for (o, &b) in out.iter_mut().zip(&self.data[base..]) {
                    *o = b as i8 as i32;
                }
            }
            16 => {
                let src = &self.data[base..base + self.dim * 2];
                for (i, o) in out.iter_mut().enumerate() {
                    *o = i16::from_le_bytes([src[2 * i], src[2 * i + 1]])
                        as i32;
                }
            }
            4 => {
                let src = &self.data[base..base + self.row_bytes];
                let mut i = 0;
                for &byte in src {
                    if i < self.dim {
                        out[i] = (((byte & 0xF) as i32) << 28) >> 28;
                        i += 1;
                    }
                    if i < self.dim {
                        out[i] = (((byte >> 4) as i32) << 28) >> 28;
                        i += 1;
                    }
                }
            }
            2 => {
                let src = &self.data[base..base + self.row_bytes];
                let mut i = 0;
                for &byte in src {
                    for shift in [0u32, 2, 4, 6] {
                        if i < self.dim {
                            out[i] =
                                ((((byte >> shift) & 0b11) as i32) << 30)
                                    >> 30;
                            i += 1;
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    /// Unpack a row straight to de-quantized f32 (`code * delta`) — the
    /// gather hot path.
    pub fn read_row_dequant(&self, row: usize, delta: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let base = row * self.row_bytes;
        match self.bits {
            8 => {
                let src = &self.data[base..base + self.dim];
                for (o, &b) in out.iter_mut().zip(src) {
                    *o = (b as i8 as f32) * delta;
                }
            }
            16 => {
                let src = &self.data[base..base + self.dim * 2];
                for (o, pair) in out.iter_mut().zip(src.chunks_exact(2)) {
                    *o = i16::from_le_bytes([pair[0], pair[1]]) as f32
                        * delta;
                }
            }
            4 => {
                // branch-free nibble unpack straight to f32 (no temp
                // allocation — this is the gather hot path)
                let src = &self.data[base..base + self.row_bytes];
                let mut i = 0;
                for &byte in src {
                    if i < self.dim {
                        out[i] = ((((byte & 0xF) as i32) << 28) >> 28)
                            as f32
                            * delta;
                        i += 1;
                    }
                    if i < self.dim {
                        out[i] =
                            ((((byte >> 4) as i32) << 28) >> 28) as f32
                                * delta;
                        i += 1;
                    }
                }
            }
            _ => {
                // 2-bit: 4 codes per byte, sign-extend, scale
                let src = &self.data[base..base + self.row_bytes];
                let mut i = 0;
                for &byte in src {
                    for shift in [0u32, 2, 4, 6] {
                        if i < self.dim {
                            out[i] = ((((byte >> shift) & 0b11) as i32)
                                << 30 >> 30)
                                as f32
                                * delta;
                            i += 1;
                        }
                    }
                }
            }
        }
    }

    /// Pack a row of i32 codes.
    pub fn write_row(&mut self, row: usize, codes: &[i32]) {
        debug_assert_eq!(codes.len(), self.dim);
        for (col, &c) in codes.iter().enumerate() {
            self.set(row, col, c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn roundtrip_prop(bw: BitWidth) {
        check(
            &format!("packed roundtrip {}bit", bw.bits()),
            120,
            move |g: &mut Gen| {
                let rows = g.usize_in(1, 40);
                let dim = g.usize_in(1, 33);
                let mut t = PackedTable::new(rows, dim, bw);
                let mut want = vec![0i32; rows * dim];
                for r in 0..rows {
                    for c in 0..dim {
                        let v = g.i32_in(bw.qn(), bw.qp());
                        t.set(r, c, v);
                        want[r * dim + c] = v;
                    }
                }
                for r in 0..rows {
                    let mut row = vec![0i32; dim];
                    t.read_row(r, &mut row);
                    for c in 0..dim {
                        if t.get(r, c) != want[r * dim + c]
                            || row[c] != want[r * dim + c]
                        {
                            return Err(format!(
                                "mismatch at ({r},{c}): got {} / {} want {}",
                                t.get(r, c),
                                row[c],
                                want[r * dim + c]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn roundtrip_2bit() {
        roundtrip_prop(BitWidth::B2);
    }

    #[test]
    fn roundtrip_4bit() {
        roundtrip_prop(BitWidth::B4);
    }

    #[test]
    fn roundtrip_8bit() {
        roundtrip_prop(BitWidth::B8);
    }

    #[test]
    fn roundtrip_16bit() {
        roundtrip_prop(BitWidth::B16);
    }

    #[test]
    fn storage_is_packed() {
        // 1000 rows x 16 dims
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B8).storage_bytes(),
            16_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B4).storage_bytes(),
            8_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B2).storage_bytes(),
            4_000
        );
        assert_eq!(
            PackedTable::new(1000, 16, BitWidth::B16).storage_bytes(),
            32_000
        );
        // odd dim pads to byte boundary per row
        assert_eq!(
            PackedTable::new(10, 3, BitWidth::B2).storage_bytes(),
            10 // 3*2=6 bits -> 1 byte per row
        );
    }

    #[test]
    fn rows_are_independent() {
        let mut t = PackedTable::new(3, 5, BitWidth::B4);
        t.write_row(1, &[-8, 7, 0, -1, 3]);
        let mut row0 = vec![9i32; 5];
        t.read_row(0, &mut row0);
        assert_eq!(row0, vec![0; 5]);
        let mut row1 = vec![0i32; 5];
        t.read_row(1, &mut row1);
        assert_eq!(row1, vec![-8, 7, 0, -1, 3]);
    }

    #[test]
    fn dequant_row_matches_scalar() {
        let mut t = PackedTable::new(2, 7, BitWidth::B8);
        t.write_row(0, &[-128, -1, 0, 1, 2, 64, 127]);
        let mut out = vec![0.0f32; 7];
        t.read_row_dequant(0, 0.5, &mut out);
        assert_eq!(out, vec![-64.0, -0.5, 0.0, 0.5, 1.0, 32.0, 63.5]);
    }

    #[test]
    fn negative_codes_sign_extend() {
        for bw in [BitWidth::B2, BitWidth::B4, BitWidth::B8, BitWidth::B16] {
            let mut t = PackedTable::new(1, 2, bw);
            t.set(0, 0, bw.qn());
            t.set(0, 1, -1);
            assert_eq!(t.get(0, 0), bw.qn(), "{bw:?}");
            assert_eq!(t.get(0, 1), -1, "{bw:?}");
        }
    }
}
