//! SIMD kernels for the packed-row hot paths, behind one-time runtime
//! dispatch.
//!
//! Every hot loop over packed codes — dequantize (codes → f32 with the
//! Δ scale), unpack (codes → i32), and the deterministic-rounding half
//! of the fused quantize→pack — funnels through the free functions in
//! this module, which select an instruction set *once* per process
//! (first use) via [`active`]:
//!
//! * x86_64: AVX2 (8 codes/iteration) when the CPU reports it, else
//!   SSE4.1 (4 codes/iteration), detected with
//!   `is_x86_feature_detected!`;
//! * aarch64: NEON (8 codes/iteration for dequant, 4 for quantize);
//! * anywhere else, or under `ALPT_FORCE_KERNEL=scalar`: the original
//!   byte-wise kernels in [`super::packed`], kept verbatim as the
//!   property-test oracle.
//!
//! **Bit-identity is the contract.** A kernel is not an approximation:
//! for any input, every kernel must produce the same output *bits* as
//! the scalar reference, so the repo-wide determinism guarantee
//! ("bit-identical at any thread count") extends to "… and any
//! kernel". That works because each vector op used here is IEEE-754
//! exactly rounded and therefore equal to its scalar counterpart:
//!
//! * dequantize is `(code as f32) * delta` — int→f32 conversion is
//!   exact for |code| ≤ 2^15 ≪ 2^24, and vector `mul_ps` rounds
//!   identically to scalar `*`;
//! * deterministic rounding is `floor(clamp(w/delta, qn, qp) + 0.5)`
//!   — `div_ps`/`add_ps`/`floor_ps` are exactly rounded, min/max
//!   clamping equals `f32::clamp` for finite inputs (stores guarantee
//!   finite weights and Δ ≥ 1e-8), and after `floor` the value is
//!   integral so truncating `cvttps` conversion is exact;
//! * no FMA anywhere — a fused multiply-add rounds once where the
//!   scalar reference rounds twice, which would break bit-identity.
//!
//! Stochastic rounding stays scalar by design: SR consumes one
//! `Pcg32` draw per element *in column order*, and that draw-order
//! contract (checkpointed generator states, resume bit-identity) is
//! worth more than vectorizing the SR multiply.
//!
//! `ALPT_FORCE_KERNEL=scalar|sse41|avx2|neon` pins the choice for
//! tests and benches; an unknown or unsupported name panics loudly —
//! a forced kernel that silently fell back would let a CI matrix leg
//! test the wrong code path and still come up green.

use super::packed::{
    dequant_codes, pack_codes, quantize_dr_codes, unpack_codes,
};
use super::BitWidth;
use std::sync::OnceLock;

/// One instruction-set implementation of the packed-row kernels.
/// Variants exist on every architecture (so names parse everywhere);
/// [`Kernel::is_supported`] says whether this build/CPU can run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Byte-wise reference kernels ([`super::packed`]) — always
    /// available, and the oracle every SIMD kernel is tested against.
    Scalar,
    /// x86_64 SSE4.1: 4 codes per iteration.
    Sse41,
    /// x86_64 AVX2: 8 codes per iteration.
    Avx2,
    /// aarch64 NEON: 8 codes per dequant iteration.
    Neon,
}

impl Kernel {
    /// The name `ALPT_FORCE_KERNEL` accepts and benches report.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Sse41 => "sse41",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Inverse of [`Kernel::name`].
    pub fn from_name(name: &str) -> Option<Kernel> {
        match name {
            "scalar" => Some(Kernel::Scalar),
            "sse41" => Some(Kernel::Sse41),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            _ => None,
        }
    }

    /// Can this build, on this CPU, run this kernel?
    pub fn is_supported(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse41 => is_x86_feature_detected!("sse4.1"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => {
                std::arch::is_aarch64_feature_detected!("neon")
            }
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

/// Every kernel this build/CPU can run, scalar first — the bench and
/// property-test iteration order.
pub fn available() -> Vec<Kernel> {
    [Kernel::Scalar, Kernel::Sse41, Kernel::Avx2, Kernel::Neon]
        .into_iter()
        .filter(|k| k.is_supported())
        .collect()
}

/// The process-wide kernel, selected once on first use: the
/// `ALPT_FORCE_KERNEL` override if set and non-empty (panicking on an
/// unknown or unsupported name), else the best supported instruction
/// set.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(detect)
}

fn detect() -> Kernel {
    match std::env::var("ALPT_FORCE_KERNEL") {
        Ok(name) if !name.is_empty() => {
            let k = Kernel::from_name(&name).unwrap_or_else(|| {
                panic!(
                    "ALPT_FORCE_KERNEL={name:?}: unknown kernel \
                     (expected scalar|sse41|avx2|neon)"
                )
            });
            assert!(
                k.is_supported(),
                "ALPT_FORCE_KERNEL={name:?}: kernel not supported by \
                 this build/CPU"
            );
            k
        }
        _ => best(),
    }
}

/// Best instruction set the CPU reports (no env override).
fn best() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
        if is_x86_feature_detected!("sse4.1") {
            return Kernel::Sse41;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

// ------------------------------------------------------------ dispatch

/// Dequantize one byte-padded packed row: `out[c] = code[c] * delta`.
pub fn dequant_row(
    k: Kernel,
    src: &[u8],
    dim: usize,
    bits: u32,
    delta: f32,
    out: &mut [f32],
) {
    debug_assert!(k.is_supported());
    match k {
        Kernel::Scalar => dequant_codes(src, dim, bits, delta, out),
        #[cfg(target_arch = "x86_64")]
        // Safety: is_supported() verified the CPU feature above.
        Kernel::Sse41 => unsafe {
            x86::dequant_row_sse41(src, dim, bits, delta, out)
        },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above.
        Kernel::Avx2 => unsafe {
            x86::dequant_row_avx2(src, dim, bits, delta, out)
        },
        #[cfg(target_arch = "aarch64")]
        // Safety: as above.
        Kernel::Neon => unsafe {
            neon::dequant_row(src, dim, bits, delta, out)
        },
        _ => unreachable!("kernel not compiled for this arch"),
    }
}

/// Unpack one byte-padded packed row to sign-extended i32 codes.
pub fn unpack_row(
    k: Kernel,
    src: &[u8],
    dim: usize,
    bits: u32,
    out: &mut [i32],
) {
    debug_assert!(k.is_supported());
    match k {
        Kernel::Scalar => unpack_codes(src, dim, bits, out),
        #[cfg(target_arch = "x86_64")]
        // Safety: is_supported() verified the CPU feature above.
        Kernel::Sse41 => unsafe {
            x86::unpack_row_sse41(src, dim, bits, out)
        },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above.
        Kernel::Avx2 => unsafe {
            x86::unpack_row_avx2(src, dim, bits, out)
        },
        #[cfg(target_arch = "aarch64")]
        // Safety: as above.
        Kernel::Neon => unsafe { neon::unpack_row(src, dim, bits, out) },
        _ => unreachable!("kernel not compiled for this arch"),
    }
}

/// Codes per quantize chunk. 64 codes hit a byte boundary at every
/// width (64·2 bits = 16 B), so each chunk packs independently, and
/// the i32 scratch stays on the stack for any `dim`.
const QCHUNK: usize = 64;

/// Fused deterministic quantize→pack of one row: vector-quantize
/// `w/delta` (clamp, round-half-up) in [`QCHUNK`]-code chunks, then
/// pack each chunk with the scalar byte packer (padding bits zero).
/// Bit-identical to the scalar `quantize_dr` + `pack_codes` pipeline —
/// see the module docs for the op-by-op argument.
pub fn quantize_dr_row(
    k: Kernel,
    dst: &mut [u8],
    dim: usize,
    bits: u32,
    bw: BitWidth,
    w: &[f32],
    delta: f32,
) {
    debug_assert!(k.is_supported());
    if matches!(k, Kernel::Scalar) {
        return quantize_dr_codes(dst, dim, bits, bw, w, delta);
    }
    let mut codes = [0i32; QCHUNK];
    let mut col = 0;
    while col < dim {
        let len = QCHUNK.min(dim - col);
        let chunk = &w[col..col + len];
        match k {
            #[cfg(target_arch = "x86_64")]
            // Safety: is_supported() verified the CPU feature above.
            Kernel::Sse41 => unsafe {
                x86::quantize_codes_dr_sse41(
                    chunk,
                    delta,
                    bw,
                    &mut codes[..len],
                )
            },
            #[cfg(target_arch = "x86_64")]
            // Safety: as above.
            Kernel::Avx2 => unsafe {
                x86::quantize_codes_dr_avx2(
                    chunk,
                    delta,
                    bw,
                    &mut codes[..len],
                )
            },
            #[cfg(target_arch = "aarch64")]
            // Safety: as above.
            Kernel::Neon => unsafe {
                neon::quantize_codes_dr(
                    chunk,
                    delta,
                    bw,
                    &mut codes[..len],
                )
            },
            _ => unreachable!("kernel not compiled for this arch"),
        }
        let lo = col * bits as usize / 8;
        let hi = ((col + len) * bits as usize).div_ceil(8);
        pack_codes(&mut dst[lo..hi], len, bits, &codes[..len]);
        col += len;
    }
}

/// Scalar extraction of one sign-extended code — the tail path shared
/// by every SIMD kernel (mirrors `PackedTable::get`).
#[inline]
fn extract_code(src: &[u8], bits: u32, col: usize) -> i32 {
    match bits {
        8 => src[col] as i8 as i32,
        16 => {
            i16::from_le_bytes([src[2 * col], src[2 * col + 1]]) as i32
        }
        4 => {
            let byte = src[col / 2];
            let nib = if col % 2 == 0 { byte & 0xF } else { byte >> 4 };
            ((nib as i32) << 28) >> 28
        }
        2 => {
            let byte = src[col / 4];
            let two = (byte >> ((col % 4) * 2)) & 0b11;
            ((two as i32) << 30) >> 30
        }
        _ => unreachable!(),
    }
}

// -------------------------------------------------- x86_64 (AVX2/SSE4.1)

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::{quantize_dr, BitWidth};
    use super::extract_code;
    use core::arch::x86_64::*;

    /// AVX2 dequantize: 8 codes per iteration, scalar ragged tail.
    ///
    /// # Safety
    /// The CPU must support AVX2 (checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_row_avx2(
        src: &[u8],
        dim: usize,
        bits: u32,
        delta: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let d = _mm256_set1_ps(delta);
        let full = dim & !7;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let v = _mm_loadl_epi64(
                        src.as_ptr().add(i) as *const __m128i
                    );
                    let x = _mm256_cvtepi8_epi32(v);
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_mul_ps(_mm256_cvtepi32_ps(x), d),
                    );
                    i += 8;
                }
            }
            16 => {
                while i < full {
                    let v = _mm_loadu_si128(
                        src.as_ptr().add(2 * i) as *const __m128i
                    );
                    let x = _mm256_cvtepi16_epi32(v);
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_mul_ps(_mm256_cvtepi32_ps(x), d),
                    );
                    i += 8;
                }
            }
            4 => {
                // 8 nibbles live in one 32-bit word: broadcast, shift
                // each lane to its nibble, sign-extend via <<28 >>28.
                let sh =
                    _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                while i < full {
                    let b = i / 2;
                    let w = u32::from_le_bytes([
                        src[b],
                        src[b + 1],
                        src[b + 2],
                        src[b + 3],
                    ]);
                    let lanes = _mm256_srlv_epi32(
                        _mm256_set1_epi32(w as i32),
                        sh,
                    );
                    let x = _mm256_srai_epi32(
                        _mm256_slli_epi32(lanes, 28),
                        28,
                    );
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_mul_ps(_mm256_cvtepi32_ps(x), d),
                    );
                    i += 8;
                }
            }
            2 => {
                let sh = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                while i < full {
                    let b = i / 4;
                    let w =
                        u16::from_le_bytes([src[b], src[b + 1]]) as u32;
                    let lanes = _mm256_srlv_epi32(
                        _mm256_set1_epi32(w as i32),
                        sh,
                    );
                    let x = _mm256_srai_epi32(
                        _mm256_slli_epi32(lanes, 30),
                        30,
                    );
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_mul_ps(_mm256_cvtepi32_ps(x), d),
                    );
                    i += 8;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j) as f32 * delta;
        }
    }

    /// AVX2 unpack to i32 codes (same lane decode as dequant, no Δ).
    ///
    /// # Safety
    /// The CPU must support AVX2 (checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_row_avx2(
        src: &[u8],
        dim: usize,
        bits: u32,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let full = dim & !7;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let v = _mm_loadl_epi64(
                        src.as_ptr().add(i) as *const __m128i
                    );
                    _mm256_storeu_si256(
                        out.as_mut_ptr().add(i) as *mut __m256i,
                        _mm256_cvtepi8_epi32(v),
                    );
                    i += 8;
                }
            }
            16 => {
                while i < full {
                    let v = _mm_loadu_si128(
                        src.as_ptr().add(2 * i) as *const __m128i
                    );
                    _mm256_storeu_si256(
                        out.as_mut_ptr().add(i) as *mut __m256i,
                        _mm256_cvtepi16_epi32(v),
                    );
                    i += 8;
                }
            }
            4 => {
                let sh =
                    _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                while i < full {
                    let b = i / 2;
                    let w = u32::from_le_bytes([
                        src[b],
                        src[b + 1],
                        src[b + 2],
                        src[b + 3],
                    ]);
                    let lanes = _mm256_srlv_epi32(
                        _mm256_set1_epi32(w as i32),
                        sh,
                    );
                    _mm256_storeu_si256(
                        out.as_mut_ptr().add(i) as *mut __m256i,
                        _mm256_srai_epi32(
                            _mm256_slli_epi32(lanes, 28),
                            28,
                        ),
                    );
                    i += 8;
                }
            }
            2 => {
                let sh = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                while i < full {
                    let b = i / 4;
                    let w =
                        u16::from_le_bytes([src[b], src[b + 1]]) as u32;
                    let lanes = _mm256_srlv_epi32(
                        _mm256_set1_epi32(w as i32),
                        sh,
                    );
                    _mm256_storeu_si256(
                        out.as_mut_ptr().add(i) as *mut __m256i,
                        _mm256_srai_epi32(
                            _mm256_slli_epi32(lanes, 30),
                            30,
                        ),
                    );
                    i += 8;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j);
        }
    }

    /// AVX2 deterministic quantize: codes = floor(clamp(w/Δ) + 0.5),
    /// 8 lanes per iteration, scalar `quantize_dr` on the tail.
    ///
    /// # Safety
    /// The CPU must support AVX2 (checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_codes_dr_avx2(
        w: &[f32],
        delta: f32,
        bw: BitWidth,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), w.len());
        let d = _mm256_set1_ps(delta);
        let qn = _mm256_set1_ps(bw.qn() as f32);
        let qp = _mm256_set1_ps(bw.qp() as f32);
        let half = _mm256_set1_ps(0.5);
        let full = w.len() & !7;
        let mut i = 0;
        while i < full {
            let x =
                _mm256_div_ps(_mm256_loadu_ps(w.as_ptr().add(i)), d);
            let x = _mm256_max_ps(_mm256_min_ps(x, qp), qn);
            let x = _mm256_floor_ps(_mm256_add_ps(x, half));
            _mm256_storeu_si256(
                out.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_cvttps_epi32(x),
            );
            i += 8;
        }
        for (j, o) in out[full..].iter_mut().enumerate() {
            *o = quantize_dr(w[full + j], delta, bw);
        }
    }

    /// SSE4.1 dequantize: 4 codes per iteration, scalar ragged tail.
    ///
    /// # Safety
    /// The CPU must support SSE4.1 (checked by the dispatcher).
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dequant_row_sse41(
        src: &[u8],
        dim: usize,
        bits: u32,
        delta: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let d = _mm_set1_ps(delta);
        let full = dim & !3;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let w = i32::from_le_bytes([
                        src[i],
                        src[i + 1],
                        src[i + 2],
                        src[i + 3],
                    ]);
                    let x = _mm_cvtepi8_epi32(_mm_cvtsi32_si128(w));
                    _mm_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm_mul_ps(_mm_cvtepi32_ps(x), d),
                    );
                    i += 4;
                }
            }
            16 => {
                while i < full {
                    let v = _mm_loadl_epi64(
                        src.as_ptr().add(2 * i) as *const __m128i
                    );
                    let x = _mm_cvtepi16_epi32(v);
                    _mm_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm_mul_ps(_mm_cvtepi32_ps(x), d),
                    );
                    i += 4;
                }
            }
            4 => {
                // no variable-shift in SSE: spread the nibbles with
                // scalar shifts, sign-extend all four lanes at once
                while i < full {
                    let b = i / 2;
                    let w = u16::from_le_bytes([src[b], src[b + 1]])
                        as i32;
                    let lanes =
                        _mm_setr_epi32(w, w >> 4, w >> 8, w >> 12);
                    let x = _mm_srai_epi32(
                        _mm_slli_epi32(lanes, 28),
                        28,
                    );
                    _mm_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm_mul_ps(_mm_cvtepi32_ps(x), d),
                    );
                    i += 4;
                }
            }
            2 => {
                while i < full {
                    let b = src[i / 4] as i32;
                    let lanes =
                        _mm_setr_epi32(b, b >> 2, b >> 4, b >> 6);
                    let x = _mm_srai_epi32(
                        _mm_slli_epi32(lanes, 30),
                        30,
                    );
                    _mm_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm_mul_ps(_mm_cvtepi32_ps(x), d),
                    );
                    i += 4;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j) as f32 * delta;
        }
    }

    /// SSE4.1 unpack to i32 codes.
    ///
    /// # Safety
    /// The CPU must support SSE4.1 (checked by the dispatcher).
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn unpack_row_sse41(
        src: &[u8],
        dim: usize,
        bits: u32,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let full = dim & !3;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let w = i32::from_le_bytes([
                        src[i],
                        src[i + 1],
                        src[i + 2],
                        src[i + 3],
                    ]);
                    _mm_storeu_si128(
                        out.as_mut_ptr().add(i) as *mut __m128i,
                        _mm_cvtepi8_epi32(_mm_cvtsi32_si128(w)),
                    );
                    i += 4;
                }
            }
            16 => {
                while i < full {
                    let v = _mm_loadl_epi64(
                        src.as_ptr().add(2 * i) as *const __m128i
                    );
                    _mm_storeu_si128(
                        out.as_mut_ptr().add(i) as *mut __m128i,
                        _mm_cvtepi16_epi32(v),
                    );
                    i += 4;
                }
            }
            4 => {
                while i < full {
                    let b = i / 2;
                    let w = u16::from_le_bytes([src[b], src[b + 1]])
                        as i32;
                    let lanes =
                        _mm_setr_epi32(w, w >> 4, w >> 8, w >> 12);
                    _mm_storeu_si128(
                        out.as_mut_ptr().add(i) as *mut __m128i,
                        _mm_srai_epi32(_mm_slli_epi32(lanes, 28), 28),
                    );
                    i += 4;
                }
            }
            2 => {
                while i < full {
                    let b = src[i / 4] as i32;
                    let lanes =
                        _mm_setr_epi32(b, b >> 2, b >> 4, b >> 6);
                    _mm_storeu_si128(
                        out.as_mut_ptr().add(i) as *mut __m128i,
                        _mm_srai_epi32(_mm_slli_epi32(lanes, 30), 30),
                    );
                    i += 4;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j);
        }
    }

    /// SSE4.1 deterministic quantize (4 lanes; see the AVX2 variant).
    ///
    /// # Safety
    /// The CPU must support SSE4.1 (checked by the dispatcher).
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn quantize_codes_dr_sse41(
        w: &[f32],
        delta: f32,
        bw: BitWidth,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), w.len());
        let d = _mm_set1_ps(delta);
        let qn = _mm_set1_ps(bw.qn() as f32);
        let qp = _mm_set1_ps(bw.qp() as f32);
        let half = _mm_set1_ps(0.5);
        let full = w.len() & !3;
        let mut i = 0;
        while i < full {
            let x = _mm_div_ps(_mm_loadu_ps(w.as_ptr().add(i)), d);
            let x = _mm_max_ps(_mm_min_ps(x, qp), qn);
            let x = _mm_floor_ps(_mm_add_ps(x, half));
            _mm_storeu_si128(
                out.as_mut_ptr().add(i) as *mut __m128i,
                _mm_cvttps_epi32(x),
            );
            i += 4;
        }
        for (j, o) in out[full..].iter_mut().enumerate() {
            *o = quantize_dr(w[full + j], delta, bw);
        }
    }
}

// ------------------------------------------------------ aarch64 (NEON)

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::super::{quantize_dr, BitWidth};
    use super::extract_code;
    use core::arch::aarch64::*;

    /// NEON dequantize: 8 codes per iteration (two 4-lane halves for
    /// the sub-byte widths), scalar ragged tail.
    ///
    /// # Safety
    /// The CPU must support NEON (checked by the dispatcher).
    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_row(
        src: &[u8],
        dim: usize,
        bits: u32,
        delta: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let full = dim & !7;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let v = vld1_s8(src.as_ptr().add(i) as *const i8);
                    let w = vmovl_s8(v);
                    let lo = vmovl_s16(vget_low_s16(w));
                    let hi = vmovl_s16(vget_high_s16(w));
                    vst1q_f32(
                        out.as_mut_ptr().add(i),
                        vmulq_n_f32(vcvtq_f32_s32(lo), delta),
                    );
                    vst1q_f32(
                        out.as_mut_ptr().add(i + 4),
                        vmulq_n_f32(vcvtq_f32_s32(hi), delta),
                    );
                    i += 8;
                }
            }
            16 => {
                while i < full {
                    let w = vld1q_s16(
                        src.as_ptr().add(2 * i) as *const i16
                    );
                    let lo = vmovl_s16(vget_low_s16(w));
                    let hi = vmovl_s16(vget_high_s16(w));
                    vst1q_f32(
                        out.as_mut_ptr().add(i),
                        vmulq_n_f32(vcvtq_f32_s32(lo), delta),
                    );
                    vst1q_f32(
                        out.as_mut_ptr().add(i + 4),
                        vmulq_n_f32(vcvtq_f32_s32(hi), delta),
                    );
                    i += 8;
                }
            }
            4 => {
                // negative vshlq_u32 counts = logical right shift;
                // sign-extend via <<28 >>28 like the scalar kernel
                const LO: [i32; 4] = [0, -4, -8, -12];
                const HI: [i32; 4] = [-16, -20, -24, -28];
                let sh_lo = vld1q_s32(LO.as_ptr());
                let sh_hi = vld1q_s32(HI.as_ptr());
                while i < full {
                    let b = i / 2;
                    let w = u32::from_le_bytes([
                        src[b],
                        src[b + 1],
                        src[b + 2],
                        src[b + 3],
                    ]);
                    let v = vdupq_n_u32(w);
                    for (half, sh) in [(0, sh_lo), (4, sh_hi)] {
                        let lanes = vreinterpretq_s32_u32(
                            vshlq_u32(v, sh),
                        );
                        let x = vshrq_n_s32::<28>(
                            vshlq_n_s32::<28>(lanes),
                        );
                        vst1q_f32(
                            out.as_mut_ptr().add(i + half),
                            vmulq_n_f32(vcvtq_f32_s32(x), delta),
                        );
                    }
                    i += 8;
                }
            }
            2 => {
                const LO: [i32; 4] = [0, -2, -4, -6];
                const HI: [i32; 4] = [-8, -10, -12, -14];
                let sh_lo = vld1q_s32(LO.as_ptr());
                let sh_hi = vld1q_s32(HI.as_ptr());
                while i < full {
                    let b = i / 4;
                    let w = u16::from_le_bytes([src[b], src[b + 1]])
                        as u32;
                    let v = vdupq_n_u32(w);
                    for (half, sh) in [(0, sh_lo), (4, sh_hi)] {
                        let lanes = vreinterpretq_s32_u32(
                            vshlq_u32(v, sh),
                        );
                        let x = vshrq_n_s32::<30>(
                            vshlq_n_s32::<30>(lanes),
                        );
                        vst1q_f32(
                            out.as_mut_ptr().add(i + half),
                            vmulq_n_f32(vcvtq_f32_s32(x), delta),
                        );
                    }
                    i += 8;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j) as f32 * delta;
        }
    }

    /// NEON unpack to i32 codes.
    ///
    /// # Safety
    /// The CPU must support NEON (checked by the dispatcher).
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack_row(
        src: &[u8],
        dim: usize,
        bits: u32,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), dim);
        let full = dim & !7;
        let mut i = 0;
        match bits {
            8 => {
                while i < full {
                    let v = vld1_s8(src.as_ptr().add(i) as *const i8);
                    let w = vmovl_s8(v);
                    vst1q_s32(
                        out.as_mut_ptr().add(i),
                        vmovl_s16(vget_low_s16(w)),
                    );
                    vst1q_s32(
                        out.as_mut_ptr().add(i + 4),
                        vmovl_s16(vget_high_s16(w)),
                    );
                    i += 8;
                }
            }
            16 => {
                while i < full {
                    let w = vld1q_s16(
                        src.as_ptr().add(2 * i) as *const i16
                    );
                    vst1q_s32(
                        out.as_mut_ptr().add(i),
                        vmovl_s16(vget_low_s16(w)),
                    );
                    vst1q_s32(
                        out.as_mut_ptr().add(i + 4),
                        vmovl_s16(vget_high_s16(w)),
                    );
                    i += 8;
                }
            }
            4 => {
                const LO: [i32; 4] = [0, -4, -8, -12];
                const HI: [i32; 4] = [-16, -20, -24, -28];
                let sh_lo = vld1q_s32(LO.as_ptr());
                let sh_hi = vld1q_s32(HI.as_ptr());
                while i < full {
                    let b = i / 2;
                    let w = u32::from_le_bytes([
                        src[b],
                        src[b + 1],
                        src[b + 2],
                        src[b + 3],
                    ]);
                    let v = vdupq_n_u32(w);
                    for (half, sh) in [(0, sh_lo), (4, sh_hi)] {
                        let lanes = vreinterpretq_s32_u32(
                            vshlq_u32(v, sh),
                        );
                        vst1q_s32(
                            out.as_mut_ptr().add(i + half),
                            vshrq_n_s32::<28>(
                                vshlq_n_s32::<28>(lanes),
                            ),
                        );
                    }
                    i += 8;
                }
            }
            2 => {
                const LO: [i32; 4] = [0, -2, -4, -6];
                const HI: [i32; 4] = [-8, -10, -12, -14];
                let sh_lo = vld1q_s32(LO.as_ptr());
                let sh_hi = vld1q_s32(HI.as_ptr());
                while i < full {
                    let b = i / 4;
                    let w = u16::from_le_bytes([src[b], src[b + 1]])
                        as u32;
                    let v = vdupq_n_u32(w);
                    for (half, sh) in [(0, sh_lo), (4, sh_hi)] {
                        let lanes = vreinterpretq_s32_u32(
                            vshlq_u32(v, sh),
                        );
                        vst1q_s32(
                            out.as_mut_ptr().add(i + half),
                            vshrq_n_s32::<30>(
                                vshlq_n_s32::<30>(lanes),
                            ),
                        );
                    }
                    i += 8;
                }
            }
            _ => unreachable!(),
        }
        for (j, o) in out[full..dim].iter_mut().enumerate() {
            *o = extract_code(src, bits, full + j);
        }
    }

    /// NEON deterministic quantize (4 lanes; `vrndmq_f32` is floor and
    /// `vcvtq_s32_f32` truncates — exact after floor).
    ///
    /// # Safety
    /// The CPU must support NEON (checked by the dispatcher).
    #[target_feature(enable = "neon")]
    pub unsafe fn quantize_codes_dr(
        w: &[f32],
        delta: f32,
        bw: BitWidth,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), w.len());
        let d = vdupq_n_f32(delta);
        let qn = vdupq_n_f32(bw.qn() as f32);
        let qp = vdupq_n_f32(bw.qp() as f32);
        let half = vdupq_n_f32(0.5);
        let full = w.len() & !3;
        let mut i = 0;
        while i < full {
            let x = vdivq_f32(vld1q_f32(w.as_ptr().add(i)), d);
            let x = vmaxq_f32(vminq_f32(x, qp), qn);
            let x = vrndmq_f32(vaddq_f32(x, half));
            vst1q_s32(out.as_mut_ptr().add(i), vcvtq_s32_f32(x));
            i += 4;
        }
        for (j, o) in out[full..].iter_mut().enumerate() {
            *o = quantize_dr(w[full + j], delta, bw);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{PackedTable, Rounding};
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    const ALL_WIDTHS: [BitWidth; 4] =
        [BitWidth::B2, BitWidth::B4, BitWidth::B8, BitWidth::B16];

    #[test]
    fn kernel_names_round_trip() {
        for k in
            [Kernel::Scalar, Kernel::Sse41, Kernel::Avx2, Kernel::Neon]
        {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::from_name("avx512"), None);
        assert_eq!(Kernel::from_name(""), None);
        assert_eq!(Kernel::from_name("AVX2"), None); // names are exact
    }

    #[test]
    fn scalar_is_always_available_and_first() {
        let ks = available();
        assert_eq!(ks[0], Kernel::Scalar);
        assert!(ks.contains(&active()));
        for k in ks {
            assert!(k.is_supported());
        }
    }

    /// Every available SIMD kernel must reproduce the scalar oracle's
    /// dequantized f32 *bits* — all widths, odd/non-lane-multiple
    /// dims, tails included.
    #[test]
    fn simd_dequant_matches_scalar_bits() {
        check("simd dequant == scalar", 200, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 67);
            let delta = g.f32_in(1e-4, 0.3);
            let mut t = PackedTable::new(1, dim, bw);
            let codes: Vec<i32> =
                (0..dim).map(|_| g.i32_in(bw.qn(), bw.qp())).collect();
            t.write_row(0, &codes);
            let src = t.raw_rows(0, 1);

            let mut want = vec![0.0f32; dim];
            crate::quant::packed::dequant_codes(
                src,
                dim,
                bw.bits(),
                delta,
                &mut want,
            );
            for k in available() {
                let mut got = vec![f32::NAN; dim];
                dequant_row(k, src, dim, bw.bits(), delta, &mut got);
                for c in 0..dim {
                    if got[c].to_bits() != want[c].to_bits() {
                        return Err(format!(
                            "{} col {c}: {} != {} ({}bit dim={dim})",
                            k.name(),
                            got[c],
                            want[c],
                            bw.bits()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Unpack: every kernel yields the scalar oracle's i32 codes.
    #[test]
    fn simd_unpack_matches_scalar() {
        check("simd unpack == scalar", 200, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 67);
            let mut t = PackedTable::new(1, dim, bw);
            let codes: Vec<i32> =
                (0..dim).map(|_| g.i32_in(bw.qn(), bw.qp())).collect();
            t.write_row(0, &codes);
            let src = t.raw_rows(0, 1);
            for k in available() {
                let mut got = vec![i32::MIN; dim];
                unpack_row(k, src, dim, bw.bits(), &mut got);
                if got != codes {
                    return Err(format!(
                        "{} ({}bit dim={dim}): {got:?} != {codes:?}",
                        k.name(),
                        bw.bits()
                    ));
                }
            }
            Ok(())
        });
    }

    /// Deterministic quantize→pack: every kernel writes the scalar
    /// oracle's packed bytes, and padding bits stay zero even when the
    /// destination starts out dirty.
    #[test]
    fn simd_quantize_dr_matches_scalar_bytes() {
        check("simd quantize DR == scalar", 200, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 67);
            let delta = g.f32_in(1e-3, 0.1);
            let w: Vec<f32> =
                (0..dim).map(|_| g.f32_normal(0.05)).collect();
            let row_bytes = (dim * bw.bits() as usize).div_ceil(8);

            let mut want = vec![0u8; row_bytes];
            crate::quant::packed::quantize_dr_codes(
                &mut want,
                dim,
                bw.bits(),
                bw,
                &w,
                delta,
            );
            let pad_bits = row_bytes * 8 - dim * bw.bits() as usize;
            for k in available() {
                let mut got = vec![0xAAu8; row_bytes];
                quantize_dr_row(
                    k,
                    &mut got,
                    dim,
                    bw.bits(),
                    bw,
                    &w,
                    delta,
                );
                if got != want {
                    return Err(format!(
                        "{} ({}bit dim={dim}): bytes differ",
                        k.name(),
                        bw.bits()
                    ));
                }
                if pad_bits > 0
                    && got[row_bytes - 1] >> (8 - pad_bits) != 0
                {
                    return Err(format!(
                        "{} ({}bit dim={dim}): padding bits set",
                        k.name(),
                        bw.bits()
                    ));
                }
            }
            Ok(())
        });
    }

    /// The dim=QCHUNK-straddling case: rows longer than one quantize
    /// chunk must still match the scalar pipeline byte for byte.
    #[test]
    fn quantize_dr_spans_chunks() {
        for bw in ALL_WIDTHS {
            let dim = QCHUNK + 13;
            let w: Vec<f32> = (0..dim)
                .map(|c| ((c as f32) - 38.0) * 0.011)
                .collect();
            let row_bytes = (dim * bw.bits() as usize).div_ceil(8);
            let mut want = vec![0u8; row_bytes];
            crate::quant::packed::quantize_dr_codes(
                &mut want,
                dim,
                bw.bits(),
                bw,
                &w,
                0.02,
            );
            for k in available() {
                let mut got = vec![0u8; row_bytes];
                quantize_dr_row(
                    k,
                    &mut got,
                    dim,
                    bw.bits(),
                    bw,
                    &w,
                    0.02,
                );
                assert_eq!(got, want, "{} {bw:?}", k.name());
            }
        }
    }

    /// The full fused path through `PackedTable` (the store update
    /// hot loop) stays bit-identical across kernels for DR *and* SR —
    /// SR is scalar everywhere, so the draws line up by construction.
    #[test]
    fn fused_table_quantize_identical_across_kernels() {
        check("fused quantize across kernels", 100, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 37);
            let delta = g.f32_in(1e-3, 0.1);
            let w: Vec<f32> =
                (0..dim).map(|_| g.f32_normal(0.05)).collect();
            let seed = g.u32_any() as u64;
            for rounding in
                [Rounding::Deterministic, Rounding::Stochastic]
            {
                let mut want: Option<Vec<u8>> = None;
                for k in available() {
                    let mut t = PackedTable::new(1, dim, bw);
                    let mut rng = Pcg32::seeded(seed);
                    t.quantize_row_packed_with(
                        k, 0, &w, delta, rounding, &mut rng,
                    );
                    match &want {
                        None => want = Some(t.bytes().to_vec()),
                        Some(want) => {
                            if t.bytes() != &want[..] {
                                return Err(format!(
                                    "{} diverged for {rounding:?} \
                                     {}bit dim={dim}",
                                    k.name(),
                                    bw.bits()
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Batched gather (prefetch + per-id Δ) equals row-at-a-time
    /// scalar dequant for every kernel, duplicate ids included.
    #[test]
    fn batched_gather_matches_per_row_scalar() {
        check("gather_dequant == per-row", 120, |g: &mut Gen| {
            let bw = *g.pick(&ALL_WIDTHS);
            let dim = g.usize_in(1, 33);
            let rows = g.usize_in(1, 50);
            let mut t = PackedTable::new(rows, dim, bw);
            let mut rng = Pcg32::seeded(g.u32_any() as u64);
            for r in 0..rows {
                let w: Vec<f32> =
                    (0..dim).map(|_| g.f32_normal(0.1)).collect();
                t.quantize_row_packed(
                    r,
                    &w,
                    0.01,
                    Rounding::Stochastic,
                    &mut rng,
                );
            }
            let deltas: Vec<f32> =
                (0..rows).map(|_| g.f32_in(1e-4, 0.5)).collect();
            let n = g.usize_in(1, 64);
            let ids: Vec<u32> = (0..n)
                .map(|_| g.usize_in(0, rows - 1) as u32)
                .collect();

            let mut want = vec![0.0f32; n * dim];
            for (i, &id) in ids.iter().enumerate() {
                crate::quant::packed::dequant_codes(
                    t.raw_rows(id as usize, 1),
                    dim,
                    bw.bits(),
                    deltas[id as usize],
                    &mut want[i * dim..(i + 1) * dim],
                );
            }
            for k in available() {
                let mut got = vec![f32::NAN; n * dim];
                t.gather_dequant_with(
                    k,
                    &ids,
                    |id| deltas[id as usize],
                    &mut got,
                );
                for (c, (a, b)) in
                    got.iter().zip(&want).enumerate()
                {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{} elem {c}: {a} != {b} ({}bit \
                             dim={dim} n={n})",
                            k.name(),
                            bw.bits()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
