//! Optimizers and the paper's learning-rate schedule (§4.1): Adam for the
//! dense parameters, SGD(+decoupled weight decay) for embedding rows and
//! step sizes, and a step decay of ×0.1 after epochs 6 and 9.

/// Plain SGD update with decoupled weight decay:
/// `w -= lr * (g + wd * w)`.
pub fn sgd_update(w: &mut [f32], g: &[f32], lr: f32, wd: f32) {
    debug_assert_eq!(w.len(), g.len());
    for (wi, &gi) in w.iter_mut().zip(g) {
        *wi -= lr * (gi + wd * *wi);
    }
}

/// Adam (Kingma & Ba 2015) over one flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    /// One update step; `lr_scale` carries the epoch decay.
    pub fn step(&mut self, w: &mut [f32], g: &[f32], lr_scale: f32) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let lr = self.lr * lr_scale;
        for i in 0..w.len() {
            let gi = g[i] + self.weight_decay * w[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * gi;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * gi * gi;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            w[i] -= lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Optimizer state `(m, v, t)` for checkpointing.
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore state captured by [`Adam::state`]; a resumed run then
    /// takes bit-identical steps to an uninterrupted one.
    pub fn load_state(
        &mut self,
        m: &[f32],
        v: &[f32],
        t: u64,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            m.len() == self.m.len() && v.len() == self.v.len(),
            "Adam state size mismatch: checkpoint has {}/{} moments, \
             optimizer expects {}",
            m.len(),
            v.len(),
            self.m.len()
        );
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
        Ok(())
    }
}

/// The paper's LR schedule: multiply by `gamma` after each epoch in
/// `milestones` (§4.1: ×0.1 after epochs 6 and 9; epochs are 1-based).
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub milestones: Vec<usize>,
    pub gamma: f32,
}

impl Default for LrSchedule {
    fn default() -> Self {
        Self { milestones: vec![6, 9], gamma: 0.1 }
    }
}

impl LrSchedule {
    /// LR scale during `epoch` (1-based).
    pub fn scale(&self, epoch: usize) -> f32 {
        let passed =
            self.milestones.iter().filter(|&&m| epoch > m).count() as i32;
        self.gamma.powi(passed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_with_decay() {
        let mut w = vec![1.0f32, -2.0];
        sgd_update(&mut w, &[0.5, 0.5], 0.1, 0.01);
        assert!((w[0] - (1.0 - 0.1 * (0.5 + 0.01))).abs() < 1e-6);
        assert!((w[1] - (-2.0 - 0.1 * (0.5 - 0.02))).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first step| ≈ lr regardless of grad scale
        for g in [1e-4f32, 1.0, 100.0] {
            let mut adam = Adam::new(1, 0.001);
            let mut w = vec![0.0f32];
            adam.step(&mut w, &[g], 1.0);
            assert!(
                (w[0].abs() - 0.001).abs() < 1e-5,
                "g={g} w={}",
                w[0]
            );
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (w - 3)^2
        let mut adam = Adam::new(1, 0.1);
        let mut w = vec![0.0f32];
        for _ in 0..500 {
            let g = 2.0 * (w[0] - 3.0);
            adam.step(&mut w, &[g], 1.0);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "w={}", w[0]);
    }

    #[test]
    fn adam_matches_reference_trace() {
        // hand-computed two steps: lr=0.1, g=1 both steps, w0=0
        // step1: m=0.1,v=0.001,mh=1,vh=1 -> w=-0.1
        // step2: m=0.19,v=0.001999; mh=0.19/0.19=1, vh=0.001999/0.001999=1
        //        w=-0.2 (+eps wiggle)
        let mut adam = Adam::new(1, 0.1);
        let mut w = vec![0.0f32];
        adam.step(&mut w, &[1.0], 1.0);
        assert!((w[0] + 0.1).abs() < 1e-5, "{}", w[0]);
        adam.step(&mut w, &[1.0], 1.0);
        assert!((w[0] + 0.2).abs() < 1e-4, "{}", w[0]);
    }

    #[test]
    fn schedule_decays_after_milestones() {
        let s = LrSchedule::default();
        assert_eq!(s.scale(1), 1.0);
        assert_eq!(s.scale(6), 1.0);
        assert!((s.scale(7) - 0.1).abs() < 1e-7);
        assert!((s.scale(9) - 0.1).abs() < 1e-7);
        assert!((s.scale(10) - 0.01).abs() < 1e-8);
        assert!((s.scale(15) - 0.01).abs() < 1e-8);
    }
}
