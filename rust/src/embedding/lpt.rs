//! Vanilla low-precision training (paper §2.3, Eq. 8; Xu et al. 2021).
//!
//! The table lives as bit-packed integer codes with one *fixed* step size
//! shared by every feature: Δ = clip / 2^{m-1}, with the clipping value
//! tuned as a hyper-parameter (the paper sweeps {1, 0.1, 0.01, 0.001}).
//! Each step de-quantizes the batch's rows, applies the SGD update in
//! float, and re-quantizes with SR or DR — there is no full-precision
//! copy anywhere, which is the entire point.
//!
//! Hot paths are sharded across threads: `gather` splits the output
//! row-wise, `update` fuses the SGD step with `quantize_row_packed` and
//! writes disjoint rows through a [`RowWriter`](crate::quant::RowWriter).
//! SR noise comes from counter-based per-row streams
//! ([`StreamKey`]), so results are bit-identical at any thread count.

use super::{init_weights, par_gather_chunks, resolve_threads,
            EmbeddingStore, Persistable, RowStats, SecondPass, UpdateHp,
            MIN_ROWS_PER_THREAD};
use crate::quant::{delta_from_clip, BitWidth, PackedTable, Rounding};
use crate::util::rng::{Pcg32, StreamKey};
use crate::util::threadpool::parallel_ranges;
use anyhow::Result;

pub struct LptStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    rounding: Rounding,
    delta: f32,
    codes: PackedTable,
    /// sharding width for gather/update (resolved; >= 1)
    threads: usize,
    /// update-step counter feeding the per-step stream key
    step: u64,
    /// per-row update counts (in-memory only; see [`RowStats`])
    counts: Vec<u32>,
}

impl LptStore {
    pub fn init(
        n: usize,
        d: usize,
        bw: BitWidth,
        clip: f32,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) -> Self {
        Self::init_with_threads(n, d, bw, clip, rounding, 0, rng)
    }

    /// Like [`LptStore::init`] with an explicit sharding width for the
    /// init quantization and subsequent gather/update (0 = one worker per
    /// hardware thread). Results are bit-identical at any value.
    pub fn init_with_threads(
        n: usize,
        d: usize,
        bw: BitWidth,
        clip: f32,
        rounding: Rounding,
        threads: usize,
        rng: &mut Pcg32,
    ) -> Self {
        let delta = delta_from_clip(clip, bw);
        let mut codes = PackedTable::new(n, d, bw);
        // quantize the standard N(0, 0.01) init (SR keeps it unbiased);
        // row streams make the init shardable and order-independent
        let init = init_weights(n, d, rng);
        let key = StreamKey::new(rng.next_u64());
        let threads = resolve_threads(threads);
        {
            let writer = codes.row_writer();
            let init_ref = &init;
            parallel_ranges(n, threads, MIN_ROWS_PER_THREAD, |range| {
                for r in range {
                    let mut rrng = key.row_rng(r as u64);
                    // Safety: ranges are disjoint → rows are disjoint.
                    unsafe {
                        writer.quantize_row_packed(
                            r,
                            &init_ref[r * d..(r + 1) * d],
                            delta,
                            Rounding::Stochastic,
                            &mut rrng,
                        );
                    }
                }
            });
        }
        Self {
            n,
            d,
            bw,
            rounding,
            delta,
            codes,
            threads,
            step: 0,
            counts: vec![0; n],
        }
    }

    pub fn delta(&self) -> f32 {
        self.delta
    }

    pub fn bit_width(&self) -> BitWidth {
        self.bw
    }

    /// Configure the sharding width (0 = one worker per hardware thread).
    /// Purely a performance knob: results are bit-identical at any value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = resolve_threads(threads);
    }

    /// Dequantize one row into `out` — the grouped-store gather kernel
    /// (same word-at-a-time path as [`LptStore::gather`], addressed by
    /// this sub-table's local row id).
    pub(crate) fn read_row_dequant_into(&self, row: usize, out: &mut [f32]) {
        self.codes.read_row_dequant(row, self.delta, out);
    }

    /// Integer codes of one row (the grouped `quantized_view` kernel).
    pub(crate) fn read_codes_into(&self, row: usize, out: &mut [i32]) {
        self.codes.read_row(row, out);
    }

    /// Prefetch hint for one local row — the grouped store's routed
    /// gather issues this ahead of [`LptStore::read_row_dequant_into`].
    pub(crate) fn prefetch_row(&self, row: usize) {
        self.codes.prefetch_row(row);
    }

    /// Serially quantize one row from a float value with this table's
    /// fixed Δ — the grouped-store migration kernel (requantize a row
    /// moving into this group). The caller supplies the SR stream so
    /// migration stays a pure function of `(plan, seed, step)`.
    pub(crate) fn write_row_from_f32(
        &mut self,
        row: usize,
        w: &[f32],
        rrng: &mut Pcg32,
    ) {
        self.codes
            .quantize_row_packed(row, w, self.delta, self.rounding, rrng);
    }

}

impl EmbeddingStore for LptStore {
    fn method_name(&self) -> &'static str {
        match self.rounding {
            Rounding::Stochastic => "LPT(SR)",
            Rounding::Deterministic => "LPT(DR)",
        }
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        let delta = self.delta;
        par_gather_chunks(ids, self.d, out, self.threads,
                          |_, chunk_ids, chunk| {
            self.codes.gather_dequant(chunk_ids, |_| delta, chunk);
        });
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        debug_assert_eq!(emb_hat.len(), ids.len() * self.d);
        debug_assert_eq!(grads.len(), ids.len() * self.d);
        // Eq. 8: w^{t+1} = Q(ŵ − η(∇ + wd·ŵ)). One serial draw keys the
        // step; every row then owns a counter-based SR stream, so shards
        // may quantize rows in any order with bit-identical results.
        //
        // Sharding requires unique ids (two shards writing one row would
        // race); the trainer always passes deduped `batch.unique`, and
        // any other caller with duplicates falls back to the serial loop,
        // which keeps the old last-write-wins-in-batch-order semantics.
        for &id in ids {
            let id = id as usize;
            self.counts[id] = self.counts[id].saturating_add(1);
        }
        let lr = hp.lr_emb * hp.lr_scale;
        let wd = hp.wd_emb;
        let d = self.d;
        let delta = self.delta;
        let rounding = self.rounding;
        let threads = if self.threads > 1
            && ids.len() > super::MIN_ROWS_PER_THREAD
            && ids_unique(ids)
        {
            self.threads
        } else {
            1
        };
        let key = StreamKey::for_step(rng.next_u64(), self.step);
        self.step = self.step.wrapping_add(1);
        let writer = self.codes.row_writer();
        parallel_ranges(ids.len(), threads, MIN_ROWS_PER_THREAD, |range| {
            // one d-sized scratch per worker, not per row
            let mut w_new = vec![0.0f32; d];
            for i in range {
                let id = ids[i] as usize;
                let what = &emb_hat[i * d..(i + 1) * d];
                let g = &grads[i * d..(i + 1) * d];
                for j in 0..d {
                    w_new[j] = what[j] - lr * (g[j] + wd * what[j]);
                }
                let mut rrng = key.row_rng(id as u64);
                // Safety: ids are unique → rows are disjoint.
                unsafe {
                    writer.quantize_row_packed(id, &w_new, delta, rounding,
                                               &mut rrng);
                }
            }
        });
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        debug_assert_eq!(codes.len(), ids.len() * self.d);
        debug_assert_eq!(delta.len(), ids.len());
        for (i, &id) in ids.iter().enumerate() {
            self.codes
                .read_row(id as usize, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = self.delta;
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.codes.storage_bytes() + 4 // + the one shared delta
    }

    fn infer_bytes(&self) -> usize {
        self.train_bytes()
    }
}

impl Persistable for LptStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.codes.row_bytes())
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        self.codes.save_raw_rows(lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        self.codes.load_raw_rows(lo, src)
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }
}

impl RowStats for LptStore {
    fn access_counts(&self) -> Option<&[u32]> {
        Some(&self.counts)
    }

    fn reset_access_counts(&mut self) {
        self.counts.fill(0);
    }
}

/// Uniqueness check gating the sharded update path: duplicate rows may
/// not be written from different shards (that would be a data race), so
/// non-unique batches take the serial loop instead. Only evaluated when
/// the batch is big enough to shard, so the hot path's cost is one hash
/// per row against O(d) row work.
pub(crate) fn ids_unique(ids: &[u32]) -> bool {
    let mut seen = std::collections::HashSet::with_capacity(ids.len());
    ids.iter().all(|&id| seen.insert(id))
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;
    use crate::embedding::fp_bytes;

    #[test]
    fn compression_ratio_4x_at_8bit() {
        let mut rng = Pcg32::seeded(1);
        let store = LptStore::init(1000, 16, BitWidth::B8, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ratio = fp_bytes(1000, 16) as f64 / store.train_bytes() as f64;
        assert!((ratio - 4.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn gather_values_on_quantization_grid() {
        let mut rng = Pcg32::seeded(2);
        let store = LptStore::init(50, 8, BitWidth::B8, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ids: Vec<u32> = (0..50).collect();
        let mut out = vec![0.0f32; 50 * 8];
        store.gather(&ids, &mut out);
        for &v in &out {
            let x = v / store.delta();
            assert!((x - x.round()).abs() < 1e-4, "{v} not on grid");
        }
    }

    #[test]
    fn update_moves_toward_gradient_direction() {
        let mut rng = Pcg32::seeded(3);
        let mut store = LptStore::init(10, 4, BitWidth::B8, 1.0,
                                       Rounding::Stochastic, &mut rng);
        let ids = [5u32];
        let mut what = vec![0.0f32; 4];
        store.gather(&ids, &mut what);
        // strong positive grad: w must decrease on average
        let grads = vec![1.0f32; 4];
        let mut h = hp();
        h.lr_emb = 0.05;
        let mut acc = vec![0.0f64; 4];
        for _ in 0..50 {
            store
                .update(&ids, &what, &grads, &h, &mut rng,
                        &mut no_second_pass())
                .unwrap();
            let mut now = vec![0.0f32; 4];
            store.gather(&ids, &mut now);
            for j in 0..4 {
                acc[j] += now[j] as f64;
            }
            store.gather(&ids, &mut what);
        }
        for j in 0..4 {
            assert!(
                acc[j] / 50.0 < -0.1,
                "dim {j} did not move down: {}",
                acc[j] / 50.0
            );
        }
    }

    #[test]
    fn dr_erases_small_updates_sr_does_not() {
        // Remark 1 at the store level: tiny gradient, many steps.
        let mk = |rounding| {
            let mut rng = Pcg32::seeded(7);
            LptStore::init(4, 4, BitWidth::B8, 1.0, rounding, &mut rng)
        };
        let run = |mut store: LptStore| {
            let mut rng = Pcg32::seeded(9);
            let ids = [0u32];
            let mut h = hp();
            h.lr_emb = 1.0;
            // |eta * g| = 1e-3 < delta/2 = 1/256
            let grads = vec![1e-3f32; 4];
            let mut what = vec![0.0f32; 4];
            let mut start = vec![0.0f32; 4];
            store.gather(&ids, &mut start);
            for _ in 0..200 {
                store.gather(&ids, &mut what);
                store
                    .update(&ids, &what, &grads, &h, &mut rng,
                            &mut no_second_pass())
                    .unwrap();
            }
            let mut end = vec![0.0f32; 4];
            store.gather(&ids, &mut end);
            (start, end)
        };
        let (s_dr, e_dr) = run(mk(Rounding::Deterministic));
        assert_eq!(s_dr, e_dr, "DR should freeze below delta/2");
        let (s_sr, e_sr) = run(mk(Rounding::Stochastic));
        let moved: f32 = s_sr
            .iter()
            .zip(&e_sr)
            .map(|(a, b)| (a - b))
            .sum();
        // SR drifts down by ~ 200 * 1e-3 = 0.2 in expectation (sum over 4
        // dims: 0.8); allow slack
        assert!(moved > 0.3, "SR did not make progress: {moved}");
    }

    #[test]
    fn quantized_view_roundtrips() {
        let mut rng = Pcg32::seeded(4);
        let store = LptStore::init(20, 8, BitWidth::B4, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ids = [1u32, 19, 5];
        let mut codes = vec![0i32; 3 * 8];
        let mut delta = vec![0.0f32; 3];
        assert!(store.quantized_view(&ids, &mut codes, &mut delta));
        let mut gathered = vec![0.0f32; 3 * 8];
        store.gather(&ids, &mut gathered);
        for i in 0..3 {
            for j in 0..8 {
                assert!(
                    (codes[i * 8 + j] as f32 * delta[i]
                        - gathered[i * 8 + j])
                        .abs()
                        < 1e-6
                );
            }
        }
    }

    #[test]
    fn duplicate_ids_fall_back_to_serial_semantics() {
        // Non-unique batches must not shard (data race) — they take the
        // serial loop and reproduce last-write-wins in batch order.
        let (n, d) = (200usize, 5usize);
        let mk = || {
            let mut rng = Pcg32::seeded(5);
            LptStore::init(n, d, BitWidth::B8, 0.1, Rounding::Stochastic,
                           &mut rng)
        };
        let mut serial = mk();
        serial.set_threads(1);
        let mut par = mk();
        par.set_threads(4);
        // big enough to shard, with one duplicated id
        let mut ids: Vec<u32> = (0..n as u32 - 1).collect();
        ids.push(7);
        let what = vec![0.02f32; ids.len() * d];
        let grads = vec![0.5f32; ids.len() * d];
        let mut rng_s = Pcg32::seeded(6);
        let mut rng_p = Pcg32::seeded(6);
        serial
            .update(&ids, &what, &grads, &hp(), &mut rng_s,
                    &mut no_second_pass())
            .unwrap();
        par.update(&ids, &what, &grads, &hp(), &mut rng_p,
                   &mut no_second_pass())
            .unwrap();
        assert_eq!(serial.codes.bytes(), par.codes.bytes());
    }

    #[test]
    fn parallel_gather_update_bit_identical_to_serial() {
        // The acceptance contract: for the same seed, the sharded engine
        // must reproduce the single-thread bytes exactly — SR noise comes
        // from per-row counter streams, not from thread order.
        for bw in [BitWidth::B2, BitWidth::B4, BitWidth::B8, BitWidth::B16]
        {
            let (n, d) = (300usize, 9usize);
            let mk = || {
                let mut rng = Pcg32::seeded(11);
                LptStore::init(n, d, bw, 0.1, Rounding::Stochastic,
                               &mut rng)
            };
            let mut serial = mk();
            serial.set_threads(1);
            let mut par = mk();
            par.set_threads(4);
            assert_eq!(serial.codes.bytes(), par.codes.bytes(),
                       "{bw:?}: init must not depend on sharding");

            let ids: Vec<u32> = (0..n as u32).collect();
            let mut out_s = vec![0.0f32; n * d];
            let mut out_p = vec![0.0f32; n * d];
            serial.gather(&ids, &mut out_s);
            par.gather(&ids, &mut out_p);
            assert_eq!(out_s, out_p, "{bw:?}: gather");

            let grads: Vec<f32> =
                (0..n * d).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
            let mut rng_s = Pcg32::seeded(77);
            let mut rng_p = Pcg32::seeded(77);
            for _ in 0..3 {
                serial
                    .update(&ids, &out_s, &grads, &hp(), &mut rng_s,
                            &mut no_second_pass())
                    .unwrap();
                par.update(&ids, &out_p, &grads, &hp(), &mut rng_p,
                           &mut no_second_pass())
                    .unwrap();
                assert_eq!(serial.codes.bytes(), par.codes.bytes(),
                           "{bw:?}: update bytes diverged");
                serial.gather(&ids, &mut out_s);
                par.gather(&ids, &mut out_p);
                assert_eq!(out_s, out_p, "{bw:?}: post-update gather");
            }
        }
    }
}
