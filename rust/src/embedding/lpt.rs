//! Vanilla low-precision training (paper §2.3, Eq. 8; Xu et al. 2021).
//!
//! The table lives as bit-packed integer codes with one *fixed* step size
//! shared by every feature: Δ = clip / 2^{m-1}, with the clipping value
//! tuned as a hyper-parameter (the paper sweeps {1, 0.1, 0.01, 0.001}).
//! Each step de-quantizes the batch's rows, applies the SGD update in
//! float, and re-quantizes with SR or DR — there is no full-precision
//! copy anywhere, which is the entire point.

use super::{init_weights, EmbeddingStore, SecondPass, UpdateHp};
use crate::quant::{
    delta_from_clip, quantize_row, BitWidth, PackedTable, Rounding,
};
use crate::util::rng::Pcg32;
use anyhow::Result;

pub struct LptStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    rounding: Rounding,
    delta: f32,
    codes: PackedTable,
    /// scratch row to avoid per-update allocation
    scratch: Vec<i32>,
}

impl LptStore {
    pub fn init(
        n: usize,
        d: usize,
        bw: BitWidth,
        clip: f32,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) -> Self {
        let delta = delta_from_clip(clip, bw);
        let mut codes = PackedTable::new(n, d, bw);
        // quantize the standard N(0, 0.01) init (SR keeps it unbiased)
        let init = init_weights(n, d, rng);
        let mut row_codes = vec![0i32; d];
        for r in 0..n {
            quantize_row(
                &init[r * d..(r + 1) * d],
                delta,
                bw,
                Rounding::Stochastic,
                rng,
                &mut row_codes,
            );
            codes.write_row(r, &row_codes);
        }
        Self { n, d, bw, rounding, delta, codes, scratch: vec![0i32; d] }
    }

    pub fn delta(&self) -> f32 {
        self.delta
    }

    pub fn bit_width(&self) -> BitWidth {
        self.bw
    }
}

impl EmbeddingStore for LptStore {
    fn method_name(&self) -> &'static str {
        match self.rounding {
            Rounding::Stochastic => "LPT(SR)",
            Rounding::Deterministic => "LPT(DR)",
        }
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        for (i, &id) in ids.iter().enumerate() {
            self.codes.read_row_dequant(
                id as usize,
                self.delta,
                &mut out[i * self.d..(i + 1) * self.d],
            );
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        // Eq. 8: w^{t+1} = Q(w^ - eta (grad + wd w^))
        let lr = hp.lr_emb * hp.lr_scale;
        let d = self.d;
        let mut w_new = vec![0.0f32; d];
        for (i, &id) in ids.iter().enumerate() {
            let what = &emb_hat[i * d..(i + 1) * d];
            let g = &grads[i * d..(i + 1) * d];
            for j in 0..d {
                w_new[j] = what[j] - lr * (g[j] + hp.wd_emb * what[j]);
            }
            quantize_row(&w_new, self.delta, self.bw, self.rounding, rng,
                         &mut self.scratch);
            self.codes.write_row(id as usize, &self.scratch);
        }
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        debug_assert_eq!(codes.len(), ids.len() * self.d);
        debug_assert_eq!(delta.len(), ids.len());
        for (i, &id) in ids.iter().enumerate() {
            self.codes
                .read_row(id as usize, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = self.delta;
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.codes.storage_bytes() + 4 // + the one shared delta
    }

    fn infer_bytes(&self) -> usize {
        self.train_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;
    use crate::embedding::fp_bytes;

    #[test]
    fn compression_ratio_4x_at_8bit() {
        let mut rng = Pcg32::seeded(1);
        let store = LptStore::init(1000, 16, BitWidth::B8, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ratio = fp_bytes(1000, 16) as f64 / store.train_bytes() as f64;
        assert!((ratio - 4.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn gather_values_on_quantization_grid() {
        let mut rng = Pcg32::seeded(2);
        let store = LptStore::init(50, 8, BitWidth::B8, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ids: Vec<u32> = (0..50).collect();
        let mut out = vec![0.0f32; 50 * 8];
        store.gather(&ids, &mut out);
        for &v in &out {
            let x = v / store.delta();
            assert!((x - x.round()).abs() < 1e-4, "{v} not on grid");
        }
    }

    #[test]
    fn update_moves_toward_gradient_direction() {
        let mut rng = Pcg32::seeded(3);
        let mut store = LptStore::init(10, 4, BitWidth::B8, 1.0,
                                       Rounding::Stochastic, &mut rng);
        let ids = [5u32];
        let mut what = vec![0.0f32; 4];
        store.gather(&ids, &mut what);
        // strong positive grad: w must decrease on average
        let grads = vec![1.0f32; 4];
        let mut h = hp();
        h.lr_emb = 0.05;
        let mut acc = vec![0.0f64; 4];
        for _ in 0..50 {
            store
                .update(&ids, &what, &grads, &h, &mut rng,
                        &mut no_second_pass())
                .unwrap();
            let mut now = vec![0.0f32; 4];
            store.gather(&ids, &mut now);
            for j in 0..4 {
                acc[j] += now[j] as f64;
            }
            store.gather(&ids, &mut what);
        }
        for j in 0..4 {
            assert!(
                acc[j] / 50.0 < -0.1,
                "dim {j} did not move down: {}",
                acc[j] / 50.0
            );
        }
    }

    #[test]
    fn dr_erases_small_updates_sr_does_not() {
        // Remark 1 at the store level: tiny gradient, many steps.
        let mk = |rounding| {
            let mut rng = Pcg32::seeded(7);
            LptStore::init(4, 4, BitWidth::B8, 1.0, rounding, &mut rng)
        };
        let run = |mut store: LptStore| {
            let mut rng = Pcg32::seeded(9);
            let ids = [0u32];
            let mut h = hp();
            h.lr_emb = 1.0;
            // |eta * g| = 1e-3 < delta/2 = 1/256
            let grads = vec![1e-3f32; 4];
            let mut what = vec![0.0f32; 4];
            let mut start = vec![0.0f32; 4];
            store.gather(&ids, &mut start);
            for _ in 0..200 {
                store.gather(&ids, &mut what);
                store
                    .update(&ids, &what, &grads, &h, &mut rng,
                            &mut no_second_pass())
                    .unwrap();
            }
            let mut end = vec![0.0f32; 4];
            store.gather(&ids, &mut end);
            (start, end)
        };
        let (s_dr, e_dr) = run(mk(Rounding::Deterministic));
        assert_eq!(s_dr, e_dr, "DR should freeze below delta/2");
        let (s_sr, e_sr) = run(mk(Rounding::Stochastic));
        let moved: f32 = s_sr
            .iter()
            .zip(&e_sr)
            .map(|(a, b)| (a - b))
            .sum();
        // SR drifts down by ~ 200 * 1e-3 = 0.2 in expectation (sum over 4
        // dims: 0.8); allow slack
        assert!(moved > 0.3, "SR did not make progress: {moved}");
    }

    #[test]
    fn quantized_view_roundtrips() {
        let mut rng = Pcg32::seeded(4);
        let store = LptStore::init(20, 8, BitWidth::B4, 0.1,
                                   Rounding::Stochastic, &mut rng);
        let ids = [1u32, 19, 5];
        let mut codes = vec![0i32; 3 * 8];
        let mut delta = vec![0.0f32; 3];
        assert!(store.quantized_view(&ids, &mut codes, &mut delta));
        let mut gathered = vec![0.0f32; 3 * 8];
        store.gather(&ids, &mut gathered);
        for i in 0..3 {
            for j in 0..8 {
                assert!(
                    (codes[i * 8 + j] as f32 * delta[i]
                        - gathered[i * 8 + j])
                        .abs()
                        < 1e-6
                );
            }
        }
    }
}
