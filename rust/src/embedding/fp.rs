//! Full-precision embedding table (the FP baseline, no compression).

use super::{init_weights, par_gather, resolve_threads, EmbeddingStore,
            Persistable, RowStats, SecondPass, UpdateHp};
use crate::optim::sgd_update;
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Plain `[n, d]` f32 table updated by SGD (+ decoupled weight decay).
pub struct FpStore {
    n: usize,
    d: usize,
    table: Vec<f32>,
    /// sharding width for gather (resolved; >= 1)
    threads: usize,
    /// per-row update counts (in-memory only; see [`RowStats`])
    counts: Vec<u32>,
}

impl FpStore {
    pub fn init(n: usize, d: usize, rng: &mut Pcg32) -> Self {
        Self {
            n,
            d,
            table: init_weights(n, d, rng),
            threads: resolve_threads(0),
            counts: vec![0; n],
        }
    }

    /// Configure the sharding width (0 = one worker per hardware thread).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = resolve_threads(threads);
    }

    /// Direct row access (used by the serve example to quantize a trained
    /// FP table through the `quantize` artifact).
    pub fn row(&self, id: u32) -> &[f32] {
        let id = id as usize;
        &self.table[id * self.d..(id + 1) * self.d]
    }

    pub fn table(&self) -> &[f32] {
        &self.table
    }
}

impl EmbeddingStore for FpStore {
    fn method_name(&self) -> &'static str {
        "FP"
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        par_gather(ids, self.d, out, self.threads, |_, id, row| {
            row.copy_from_slice(self.row(id));
        });
    }

    fn update(
        &mut self,
        ids: &[u32],
        _emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        _rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        let lr = hp.lr_emb * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            self.counts[id] = self.counts[id].saturating_add(1);
            let row = &mut self.table[id * self.d..(id + 1) * self.d];
            sgd_update(row, &grads[i * self.d..(i + 1) * self.d], lr,
                       hp.wd_emb);
        }
        Ok(())
    }

    fn train_bytes(&self) -> usize {
        self.table.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.table.len() * 4
    }
}

impl Persistable for FpStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.d * 4)
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        super::save_f32_rows(&self.table, self.n, self.d, lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        super::load_f32_rows(&mut self.table, self.n, self.d, lo, src)
    }
}

impl RowStats for FpStore {
    fn access_counts(&self) -> Option<&[u32]> {
        Some(&self.counts)
    }

    fn reset_access_counts(&mut self) {
        self.counts.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;

    #[test]
    fn gather_then_update_moves_rows() {
        let mut rng = Pcg32::seeded(1);
        let mut store = FpStore::init(10, 4, &mut rng);
        let ids = [3u32, 7];
        let mut before = vec![0.0; 8];
        store.gather(&ids, &mut before);
        let grads = vec![1.0f32; 8];
        store
            .update(&ids, &before, &grads, &hp(), &mut rng,
                    &mut no_second_pass())
            .unwrap();
        let mut after = vec![0.0; 8];
        store.gather(&ids, &mut after);
        for (b, a) in before.iter().zip(&after) {
            assert!((a - (b - 0.1)).abs() < 1e-6);
        }
        // untouched rows stay put
        let mut other = vec![0.0; 4];
        store.gather(&[0], &mut other);
        assert_eq!(other, store.row(0));
    }

    #[test]
    fn bytes_are_fp() {
        let mut rng = Pcg32::seeded(2);
        let store = FpStore::init(100, 16, &mut rng);
        assert_eq!(store.train_bytes(), 100 * 16 * 4);
        assert_eq!(store.infer_bytes(), 100 * 16 * 4);
    }
}
