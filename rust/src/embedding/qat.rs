//! Quantization-aware-training baselines: LSQ (Esser et al. 2020) and
//! PACT (Choi et al. 2018).
//!
//! Both keep a full-precision master table (that is QAT's defining
//! property — and why Table 1 gives them a 1× *training* compression
//! ratio) and fake-quantize in the forward pass with deterministic
//! rounding. Gradients reach the master weights via the straight-through
//! estimator; the quantizer parameter (Δ for LSQ, clipping value α for
//! PACT) is learned from its own estimator. Inference ships packed
//! integers + the quantizer parameter (4× at 8 bits).

use super::{
    init_weights, EmbeddingStore, Persistable, RowStats, SecondPass,
    UpdateHp,
};
use crate::quant::{
    init_delta, lsq_delta_grad_row, quantize_dr, ste_weight_grad_row,
    BitWidth,
};
use crate::util::rng::Pcg32;
use anyhow::Result;

/// LSQ: learned per-feature step size, Eq. 6–7 with DR.
pub struct LsqStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    master: Vec<f32>,
    delta: Vec<f32>,
    /// reusable STE-gradient scratch row (avoids a per-update alloc)
    ste: Vec<f32>,
}

impl LsqStore {
    pub fn init(n: usize, d: usize, bw: BitWidth, rng: &mut Pcg32) -> Self {
        let master = init_weights(n, d, rng);
        let delta = (0..n)
            .map(|r| init_delta(&master[r * d..(r + 1) * d], bw))
            .collect();
        Self { n, d, bw, master, delta, ste: vec![0.0; d] }
    }

    pub fn delta_of(&self, id: u32) -> f32 {
        self.delta[id as usize]
    }
}

impl EmbeddingStore for LsqStore {
    fn method_name(&self) -> &'static str {
        "LSQ"
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        // forward sees Q_D(w, delta) — fake quantization
        let d = self.d;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let dl = self.delta[id];
            let row = &self.master[id * d..(id + 1) * d];
            let o = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                o[j] = quantize_dr(row[j], dl, self.bw) as f32 * dl;
            }
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        _emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        _rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let lr = hp.lr_emb * hp.lr_scale;
        let lr_d = hp.lr_delta * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let dl = self.delta[id];
            let g = &grads[i * d..(i + 1) * d];
            // delta gradient first (Eq. 7 needs the pre-update weights)
            let row = &self.master[id * d..(id + 1) * d];
            let dg = lsq_delta_grad_row(row, dl, self.bw, g);
            // STE weight gradient (masked to the clip interior), into the
            // store's scratch row
            ste_weight_grad_row(row, dl, self.bw, g, &mut self.ste);
            let row = &mut self.master[id * d..(id + 1) * d];
            for j in 0..d {
                row[j] -= lr * (self.ste[j] + hp.wd_emb * row[j]);
            }
            self.delta[id] = (self.delta[id]
                - lr_d * (hp.grad_scale * dg + hp.wd_delta * self.delta[id]))
                .max(1e-8);
        }
        Ok(())
    }

    fn train_bytes(&self) -> usize {
        // FP master + delta: no training compression (the paper's point)
        self.master.len() * 4 + self.delta.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.master.len() * (self.bw.bits() as usize) / 8
            + self.delta.len() * 4
    }
}

impl Persistable for LsqStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.d * 4)
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        super::save_f32_rows(&self.master, self.n, self.d, lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        super::load_f32_rows(&mut self.master, self.n, self.d, lo, src)
    }

    fn aux_params(&self) -> &[f32] {
        &self.delta
    }

    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        anyhow::ensure!(
            aux.len() == self.n,
            "LSQ delta count mismatch: {} vs {} rows",
            aux.len(),
            self.n
        );
        self.delta.copy_from_slice(aux);
        Ok(())
    }
}

impl RowStats for LsqStore {}

/// PACT: learned per-feature clipping value α; Δ = α / 2^{m-1}. The α
/// estimator only receives gradient from *clipped* elements (its original
/// formulation), which is why it trails LSQ at low bit widths (Table 2).
pub struct PactStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    master: Vec<f32>,
    alpha: Vec<f32>,
    /// reusable STE-gradient scratch row (avoids a per-update alloc)
    ste: Vec<f32>,
}

impl PactStore {
    pub fn init(
        n: usize,
        d: usize,
        bw: BitWidth,
        init_clip: f32,
        rng: &mut Pcg32,
    ) -> Self {
        let master = init_weights(n, d, rng);
        Self {
            n,
            d,
            bw,
            master,
            alpha: vec![init_clip; n],
            ste: vec![0.0; d],
        }
    }

    pub fn alpha_of(&self, id: u32) -> f32 {
        self.alpha[id as usize]
    }

    /// Test/debug helper: poke a master weight.
    #[doc(hidden)]
    pub fn set_master(&mut self, idx: usize, v: f32) {
        self.master[idx] = v;
    }

    #[inline]
    fn delta(&self, id: usize) -> f32 {
        self.alpha[id] / (1u32 << (self.bw.bits() - 1)) as f32
    }
}

impl EmbeddingStore for PactStore {
    fn method_name(&self) -> &'static str {
        "PACT"
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        let d = self.d;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let dl = self.delta(id);
            let row = &self.master[id * d..(id + 1) * d];
            let o = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                o[j] = quantize_dr(row[j], dl, self.bw) as f32 * dl;
            }
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        _emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        _rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let lr = hp.lr_emb * hp.lr_scale;
        let lr_a = hp.lr_delta * hp.lr_scale;
        let qn = self.bw.qn() as f32;
        let qp = self.bw.qp() as f32;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let dl = self.delta(id);
            let g = &grads[i * d..(i + 1) * d];
            let row = &self.master[id * d..(id + 1) * d];
            // PACT alpha grad: clipped-high elements pass +g, clipped-low
            // pass -g (d clip(w, ±α)/dα = sign at the clip boundary,
            // scaled by qp/2^{m-1} ≈ 1); interior contributes nothing.
            let mut da = 0.0f32;
            for j in 0..d {
                let x = row[j] / dl;
                if x >= qp {
                    da += g[j];
                } else if x <= qn {
                    da -= g[j];
                }
            }
            ste_weight_grad_row(row, dl, self.bw, g, &mut self.ste);
            let row = &mut self.master[id * d..(id + 1) * d];
            for j in 0..d {
                row[j] -= lr * (self.ste[j] + hp.wd_emb * row[j]);
            }
            self.alpha[id] = (self.alpha[id]
                - lr_a * (hp.grad_scale * da + hp.wd_delta * self.alpha[id]))
                .max(1e-6);
        }
        Ok(())
    }

    fn train_bytes(&self) -> usize {
        self.master.len() * 4 + self.alpha.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.master.len() * (self.bw.bits() as usize) / 8
            + self.alpha.len() * 4
    }
}

impl Persistable for PactStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.d * 4)
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        super::save_f32_rows(&self.master, self.n, self.d, lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        super::load_f32_rows(&mut self.master, self.n, self.d, lo, src)
    }

    fn aux_params(&self) -> &[f32] {
        &self.alpha
    }

    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        anyhow::ensure!(
            aux.len() == self.n,
            "PACT alpha count mismatch: {} vs {} rows",
            aux.len(),
            self.n
        );
        self.alpha.copy_from_slice(aux);
        Ok(())
    }
}

impl RowStats for PactStore {}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;

    #[test]
    fn lsq_forward_is_quantized_master_is_not() {
        let mut rng = Pcg32::seeded(1);
        let store = LsqStore::init(10, 8, BitWidth::B4, &mut rng);
        let mut out = vec![0.0f32; 8];
        store.gather(&[3], &mut out);
        let dl = store.delta_of(3);
        for &v in &out {
            let x = v / dl;
            assert!((x - x.round()).abs() < 1e-4, "fake-quant off grid: {v}");
        }
        // master itself is full precision (almost surely off grid)
        let off_grid = store.master[3 * 8..4 * 8]
            .iter()
            .filter(|&&w| ((w / dl) - (w / dl).round()).abs() > 1e-3)
            .count();
        assert!(off_grid > 0);
    }

    #[test]
    fn lsq_update_moves_master_and_delta() {
        let mut rng = Pcg32::seeded(2);
        let mut store = LsqStore::init(10, 4, BitWidth::B8, &mut rng);
        let m0 = store.master[4 * 4..5 * 4].to_vec();
        let d0 = store.delta_of(4);
        let grads = vec![0.5f32; 4];
        let emb = vec![0.0f32; 4];
        store
            .update(&[4], &emb, &grads, &hp(), &mut rng,
                    &mut no_second_pass())
            .unwrap();
        assert_ne!(m0, store.master[4 * 4..5 * 4].to_vec());
        assert_ne!(d0, store.delta_of(4));
    }

    #[test]
    fn lsq_train_ratio_is_1x_infer_4x() {
        let mut rng = Pcg32::seeded(3);
        let store = LsqStore::init(1000, 16, BitWidth::B8, &mut rng);
        let fp = 1000 * 16 * 4;
        assert!(store.train_bytes() >= fp, "QAT holds FP masters");
        let infer_ratio = fp as f64 / store.infer_bytes() as f64;
        assert!((infer_ratio - 3.2).abs() < 0.05, "ratio={infer_ratio}");
    }

    #[test]
    fn pact_alpha_only_learns_from_clipped() {
        let mut rng = Pcg32::seeded(4);
        // alpha = 1.0 so only the weight we poke below ever clips
        let mut store = PactStore::init(4, 4, BitWidth::B8, 1.0, &mut rng);
        // master ~ N(0, 0.01), alpha = 1.0 -> nothing clipped
        let a0 = store.alpha_of(0);
        let grads = vec![1.0f32; 4];
        let emb = vec![0.0f32; 4];
        let mut h = hp();
        h.wd_delta = 0.0;
        store
            .update(&[0], &emb, &grads, &h, &mut rng, &mut no_second_pass())
            .unwrap();
        assert_eq!(a0, store.alpha_of(0), "alpha moved without clipping");
        // force clipping: blow up a master weight
        store.master[0] = 1000.0;
        store
            .update(&[0], &emb, &grads, &h, &mut rng, &mut no_second_pass())
            .unwrap();
        assert_ne!(a0, store.alpha_of(0), "alpha should move when clipped");
    }

    #[test]
    fn pact_forward_respects_clip() {
        let mut rng = Pcg32::seeded(5);
        let mut store = PactStore::init(2, 4, BitWidth::B8, 0.05, &mut rng);
        store.master[0] = 3.0; // way beyond alpha
        let mut out = vec![0.0f32; 4];
        store.gather(&[0], &mut out);
        assert!(out[0] <= 0.05 + 1e-6, "clip violated: {}", out[0]);
    }
}
