//! Mixed-precision grouped embedding store: one packed sub-table per
//! precision group.
//!
//! A [`crate::config::PrecisionPlan`] assigns every field a bit width;
//! fields of equal width form a *group* backed by one ordinary
//! [`LptStore`]/[`AlptStore`] sub-table, so each group reuses the
//! existing sharded gather/update kernels, the fused quantize→pack row
//! writers and the per-row learned Δ unchanged. The grouped store
//! presents the same [`EmbeddingStore`] trait to the trainer, routing
//! global row ids to `(group, local row)` through a precomputed
//! field-offset table (one binary search per row, no allocation on the
//! gather/update hot path).
//!
//! **Determinism.** The `StreamKey` contract extends to groups: gather is
//! a pure per-row function sharded with [`par_gather_chunks`], and update runs
//! the groups in a fixed (ascending-width) order, each sub-store drawing
//! its own step key and per-row counter streams — so grouped sharded
//! gather/update are bit-identical to the serial path at any thread
//! count, property-tested below.
//!
//! **ALPT across groups.** Algorithm 1's Δ-gradient pass runs the model
//! over the *whole batch*, so a group's sub-store cannot call the
//! trainer's `second_pass` with only its own rows (batch positions would
//! no longer line up with the model's index tensor). The grouped store
//! therefore keeps a full-batch second-pass context — every row starts
//! at its gathered value ŵ (exactly representable under its own Δ, so
//! fake-quantization passes it through unchanged) — and scatters each
//! group's `w^{t+1}`/Δ/width into it before forwarding the call. Groups
//! run sequentially; earlier groups' updated rows stay in the context
//! for later groups, a sequential-coordinate flavour of Algorithm 1.

use super::{
    par_gather_chunks, resolve_threads, rounding_of, AlptStore,
    EmbeddingStore, HashingStore, LptStore, Persistable, PruningStore,
    RowStats, SecondPass, UpdateHp,
};
use crate::config::{Experiment, FieldKind, GroupKind, Method};
use crate::data::Schema;
use crate::quant::BitWidth;
use crate::util::rng::{Pcg32, StreamKey};
use anyhow::{bail, ensure, Result};

/// One plan group: a sub-table holding every row whose field the plan
/// gave the same assignment. For packed groups `bits` is the real code
/// width; for structural groups (hashed / pruned) it is the plan's
/// *nominal* default width — a label for checkpoint headers and
/// diagnostics, not a storage parameter.
struct Group {
    bits: BitWidth,
    rows: usize,
    store: SubStore,
}

/// The concrete sub-table families a plan can build: the packed
/// quantized stores (grouped by width) plus the structural kinds, which
/// replace packing outright for the fields that select them.
enum SubStore {
    Lpt(LptStore),
    Alpt(AlptStore),
    Hashed(HashingStore),
    Pruned(PruningStore),
}

impl SubStore {
    fn as_store(&self) -> &dyn EmbeddingStore {
        match self {
            SubStore::Lpt(s) => s,
            SubStore::Alpt(s) => s,
            SubStore::Hashed(s) => s,
            SubStore::Pruned(s) => s,
        }
    }

    fn as_store_mut(&mut self) -> &mut dyn EmbeddingStore {
        match self {
            SubStore::Lpt(s) => s,
            SubStore::Alpt(s) => s,
            SubStore::Hashed(s) => s,
            SubStore::Pruned(s) => s,
        }
    }

    /// Checkpoint group-kind token (format v3's `kind` header).
    fn kind_key(&self) -> &'static str {
        match self {
            SubStore::Lpt(_) => "lpt",
            SubStore::Alpt(_) => "alpt",
            SubStore::Hashed(_) => "hash",
            SubStore::Pruned(_) => "prune",
        }
    }

    fn is_structural(&self) -> bool {
        matches!(self, SubStore::Hashed(_) | SubStore::Pruned(_))
    }

    fn read_row_dequant_into(&self, local: usize, out: &mut [f32]) {
        match self {
            SubStore::Lpt(s) => s.read_row_dequant_into(local, out),
            SubStore::Alpt(s) => s.read_row_dequant_into(local, out),
            // structural kinds have no codes to dequantize; their gather
            // is already a pure per-row function
            SubStore::Hashed(s) => s.gather(&[local as u32], out),
            SubStore::Pruned(s) => s.gather(&[local as u32], out),
        }
    }

    /// Prefetch hint for one local row (no-op for structural kinds —
    /// their rows are plain f32, covered by the hardware prefetcher).
    fn prefetch_row(&self, local: usize) {
        match self {
            SubStore::Lpt(s) => s.prefetch_row(local),
            SubStore::Alpt(s) => s.prefetch_row(local),
            SubStore::Hashed(_) | SubStore::Pruned(_) => {}
        }
    }

    /// Integer codes of one row. Callers must route around structural
    /// groups (`quantized_view` reports them by returning `false`).
    fn read_codes_into(&self, local: usize, out: &mut [i32]) {
        match self {
            SubStore::Lpt(s) => s.read_codes_into(local, out),
            SubStore::Alpt(s) => s.read_codes_into(local, out),
            _ => unreachable!("structural groups hold no packed codes"),
        }
    }

    /// Per-row step size. Callers must route around structural groups.
    fn row_delta(&self, local: usize) -> f32 {
        match self {
            SubStore::Lpt(s) => s.delta(),
            SubStore::Alpt(s) => s.delta_of(local as u32),
            _ => unreachable!("structural groups hold no step sizes"),
        }
    }

    fn set_threads(&mut self, threads: usize) {
        match self {
            SubStore::Lpt(s) => s.set_threads(threads),
            SubStore::Alpt(s) => s.set_threads(threads),
            // structural sub-stores are serial; nothing to configure
            SubStore::Hashed(_) | SubStore::Pruned(_) => {}
        }
    }
}

/// One contiguous run of global row ids living in one group (a field, or
/// the warm-start surplus tail). Sorted by `start` for binary search.
#[derive(Clone, Copy, Debug)]
struct RowRange {
    start: u32,
    group: u32,
    local_base: u32,
}

/// Mixed-precision embedding store (see module docs).
pub struct GroupedStore {
    n: usize,
    d: usize,
    name: &'static str,
    is_alpt: bool,
    groups: Vec<Group>,
    ranges: Vec<RowRange>,
    /// per-global-row update counts (in-memory only; see [`RowStats`]) —
    /// the frequency signal the budget planner reads at epoch boundaries
    counts: Vec<u32>,
    /// sharding width for gather (resolved; >= 1)
    threads: usize,
    // ---- update scratch, reused across steps (grown on demand)
    ids_g: Vec<Vec<u32>>,
    pos_g: Vec<Vec<u32>>,
    emb_g: Vec<f32>,
    grad_g: Vec<f32>,
    // full-batch second-pass context (ALPT only)
    sp_w: Vec<f32>,
    sp_delta: Vec<f32>,
    sp_bw: Vec<BitWidth>,
}

impl GroupedStore {
    /// Build the grouped store an experiment's (non-uniform) precision
    /// plan describes over a concrete field layout. Rows beyond the
    /// schema (`n_features > schema.n_features()`, warm-start headroom)
    /// join the last field's group. Sub-stores are constructed in
    /// ascending-width order, each consuming `rng` in turn, so the
    /// result is a pure function of `(plan, layout, seed)`.
    pub fn from_plan(
        exp: &Experiment,
        schema: &Schema,
        kinds: &[FieldKind],
        n_features: usize,
        dim: usize,
        rng: &mut Pcg32,
    ) -> Result<GroupedStore> {
        ensure!(
            kinds.len() == schema.n_fields(),
            "field-kind layout has {} entries for {} fields",
            kinds.len(),
            schema.n_fields()
        );
        ensure!(
            n_features >= schema.n_features(),
            "table of {n_features} rows is smaller than the schema's {}",
            schema.n_features()
        );
        let per_field = exp.bits.resolve_kinds(kinds)?;
        let (mode, name, is_alpt) = match exp.method {
            Method::Lpt(m) => (
                m,
                match m {
                    crate::config::RoundingMode::Sr => "LPT(SR)[mixed]",
                    crate::config::RoundingMode::Dr => "LPT(DR)[mixed]",
                },
                false,
            ),
            Method::Alpt(m) => (
                m,
                match m {
                    crate::config::RoundingMode::Sr => "ALPT(SR)[mixed]",
                    crate::config::RoundingMode::Dr => "ALPT(DR)[mixed]",
                },
                true,
            ),
            other => bail!(
                "per-field precision plans need a quantized-training \
                 method (lpt/alpt), not {}",
                other.key()
            ),
        };

        // Fixed group order: distinct packed widths ascending first —
        // constructed in the same order (and consuming the generator in
        // the same order) as before structural kinds existed, so
        // quant-only plans stay byte-identical — then one hashed group,
        // then one pruned group.
        let mut widths: Vec<BitWidth> = Vec::new();
        for k in &per_field {
            if let GroupKind::Bits(b) = k {
                let Some(bw) = BitWidth::from_bits(*b) else {
                    bail!("unsupported bit width {b}");
                };
                if !widths.contains(&bw) {
                    widths.push(bw);
                }
            }
        }
        widths.sort_by_key(|bw| bw.bits());
        let has_hashed = per_field.contains(&GroupKind::Hashed);
        let has_pruned = per_field.contains(&GroupKind::Pruned);
        let hash_gidx = widths.len();
        let prune_gidx = widths.len() + has_hashed as usize;
        let n_groups =
            widths.len() + has_hashed as usize + has_pruned as usize;
        let gidx = |k: GroupKind| -> u32 {
            (match k {
                GroupKind::Bits(b) => widths
                    .iter()
                    .position(|w| w.bits() == b)
                    .unwrap(),
                GroupKind::Hashed => hash_gidx,
                GroupKind::Pruned => prune_gidx,
            }) as u32
        };

        let mut rows_per = vec![0usize; n_groups];
        let mut ranges = Vec::with_capacity(schema.n_fields() + 1);
        for (f, &k) in per_field.iter().enumerate() {
            let g = gidx(k);
            ranges.push(RowRange {
                start: schema.offsets[f],
                group: g,
                local_base: rows_per[g as usize] as u32,
            });
            rows_per[g as usize] += schema.vocabs[f] as usize;
        }
        let surplus = n_features - schema.n_features();
        if surplus > 0 {
            let g = ranges.last().unwrap().group;
            ranges.push(RowRange {
                start: schema.n_features() as u32,
                group: g,
                local_base: rows_per[g as usize] as u32,
            });
            rows_per[g as usize] += surplus;
        }

        // structural groups label their checkpoint headers with the
        // plan's default width (they hold no packed codes)
        let nominal = exp.bits.scale_width();
        let groups = (0..n_groups)
            .map(|g| {
                let rows = rows_per[g];
                if g < widths.len() {
                    let bw = widths[g];
                    let store = if is_alpt {
                        SubStore::Alpt(AlptStore::init_with_clip_threads(
                            rows,
                            dim,
                            bw,
                            rounding_of(mode),
                            exp.clip,
                            exp.threads,
                            rng,
                        ))
                    } else {
                        SubStore::Lpt(LptStore::init_with_threads(
                            rows,
                            dim,
                            bw,
                            exp.clip,
                            rounding_of(mode),
                            exp.threads,
                            rng,
                        ))
                    };
                    Group { bits: bw, rows, store }
                } else if has_hashed && g == hash_gidx {
                    Group {
                        bits: nominal,
                        rows,
                        store: SubStore::Hashed(HashingStore::init(
                            rows, dim, 2, rng,
                        )),
                    }
                } else {
                    Group {
                        bits: nominal,
                        rows,
                        store: SubStore::Pruned(PruningStore::init(
                            rows, dim, 0.5, 0.99, 3000.0, rng,
                        )),
                    }
                }
            })
            .collect::<Vec<_>>();

        Ok(GroupedStore {
            n: n_features,
            d: dim,
            name,
            is_alpt,
            groups,
            ranges,
            counts: vec![0; n_features],
            threads: resolve_threads(exp.threads),
            ids_g: vec![Vec::new(); n_groups],
            pos_g: vec![Vec::new(); n_groups],
            emb_g: Vec::new(),
            grad_g: Vec::new(),
            sp_w: Vec::new(),
            sp_delta: Vec::new(),
            sp_bw: Vec::new(),
        })
    }

    /// Rebuild this store under a *new* all-packed plan (carried in
    /// `exp.bits`), migrating every row: its float value is read from
    /// the old group and deterministically re-quantized into its new
    /// group on a counter-based per-row SR stream keyed by one serial
    /// draw and the store's step counter — so migration is a pure
    /// function of `(old store, new plan, rng state)` and bit-identical
    /// at any thread count. ALPT step sizes carry over rescaled by
    /// `qp_old / qp_new`, preserving each row's representable range
    /// across width changes. Structural groups cannot migrate (their
    /// parameters are not per-row); both sides must be packed-only.
    pub fn migrate_from(
        old: &GroupedStore,
        exp: &Experiment,
        schema: &Schema,
        kinds: &[FieldKind],
        rng: &mut Pcg32,
    ) -> Result<GroupedStore> {
        ensure!(
            !old.has_structural_groups(),
            "cannot migrate away from a plan with hashed/pruned groups: \
             their parameters are shared, not per-row"
        );
        ensure!(
            !exp.bits.has_structural(),
            "cannot migrate into plan {:?}: hashed/pruned groups have no \
             per-row payload to requantize into",
            exp.bits.key()
        );
        let mut new = GroupedStore::from_plan(
            exp,
            schema,
            kinds,
            old.n_features(),
            old.dim(),
            rng,
        )?;
        let step = old.step_counter();
        let key = StreamKey::for_step(rng.next_u64(), step);
        let d = old.dim();
        let mut w = vec![0.0f32; d];
        for id in 0..old.n_features() as u32 {
            let (og, olocal) = old.locate(id);
            let (ng, nlocal) = new.locate(id);
            old.groups[og].store.read_row_dequant_into(olocal, &mut w);
            let mut rrng = key.row_rng(id as u64);
            match &mut new.groups[ng].store {
                SubStore::Lpt(s) => {
                    s.write_row_from_f32(nlocal, &w, &mut rrng);
                }
                SubStore::Alpt(s) => {
                    let qp_old = old.groups[og].bits.qp() as f32;
                    let qp_new = new.groups[ng].bits.qp() as f32;
                    let delta = old.groups[og].store.row_delta(olocal)
                        * (qp_old / qp_new);
                    s.write_row_from_f32(nlocal, &w, delta, &mut rrng);
                }
                _ => unreachable!("checked packed-only above"),
            }
        }
        // the SR step counter and the epoch's frequency signal both
        // survive the move
        new.set_step_counter(step);
        new.counts.copy_from_slice(&old.counts);
        Ok(new)
    }

    /// Map a global row id to its `(group, local row)`.
    #[inline]
    fn locate(&self, id: u32) -> (usize, usize) {
        debug_assert!((id as usize) < self.n);
        let i = self.ranges.partition_point(|r| r.start <= id) - 1;
        let r = self.ranges[i];
        (r.group as usize, (r.local_base + (id - r.start)) as usize)
    }

    /// Number of precision groups (ascending bit width).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Bit width of group `g`.
    pub fn group_bits(&self, g: usize) -> u32 {
        self.groups[g].bits.bits()
    }

    /// Row count of group `g`'s sub-table.
    pub fn group_rows(&self, g: usize) -> usize {
        self.groups[g].rows
    }

    /// Group `g`'s sub-store — the checkpoint subsystem serializes each
    /// group through the ordinary [`EmbeddingStore`] row/aux hooks.
    pub fn group_store(&self, g: usize) -> &dyn EmbeddingStore {
        self.groups[g].store.as_store()
    }

    /// Mutable counterpart of [`GroupedStore::group_store`].
    pub fn group_store_mut(&mut self, g: usize) -> &mut dyn EmbeddingStore {
        self.groups[g].store.as_store_mut()
    }

    /// The bit width of the group holding global row `id`.
    pub fn bits_of_row(&self, id: u32) -> u32 {
        let (g, _) = self.locate(id);
        self.groups[g].bits.bits()
    }

    /// Checkpoint group-kind token of group `g` ("lpt" / "alpt" /
    /// "hash" / "prune") — format v3's per-group `kind` header.
    pub fn group_kind(&self, g: usize) -> &'static str {
        self.groups[g].store.kind_key()
    }

    /// Whether the plan routed any field to a hashed/pruned group.
    pub fn has_structural_groups(&self) -> bool {
        self.groups.iter().any(|g| g.store.is_structural())
    }

    /// Public `(group, local row)` address of global row `id` — the
    /// delta journal serializes single dirty rows through it.
    pub fn row_location(&self, id: u32) -> (usize, usize) {
        self.locate(id)
    }

    /// Configure the sharding width (0 = one worker per hardware thread).
    /// Purely a performance knob: results are bit-identical at any value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = resolve_threads(threads);
        for group in &mut self.groups {
            group.store.set_threads(threads);
        }
    }
}

impl EmbeddingStore for GroupedStore {
    fn method_name(&self) -> &'static str {
        self.name
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        // Chunked like the single-table stores so prefetch hints can
        // run ahead of the decode: each row is routed twice — once
        // PREFETCH_AHEAD iterations early to start the line fill, once
        // to decode — which trades a second binary search (L1-resident
        // ranges) for the sub-table row's memory latency.
        let d = self.d;
        par_gather_chunks(ids, d, out, self.threads,
                          |_, chunk_ids, chunk| {
            for (k, (&id, row)) in chunk_ids
                .iter()
                .zip(chunk.chunks_mut(d))
                .enumerate()
            {
                if let Some(&ahead) = chunk_ids
                    .get(k + crate::quant::PackedTable::PREFETCH_AHEAD)
                {
                    let (ag, alocal) = self.locate(ahead);
                    self.groups[ag].store.prefetch_row(alocal);
                }
                let (g, local) = self.locate(id);
                self.groups[g].store.read_row_dequant_into(local, row);
            }
        });
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let n_u = ids.len();
        debug_assert_eq!(emb_hat.len(), n_u * d);
        debug_assert_eq!(grads.len(), n_u * d);

        // route each batch row to its group (reused scratch); duplicate
        // ids land in the same group, whose sub-store then takes its
        // serial last-write-wins fallback — nothing here needs uniqueness
        for v in &mut self.ids_g {
            v.clear();
        }
        for v in &mut self.pos_g {
            v.clear();
        }
        for (i, &id) in ids.iter().enumerate() {
            self.counts[id as usize] =
                self.counts[id as usize].saturating_add(1);
            let (g, local) = self.locate(id);
            self.ids_g[g].push(local as u32);
            self.pos_g[g].push(i as u32);
        }

        // full-batch second-pass context: every row starts at its
        // gathered value ŵ (on its own Δ-grid, so fake-quantization is
        // the identity for rows outside the group under update) with its
        // group's Δ and width
        if self.is_alpt {
            self.sp_w.clear();
            self.sp_w.extend_from_slice(emb_hat);
            self.sp_delta.clear();
            self.sp_delta.resize(n_u, 0.0);
            self.sp_bw.clear();
            self.sp_bw.resize(n_u, BitWidth::B8);
            for (i, &id) in ids.iter().enumerate() {
                let (g, local) = self.locate(id);
                if self.groups[g].store.is_structural() {
                    // structural rows have no Δ-grid; park them on a
                    // fine 16-bit grid scaled to the row's own range so
                    // fake-quantization passes them through unchanged
                    let m = emb_hat[i * d..(i + 1) * d]
                        .iter()
                        .fold(0.0f32, |a, &v| a.max(v.abs()));
                    self.sp_bw[i] = BitWidth::B16;
                    self.sp_delta[i] =
                        (m / BitWidth::B16.qp() as f32).max(1e-12);
                } else {
                    self.sp_delta[i] =
                        self.groups[g].store.row_delta(local);
                    self.sp_bw[i] = self.groups[g].bits;
                }
            }
        }

        // fixed ascending-width group order; every group updates every
        // step (empty batches included) so the per-group SR step counters
        // stay in lockstep — one shared `step` survives checkpointing
        let Self {
            groups,
            ids_g,
            pos_g,
            emb_g,
            grad_g,
            sp_w,
            sp_delta,
            sp_bw,
            ..
        } = self;
        for (g, group) in groups.iter_mut().enumerate() {
            let ids_local = &ids_g[g];
            let pos = &pos_g[g];
            let k = pos.len();
            if emb_g.len() < k * d {
                emb_g.resize(k * d, 0.0);
                grad_g.resize(k * d, 0.0);
            }
            for (j, &i) in pos.iter().enumerate() {
                let i = i as usize;
                emb_g[j * d..(j + 1) * d]
                    .copy_from_slice(&emb_hat[i * d..(i + 1) * d]);
                grad_g[j * d..(j + 1) * d]
                    .copy_from_slice(&grads[i * d..(i + 1) * d]);
            }
            // forward the group's Δ-gradient pass with full-batch
            // positions restored (see module docs); only ALPT sub-stores
            // ever invoke this
            let mut sp = |w_new: &[f32],
                          delta: &[f32],
                          bws: &[BitWidth]|
             -> Result<Vec<f32>> {
                debug_assert_eq!(delta.len(), k);
                for (j, &i) in pos.iter().enumerate() {
                    let i = i as usize;
                    sp_w[i * d..(i + 1) * d]
                        .copy_from_slice(&w_new[j * d..(j + 1) * d]);
                    sp_delta[i] = delta[j];
                    sp_bw[i] = bws[j];
                }
                let full = second_pass(
                    &sp_w[..n_u * d],
                    &sp_delta[..n_u],
                    &sp_bw[..n_u],
                )?;
                ensure!(
                    full.len() == n_u,
                    "second pass returned {} gradients for {n_u} rows",
                    full.len()
                );
                Ok(pos.iter().map(|&i| full[i as usize]).collect())
            };
            group.store.as_store_mut().update(
                ids_local,
                &emb_g[..k * d],
                &grad_g[..k * d],
                hp,
                rng,
                &mut sp,
            )?;
        }
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        // hashed/pruned rows hold no integer codes — the whole table
        // falls back to the float path, like the standalone stores
        if self.has_structural_groups() {
            return false;
        }
        debug_assert_eq!(codes.len(), ids.len() * self.d);
        debug_assert_eq!(delta.len(), ids.len());
        for (i, &id) in ids.iter().enumerate() {
            let (g, local) = self.locate(id);
            self.groups[g]
                .store
                .read_codes_into(local, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = self.groups[g].store.row_delta(local);
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.store.as_store().train_bytes()).sum()
    }

    fn infer_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.store.as_store().infer_bytes()).sum()
    }

    fn end_step(&mut self) {
        for group in &mut self.groups {
            group.store.as_store_mut().end_step();
        }
    }

    fn as_grouped(&self) -> Option<&GroupedStore> {
        Some(self)
    }

    fn as_grouped_mut(&mut self) -> Option<&mut GroupedStore> {
        Some(self)
    }
}

impl Persistable for GroupedStore {
    // Row/aux payloads serialize *per group* (checkpoint formats v2/v3
    // walk `group_store`); only the shared step counter lives here. The
    // sub-stores advance in lockstep (packed groups step in `update`,
    // structural ones in `end_step`), so reading any one group — the
    // first — reports the store-wide count.
    fn step_counter(&self) -> u64 {
        self.groups[0].store.as_store().step_counter()
    }

    fn set_step_counter(&mut self, step: u64) {
        for group in &mut self.groups {
            group.store.as_store_mut().set_step_counter(step);
        }
    }
}

impl RowStats for GroupedStore {
    fn access_counts(&self) -> Option<&[u32]> {
        Some(&self.counts)
    }

    fn reset_access_counts(&mut self) {
        self.counts.fill(0);
        for group in &mut self.groups {
            group.store.as_store_mut().reset_access_counts();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{eq7_second_pass, hp, no_second_pass};
    use super::*;
    use crate::config::{PrecisionPlan, RoundingMode};
    use crate::util::prop::{check, Gen};

    fn mixed_exp(method: Method, plan: &str) -> Experiment {
        Experiment {
            method,
            bits: PrecisionPlan::parse(plan).unwrap(),
            threads: 1,
            use_runtime: false,
            ..Experiment::default()
        }
    }

    /// Two 3-field layouts used across the tests: a numeric field, then
    /// two categorical ones.
    fn toy_layout() -> (Schema, Vec<FieldKind>) {
        (
            Schema::new(vec![40, 100, 60]),
            vec![
                FieldKind::Numeric,
                FieldKind::Categorical,
                FieldKind::Categorical,
            ],
        )
    }

    fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
        let ids: Vec<u32> = (0..store.n_features() as u32).collect();
        let mut out = vec![0.0f32; ids.len() * store.dim()];
        store.gather(&ids, &mut out);
        out
    }

    #[test]
    fn routing_respects_the_plan() {
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(Method::Lpt(RoundingMode::Sr), "num:4,cat:8");
        let mut rng = Pcg32::seeded(1);
        let store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 6, &mut rng,
        )
        .unwrap();
        assert_eq!(store.n_groups(), 2, "4-bit and 8-bit groups");
        assert_eq!(store.group_bits(0), 4);
        assert_eq!(store.group_bits(1), 8);
        assert_eq!(store.group_rows(0), 40, "numeric field rows");
        assert_eq!(store.group_rows(1), 160, "categorical rows");
        // every row reports its field's width
        for id in 0..40 {
            assert_eq!(store.bits_of_row(id), 4);
        }
        for id in 40..200 {
            assert_eq!(store.bits_of_row(id), 8);
        }
        assert_eq!(store.n_features(), 200);
        // mixed memory: smaller than uniform-8, larger than uniform-4
        let bytes8 = 200 * 6; // packed bytes at 8 bits
        let bytes4 = 200 * 3;
        assert!(store.train_bytes() > bytes4 + 4);
        assert!(store.train_bytes() < bytes8 + 4 + 200 * 4);
    }

    #[test]
    fn warm_start_surplus_rows_join_the_last_group() {
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(Method::Lpt(RoundingMode::Sr), "num:4,cat:8");
        let mut rng = Pcg32::seeded(2);
        let store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features() + 25, 4, &mut rng,
        )
        .unwrap();
        assert_eq!(store.n_features(), 225);
        assert_eq!(store.group_rows(1), 160 + 25);
        assert_eq!(store.bits_of_row(224), 8);
        // gather over the surplus rows works
        let mut out = vec![0.0f32; 4];
        store.gather(&[224], &mut out);
    }

    #[test]
    fn single_group_plan_matches_the_plain_store() {
        // "cat:4" on an all-categorical layout collapses to one group
        // whose construction consumes the generator exactly like the
        // plain store — gathers must be bit-identical.
        let schema = Schema::new(vec![70, 30]);
        let kinds = vec![FieldKind::Categorical; 2];
        let exp = mixed_exp(Method::Alpt(RoundingMode::Sr), "cat:4");
        let mut rng_a = Pcg32::seeded(7);
        let grouped = GroupedStore::from_plan(
            &exp, &schema, &kinds, 100, 5, &mut rng_a,
        )
        .unwrap();
        assert_eq!(grouped.n_groups(), 1);
        let mut rng_b = Pcg32::seeded(7);
        let plain = AlptStore::init_with_clip_threads(
            100,
            5,
            BitWidth::B4,
            crate::quant::Rounding::Stochastic,
            exp.clip,
            exp.threads,
            &mut rng_b,
        );
        assert_eq!(gather_all(&grouped), gather_all(&plain));
    }

    #[test]
    fn non_quantized_methods_are_rejected() {
        let (schema, kinds) = toy_layout();
        for method in [Method::Fp, Method::Lsq, Method::Pact] {
            let exp = mixed_exp(method, "num:4,cat:8");
            let mut rng = Pcg32::seeded(3);
            let err = GroupedStore::from_plan(
                &exp, &schema, &kinds, schema.n_features(), 4, &mut rng,
            )
            .map(|_| ())
            .unwrap_err();
            assert!(
                format!("{err:#}").contains("lpt/alpt"),
                "{method:?}: {err:#}"
            );
        }
    }

    #[test]
    fn quantized_view_reports_per_row_deltas_and_codes() {
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(Method::Alpt(RoundingMode::Sr), "num:2,cat:8");
        let mut rng = Pcg32::seeded(4);
        let store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 6, &mut rng,
        )
        .unwrap();
        let ids = [0u32, 39, 40, 199];
        let mut codes = vec![0i32; ids.len() * 6];
        let mut delta = vec![0.0f32; ids.len()];
        assert!(store.quantized_view(&ids, &mut codes, &mut delta));
        // codes * delta reproduces the gathered values exactly
        let mut gathered = vec![0.0f32; ids.len() * 6];
        store.gather(&ids, &mut gathered);
        for i in 0..ids.len() {
            for j in 0..6 {
                assert_eq!(
                    codes[i * 6 + j] as f32 * delta[i],
                    gathered[i * 6 + j],
                    "row {i} col {j}"
                );
            }
        }
        // 2-bit rows carry 2-bit codes
        for (j, &c) in codes.iter().take(12).enumerate() {
            assert!((-2..=1).contains(&c), "2-bit code {c} at {j}");
        }
    }

    #[test]
    fn grouped_update_learns_and_preserves_untouched_groups() {
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(Method::Alpt(RoundingMode::Sr), "num:4,cat:8");
        let mut rng = Pcg32::seeded(5);
        let mut store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        let before_numeric = {
            let mut out = vec![0.0f32; 4];
            store.gather(&[3], &mut out);
            out
        };
        // touch only categorical rows with a strong gradient
        let ids = [50u32, 120];
        let mut what = vec![0.0f32; 2 * 4];
        store.gather(&ids, &mut what);
        let grads = vec![1.0f32; 2 * 4];
        let mut h = hp();
        h.lr_emb = 0.5;
        let mut sp = eq7_second_pass();
        let mut step_rng = Pcg32::seeded(6);
        for _ in 0..30 {
            store.gather(&ids, &mut what);
            store
                .update(&ids, &what, &grads, &h, &mut step_rng, &mut sp)
                .unwrap();
        }
        let mut now = vec![0.0f32; 2 * 4];
        store.gather(&ids, &mut now);
        assert!(
            now.iter().sum::<f32>() < -0.5,
            "rows did not move down: {now:?}"
        );
        // the numeric group, never referenced, is untouched
        let mut after_numeric = vec![0.0f32; 4];
        store.gather(&[3], &mut after_numeric);
        assert_eq!(before_numeric, after_numeric);
    }

    #[test]
    fn grouped_sharded_bit_identical_to_serial() {
        // the extended StreamKey contract: grouped gather/update must be
        // bit-identical to the serial path at any thread count, for both
        // store families and mixed widths.
        for method in
            [Method::Lpt(RoundingMode::Sr), Method::Alpt(RoundingMode::Sr)]
        {
            check(
                &format!("grouped serial == sharded ({method:?})"),
                6,
                move |g: &mut Gen| {
                    let v0 = g.usize_in(40, 120) as u32;
                    let v1 = g.usize_in(80, 200) as u32;
                    let v2 = g.usize_in(30, 90) as u32;
                    let schema = Schema::new(vec![v0, v1, v2]);
                    let kinds = vec![
                        FieldKind::Numeric,
                        FieldKind::Categorical,
                        FieldKind::Categorical,
                    ];
                    let d = g.usize_in(3, 9);
                    let n = schema.n_features();
                    let seed = g.u32_any() as u64;
                    let mk = |threads: usize| {
                        let mut exp = mixed_exp(method, "num:4,f2:2,cat:8");
                        exp.threads = threads;
                        let mut rng = Pcg32::seeded(seed);
                        let mut s = GroupedStore::from_plan(
                            &exp, &schema, &kinds, n, d, &mut rng,
                        )
                        .unwrap();
                        s.set_threads(threads);
                        s
                    };
                    let mut serial = mk(1);
                    let mut par = mk(4);
                    if gather_all(&serial) != gather_all(&par) {
                        return Err("init diverged".into());
                    }
                    let ids: Vec<u32> = (0..n as u32).collect();
                    let grads: Vec<f32> = (0..n * d)
                        .map(|i| ((i % 13) as f32 - 6.0) * 0.01)
                        .collect();
                    let mut what_s = vec![0.0f32; n * d];
                    let mut what_p = vec![0.0f32; n * d];
                    let mut rng_s = Pcg32::seeded(seed ^ 0xABCD);
                    let mut rng_p = Pcg32::seeded(seed ^ 0xABCD);
                    let mut sp_s = eq7_second_pass();
                    let mut sp_p = eq7_second_pass();
                    for _ in 0..2 {
                        serial.gather(&ids, &mut what_s);
                        par.gather(&ids, &mut what_p);
                        if what_s != what_p {
                            return Err("gather diverged".into());
                        }
                        serial
                            .update(&ids, &what_s, &grads, &hp(),
                                    &mut rng_s, &mut sp_s)
                            .map_err(|e| format!("{e:#}"))?;
                        par.update(&ids, &what_p, &grads, &hp(),
                                   &mut rng_p, &mut sp_p)
                            .map_err(|e| format!("{e:#}"))?;
                        if gather_all(&serial) != gather_all(&par) {
                            return Err("update diverged".into());
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn structural_plan_builds_hash_and_prune_groups() {
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(
            Method::Lpt(RoundingMode::Sr),
            "f0:hash,f2:prune,default:8",
        );
        let mut rng = Pcg32::seeded(11);
        let mut store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        assert_eq!(store.n_groups(), 3, "packed + hashed + pruned");
        assert_eq!(store.group_kind(0), "lpt");
        assert_eq!(store.group_kind(1), "hash");
        assert_eq!(store.group_kind(2), "prune");
        assert!(store.has_structural_groups());
        // structural groups carry the plan's nominal (default) width
        assert_eq!(store.group_bits(1), 8);
        assert_eq!(store.group_bits(2), 8);
        assert_eq!(store.group_rows(0), 100, "field 1 stays packed");
        assert_eq!(store.group_rows(1), 40, "field 0 rows");
        assert_eq!(store.group_rows(2), 60, "field 2 rows");
        // no integer-code view once structural groups exist
        let ids = [3u32, 50, 150];
        let mut codes = vec![0i32; 3 * 4];
        let mut delta = vec![0.0f32; 3];
        assert!(!store.quantized_view(&ids, &mut codes, &mut delta));
        // gather + update cross all three kinds and learn
        let grads = vec![1.0f32; 3 * 4];
        let mut h = hp();
        h.lr_emb = 0.3;
        let mut sp = no_second_pass();
        let mut rng2 = Pcg32::seeded(12);
        let mut what = vec![0.0f32; 3 * 4];
        for _ in 0..20 {
            store.gather(&ids, &mut what);
            store
                .update(&ids, &what, &grads, &h, &mut rng2, &mut sp)
                .unwrap();
            store.end_step();
        }
        store.gather(&ids, &mut what);
        assert!(
            what.iter().sum::<f32>() < -1.0,
            "rows did not descend: {what:?}"
        );
        // packed groups step in update, structural ones in end_step —
        // one shared counter describes them all
        assert_eq!(store.step_counter(), 20);
        for g in 0..store.n_groups() {
            assert_eq!(store.group_store(g).step_counter(), 20, "group {g}");
        }
        // the store-level frequency signal saw every touch
        let counts = store.access_counts().unwrap();
        for &id in &ids {
            assert_eq!(counts[id as usize], 20, "row {id}");
        }
        assert_eq!(counts.iter().map(|&c| c as u64).sum::<u64>(), 60);
        store.reset_access_counts();
        assert!(store.access_counts().unwrap().iter().all(|&c| c == 0));
    }

    #[test]
    fn alpt_second_pass_spans_structural_rows() {
        // the full-batch Δ-gradient context must hold sane entries for
        // hashed rows sitting in the same batch as packed ALPT rows
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(
            Method::Alpt(RoundingMode::Sr),
            "f0:hash,default:4",
        );
        let mut rng = Pcg32::seeded(13);
        let mut store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        let ids = [5u32, 80, 170]; // hashed, packed, packed
        let grads = vec![0.2f32; 3 * 4];
        let mut sp = eq7_second_pass();
        let mut rng2 = Pcg32::seeded(14);
        let mut what = vec![0.0f32; 3 * 4];
        for _ in 0..10 {
            store.gather(&ids, &mut what);
            store
                .update(&ids, &what, &grads, &hp(), &mut rng2, &mut sp)
                .unwrap();
            store.end_step();
        }
        store.gather(&ids, &mut what);
        assert!(what.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn migrate_requantizes_deterministically() {
        let (schema, kinds) = toy_layout();
        let exp_old =
            mixed_exp(Method::Alpt(RoundingMode::Sr), "num:4,cat:8");
        let mut rng = Pcg32::seeded(21);
        let mut old = GroupedStore::from_plan(
            &exp_old, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        // train a little so the table is away from init
        let ids: Vec<u32> = (0..200u32).step_by(7).collect();
        let grads: Vec<f32> = (0..ids.len() * 4)
            .map(|i| ((i % 5) as f32 - 2.0) * 0.05)
            .collect();
        let mut sp = eq7_second_pass();
        let mut rng_u = Pcg32::seeded(22);
        let mut what = vec![0.0f32; ids.len() * 4];
        for _ in 0..5 {
            old.gather(&ids, &mut what);
            old.update(&ids, &what, &grads, &hp(), &mut rng_u, &mut sp)
                .unwrap();
        }
        let exp_new =
            mixed_exp(Method::Alpt(RoundingMode::Sr), "num:8,cat:2");
        let mk = || {
            let mut r = Pcg32::seeded(33);
            GroupedStore::migrate_from(&old, &exp_new, &schema, &kinds,
                                       &mut r)
                .unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(
            gather_all(&a),
            gather_all(&b),
            "migration is not a pure function of (store, plan, rng)"
        );
        assert_eq!(a.step_counter(), old.step_counter());
        assert_eq!(a.access_counts().unwrap(), old.access_counts().unwrap());
        assert_eq!(a.bits_of_row(0), 8, "numeric field widened");
        assert_eq!(a.bits_of_row(50), 2, "categorical field narrowed");
        // SR lands each migrated value on one of the two grid points
        // bracketing the old value: |new - old| <= the row's new Δ
        let before = gather_all(&old);
        let after = gather_all(&a);
        let all_ids: Vec<u32> = (0..200).collect();
        let mut codes = vec![0i32; 200 * 4];
        let mut delta = vec![0.0f32; 200];
        assert!(a.quantized_view(&all_ids, &mut codes, &mut delta));
        for (i, (&x, &y)) in before.iter().zip(&after).enumerate() {
            let tol = delta[i / 4] + 1e-6;
            assert!(
                (x - y).abs() <= tol,
                "row {} col {}: {x} -> {y} (Δ={})",
                i / 4,
                i % 4,
                delta[i / 4]
            );
        }
    }

    #[test]
    fn migrate_rejects_structural_plans_on_either_side() {
        let (schema, kinds) = toy_layout();
        let exp_packed =
            mixed_exp(Method::Lpt(RoundingMode::Sr), "num:4,cat:8");
        let exp_structural = mixed_exp(
            Method::Lpt(RoundingMode::Sr),
            "f0:hash,default:8",
        );
        let mut rng = Pcg32::seeded(41);
        let packed = GroupedStore::from_plan(
            &exp_packed, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        let structural = GroupedStore::from_plan(
            &exp_structural, &schema, &kinds, schema.n_features(), 4,
            &mut rng,
        )
        .unwrap();
        let mut r = Pcg32::seeded(42);
        let err = GroupedStore::migrate_from(
            &packed, &exp_structural, &schema, &kinds, &mut r,
        )
        .map(|_| ())
        .unwrap_err();
        assert!(format!("{err:#}").contains("no per-row payload"), "{err:#}");
        let err = GroupedStore::migrate_from(
            &structural, &exp_packed, &schema, &kinds, &mut r,
        )
        .map(|_| ())
        .unwrap_err();
        assert!(format!("{err:#}").contains("shared"), "{err:#}");
    }

    #[test]
    fn step_counters_stay_in_lockstep_across_groups() {
        // batches that miss a group entirely must still advance its SR
        // step counter, so one persisted `step` restores every group
        let (schema, kinds) = toy_layout();
        let exp = mixed_exp(Method::Lpt(RoundingMode::Sr), "num:4,cat:8");
        let mut rng = Pcg32::seeded(9);
        let mut store = GroupedStore::from_plan(
            &exp, &schema, &kinds, schema.n_features(), 4, &mut rng,
        )
        .unwrap();
        let ids = [50u32]; // categorical only — numeric group sees no rows
        let mut what = vec![0.0f32; 4];
        store.gather(&ids, &mut what);
        let grads = vec![0.1f32; 4];
        let mut sp = eq7_second_pass();
        let mut step_rng = Pcg32::seeded(10);
        for _ in 0..3 {
            store
                .update(&ids, &what, &grads, &hp(), &mut step_rng, &mut sp)
                .unwrap();
        }
        assert_eq!(store.step_counter(), 3);
        for g in 0..store.n_groups() {
            assert_eq!(store.group_store(g).step_counter(), 3, "group {g}");
        }
        store.set_step_counter(7);
        for g in 0..store.n_groups() {
            assert_eq!(store.group_store(g).step_counter(), 7);
        }
    }
}
