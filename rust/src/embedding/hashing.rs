//! Quotient–remainder compositional embeddings (Shi et al. 2020) — the
//! paper's hashing baseline (appendix B.2).
//!
//! Two tables: E1 ∈ R^{r×d} indexed by `id % r` and E2 ∈ R^{⌈n/r⌉×d}
//! indexed by `id / r`; the final embedding is their element-wise product.
//! With r = 2 the parameter count is ~n/2 ⇒ 2× compression at train AND
//! inference, at the cost of forced parameter sharing (the accuracy hit
//! Table 1 shows).
//!
//! Persistence: the two tables are *shared* across feature ids — a
//! feature's embedding does not decompose into a per-row payload — so
//! the store persists through [`Persistable::aux_params`] alone
//! (`ckpt_row_bytes` stays `None`): one flat block of `r·d + ⌈n/r⌉·d`
//! floats, E1 first. That is checkpoint format v3's "aux-only" store /
//! group kind.

use super::{EmbeddingStore, Persistable, RowStats, SecondPass, UpdateHp};
use crate::util::rng::Pcg32;
use anyhow::{ensure, Result};

pub struct HashingStore {
    n: usize,
    d: usize,
    r: usize,
    /// Both tables in one flat block: the remainder table `[r, d]`
    /// followed by the quotient table `[ceil(n/r), d]` — the layout
    /// `aux_params` persists verbatim.
    params: Vec<f32>,
    /// Update steps completed (persisted so resumed runs keep counting
    /// from where they stopped, like every other store).
    step: u64,
}

impl HashingStore {
    pub fn init(n: usize, d: usize, r: usize, rng: &mut Pcg32) -> Self {
        assert!(r >= 1);
        let q_rows = n.div_ceil(r);
        // init near 1 x small so products start near the usual N(0, 0.01):
        // e1 ~ N(1, 0.1) (gating), e2 ~ N(0, 0.01) (content).
        // Draw order (e1 fully, then e2) is part of the determinism
        // contract: it must match the pre-split two-vector layout.
        let mut params = Vec::with_capacity((r + q_rows) * d);
        params.extend((0..r * d).map(|_| rng.normal_scaled(1.0, 0.1)));
        params
            .extend((0..q_rows * d).map(|_| rng.normal_scaled(0.0, 0.01)));
        Self { n, d, r, params, step: 0 }
    }

    #[inline]
    fn split(&self, id: u32) -> (usize, usize) {
        ((id as usize % self.r), (id as usize / self.r))
    }

    /// Total persisted parameter count (`aux_params().len()`).
    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

impl EmbeddingStore for HashingStore {
    fn method_name(&self) -> &'static str {
        "Hashing"
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        let d = self.d;
        let e2 = &self.params[self.r * d..];
        for (i, &id) in ids.iter().enumerate() {
            let (rem, quo) = self.split(id);
            let a = &self.params[rem * d..(rem + 1) * d];
            let b = &e2[quo * d..(quo + 1) * d];
            let o = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                o[j] = a[j] * b[j];
            }
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        _emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        _rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let e1_len = self.r * d;
        let lr = hp.lr_emb * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let (rem, quo) = self.split(id);
            let g = &grads[i * d..(i + 1) * d];
            // chain rule through the product, with decoupled weight decay
            for j in 0..d {
                let a = self.params[rem * d + j];
                let b = self.params[e1_len + quo * d + j];
                self.params[rem * d + j] -=
                    lr * (g[j] * b + hp.wd_emb * a);
                self.params[e1_len + quo * d + j] -=
                    lr * (g[j] * a + hp.wd_emb * b);
            }
        }
        Ok(())
    }

    fn train_bytes(&self) -> usize {
        self.params.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.train_bytes()
    }

    fn end_step(&mut self) {
        self.step = self.step.wrapping_add(1);
    }
}

impl Persistable for HashingStore {
    // ckpt_row_bytes stays None: the shared tables do not decompose into
    // per-feature rows, so the whole parameter block persists as aux.

    fn aux_params(&self) -> &[f32] {
        &self.params
    }

    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        ensure!(
            aux.len() == self.params.len(),
            "hashing parameter count mismatch: checkpoint has {}, \
             table (n={}, d={}, r={}) expects {}",
            aux.len(),
            self.n,
            self.d,
            self.r,
            self.params.len()
        );
        self.params.copy_from_slice(aux);
        Ok(())
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }
}

impl RowStats for HashingStore {}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;
    use crate::embedding::fp_bytes;

    #[test]
    fn compression_is_about_r() {
        let mut rng = Pcg32::seeded(1);
        let store = HashingStore::init(10_000, 16, 2, &mut rng);
        let ratio = fp_bytes(10_000, 16) as f64 / store.train_bytes() as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn collisions_share_parameters() {
        let mut rng = Pcg32::seeded(2);
        let mut store = HashingStore::init(100, 4, 2, &mut rng);
        // ids 4 and 5 share the quotient row 2 with r=2
        let mut before = vec![0.0f32; 2 * 4];
        store.gather(&[4, 5], &mut before);
        // update id 4 only
        let grads = vec![1.0f32; 4];
        let emb = before[..4].to_vec();
        store
            .update(&[4], &emb, &grads, &hp(), &mut rng,
                    &mut no_second_pass())
            .unwrap();
        let mut after = vec![0.0f32; 2 * 4];
        store.gather(&[4, 5], &mut after);
        // id 5's embedding must have moved too (shared quotient row)
        assert_ne!(&before[4..], &after[4..], "no sharing happened");
    }

    #[test]
    fn gradient_descends_product_loss() {
        // minimize ||e(id) - target||^2 through the composed embedding
        let mut rng = Pcg32::seeded(3);
        let mut store = HashingStore::init(50, 4, 2, &mut rng);
        let target = [0.5f32, -0.3, 0.2, 0.1];
        let ids = [7u32];
        let mut h = hp();
        h.lr_emb = 0.2;
        let mut first = f32::NAN;
        let mut last = 0.0;
        for step in 0..300 {
            let mut e = vec![0.0f32; 4];
            store.gather(&ids, &mut e);
            let mut g = vec![0.0f32; 4];
            let mut loss = 0.0;
            for j in 0..4 {
                g[j] = 2.0 * (e[j] - target[j]);
                loss += (e[j] - target[j]).powi(2);
            }
            if step == 0 {
                first = loss;
            }
            last = loss;
            store
                .update(&ids, &e, &g, &h, &mut rng, &mut no_second_pass())
                .unwrap();
        }
        assert!(last < first * 0.01, "loss {first} -> {last}");
    }

    #[test]
    fn aux_roundtrip_restores_every_parameter() {
        let mut rng = Pcg32::seeded(4);
        let mut store = HashingStore::init(30, 4, 2, &mut rng);
        // perturb, snapshot, restore into a freshly-initialized twin
        let grads = vec![0.7f32; 4];
        let emb = vec![0.0f32; 4];
        store
            .update(&[11], &emb, &grads, &hp(), &mut rng,
                    &mut no_second_pass())
            .unwrap();
        store.end_step();
        let saved = store.aux_params().to_vec();
        let mut rng2 = Pcg32::seeded(99);
        let mut twin = HashingStore::init(30, 4, 2, &mut rng2);
        twin.load_aux_params(&saved).unwrap();
        twin.set_step_counter(store.step_counter());
        assert_eq!(twin.aux_params(), store.aux_params());
        assert_eq!(twin.step_counter(), 1);
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        store.gather(&[11], &mut a);
        twin.gather(&[11], &mut b);
        assert_eq!(a, b);
        // wrong geometry is rejected
        assert!(twin.load_aux_params(&saved[1..]).is_err());
    }
}
