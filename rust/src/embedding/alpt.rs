//! ALPT — the paper's contribution (Algorithm 1): low-precision training
//! with a *learned, feature-wise* step size.
//!
//! Per batch step:
//!   1. de-quantize the batch rows ŵ = Δ_b·w̃_b, run fwd/bwd, update in
//!      float: w^{t+1} = ŵ − η(∇f + wd·ŵ)   (done by the trainer + here);
//!   2. run a second fwd/bwd through Q_D(w^{t+1}, Δ^t) (LSQ estimator,
//!      Eq. 7) to get ∂f/∂Δ — the `second_pass` callback, which executes
//!      the `train_fq` artifact; update Δ with gradient scale g and its
//!      own LR / weight decay;
//!   3. re-quantize w̃^{t+1} = Q̃_S(w^{t+1}, Δ^{t+1}).
//!
//! Steps 1 and 3 are sharded row-wise across threads (step 2 is a batch
//! reduction that stays serial); SR noise comes from counter-based
//! per-row streams so the packed result is bit-identical at any thread
//! count. Step 3 uses the fused quantize→pack path — no i32 scratch.
//!
//! Storage is identical to LPT plus one learned f32 Δ per feature row —
//! Table 1's 3.2× (vs 4×) training-compression ratio at d=16.

use super::lpt::ids_unique;
use super::{init_weights, par_gather, par_gather_chunks,
            resolve_threads, EmbeddingStore, Persistable, RowStats,
            SecondPass, UpdateHp, MIN_ROWS_PER_THREAD};
use crate::quant::{delta_from_clip, init_delta, BitWidth, PackedTable,
                   Rounding};
use crate::util::rng::{Pcg32, StreamKey};
use crate::util::threadpool::parallel_ranges;
use anyhow::Result;

pub struct AlptStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    rounding: Rounding,
    /// learned per-feature step sizes
    delta: Vec<f32>,
    codes: PackedTable,
    /// sharding width for gather/update (resolved; >= 1)
    threads: usize,
    /// update-step counter feeding the per-step stream key
    step: u64,
    /// reusable w^{t+1} buffer (`U*d`, grown on demand)
    w_new: Vec<f32>,
    /// reusable gathered-Δ buffer (`U`, grown on demand)
    delta_t: Vec<f32>,
    /// reusable per-row bit-width buffer handed to the second pass
    bw_t: Vec<BitWidth>,
    /// per-row update counts (in-memory only; see [`RowStats`])
    counts: Vec<u32>,
}

impl AlptStore {
    pub fn init(
        n: usize,
        d: usize,
        bw: BitWidth,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) -> Self {
        Self::init_with_clip(n, d, bw, rounding, 0.1, rng)
    }

    /// Init with an explicit clip floor for the step size: Delta starts at
    /// max(LSQ init, clip/2^{m-1}) so ALPT never begins with a tighter
    /// representable range than tuned-clip LPT. At very low bit widths the
    /// LSQ init (2 E|w|/sqrt(q), q = 2^{m-1}-1) collapses and would other-
    /// wise freeze the row range before the Delta learning catches up.
    pub fn init_with_clip(
        n: usize,
        d: usize,
        bw: BitWidth,
        rounding: Rounding,
        clip: f32,
        rng: &mut Pcg32,
    ) -> Self {
        Self::init_with_clip_threads(n, d, bw, rounding, clip, 0, rng)
    }

    /// Like [`AlptStore::init_with_clip`] with an explicit sharding width
    /// for the init quantization and subsequent gather/update (0 = one
    /// worker per hardware thread). Results are bit-identical at any
    /// value.
    pub fn init_with_clip_threads(
        n: usize,
        d: usize,
        bw: BitWidth,
        rounding: Rounding,
        clip: f32,
        threads: usize,
        rng: &mut Pcg32,
    ) -> Self {
        let init = init_weights(n, d, rng);
        let key = StreamKey::new(rng.next_u64());
        let mut codes = PackedTable::new(n, d, bw);
        let mut delta = vec![0.0f32; n];
        let floor = delta_from_clip(clip, bw);
        let threads = resolve_threads(threads);
        let init_threads =
            threads.min(n.div_ceil(MIN_ROWS_PER_THREAD).max(1));
        // per-row: LSQ-style Δ init with the clip floor, then SR-quantize
        // the row from its counter stream. Each row is written exactly
        // once (disjoint ranges), satisfying RowWriter's safety contract.
        fn fill_row(
            r: usize,
            dl: &mut f32,
            writer: &crate::quant::RowWriter<'_>,
            init: &[f32],
            d: usize,
            bw: BitWidth,
            floor: f32,
            key: StreamKey,
        ) {
            let row = &init[r * d..(r + 1) * d];
            *dl = init_delta(row, bw).max(floor);
            let mut rrng = key.row_rng(r as u64);
            // Safety: callers fill disjoint rows (see above).
            unsafe {
                writer.quantize_row_packed(r, row, *dl,
                                           Rounding::Stochastic, &mut rrng);
            }
        }
        if init_threads <= 1 {
            let writer = codes.row_writer();
            for (r, dl) in delta.iter_mut().enumerate() {
                fill_row(r, dl, &writer, &init, d, bw, floor, key);
            }
        } else {
            // shard rows: each worker owns a contiguous Δ chunk and the
            // matching (disjoint) packed rows
            let writer = codes.row_writer();
            let init_ref = &init;
            let rows_per = n.div_ceil(init_threads);
            std::thread::scope(|s| {
                for (t, dchunk) in delta.chunks_mut(rows_per).enumerate() {
                    let lo = t * rows_per;
                    let writer = &writer;
                    s.spawn(move || {
                        for (k, dl) in dchunk.iter_mut().enumerate() {
                            fill_row(lo + k, dl, writer, init_ref, d, bw,
                                     floor, key);
                        }
                    });
                }
            });
        }
        Self {
            n,
            d,
            bw,
            rounding,
            delta,
            codes,
            threads,
            step: 0,
            w_new: Vec::new(),
            delta_t: Vec::new(),
            bw_t: Vec::new(),
            counts: vec![0; n],
        }
    }

    pub fn delta_of(&self, id: u32) -> f32 {
        self.delta[id as usize]
    }

    pub fn bit_width(&self) -> BitWidth {
        self.bw
    }

    /// Mean learned step size (diagnostics / Figure-4 sweeps).
    pub fn mean_delta(&self) -> f64 {
        self.delta.iter().map(|&x| x as f64).sum::<f64>()
            / self.n.max(1) as f64
    }

    /// Configure the sharding width (0 = one worker per hardware thread).
    /// Purely a performance knob: results are bit-identical at any value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = resolve_threads(threads);
    }

    /// Dequantize one row into `out` — the grouped-store gather kernel
    /// (same word-at-a-time path as [`AlptStore::gather`], addressed by
    /// this sub-table's local row id).
    pub(crate) fn read_row_dequant_into(&self, row: usize, out: &mut [f32]) {
        self.codes.read_row_dequant(row, self.delta[row], out);
    }

    /// Integer codes of one row (the grouped `quantized_view` kernel).
    pub(crate) fn read_codes_into(&self, row: usize, out: &mut [i32]) {
        self.codes.read_row(row, out);
    }

    /// Prefetch hint for one local row — the grouped store's routed
    /// gather issues this ahead of [`AlptStore::read_row_dequant_into`].
    pub(crate) fn prefetch_row(&self, row: usize) {
        self.codes.prefetch_row(row);
    }

    /// Serially quantize one row from a float value under an explicit
    /// learned Δ — the grouped-store migration kernel. The row's Δ is
    /// set first (rescaled by the caller so the representable range
    /// carries across widths), then the value is packed from the
    /// caller-supplied SR stream, keeping migration a pure function of
    /// `(plan, seed, step)`.
    pub(crate) fn write_row_from_f32(
        &mut self,
        row: usize,
        w: &[f32],
        delta: f32,
        rrng: &mut Pcg32,
    ) {
        // a collapsed Δ would freeze the row forever (same floor as the
        // Δ update)
        self.delta[row] = delta.max(1e-8);
        self.codes.quantize_row_packed(row, w, self.delta[row],
                                       self.rounding, rrng);
    }

}

impl EmbeddingStore for AlptStore {
    fn method_name(&self) -> &'static str {
        match self.rounding {
            Rounding::Stochastic => "ALPT(SR)",
            Rounding::Deterministic => "ALPT(DR)",
        }
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        par_gather_chunks(ids, self.d, out, self.threads,
                          |_, chunk_ids, chunk| {
            self.codes.gather_dequant(
                chunk_ids,
                |id| self.delta[id as usize],
                chunk,
            );
        });
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let n_u = ids.len();
        debug_assert_eq!(emb_hat.len(), n_u * d);
        debug_assert_eq!(grads.len(), n_u * d);
        for &id in ids {
            let id = id as usize;
            self.counts[id] = self.counts[id].saturating_add(1);
        }
        let lr = hp.lr_emb * hp.lr_scale;
        let wd = hp.wd_emb;
        // Step 3 writes rows by id, so sharding it requires unique ids
        // (the trainer passes deduped `batch.unique`); duplicates fall
        // back to the serial loop, preserving last-write-wins order.
        // Steps 1–2 are indexed by batch position and stay safe either
        // way.
        let row_threads = if self.threads > 1
            && n_u > super::MIN_ROWS_PER_THREAD
            && ids_unique(ids)
        {
            self.threads
        } else {
            1
        };

        // Step 1: float update of the batch rows, sharded row-wise into
        // the reusable w_new scratch.
        self.w_new.resize(n_u * d, 0.0);
        par_gather(
            ids,
            d,
            &mut self.w_new[..n_u * d],
            self.threads,
            |i, _, out| {
                let what = &emb_hat[i * d..(i + 1) * d];
                let g = &grads[i * d..(i + 1) * d];
                for j in 0..d {
                    out[j] = what[j] - lr * (g[j] + wd * what[j]);
                }
            },
        );

        // Step 2: d f / d Delta at (w^{t+1}, Delta^t) via the fake-quant
        // pass, then the Delta update (scaled gradient + weight decay).
        // An empty batch skips the model pass entirely — a grouped store
        // updates every precision group each step (keeping the SR step
        // counters in lockstep), including groups the batch missed.
        self.delta_t.resize(n_u, 0.0);
        for (i, &id) in ids.iter().enumerate() {
            self.delta_t[i] = self.delta[id as usize];
        }
        let d_delta = if n_u == 0 {
            Vec::new()
        } else {
            self.bw_t.clear();
            self.bw_t.resize(n_u, self.bw);
            second_pass(
                &self.w_new[..n_u * d],
                &self.delta_t[..n_u],
                &self.bw_t[..n_u],
            )?
        };
        debug_assert_eq!(d_delta.len(), n_u);
        let lr_d = hp.lr_delta * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let g = hp.grad_scale * d_delta[i] + hp.wd_delta * self.delta[id];
            // keep Delta strictly positive; collapse to 0 would freeze the
            // row forever
            self.delta[id] = (self.delta[id] - lr_d * g).max(1e-8);
        }

        // Step 3: re-quantize with Delta^{t+1} — sharded, fused
        // quantize→pack through disjoint-row writes.
        let key = StreamKey::for_step(rng.next_u64(), self.step);
        self.step = self.step.wrapping_add(1);
        let rounding = self.rounding;
        let w_new = &self.w_new[..n_u * d];
        let delta = &self.delta;
        let writer = self.codes.row_writer();
        parallel_ranges(n_u, row_threads, MIN_ROWS_PER_THREAD, |range| {
            for i in range {
                let id = ids[i] as usize;
                let mut rrng = key.row_rng(id as u64);
                // Safety: ids are unique → rows are disjoint.
                unsafe {
                    writer.quantize_row_packed(
                        id,
                        &w_new[i * d..(i + 1) * d],
                        delta[id],
                        rounding,
                        &mut rrng,
                    );
                }
            }
        });
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        for (i, &id) in ids.iter().enumerate() {
            self.codes
                .read_row(id as usize, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = self.delta[id as usize];
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.delta.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.train_bytes()
    }
}

impl Persistable for AlptStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.codes.row_bytes())
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        self.codes.save_raw_rows(lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        self.codes.load_raw_rows(lo, src)
    }

    fn aux_params(&self) -> &[f32] {
        &self.delta
    }

    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        anyhow::ensure!(
            aux.len() == self.n,
            "ALPT delta count mismatch: {} vs {} rows",
            aux.len(),
            self.n
        );
        self.delta.copy_from_slice(aux);
        Ok(())
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }
}

impl RowStats for AlptStore {
    fn access_counts(&self) -> Option<&[u32]> {
        Some(&self.counts)
    }

    fn reset_access_counts(&mut self) {
        self.counts.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{eq7_second_pass, hp};
    use super::*;
    use crate::embedding::fp_bytes;
    use crate::quant::lsq_delta_grad_row;

    #[test]
    fn ratio_3_2x_at_8bit_d16() {
        let mut rng = Pcg32::seeded(1);
        let store = AlptStore::init(1000, 16, BitWidth::B8,
                                    Rounding::Stochastic, &mut rng);
        let ratio = fp_bytes(1000, 16) as f64 / store.train_bytes() as f64;
        assert!((ratio - 3.2).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn per_feature_deltas_differ() {
        let mut rng = Pcg32::seeded(2);
        let store = AlptStore::init(100, 8, BitWidth::B8,
                                    Rounding::Stochastic, &mut rng);
        let d0 = store.delta_of(0);
        let distinct =
            (1..100).filter(|&i| store.delta_of(i) != d0).count();
        assert!(distinct > 90, "deltas should be feature-wise");
        assert!((0..100).all(|i| store.delta_of(i) > 0.0));
    }

    #[test]
    fn update_learns_delta_and_requantizes() {
        let mut rng = Pcg32::seeded(3);
        let mut store = AlptStore::init(10, 4, BitWidth::B8,
                                        Rounding::Stochastic, &mut rng);
        let ids = [2u32, 7];
        let before = [store.delta_of(2), store.delta_of(7)];
        let mut what = vec![0.0f32; 8];
        store.gather(&ids, &mut what);
        let grads = vec![0.01f32; 8];
        let mut h = hp();
        h.lr_delta = 1e-3;
        let mut sp = eq7_second_pass();
        store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
        let after = [store.delta_of(2), store.delta_of(7)];
        assert!(before[0] != after[0] || before[1] != after[1],
                "delta did not move");
        // untouched feature's delta unchanged
        assert_eq!(store.delta_of(0), {
            let mut rng2 = Pcg32::seeded(3);
            AlptStore::init(10, 4, BitWidth::B8, Rounding::Stochastic,
                            &mut rng2)
            .delta_of(0)
        });
    }

    #[test]
    fn delta_stays_positive_under_adversarial_grads() {
        let mut rng = Pcg32::seeded(4);
        let mut store = AlptStore::init(4, 4, BitWidth::B8,
                                        Rounding::Stochastic, &mut rng);
        let ids = [0u32];
        let mut h = hp();
        h.lr_delta = 10.0; // absurdly large on purpose
        let mut sp = eq7_second_pass();
        for _ in 0..20 {
            let mut what = vec![0.0f32; 4];
            store.gather(&ids, &mut what);
            let grads = vec![1.0f32; 4];
            store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
            assert!(store.delta_of(0) > 0.0);
        }
    }

    #[test]
    fn larger_weights_grow_delta() {
        // if w^{t+1} blows past the representable range, Eq. 7 pushes
        // Delta up so the range expands (that's the adaptivity story)
        let mut rng = Pcg32::seeded(5);
        let mut store = AlptStore::init(4, 4, BitWidth::B2,
                                        Rounding::Stochastic, &mut rng);
        let ids = [1u32];
        let d0 = store.delta_of(1);
        let mut h = hp();
        h.lr_emb = 1.0;
        h.lr_delta = 1e-3;
        let mut sp = move |w_new: &[f32],
                           delta: &[f32],
                           bws: &[BitWidth]| {
            // upstream grads negative (loss decreases as Q grows): with
            // clipped-high weights Eq.7 gives qp, so d_delta < 0 -> Delta
            // grows.
            let d = w_new.len() / delta.len();
            let ups = vec![-1.0f32; d];
            Ok(delta
                .iter()
                .enumerate()
                .map(|(i, &dl)| {
                    lsq_delta_grad_row(&w_new[i * d..(i + 1) * d], dl,
                                       bws[i], &ups)
                })
                .collect::<Vec<f32>>())
        };
        for _ in 0..30 {
            let mut what = vec![0.0f32; 4];
            store.gather(&ids, &mut what);
            // large negative grad drives w up hard
            let grads = vec![-1.0f32; 4];
            store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
        }
        assert!(
            store.delta_of(1) > d0 * 2.0,
            "delta should grow: {} -> {}",
            d0,
            store.delta_of(1)
        );
    }

    #[test]
    fn parallel_engine_bit_identical_to_serial() {
        // Sharded step-1/step-3 must reproduce the single-thread result
        // exactly: packed bytes AND learned deltas.
        let (n, d) = (260usize, 7usize);
        let bw = BitWidth::B4;
        let mk = || {
            let mut rng = Pcg32::seeded(21);
            AlptStore::init(n, d, bw, Rounding::Stochastic, &mut rng)
        };
        let mut serial = mk();
        serial.set_threads(1);
        let mut par = mk();
        par.set_threads(4);
        assert_eq!(serial.codes.bytes(), par.codes.bytes());

        let ids: Vec<u32> = (0..n as u32).collect();
        let mut what_s = vec![0.0f32; n * d];
        let mut what_p = vec![0.0f32; n * d];
        let grads: Vec<f32> =
            (0..n * d).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect();
        let mut rng_s = Pcg32::seeded(33);
        let mut rng_p = Pcg32::seeded(33);
        let mut sp_s = eq7_second_pass();
        let mut sp_p = eq7_second_pass();
        for _ in 0..3 {
            serial.gather(&ids, &mut what_s);
            par.gather(&ids, &mut what_p);
            assert_eq!(what_s, what_p, "gather diverged");
            serial
                .update(&ids, &what_s, &grads, &hp(), &mut rng_s,
                        &mut sp_s)
                .unwrap();
            par.update(&ids, &what_p, &grads, &hp(), &mut rng_p,
                       &mut sp_p)
                .unwrap();
            assert_eq!(serial.codes.bytes(), par.codes.bytes(),
                       "packed bytes diverged");
            assert_eq!(serial.delta, par.delta, "deltas diverged");
        }
    }
}
