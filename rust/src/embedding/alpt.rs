//! ALPT — the paper's contribution (Algorithm 1): low-precision training
//! with a *learned, feature-wise* step size.
//!
//! Per batch step:
//!   1. de-quantize the batch rows ŵ = Δ_b·w̃_b, run fwd/bwd, update in
//!      float: w^{t+1} = ŵ − η(∇f + wd·ŵ)   (done by the trainer + here);
//!   2. run a second fwd/bwd through Q_D(w^{t+1}, Δ^t) (LSQ estimator,
//!      Eq. 7) to get ∂f/∂Δ — the `second_pass` callback, which executes
//!      the `train_fq` artifact; update Δ with gradient scale g and its
//!      own LR / weight decay;
//!   3. re-quantize w̃^{t+1} = Q̃_S(w^{t+1}, Δ^{t+1}).
//!
//! Storage is identical to LPT plus one learned f32 Δ per feature row —
//! Table 1's 3.2× (vs 4×) training-compression ratio at d=16.

use super::{init_weights, EmbeddingStore, SecondPass, UpdateHp};
use crate::quant::{delta_from_clip, init_delta, quantize_row, BitWidth,
                   PackedTable, Rounding};
use crate::util::rng::Pcg32;
use anyhow::Result;

pub struct AlptStore {
    n: usize,
    d: usize,
    bw: BitWidth,
    rounding: Rounding,
    /// learned per-feature step sizes
    delta: Vec<f32>,
    codes: PackedTable,
    scratch: Vec<i32>,
}

impl AlptStore {
    pub fn init(
        n: usize,
        d: usize,
        bw: BitWidth,
        rounding: Rounding,
        rng: &mut Pcg32,
    ) -> Self {
        Self::init_with_clip(n, d, bw, rounding, 0.1, rng)
    }

    /// Init with an explicit clip floor for the step size: Delta starts at
    /// max(LSQ init, clip/2^{m-1}) so ALPT never begins with a tighter
    /// representable range than tuned-clip LPT. At very low bit widths the
    /// LSQ init (2 E|w|/sqrt(q), q = 2^{m-1}-1) collapses and would other-
    /// wise freeze the row range before the Delta learning catches up.
    pub fn init_with_clip(
        n: usize,
        d: usize,
        bw: BitWidth,
        rounding: Rounding,
        clip: f32,
        rng: &mut Pcg32,
    ) -> Self {
        let init = init_weights(n, d, rng);
        let mut codes = PackedTable::new(n, d, bw);
        let mut delta = vec![0.0f32; n];
        let mut row_codes = vec![0i32; d];
        let floor = delta_from_clip(clip, bw);
        for r in 0..n {
            let row = &init[r * d..(r + 1) * d];
            // LSQ-style init with the clip floor
            delta[r] = init_delta(row, bw).max(floor);
            quantize_row(row, delta[r], bw, Rounding::Stochastic, rng,
                         &mut row_codes);
            codes.write_row(r, &row_codes);
        }
        Self { n, d, bw, rounding, delta, codes, scratch: vec![0i32; d] }
    }

    pub fn delta_of(&self, id: u32) -> f32 {
        self.delta[id as usize]
    }

    pub fn bit_width(&self) -> BitWidth {
        self.bw
    }

    /// Mean learned step size (diagnostics / Figure-4 sweeps).
    pub fn mean_delta(&self) -> f64 {
        self.delta.iter().map(|&x| x as f64).sum::<f64>()
            / self.n.max(1) as f64
    }
}

impl EmbeddingStore for AlptStore {
    fn method_name(&self) -> &'static str {
        match self.rounding {
            Rounding::Stochastic => "ALPT(SR)",
            Rounding::Deterministic => "ALPT(DR)",
        }
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        for (i, &id) in ids.iter().enumerate() {
            self.codes.read_row_dequant(
                id as usize,
                self.delta[id as usize],
                &mut out[i * self.d..(i + 1) * self.d],
            );
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let lr = hp.lr_emb * hp.lr_scale;

        // Step 1: float update of the batch rows.
        let mut w_new = vec![0.0f32; ids.len() * d];
        for i in 0..ids.len() {
            let what = &emb_hat[i * d..(i + 1) * d];
            let g = &grads[i * d..(i + 1) * d];
            let out = &mut w_new[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] = what[j] - lr * (g[j] + hp.wd_emb * what[j]);
            }
        }

        // Step 2: d f / d Delta at (w^{t+1}, Delta^t) via the fake-quant
        // pass, then the Delta update (scaled gradient + weight decay).
        let delta_t: Vec<f32> =
            ids.iter().map(|&id| self.delta[id as usize]).collect();
        let d_delta = second_pass(&w_new, &delta_t)?;
        debug_assert_eq!(d_delta.len(), ids.len());
        let lr_d = hp.lr_delta * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let g = hp.grad_scale * d_delta[i] + hp.wd_delta * self.delta[id];
            // keep Delta strictly positive; collapse to 0 would freeze the
            // row forever
            self.delta[id] = (self.delta[id] - lr_d * g).max(1e-8);
        }

        // Step 3: re-quantize with Delta^{t+1}.
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            quantize_row(
                &w_new[i * d..(i + 1) * d],
                self.delta[id],
                self.bw,
                self.rounding,
                rng,
                &mut self.scratch,
            );
            self.codes.write_row(id, &self.scratch);
        }
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        for (i, &id) in ids.iter().enumerate() {
            self.codes
                .read_row(id as usize, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = self.delta[id as usize];
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.delta.len() * 4
    }

    fn infer_bytes(&self) -> usize {
        self.train_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::hp;
    use super::*;
    use crate::embedding::fp_bytes;
    use crate::quant::lsq_delta_grad_row;

    /// Rust-side second pass: Eq. 7 applied to a synthetic upstream
    /// gradient of all-ones (what the artifact does with real grads).
    fn eq7_second_pass(
        bw: BitWidth,
    ) -> impl FnMut(&[f32], &[f32]) -> Result<Vec<f32>> {
        move |w_new: &[f32], delta: &[f32]| {
            let d = w_new.len() / delta.len();
            let ups = vec![1.0f32; d];
            Ok(delta
                .iter()
                .enumerate()
                .map(|(i, &dl)| {
                    lsq_delta_grad_row(&w_new[i * d..(i + 1) * d], dl, bw,
                                       &ups)
                })
                .collect())
        }
    }

    #[test]
    fn ratio_3_2x_at_8bit_d16() {
        let mut rng = Pcg32::seeded(1);
        let store = AlptStore::init(1000, 16, BitWidth::B8,
                                    Rounding::Stochastic, &mut rng);
        let ratio = fp_bytes(1000, 16) as f64 / store.train_bytes() as f64;
        assert!((ratio - 3.2).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn per_feature_deltas_differ() {
        let mut rng = Pcg32::seeded(2);
        let store = AlptStore::init(100, 8, BitWidth::B8,
                                    Rounding::Stochastic, &mut rng);
        let d0 = store.delta_of(0);
        let distinct =
            (1..100).filter(|&i| store.delta_of(i) != d0).count();
        assert!(distinct > 90, "deltas should be feature-wise");
        assert!((0..100).all(|i| store.delta_of(i) > 0.0));
    }

    #[test]
    fn update_learns_delta_and_requantizes() {
        let mut rng = Pcg32::seeded(3);
        let mut store = AlptStore::init(10, 4, BitWidth::B8,
                                        Rounding::Stochastic, &mut rng);
        let ids = [2u32, 7];
        let before = [store.delta_of(2), store.delta_of(7)];
        let mut what = vec![0.0f32; 8];
        store.gather(&ids, &mut what);
        let grads = vec![0.01f32; 8];
        let mut h = hp();
        h.lr_delta = 1e-3;
        let mut sp = eq7_second_pass(BitWidth::B8);
        store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
        let after = [store.delta_of(2), store.delta_of(7)];
        assert!(before[0] != after[0] || before[1] != after[1],
                "delta did not move");
        // untouched feature's delta unchanged
        assert_eq!(store.delta_of(0), {
            let mut rng2 = Pcg32::seeded(3);
            AlptStore::init(10, 4, BitWidth::B8, Rounding::Stochastic,
                            &mut rng2)
            .delta_of(0)
        });
    }

    #[test]
    fn delta_stays_positive_under_adversarial_grads() {
        let mut rng = Pcg32::seeded(4);
        let mut store = AlptStore::init(4, 4, BitWidth::B8,
                                        Rounding::Stochastic, &mut rng);
        let ids = [0u32];
        let mut h = hp();
        h.lr_delta = 10.0; // absurdly large on purpose
        let mut sp = eq7_second_pass(BitWidth::B8);
        for _ in 0..20 {
            let mut what = vec![0.0f32; 4];
            store.gather(&ids, &mut what);
            let grads = vec![1.0f32; 4];
            store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
            assert!(store.delta_of(0) > 0.0);
        }
    }

    #[test]
    fn larger_weights_grow_delta() {
        // if w^{t+1} blows past the representable range, Eq. 7 pushes
        // Delta up so the range expands (that's the adaptivity story)
        let mut rng = Pcg32::seeded(5);
        let mut store = AlptStore::init(4, 4, BitWidth::B2,
                                        Rounding::Stochastic, &mut rng);
        let ids = [1u32];
        let d0 = store.delta_of(1);
        let mut h = hp();
        h.lr_emb = 1.0;
        h.lr_delta = 1e-3;
        let mut sp = move |w_new: &[f32], delta: &[f32]| {
            // upstream grads negative (loss decreases as Q grows): with
            // clipped-high weights Eq.7 gives qp, so d_delta < 0 -> Delta
            // grows.
            let d = w_new.len() / delta.len();
            let ups = vec![-1.0f32; d];
            Ok(delta
                .iter()
                .enumerate()
                .map(|(i, &dl)| {
                    lsq_delta_grad_row(&w_new[i * d..(i + 1) * d], dl,
                                       BitWidth::B2, &ups)
                })
                .collect::<Vec<f32>>())
        };
        for _ in 0..30 {
            let mut what = vec![0.0f32; 4];
            store.gather(&ids, &mut what);
            // large negative grad drives w up hard
            let grads = vec![-1.0f32; 4];
            store.update(&ids, &what, &grads, &h, &mut rng, &mut sp).unwrap();
        }
        assert!(
            store.delta_of(1) > d0 * 2.0,
            "delta should grow: {} -> {}",
            d0,
            store.delta_of(1)
        );
    }
}
