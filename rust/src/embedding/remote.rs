//! Coordinator-side store whose rows live on worker processes.
//!
//! [`RemoteStore`] implements the [`EmbeddingStore`] trait split over
//! the `coordinator::net` RPC: `gather` fans GATHER requests out by
//! [`RowPartition`], dequantizes the returned packed rows locally
//! (quantized bytes cross the wire, not f32 — the paper's compression
//! is also the transport's), and `update` ships per-row f32 gradients
//! plus the `(draw, step)` pair that keys the stochastic-rounding
//! streams, so workers quantize bit-identically to a single process.
//!
//! Checkpointing is layout-free: `save_rows` reassembles rows in
//! canonical *global* order from whatever shards own them, so a
//! checkpoint written under N workers is byte-identical to the
//! single-process file and reloads under any M (resume on M workers,
//! or on one process, or straight into `alpt serve`). Nothing about
//! the worker layout is persisted.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::experiment_to_json;
use crate::config::{Experiment, Method};
use crate::coordinator::net::{
    read_frame, write_frame, GatherReq, GatherResp, LoadReq, Op, UpdateReq,
    WorkerHub, WorkerLink, BARRIER_ATTACHED, BARRIER_EPOCH, BARRIER_QUIESCE,
    FLAG_RESPONSE, PROTO_VERSION,
};
use crate::coordinator::sharding::RowPartition;
use crate::embedding::{
    EmbeddingStore, Persistable, RowStats, SecondPass, UpdateHp,
};
use crate::quant::{delta_from_clip, BitWidth, PackedTable};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Batch staging area: the packed rows + Δ of the last gathered batch,
/// kept in wire form so `quantized_view` and ALPT's second pass read
/// the exact bytes the workers hold.
struct GatherCache {
    ids: Vec<u32>,
    cap: usize,
    table: PackedTable,
    delta: Vec<f32>,
}

/// An embedding table sharded across worker processes (see module
/// docs). Built by [`RemoteStore::attach`], which consumes the local
/// store's rows and streams them to registered workers.
pub struct RemoteStore {
    method_name: &'static str,
    is_alpt: bool,
    n: usize,
    d: usize,
    row_bytes: usize,
    bw: BitWidth,
    /// LPT's fixed shared step size (unused for ALPT).
    lpt_delta: f32,
    train_bytes: usize,
    infer_bytes: usize,
    /// Mirror of the workers' update-step counter: advanced once per
    /// `update` exactly like the local stores, persisted in the
    /// checkpoint meta so resumes continue the same SR streams.
    step: u64,
    part: RowPartition,
    links: Vec<Mutex<WorkerLink>>,
    max_frame: u64,
    cache: Mutex<GatherCache>,
    /// Δ table mirror for `aux_params`'s borrowed-slice contract;
    /// refreshed at every `prepare_save` quiesce. Empty for LPT.
    aux_cache: Vec<f32>,
    shut: AtomicBool,
}

impl RemoteStore {
    /// Accept `workers` registrations on `hub`, assign shard indices in
    /// arrival order, stream the local store's rows out, and return the
    /// remote handle that replaces it. The local store is left intact
    /// (the caller drops it).
    pub fn attach(
        local: &dyn EmbeddingStore,
        exp: &Experiment,
        hub: WorkerHub,
        workers: usize,
    ) -> Result<RemoteStore> {
        ensure!(workers >= 1, "--workers must be at least 1");
        let is_alpt = match exp.method {
            Method::Alpt(_) => true,
            Method::Lpt(_) => false,
            other => bail!(
                "distributed training shards packed tables; method {} \
                 has none (use lpt/alpt)",
                other.key()
            ),
        };
        ensure!(
            exp.bits.is_uniform(),
            "distributed training requires a uniform precision plan \
             (got --plan {:?}); mixed plans migrate rows between \
             groups, which the row partition does not model yet",
            exp.bits.key()
        );
        ensure!(
            exp.replan_budget == 0,
            "--replan-budget and --workers are mutually exclusive: \
             re-planning migrates rows between precision groups"
        );
        let bw = exp.bit_width()?;
        let row_bytes = local.ckpt_row_bytes().context(
            "distributed training requires a store with packed row \
             payloads",
        )?;
        let n = local.n_features();
        let d = local.dim();
        let part = RowPartition::new(n, workers);
        let cfg = *hub.cfg();
        let exp_json = experiment_to_json(exp);

        // registration: accept each worker, answer its HELLO with the
        // shard assignment (index = arrival order)
        let mut links = Vec::with_capacity(workers);
        for shard in 0..workers {
            let mut stream = hub.accept_worker().with_context(|| {
                format!(
                    "waiting for worker {}/{workers} to register",
                    shard + 1
                )
            })?;
            let (op, flags, seq, payload) =
                read_frame(&mut stream, cfg.max_frame)
                    .with_context(|| format!("worker {shard} HELLO"))?;
            ensure!(
                op == Op::Hello && flags & FLAG_RESPONSE == 0,
                "worker {shard} opened with {op:?} instead of HELLO"
            );
            let mut pos = 0;
            let proto =
                crate::checkpoint::format::take_u32(&payload, &mut pos)?;
            if proto != PROTO_VERSION {
                let msg = format!(
                    "protocol version mismatch: worker speaks v{proto}, \
                     coordinator v{PROTO_VERSION}"
                );
                write_frame(
                    &mut stream,
                    Op::Err,
                    FLAG_RESPONSE,
                    seq,
                    msg.as_bytes(),
                )
                .ok();
                bail!("{msg}");
            }
            let assignment = Json::obj(vec![
                ("shard", Json::num(shard as f64)),
                ("n_shards", Json::num(workers as f64)),
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("row_bytes", Json::num(row_bytes as f64)),
                ("step", Json::num(local.step_counter() as f64)),
                ("experiment", exp_json.clone()),
            ])
            .to_string();
            write_frame(
                &mut stream,
                Op::Hello,
                FLAG_RESPONSE,
                seq,
                assignment.as_bytes(),
            )?;
            links.push(Mutex::new(WorkerLink::from_stream(stream, &cfg)?));
        }

        // distribution: stream each shard's rows (+ Δ slice) in
        // frame-sized chunks of contiguous locals, then arm it
        let aux_all = local.aux_params();
        let chunk_rows = frame_chunk_rows(cfg.max_frame, row_bytes);
        let mut rowbuf = vec![0u8; chunk_rows * row_bytes];
        for (shard, link) in links.iter_mut().enumerate() {
            let link = link.get_mut().unwrap();
            let shard_n = part.shard_rows(shard);
            let mut lo = 0usize;
            while lo < shard_n {
                let hi = (lo + chunk_rows).min(shard_n);
                let count = hi - lo;
                let mut aux = Vec::with_capacity(if aux_all.is_empty() {
                    0
                } else {
                    count
                });
                for k in 0..count {
                    let g = part.global_of(shard, (lo + k) as u32) as usize;
                    local.save_rows(
                        g,
                        &mut rowbuf[k * row_bytes..(k + 1) * row_bytes],
                    )?;
                    if !aux_all.is_empty() {
                        aux.push(aux_all[g]);
                    }
                }
                let req = LoadReq {
                    start_local: lo as u32,
                    row_bytes: row_bytes as u32,
                    rows: rowbuf[..count * row_bytes].to_vec(),
                    aux,
                };
                link.call(Op::Load, &req.encode()).with_context(|| {
                    format!("loading rows onto worker shard {shard}")
                })?;
                lo = hi;
            }
            link.call(Op::Barrier, &[BARRIER_ATTACHED]).with_context(
                || format!("arming worker shard {shard}"),
            )?;
        }

        Ok(RemoteStore {
            method_name: local.method_name(),
            is_alpt,
            n,
            d,
            row_bytes,
            bw,
            lpt_delta: delta_from_clip(exp.clip, bw),
            train_bytes: local.train_bytes(),
            infer_bytes: local.infer_bytes(),
            step: local.step_counter(),
            part,
            links,
            max_frame: cfg.max_frame,
            cache: Mutex::new(GatherCache {
                ids: Vec::new(),
                cap: 0,
                table: PackedTable::new(0, d, bw),
                delta: Vec::new(),
            }),
            aux_cache: aux_all.to_vec(),
            shut: AtomicBool::new(false),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.part.n_shards()
    }

    fn call_shard(
        &self,
        shard: usize,
        op: Op,
        payload: &[u8],
    ) -> Result<Vec<u8>> {
        self.links[shard]
            .lock()
            .unwrap()
            .call(op, payload)
            .with_context(|| format!("worker shard {shard}"))
    }

    /// Fetch packed rows + Δ for `ids` into the cache (the fallible
    /// core of `gather`).
    fn fetch_batch(&self, ids: &[u32]) -> Result<()> {
        let rb = self.row_bytes;
        let mut cache = self.cache.lock().unwrap();
        if ids.len() > cache.cap {
            cache.cap = ids.len().next_power_of_two();
            cache.table = PackedTable::new(cache.cap, self.d, self.bw);
        }
        cache.delta.resize(cache.cap, 0.0);
        for (shard, (positions, globals)) in
            self.part.split(ids).into_iter().enumerate()
        {
            if globals.is_empty() {
                continue;
            }
            let req = GatherReq { aux_only: false, ids: globals };
            let resp = self.call_shard(shard, Op::Gather, &req.encode())?;
            let resp = GatherResp::decode(&resp)?;
            ensure!(
                resp.row_bytes as usize == rb
                    && resp.rows.len() == positions.len() * rb,
                "shard {shard} GATHER returned {} bytes of {}-byte rows \
                 for {} ids",
                resp.rows.len(),
                resp.row_bytes,
                positions.len()
            );
            if self.is_alpt {
                ensure!(
                    resp.aux.len() == positions.len(),
                    "shard {shard} GATHER returned {} deltas for {} ids",
                    resp.aux.len(),
                    positions.len()
                );
            }
            for (k, &pos) in positions.iter().enumerate() {
                cache
                    .table
                    .load_raw_rows(pos, &resp.rows[k * rb..(k + 1) * rb])?;
                cache.delta[pos] = if self.is_alpt {
                    resp.aux[k]
                } else {
                    self.lpt_delta
                };
            }
        }
        cache.ids.clear();
        cache.ids.extend_from_slice(ids);
        Ok(())
    }

    /// Per-id Δ for the batch, from the cache when it matches (the
    /// trainer always gathers first) or a fresh aux round trip.
    fn deltas_for(&self, ids: &[u32]) -> Result<Vec<f32>> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.ids == ids {
                return Ok(cache.delta[..ids.len()].to_vec());
            }
        }
        let mut out = vec![0.0f32; ids.len()];
        if !self.is_alpt {
            out.fill(self.lpt_delta);
            return Ok(out);
        }
        for (shard, (positions, globals)) in
            self.part.split(ids).into_iter().enumerate()
        {
            if globals.is_empty() {
                continue;
            }
            let req = GatherReq { aux_only: true, ids: globals };
            let resp = self.call_shard(shard, Op::Gather, &req.encode())?;
            let resp = GatherResp::decode(&resp)?;
            ensure!(
                resp.aux.len() == positions.len(),
                "shard {shard} aux GATHER returned {} deltas for {} ids",
                resp.aux.len(),
                positions.len()
            );
            for (k, &pos) in positions.iter().enumerate() {
                out[pos] = resp.aux[k];
            }
        }
        Ok(out)
    }

    /// Epoch barrier: every worker acks, proving it is alive and has
    /// applied all updates sent so far.
    pub fn barrier(&self) -> Result<()> {
        for shard in 0..self.part.n_shards() {
            self.call_shard(shard, Op::Barrier, &[BARRIER_EPOCH])
                .with_context(|| {
                    format!("epoch barrier: worker shard {shard}")
                })?;
        }
        Ok(())
    }

    /// Clean shutdown: every worker acks SHUTDOWN and exits 0.
    /// Idempotent; also attempted (best-effort) on drop.
    pub fn shutdown(&self) -> Result<()> {
        if self.shut.swap(true, Ordering::SeqCst) {
            return Ok(());
        }
        for shard in 0..self.part.n_shards() {
            self.call_shard(shard, Op::Shutdown, &[])?;
        }
        Ok(())
    }
}

impl Drop for RemoteStore {
    fn drop(&mut self) {
        if !self.shut.swap(true, Ordering::SeqCst) {
            for link in &self.links {
                if let Ok(mut link) = link.lock() {
                    link.call(Op::Shutdown, &[]).ok();
                }
            }
        }
    }
}

/// Rows per frame so one chunk stays well under the frame cap.
fn frame_chunk_rows(max_frame: u64, row_bytes: usize) -> usize {
    ((max_frame as usize / 2) / row_bytes.max(1)).clamp(1, 1 << 16)
}

impl EmbeddingStore for RemoteStore {
    fn method_name(&self) -> &'static str {
        self.method_name
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Infallible by trait contract: a dead worker here means the
    /// training step cannot produce correct results, so fail the
    /// process loudly rather than return garbage.
    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        if let Err(e) = self.fetch_batch(ids) {
            panic!("distributed gather failed: {e:#}");
        }
        // wire bytes were staged contiguously by fetch_batch; decode
        // them with the batch-sequential SIMD dequantize
        let cache = self.cache.lock().unwrap();
        cache.table.dequant_rows(ids.len(), &cache.delta, out);
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let n_u = ids.len();
        debug_assert_eq!(emb_hat.len(), n_u * d);
        debug_assert_eq!(grads.len(), n_u * d);

        // ALPT's second pass needs w^{t+1} and Δ^t on the coordinator
        // (it runs the model); workers recompute w^{t+1} from the same
        // grads with the same f32 ops, so only grads cross the wire.
        let d_delta = if self.is_alpt && n_u > 0 {
            let lr = hp.lr_emb * hp.lr_scale;
            let wd = hp.wd_emb;
            let mut w_new = vec![0.0f32; n_u * d];
            for i in 0..n_u {
                let what = &emb_hat[i * d..(i + 1) * d];
                let g = &grads[i * d..(i + 1) * d];
                let out = &mut w_new[i * d..(i + 1) * d];
                for j in 0..d {
                    out[j] = what[j] - lr * (g[j] + wd * what[j]);
                }
            }
            let delta_t = self.deltas_for(ids)?;
            let bw_t = vec![self.bw; n_u];
            second_pass(&w_new, &delta_t, &bw_t)?
        } else {
            Vec::new()
        };

        // same per-update RNG protocol as the local stores: exactly one
        // draw, taken after the second pass
        let draw = rng.next_u64();
        let step = self.step;
        self.step = self.step.wrapping_add(1);
        let hp_arr =
            [hp.lr_emb, hp.wd_emb, hp.lr_delta, hp.wd_delta, hp.grad_scale,
             hp.lr_scale];
        for (shard, (positions, globals)) in
            self.part.split(ids).into_iter().enumerate()
        {
            if globals.is_empty() {
                continue;
            }
            let mut shard_grads = Vec::with_capacity(positions.len() * d);
            let mut shard_dd = Vec::with_capacity(if self.is_alpt {
                positions.len()
            } else {
                0
            });
            for &pos in &positions {
                shard_grads.extend_from_slice(&grads[pos * d..(pos + 1) * d]);
                if self.is_alpt {
                    shard_dd.push(d_delta[pos]);
                }
            }
            let req = UpdateReq {
                step,
                draw,
                hp: hp_arr,
                ids: globals,
                grads: shard_grads,
                d_delta: shard_dd,
            };
            self.call_shard(shard, Op::Update, &req.encode())
                .context("distributed update")?;
        }
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        {
            let cache = self.cache.lock().unwrap();
            if cache.ids == ids {
                for i in 0..ids.len() {
                    cache
                        .table
                        .read_row(i, &mut codes[i * self.d..(i + 1) * self.d]);
                    delta[i] = cache.delta[i];
                }
                return true;
            }
        }
        // cold view (no preceding gather): fetch, then serve
        if let Err(e) = self.fetch_batch(ids) {
            panic!("distributed quantized_view failed: {e:#}");
        }
        let cache = self.cache.lock().unwrap();
        for i in 0..ids.len() {
            cache.table.read_row(i, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = cache.delta[i];
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.train_bytes
    }

    fn infer_bytes(&self) -> usize {
        self.infer_bytes
    }

    fn as_remote(&self) -> Option<&RemoteStore> {
        Some(self)
    }
}

impl Persistable for RemoteStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.row_bytes)
    }

    /// Reassemble rows `[lo, lo + count)` in canonical global order
    /// from whatever shards own them — this is what makes checkpoints
    /// layout-free (byte-identical to single-process, reloadable under
    /// any worker count).
    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        let rb = self.row_bytes;
        ensure!(dst.len() % rb == 0, "unaligned row payload");
        let count = dst.len() / rb;
        ensure!(lo + count <= self.n, "rows out of range");
        let chunk = frame_chunk_rows(self.max_frame, rb);
        let mut c_lo = lo;
        while c_lo < lo + count {
            let c_hi = (c_lo + chunk).min(lo + count);
            let ids: Vec<u32> = (c_lo..c_hi).map(|g| g as u32).collect();
            for (shard, (positions, globals)) in
                self.part.split(&ids).into_iter().enumerate()
            {
                if globals.is_empty() {
                    continue;
                }
                let req = GatherReq { aux_only: false, ids: globals };
                let resp =
                    self.call_shard(shard, Op::Gather, &req.encode())?;
                let resp = GatherResp::decode(&resp)?;
                ensure!(
                    resp.row_bytes as usize == rb
                        && resp.rows.len() == positions.len() * rb,
                    "shard {shard} returned a malformed checkpoint GATHER"
                );
                for (k, &pos) in positions.iter().enumerate() {
                    let g = c_lo + pos;
                    dst[(g - lo) * rb..(g - lo + 1) * rb]
                        .copy_from_slice(&resp.rows[k * rb..(k + 1) * rb]);
                }
            }
            c_lo = c_hi;
        }
        Ok(())
    }

    fn load_rows(&mut self, _lo: usize, _src: &[u8]) -> Result<()> {
        bail!(
            "a remote store cannot load checkpoint rows; resume into a \
             local store first, then attach workers"
        )
    }

    fn aux_params(&self) -> &[f32] {
        &self.aux_cache
    }

    fn load_aux_params(&mut self, _aux: &[f32]) -> Result<()> {
        bail!(
            "a remote store cannot load checkpoint aux params; resume \
             into a local store first, then attach workers"
        )
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }

    /// Quiesce every worker, then mirror the Δ table so the subsequent
    /// `aux_params` calls serve checkpoint-coherent values.
    fn prepare_save(&mut self) -> Result<()> {
        for shard in 0..self.part.n_shards() {
            self.call_shard(shard, Op::Barrier, &[BARRIER_QUIESCE])
                .with_context(|| {
                    format!("checkpoint quiesce: worker shard {shard}")
                })?;
        }
        if !self.is_alpt {
            return Ok(());
        }
        let mut aux = vec![0.0f32; self.n];
        // aux-only gathers are 4 bytes/row; chunk as if rows were f32s
        let chunk = frame_chunk_rows(self.max_frame, 4);
        let mut lo = 0usize;
        while lo < self.n {
            let hi = (lo + chunk).min(self.n);
            let ids: Vec<u32> = (lo..hi).map(|g| g as u32).collect();
            for (shard, (positions, globals)) in
                self.part.split(&ids).into_iter().enumerate()
            {
                if globals.is_empty() {
                    continue;
                }
                let req = GatherReq { aux_only: true, ids: globals };
                let resp =
                    self.call_shard(shard, Op::Gather, &req.encode())?;
                let resp = GatherResp::decode(&resp)?;
                ensure!(
                    resp.aux.len() == positions.len(),
                    "shard {shard} returned {} deltas for {} ids",
                    resp.aux.len(),
                    positions.len()
                );
                for (k, &pos) in positions.iter().enumerate() {
                    aux[lo + pos] = resp.aux[k];
                }
            }
            lo = hi;
        }
        self.aux_cache = aux;
        Ok(())
    }

    /// Journaled row writes would be one RPC per dirty row against a
    /// Δ mirror that is only coherent at quiesce points; continuous
    /// saves fall back to full snapshots instead.
    fn supports_delta_journal(&self) -> bool {
        false
    }
}

impl RowStats for RemoteStore {
    // access counts stay on the workers; re-planning (their one
    // consumer) is mutually exclusive with --workers
}
