//! Coordinator-side store whose rows live on worker processes.
//!
//! [`RemoteStore`] implements the [`EmbeddingStore`] trait split over
//! the `coordinator::net` RPC: `gather` fans GATHER requests out by
//! [`RowPartition`], dequantizes the returned packed rows locally
//! (quantized bytes cross the wire, not f32 — the paper's compression
//! is also the transport's), and `update` ships per-row f32 gradients
//! plus the `(draw, step)` pair that keys the stochastic-rounding
//! streams, so workers quantize bit-identically to a single process.
//!
//! # Overlap
//!
//! The hot path is both *parallel* and *pipelined*:
//!
//! - **Fan-out**: every multi-shard wave (gather, update, aux gather,
//!   barriers, checkpoint reads) runs one scoped thread per shard, so
//!   per-batch wall-clock is the max over shards, not the sum. The
//!   gather caches are locked only for the final copy-in; decode and
//!   the network wait happen outside.
//! - **Batch-ahead prefetch**: with overlap on (the default), `update`
//!   only *writes* its frames, and [`prefetch`](RemoteStore::prefetch)
//!   then writes the GATHER for the *next* batch on the same
//!   connections. Responses are collected just-in-time — one parallel
//!   recv wave at the next `gather` — into a second cache that is
//!   swapped in when the ids match.
//!
//! Overlap does not loosen the bit-identity contract: each worker's
//! serve loop is strictly serial and each connection is FIFO, so a
//! worker always applies update *k* before serving the prefetched
//! gather for batch *k+1*. Rows shared between consecutive batches are
//! therefore observed exactly as a fully synchronous schedule would
//! observe them, and N-worker checkpoints stay byte-identical to
//! single-process training. `--no-overlap` restores the synchronous
//! schedule for debugging; checkpoints are identical either way.
//!
//! Checkpointing is layout-free: `save_rows` reassembles rows in
//! canonical *global* order from whatever shards own them, so a
//! checkpoint written under N workers is byte-identical to the
//! single-process file and reloads under any M (resume on M workers,
//! or on one process, or straight into `alpt serve`). Nothing about
//! the worker layout is persisted.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::experiment_to_json;
use crate::config::{Experiment, Method};
use crate::coordinator::net::{
    read_frame, write_frame, GatherReq, GatherResp, LoadReq, Op, UpdateReq,
    WorkerHub, WorkerLink, BARRIER_ATTACHED, BARRIER_EPOCH, BARRIER_QUIESCE,
    FLAG_RESPONSE, PROTO_VERSION,
};
use crate::coordinator::sharding::RowPartition;
use crate::embedding::{
    EmbeddingStore, Persistable, RowStats, SecondPass, UpdateHp,
};
use crate::metrics::LatencyHistogram;
use crate::quant::{delta_from_clip, BitWidth, PackedTable};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Batch staging area: the packed rows + Δ of one gathered batch, kept
/// in wire form so `quantized_view` and ALPT's second pass read the
/// exact bytes the workers hold. The store keeps two — the current
/// batch and the prefetch target — and swaps them on a prefetch hit.
struct GatherCache {
    ids: Vec<u32>,
    cap: usize,
    table: PackedTable,
    delta: Vec<f32>,
}

impl GatherCache {
    fn empty(d: usize, bw: BitWidth) -> GatherCache {
        GatherCache {
            ids: Vec::new(),
            cap: 0,
            table: PackedTable::new(0, d, bw),
            delta: Vec::new(),
        }
    }

    /// Grow the staging table to hold `n` rows (never shrinks).
    fn ensure_cap(&mut self, n: usize, d: usize, bw: BitWidth) {
        if n > self.cap {
            self.cap = n.next_power_of_two();
            self.table = PackedTable::new(self.cap, d, bw);
        }
        self.delta.resize(self.cap, 0.0);
    }
}

/// A batch-ahead GATHER in flight: ids were sent to the shards right
/// after the previous batch's UPDATE frames; responses are still on
/// the wire and will be drained into the `next` cache by `settle`.
struct Prefetch {
    ids: Vec<u32>,
    /// Per-shard `(batch positions, global ids)` from `part.split`,
    /// computed once at send time.
    splits: Vec<(Vec<usize>, Vec<u32>)>,
}

/// An embedding table sharded across worker processes (see module
/// docs). Built by [`RemoteStore::attach`], which consumes the local
/// store's rows and streams them to registered workers.
pub struct RemoteStore {
    method_name: &'static str,
    is_alpt: bool,
    n: usize,
    d: usize,
    row_bytes: usize,
    bw: BitWidth,
    /// LPT's fixed shared step size (unused for ALPT).
    lpt_delta: f32,
    train_bytes: usize,
    infer_bytes: usize,
    /// Mirror of the workers' update-step counter: advanced once per
    /// `update` exactly like the local stores, persisted in the
    /// checkpoint meta so resumes continue the same SR streams.
    step: u64,
    part: RowPartition,
    links: Vec<Mutex<WorkerLink>>,
    max_frame: u64,
    /// The current batch's staged rows.
    cache: Mutex<GatherCache>,
    /// The prefetch target; swapped into `cache` on a prefetch hit.
    next: Mutex<GatherCache>,
    /// The batch-ahead GATHER awaiting collection, if any.
    prefetch: Mutex<Option<Prefetch>>,
    /// Batch-ahead pipelining on/off (`--no-overlap` clears it).
    overlap: AtomicBool,
    /// Parallel shard fan-out on/off (benches toggle it to measure the
    /// serial baseline; always on in training).
    fan_out_on: AtomicBool,
    /// Any frames written without their responses collected yet.
    has_inflight: AtomicBool,
    /// Per-shard wall-clock of every response-bearing RPC wave.
    rpc_lat: Vec<LatencyHistogram>,
    /// Δ table mirror for `aux_params`'s borrowed-slice contract;
    /// refreshed at every `prepare_save` quiesce. Empty for LPT.
    aux_cache: Vec<f32>,
    shut: AtomicBool,
}

/// Encode one GATHER payload per shard (`None` where the shard owns
/// none of the batch), outside any lock.
fn gather_payloads(
    splits: &[(Vec<usize>, Vec<u32>)],
    aux_only: bool,
) -> Vec<Option<Vec<u8>>> {
    splits
        .iter()
        .map(|(_, globals)| {
            if globals.is_empty() {
                None
            } else {
                let req =
                    GatherReq { aux_only, ids: globals.clone() };
                Some(req.encode())
            }
        })
        .collect()
}

impl RemoteStore {
    /// Accept `workers` registrations on `hub`, assign shard indices in
    /// arrival order, stream the local store's rows out, and return the
    /// remote handle that replaces it. The local store is left intact
    /// (the caller drops it).
    pub fn attach(
        local: &dyn EmbeddingStore,
        exp: &Experiment,
        hub: WorkerHub,
        workers: usize,
    ) -> Result<RemoteStore> {
        ensure!(workers >= 1, "--workers must be at least 1");
        let is_alpt = match exp.method {
            Method::Alpt(_) => true,
            Method::Lpt(_) => false,
            other => bail!(
                "distributed training shards packed tables; method {} \
                 has none (use lpt/alpt)",
                other.key()
            ),
        };
        ensure!(
            exp.bits.is_uniform(),
            "distributed training requires a uniform precision plan \
             (got --plan {:?}); mixed plans migrate rows between \
             groups, which the row partition does not model yet",
            exp.bits.key()
        );
        ensure!(
            exp.replan_budget == 0,
            "--replan-budget and --workers are mutually exclusive: \
             re-planning migrates rows between precision groups"
        );
        let bw = exp.bit_width()?;
        let row_bytes = local.ckpt_row_bytes().context(
            "distributed training requires a store with packed row \
             payloads",
        )?;
        let n = local.n_features();
        let d = local.dim();
        let part = RowPartition::new(n, workers);
        let cfg = *hub.cfg();
        let exp_json = experiment_to_json(exp);

        // registration: accept each worker, answer its HELLO with the
        // shard assignment (index = arrival order)
        let mut links = Vec::with_capacity(workers);
        for shard in 0..workers {
            let mut stream = hub.accept_worker().with_context(|| {
                format!(
                    "waiting for worker {}/{workers} to register",
                    shard + 1
                )
            })?;
            let (op, flags, seq, payload) =
                read_frame(&mut stream, cfg.max_frame)
                    .with_context(|| format!("worker {shard} HELLO"))?;
            ensure!(
                op == Op::Hello && flags & FLAG_RESPONSE == 0,
                "worker {shard} opened with {op:?} instead of HELLO"
            );
            let mut pos = 0;
            let proto =
                crate::checkpoint::format::take_u32(&payload, &mut pos)?;
            if proto != PROTO_VERSION {
                let msg = format!(
                    "protocol version mismatch: worker speaks v{proto}, \
                     coordinator v{PROTO_VERSION}"
                );
                write_frame(
                    &mut stream,
                    Op::Err,
                    FLAG_RESPONSE,
                    seq,
                    msg.as_bytes(),
                )
                .ok();
                bail!("{msg}");
            }
            let assignment = Json::obj(vec![
                ("shard", Json::num(shard as f64)),
                ("n_shards", Json::num(workers as f64)),
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("row_bytes", Json::num(row_bytes as f64)),
                ("step", Json::num(local.step_counter() as f64)),
                ("experiment", exp_json.clone()),
            ])
            .to_string();
            write_frame(
                &mut stream,
                Op::Hello,
                FLAG_RESPONSE,
                seq,
                assignment.as_bytes(),
            )?;
            links.push(Mutex::new(WorkerLink::from_stream(stream, &cfg)?));
        }

        // distribution: stream each shard's rows (+ Δ slice) in
        // frame-sized chunks of contiguous locals, then arm it
        let aux_all = local.aux_params();
        let chunk_rows = frame_chunk_rows(cfg.max_frame, row_bytes);
        let mut rowbuf = vec![0u8; chunk_rows * row_bytes];
        for (shard, link) in links.iter_mut().enumerate() {
            let link = link.get_mut().unwrap();
            let shard_n = part.shard_rows(shard);
            let mut lo = 0usize;
            while lo < shard_n {
                let hi = (lo + chunk_rows).min(shard_n);
                let count = hi - lo;
                let mut aux = Vec::with_capacity(if aux_all.is_empty() {
                    0
                } else {
                    count
                });
                for k in 0..count {
                    let g = part.global_of(shard, (lo + k) as u32) as usize;
                    local.save_rows(
                        g,
                        &mut rowbuf[k * row_bytes..(k + 1) * row_bytes],
                    )?;
                    if !aux_all.is_empty() {
                        aux.push(aux_all[g]);
                    }
                }
                let req = LoadReq {
                    start_local: lo as u32,
                    row_bytes: row_bytes as u32,
                    rows: rowbuf[..count * row_bytes].to_vec(),
                    aux,
                };
                link.call(Op::Load, &req.encode()).with_context(|| {
                    format!("loading rows onto worker shard {shard}")
                })?;
                lo = hi;
            }
            link.call(Op::Barrier, &[BARRIER_ATTACHED]).with_context(
                || format!("arming worker shard {shard}"),
            )?;
        }

        Ok(RemoteStore {
            method_name: local.method_name(),
            is_alpt,
            n,
            d,
            row_bytes,
            bw,
            lpt_delta: delta_from_clip(exp.clip, bw),
            train_bytes: local.train_bytes(),
            infer_bytes: local.infer_bytes(),
            step: local.step_counter(),
            part,
            rpc_lat: (0..links.len())
                .map(|_| LatencyHistogram::new())
                .collect(),
            links,
            max_frame: cfg.max_frame,
            cache: Mutex::new(GatherCache::empty(d, bw)),
            next: Mutex::new(GatherCache::empty(d, bw)),
            prefetch: Mutex::new(None),
            overlap: AtomicBool::new(true),
            fan_out_on: AtomicBool::new(true),
            has_inflight: AtomicBool::new(false),
            aux_cache: aux_all.to_vec(),
            shut: AtomicBool::new(false),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.part.n_shards()
    }

    /// Enable/disable batch-ahead pipelining (`--no-overlap` clears
    /// it). With overlap off, `update` waits for every shard's ack and
    /// `prefetch` is a no-op — the fully synchronous schedule.
    pub fn set_overlap(&self, on: bool) {
        self.overlap.store(on, Ordering::SeqCst);
    }

    pub fn overlap_enabled(&self) -> bool {
        self.overlap.load(Ordering::SeqCst)
    }

    /// Enable/disable parallel shard fan-out (benches toggle it off to
    /// measure the serial per-shard baseline).
    pub fn set_fan_out(&self, on: bool) {
        self.fan_out_on.store(on, Ordering::SeqCst);
    }

    /// Per-shard wall-clock histograms of every response-bearing RPC
    /// wave since attach (gathers, update acks/drains, barriers,
    /// checkpoint reads). Indexed by shard.
    pub fn rpc_latency(&self) -> &[LatencyHistogram] {
        &self.rpc_lat
    }

    /// Run `f` once per shard against that shard's link. With more
    /// than one shard (and fan-out enabled) the shards run on scoped
    /// threads, so the wave costs the slowest shard, not the sum.
    /// Results come back in shard order; the first error wins and is
    /// annotated with the shard index. `record` adds each shard's
    /// wall-clock to its latency histogram (off for send-only waves,
    /// which complete in microseconds and would drown the signal).
    fn fan_out<R, F>(&self, record: bool, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize, &mut WorkerLink) -> Result<R> + Sync,
    {
        let run_one = |shard: usize| -> Result<R> {
            let start = Instant::now();
            let mut link = self.links[shard].lock().unwrap();
            let out = f(shard, &mut link)
                .with_context(|| format!("worker shard {shard}"));
            if record {
                self.rpc_lat[shard]
                    .record_ms(start.elapsed().as_secs_f64() * 1e3);
            }
            out
        };
        let n = self.links.len();
        if n == 1 || !self.fan_out_on.load(Ordering::Relaxed) {
            return (0..n).map(run_one).collect();
        }
        std::thread::scope(|scope| {
            let run_one = &run_one;
            let handles: Vec<_> = (0..n)
                .map(|shard| scope.spawn(move || run_one(shard)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// Copy one shard's GATHER reply into a staging cache at the
    /// batch positions the shard owns. The caller holds the cache lock
    /// only for this copy-in; decode happened outside it.
    fn store_shard_rows(
        &self,
        cache: &mut GatherCache,
        shard: usize,
        positions: &[usize],
        resp: &GatherResp,
    ) -> Result<()> {
        let rb = self.row_bytes;
        ensure!(
            resp.row_bytes as usize == rb
                && resp.rows.len() == positions.len() * rb,
            "shard {shard} GATHER returned {} bytes of {}-byte rows \
             for {} ids",
            resp.rows.len(),
            resp.row_bytes,
            positions.len()
        );
        if self.is_alpt {
            ensure!(
                resp.aux.len() == positions.len(),
                "shard {shard} GATHER returned {} deltas for {} ids",
                resp.aux.len(),
                positions.len()
            );
        }
        for (k, &pos) in positions.iter().enumerate() {
            cache
                .table
                .load_raw_rows(pos, &resp.rows[k * rb..(k + 1) * rb])?;
            cache.delta[pos] = if self.is_alpt {
                resp.aux[k]
            } else {
                self.lpt_delta
            };
        }
        Ok(())
    }

    /// Drain every outstanding response: pipelined UPDATE acks are
    /// checked and discarded, the batch-ahead GATHER replies land in
    /// the `next` cache. One parallel recv wave per call; a no-op when
    /// nothing is in flight. Every response-bearing RPC goes through
    /// here first, so a synchronous caller can never steal a frame
    /// that belongs to the pipeline.
    fn settle(&self) -> Result<()> {
        let pf = self.prefetch.lock().unwrap().take();
        if !self.has_inflight.swap(false, Ordering::SeqCst) {
            return Ok(());
        }
        if let Some(pf) = &pf {
            let mut next = self.next.lock().unwrap();
            next.ensure_cap(pf.ids.len(), self.d, self.bw);
            next.ids.clear();
        }
        let pf_ref = &pf;
        self.fan_out(true, |shard, link| {
            while link.in_flight() > 0 {
                let op = link.next_pending_op().unwrap();
                let payload = link.recv_response()?;
                if op != Op::Gather {
                    continue; // an UPDATE ack: validated, nothing to keep
                }
                let pf = pf_ref.as_ref().with_context(|| {
                    format!(
                        "shard {shard} sent a GATHER reply with no \
                         prefetch outstanding"
                    )
                })?;
                let resp = GatherResp::decode(&payload)?;
                let mut next = self.next.lock().unwrap();
                self.store_shard_rows(
                    &mut next,
                    shard,
                    &pf.splits[shard].0,
                    &resp,
                )?;
            }
            Ok(())
        })?;
        if let Some(pf) = pf {
            let mut next = self.next.lock().unwrap();
            next.ids = pf.ids;
        }
        Ok(())
    }

    /// Issue the GATHER for the *next* batch without waiting for the
    /// replies. Called by the trainer right after `update` wrote batch
    /// k's frames, so on every connection the worker sees UPDATE(k)
    /// before GATHER(k+1) — FIFO order is the determinism argument.
    /// No-op with overlap off. Infallible like `gather`, and for the
    /// same reason: a dead worker means training cannot continue.
    pub fn prefetch(&self, ids: &[u32]) {
        if ids.is_empty() || !self.overlap.load(Ordering::Relaxed) {
            return;
        }
        if let Err(e) = self.send_prefetch(ids) {
            panic!("distributed prefetch failed: {e:#}");
        }
    }

    fn send_prefetch(&self, ids: &[u32]) -> Result<()> {
        let mut pf = self.prefetch.lock().unwrap();
        if pf.is_some() {
            // one batch-ahead window only; keep the earlier prefetch
            return Ok(());
        }
        let splits = self.part.split(ids);
        let payloads = gather_payloads(&splits, false);
        self.fan_out(false, |shard, link| {
            if let Some(p) = &payloads[shard] {
                link.send_request(Op::Gather, p)?;
            }
            Ok(())
        })?;
        *pf = Some(Prefetch { ids: ids.to_vec(), splits });
        self.has_inflight.store(true, Ordering::SeqCst);
        Ok(())
    }

    /// Fetch packed rows + Δ for `ids` into the cache (the fallible
    /// core of `gather`): drain the pipeline, then either swap in the
    /// prefetched batch (the hot path) or fan a synchronous GATHER
    /// out to all shards.
    fn fetch_batch(&self, ids: &[u32]) -> Result<()> {
        self.settle()?;
        {
            let mut next = self.next.lock().unwrap();
            if next.ids == ids {
                let mut cache = self.cache.lock().unwrap();
                std::mem::swap(&mut *cache, &mut *next);
                next.ids.clear();
                return Ok(());
            }
        }
        let splits = self.part.split(ids);
        let payloads = gather_payloads(&splits, false);
        {
            let mut cache = self.cache.lock().unwrap();
            cache.ensure_cap(ids.len(), self.d, self.bw);
            cache.ids.clear();
        }
        self.fan_out(true, |shard, link| {
            let Some(p) = &payloads[shard] else { return Ok(()) };
            let resp = GatherResp::decode(&link.call(Op::Gather, p)?)?;
            let mut cache = self.cache.lock().unwrap();
            self.store_shard_rows(
                &mut cache,
                shard,
                &splits[shard].0,
                &resp,
            )
        })?;
        let mut cache = self.cache.lock().unwrap();
        cache.ids.clear();
        cache.ids.extend_from_slice(ids);
        Ok(())
    }

    /// Per-id Δ for the batch, from the cache when it matches (the
    /// trainer always gathers first) or a fresh fanned-out aux round
    /// trip.
    fn deltas_for(&self, ids: &[u32]) -> Result<Vec<f32>> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.ids == ids {
                return Ok(cache.delta[..ids.len()].to_vec());
            }
        }
        let mut out = vec![0.0f32; ids.len()];
        if !self.is_alpt {
            out.fill(self.lpt_delta);
            return Ok(out);
        }
        self.settle()?;
        let splits = self.part.split(ids);
        let payloads = gather_payloads(&splits, true);
        let shard_aux = self.fan_out(true, |shard, link| {
            let Some(p) = &payloads[shard] else {
                return Ok(Vec::new());
            };
            let resp = GatherResp::decode(&link.call(Op::Gather, p)?)?;
            ensure!(
                resp.aux.len() == splits[shard].0.len(),
                "shard {shard} aux GATHER returned {} deltas for {} ids",
                resp.aux.len(),
                splits[shard].0.len()
            );
            Ok(resp.aux)
        })?;
        for (shard, aux) in shard_aux.into_iter().enumerate() {
            for (k, &pos) in splits[shard].0.iter().enumerate() {
                out[pos] = aux[k];
            }
        }
        Ok(out)
    }

    /// Epoch barrier: every worker acks, proving it is alive and has
    /// applied all updates sent so far.
    pub fn barrier(&self) -> Result<()> {
        self.settle()?;
        self.fan_out(true, |_, link| {
            link.call(Op::Barrier, &[BARRIER_EPOCH]).map(|_| ())
        })
        .context("epoch barrier")?;
        Ok(())
    }

    /// Clean shutdown: every worker acks SHUTDOWN and exits 0.
    /// Idempotent; also attempted (best-effort) on drop.
    pub fn shutdown(&self) -> Result<()> {
        if self.shut.swap(true, Ordering::SeqCst) {
            return Ok(());
        }
        self.settle()?;
        self.fan_out(true, |_, link| {
            link.call(Op::Shutdown, &[]).map(|_| ())
        })?;
        Ok(())
    }
}

impl Drop for RemoteStore {
    fn drop(&mut self) {
        if !self.shut.swap(true, Ordering::SeqCst) {
            self.settle().ok();
            for link in &self.links {
                if let Ok(mut link) = link.lock() {
                    link.call(Op::Shutdown, &[]).ok();
                }
            }
        }
    }
}

/// Rows per frame so one chunk stays well under the frame cap.
fn frame_chunk_rows(max_frame: u64, row_bytes: usize) -> usize {
    ((max_frame as usize / 2) / row_bytes.max(1)).clamp(1, 1 << 16)
}

impl EmbeddingStore for RemoteStore {
    fn method_name(&self) -> &'static str {
        self.method_name
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Infallible by trait contract: a dead worker here means the
    /// training step cannot produce correct results, so fail the
    /// process loudly rather than return garbage. This is also where a
    /// worker lost *between* batches surfaces — the settle drain finds
    /// the broken connection before the swap.
    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        if let Err(e) = self.fetch_batch(ids) {
            panic!("distributed gather failed: {e:#}");
        }
        // wire bytes were staged contiguously by fetch_batch; decode
        // them with the batch-sequential SIMD dequantize
        let cache = self.cache.lock().unwrap();
        cache.table.dequant_rows(ids.len(), &cache.delta, out);
    }

    /// Feed the ids of the batch after next into the pipeline (see
    /// [`RemoteStore::prefetch`]).
    fn prefetch_ids(&self, ids: &[u32]) {
        self.prefetch(ids);
    }

    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let n_u = ids.len();
        debug_assert_eq!(emb_hat.len(), n_u * d);
        debug_assert_eq!(grads.len(), n_u * d);

        // ALPT's second pass needs w^{t+1} and Δ^t on the coordinator
        // (it runs the model); workers recompute w^{t+1} from the same
        // grads with the same f32 ops, so only grads cross the wire.
        let d_delta = if self.is_alpt && n_u > 0 {
            let lr = hp.lr_emb * hp.lr_scale;
            let wd = hp.wd_emb;
            let mut w_new = vec![0.0f32; n_u * d];
            for i in 0..n_u {
                let what = &emb_hat[i * d..(i + 1) * d];
                let g = &grads[i * d..(i + 1) * d];
                let out = &mut w_new[i * d..(i + 1) * d];
                for j in 0..d {
                    out[j] = what[j] - lr * (g[j] + wd * what[j]);
                }
            }
            let delta_t = self.deltas_for(ids)?;
            let bw_t = vec![self.bw; n_u];
            second_pass(&w_new, &delta_t, &bw_t)?
        } else {
            Vec::new()
        };

        // same per-update RNG protocol as the local stores: exactly one
        // draw, taken after the second pass
        let draw = rng.next_u64();
        let step = self.step;
        self.step = self.step.wrapping_add(1);
        let hp_arr =
            [hp.lr_emb, hp.wd_emb, hp.lr_delta, hp.wd_delta, hp.grad_scale,
             hp.lr_scale];
        // encode every shard's frame before touching any link
        let mut payloads: Vec<Option<Vec<u8>>> =
            Vec::with_capacity(self.part.n_shards());
        for (positions, globals) in self.part.split(ids) {
            if globals.is_empty() {
                payloads.push(None);
                continue;
            }
            let mut shard_grads = Vec::with_capacity(positions.len() * d);
            let mut shard_dd = Vec::with_capacity(if self.is_alpt {
                positions.len()
            } else {
                0
            });
            for &pos in &positions {
                shard_grads.extend_from_slice(&grads[pos * d..(pos + 1) * d]);
                if self.is_alpt {
                    shard_dd.push(d_delta[pos]);
                }
            }
            let req = UpdateReq {
                step,
                draw,
                hp: hp_arr,
                ids: globals,
                grads: shard_grads,
                d_delta: shard_dd,
            };
            payloads.push(Some(req.encode()));
        }
        if self.overlap.load(Ordering::Relaxed) {
            // pipelined: write the frames and move on; the acks ride
            // back with the prefetched GATHER replies at the next
            // settle. FIFO per connection keeps the worker's apply
            // order identical to the synchronous schedule.
            self.fan_out(false, |shard, link| {
                if let Some(p) = &payloads[shard] {
                    link.send_request(Op::Update, p)?;
                }
                Ok(())
            })
            .context("distributed update (pipelined send)")?;
            self.has_inflight.store(true, Ordering::SeqCst);
        } else {
            self.fan_out(true, |shard, link| {
                if let Some(p) = &payloads[shard] {
                    link.call(Op::Update, p)?;
                }
                Ok(())
            })
            .context("distributed update")?;
        }
        Ok(())
    }

    fn quantized_view(
        &self,
        ids: &[u32],
        codes: &mut [i32],
        delta: &mut [f32],
    ) -> bool {
        {
            let cache = self.cache.lock().unwrap();
            if cache.ids == ids {
                for i in 0..ids.len() {
                    cache
                        .table
                        .read_row(i, &mut codes[i * self.d..(i + 1) * self.d]);
                    delta[i] = cache.delta[i];
                }
                return true;
            }
        }
        // cold view (no preceding gather): fetch, then serve
        if let Err(e) = self.fetch_batch(ids) {
            panic!("distributed quantized_view failed: {e:#}");
        }
        let cache = self.cache.lock().unwrap();
        for i in 0..ids.len() {
            cache.table.read_row(i, &mut codes[i * self.d..(i + 1) * self.d]);
            delta[i] = cache.delta[i];
        }
        true
    }

    fn train_bytes(&self) -> usize {
        self.train_bytes
    }

    fn infer_bytes(&self) -> usize {
        self.infer_bytes
    }

    fn as_remote(&self) -> Option<&RemoteStore> {
        Some(self)
    }
}

impl Persistable for RemoteStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.row_bytes)
    }

    /// Reassemble rows `[lo, lo + count)` in canonical global order
    /// from whatever shards own them — this is what makes checkpoints
    /// layout-free (byte-identical to single-process, reloadable under
    /// any worker count). Each chunk is one parallel GATHER wave.
    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        let rb = self.row_bytes;
        ensure!(dst.len() % rb == 0, "unaligned row payload");
        let count = dst.len() / rb;
        ensure!(lo + count <= self.n, "rows out of range");
        self.settle()?;
        let chunk = frame_chunk_rows(self.max_frame, rb);
        let mut c_lo = lo;
        while c_lo < lo + count {
            let c_hi = (c_lo + chunk).min(lo + count);
            let ids: Vec<u32> = (c_lo..c_hi).map(|g| g as u32).collect();
            let splits = self.part.split(&ids);
            let payloads = gather_payloads(&splits, false);
            let shard_resps = self.fan_out(true, |shard, link| {
                let Some(p) = &payloads[shard] else { return Ok(None) };
                let resp =
                    GatherResp::decode(&link.call(Op::Gather, p)?)?;
                ensure!(
                    resp.row_bytes as usize == rb
                        && resp.rows.len() == splits[shard].0.len() * rb,
                    "shard {shard} returned a malformed checkpoint GATHER"
                );
                Ok(Some(resp))
            })?;
            for (shard, resp) in shard_resps.into_iter().enumerate() {
                let Some(resp) = resp else { continue };
                for (k, &pos) in splits[shard].0.iter().enumerate() {
                    let g = c_lo + pos;
                    dst[(g - lo) * rb..(g - lo + 1) * rb]
                        .copy_from_slice(&resp.rows[k * rb..(k + 1) * rb]);
                }
            }
            c_lo = c_hi;
        }
        Ok(())
    }

    fn load_rows(&mut self, _lo: usize, _src: &[u8]) -> Result<()> {
        bail!(
            "a remote store cannot load checkpoint rows; resume into a \
             local store first, then attach workers"
        )
    }

    fn aux_params(&self) -> &[f32] {
        &self.aux_cache
    }

    fn load_aux_params(&mut self, _aux: &[f32]) -> Result<()> {
        bail!(
            "a remote store cannot load checkpoint aux params; resume \
             into a local store first, then attach workers"
        )
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }

    /// Quiesce every worker, then mirror the Δ table so the subsequent
    /// `aux_params` calls serve checkpoint-coherent values. Both the
    /// quiesce and the aux sweep are parallel waves.
    fn prepare_save(&mut self) -> Result<()> {
        self.settle()?;
        self.fan_out(true, |_, link| {
            link.call(Op::Barrier, &[BARRIER_QUIESCE]).map(|_| ())
        })
        .context("checkpoint quiesce")?;
        if !self.is_alpt {
            return Ok(());
        }
        let mut aux = vec![0.0f32; self.n];
        // aux-only gathers are 4 bytes/row; chunk as if rows were f32s
        let chunk = frame_chunk_rows(self.max_frame, 4);
        let mut lo = 0usize;
        while lo < self.n {
            let hi = (lo + chunk).min(self.n);
            let ids: Vec<u32> = (lo..hi).map(|g| g as u32).collect();
            let splits = self.part.split(&ids);
            let payloads = gather_payloads(&splits, true);
            let shard_aux = self.fan_out(true, |shard, link| {
                let Some(p) = &payloads[shard] else {
                    return Ok(Vec::new());
                };
                let resp =
                    GatherResp::decode(&link.call(Op::Gather, p)?)?;
                ensure!(
                    resp.aux.len() == splits[shard].0.len(),
                    "shard {shard} returned {} deltas for {} ids",
                    resp.aux.len(),
                    splits[shard].0.len()
                );
                Ok(resp.aux)
            })?;
            for (shard, sa) in shard_aux.into_iter().enumerate() {
                for (k, &pos) in splits[shard].0.iter().enumerate() {
                    aux[lo + pos] = sa[k];
                }
            }
            lo = hi;
        }
        self.aux_cache = aux;
        Ok(())
    }

    /// Journaled row writes would be one RPC per dirty row against a
    /// Δ mirror that is only coherent at quiesce points; continuous
    /// saves fall back to full snapshots instead.
    fn supports_delta_journal(&self) -> bool {
        false
    }
}

impl RowStats for RemoteStore {
    // access counts stay on the workers; re-planning (their one
    // consumer) is mutually exclusive with --workers
}
