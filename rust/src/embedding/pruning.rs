//! Magnitude pruning with a retraining schedule (Deng et al. 2021,
//! DeepLight) — the paper's pruning baseline (appendix B.2).
//!
//! The sparsity ratio ramps as `R_x (1 − D^{k/U})` at optimizer step `k`
//! (paper: R_x = 0.5, D = 0.99, U = 3000). Every `recompute_every` steps
//! the global magnitude threshold is re-estimated and the mask refreshed —
//! pruned weights may grow back if their gradient resurrects them
//! (prune-and-retrain). Training memory stays full-precision (ratio 1× in
//! Table 1); inference ships only surviving weights (≈2× at R_x = 0.5).
//!
//! Persistence: the dense table is an ordinary per-row f32 payload
//! (`ckpt_row_bytes = d·4`, plain checkpoint format v1 when standalone);
//! the mask rides in `aux_params` as one f32 per element (1.0 = live,
//! 0.0 = pruned) so the aux length divides the row count evenly — the
//! invariant the delta journal's per-row aux capture relies on.

use super::{
    init_weights, EmbeddingStore, Persistable, RowStats, SecondPass,
    UpdateHp,
};
use crate::optim::sgd_update;
use crate::util::rng::Pcg32;
use anyhow::{ensure, Result};

pub struct PruningStore {
    n: usize,
    d: usize,
    table: Vec<f32>,
    /// 1.0 = live, 0.0 = pruned — f32 so it persists through the same
    /// aux channel as every other per-row scalar (see module docs).
    mask: Vec<f32>,
    target_sparsity: f32,
    damping: f32,
    ramp_steps: f32,
    step: u64,
    recompute_every: u64,
    current_sparsity: f32,
}

impl PruningStore {
    pub fn init(
        n: usize,
        d: usize,
        target_sparsity: f32,
        damping: f32,
        ramp_steps: f32,
        rng: &mut Pcg32,
    ) -> Self {
        Self {
            n,
            d,
            table: init_weights(n, d, rng),
            mask: vec![1.0; n * d],
            target_sparsity,
            damping,
            ramp_steps,
            step: 0,
            recompute_every: 100,
            current_sparsity: 0.0,
        }
    }

    /// Scheduled sparsity at step `k`: R_x (1 − D^{k/U}).
    pub fn scheduled_sparsity(&self, k: u64) -> f32 {
        self.target_sparsity
            * (1.0 - self.damping.powf(k as f32 / self.ramp_steps))
    }

    pub fn sparsity(&self) -> f32 {
        self.current_sparsity
    }

    fn refresh_mask(&mut self) {
        let want = self.scheduled_sparsity(self.step);
        if want <= 0.0 {
            return;
        }
        // global magnitude threshold via select_nth on |w|
        let k = ((self.table.len() as f32) * want) as usize;
        if k == 0 || k >= self.table.len() {
            return;
        }
        let mut mags: Vec<f32> =
            self.table.iter().map(|x| x.abs()).collect();
        let (_, nth, _) = mags.select_nth_unstable_by(k, |a, b| {
            a.partial_cmp(b).unwrap()
        });
        let threshold = *nth;
        let mut pruned = 0usize;
        for (m, w) in self.mask.iter_mut().zip(self.table.iter_mut()) {
            let live = w.abs() > threshold;
            *m = if live { 1.0 } else { 0.0 };
            if !live {
                *w = 0.0;
                pruned += 1;
            }
        }
        self.current_sparsity = pruned as f32 / self.table.len() as f32;
    }
}

impl EmbeddingStore for PruningStore {
    fn method_name(&self) -> &'static str {
        "Pruning"
    }

    fn n_features(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn gather(&self, ids: &[u32], out: &mut [f32]) {
        let d = self.d;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            out[i * d..(i + 1) * d]
                .copy_from_slice(&self.table[id * d..(id + 1) * d]);
        }
    }

    fn update(
        &mut self,
        ids: &[u32],
        _emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        _rng: &mut Pcg32,
        _second_pass: &mut SecondPass,
    ) -> Result<()> {
        let d = self.d;
        let lr = hp.lr_emb * hp.lr_scale;
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let row = &mut self.table[id * d..(id + 1) * d];
            // gradients flow into pruned slots too (grow-back), per the
            // prune-and-retrain scheme
            sgd_update(row, &grads[i * d..(i + 1) * d], lr, hp.wd_emb);
        }
        Ok(())
    }

    fn end_step(&mut self) {
        self.step += 1;
        if self.step % self.recompute_every == 0 {
            self.refresh_mask();
        }
    }

    fn train_bytes(&self) -> usize {
        // full dense table + the mask's 1-bit information content (the
        // f32 in-memory representation is a persistence convenience, not
        // what Table 1 charges the method for)
        self.table.len() * 4 + self.mask.len() / 8
    }

    fn infer_bytes(&self) -> usize {
        // surviving weights only (paper counts values, not index overhead)
        let nnz = self.mask.iter().filter(|&&m| m != 0.0).count();
        nnz * 4
    }
}

impl Persistable for PruningStore {
    fn ckpt_row_bytes(&self) -> Option<usize> {
        Some(self.d * 4)
    }

    fn save_rows(&self, lo: usize, dst: &mut [u8]) -> Result<()> {
        super::save_f32_rows(&self.table, self.n, self.d, lo, dst)
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        super::load_f32_rows(&mut self.table, self.n, self.d, lo, src)
    }

    fn aux_params(&self) -> &[f32] {
        &self.mask
    }

    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        ensure!(
            aux.len() == self.mask.len(),
            "pruning mask length mismatch: checkpoint has {}, table \
             ({} rows x {} dims) expects {}",
            aux.len(),
            self.n,
            self.d,
            self.mask.len()
        );
        ensure!(
            aux.iter().all(|&m| m == 0.0 || m == 1.0),
            "pruning mask holds values other than 0.0/1.0"
        );
        self.mask.copy_from_slice(aux);
        let pruned = self.mask.iter().filter(|&&m| m == 0.0).count();
        self.current_sparsity = pruned as f32 / self.mask.len() as f32;
        Ok(())
    }

    fn step_counter(&self) -> u64 {
        self.step
    }

    fn set_step_counter(&mut self, step: u64) {
        self.step = step;
    }
}

impl RowStats for PruningStore {}

#[cfg(test)]
mod tests {
    use super::super::testutil::{hp, no_second_pass};
    use super::*;

    #[test]
    fn schedule_ramps_to_target() {
        let mut rng = Pcg32::seeded(1);
        let store = PruningStore::init(100, 8, 0.5, 0.99, 3000.0, &mut rng);
        assert_eq!(store.scheduled_sparsity(0), 0.0);
        let mid = store.scheduled_sparsity(3000);
        assert!(mid > 0.0 && mid < 0.5);
        let late = store.scheduled_sparsity(2_000_000);
        assert!((late - 0.5).abs() < 1e-3, "late={late}");
        assert!(store.scheduled_sparsity(1000) < store.scheduled_sparsity(5000));
    }

    #[test]
    fn mask_prunes_small_weights() {
        let mut rng = Pcg32::seeded(2);
        let mut store =
            PruningStore::init(200, 8, 0.5, 0.99, 100.0, &mut rng);
        // run enough steps for the schedule + refresh to bite
        for _ in 0..12_000 {
            store.end_step();
        }
        let s = store.sparsity();
        assert!(s > 0.3, "sparsity={s}");
        // pruned fraction of weights are exactly zero
        let zeros =
            store.table.iter().filter(|&&w| w == 0.0).count() as f32;
        assert!((zeros / store.table.len() as f32 - s).abs() < 1e-6);
        // inference shrinks accordingly
        assert!(store.infer_bytes() < store.n * store.d * 4 * 7 / 10);
    }

    #[test]
    fn pruned_weights_can_grow_back() {
        let mut rng = Pcg32::seeded(3);
        let mut store =
            PruningStore::init(50, 4, 0.5, 0.99, 50.0, &mut rng);
        for _ in 0..500 {
            store.end_step();
        }
        // find a pruned slot in row 0, hit it with a gradient
        let row0 = store.table[0..4].to_vec();
        let slot = (0..4).find(|&j| row0[j] == 0.0);
        if let Some(j) = slot {
            let mut g = vec![0.0f32; 4];
            g[j] = -1.0; // push the weight up
            let emb = row0.clone();
            store
                .update(&[0], &emb, &g, &hp(), &mut rng,
                        &mut no_second_pass())
                .unwrap();
            assert!(store.table[j] > 0.0, "weight did not grow back");
        }
    }

    #[test]
    fn rows_and_mask_roundtrip_through_persistable_hooks() {
        let mut rng = Pcg32::seeded(4);
        let mut store =
            PruningStore::init(60, 4, 0.5, 0.99, 50.0, &mut rng);
        for _ in 0..600 {
            store.end_step();
        }
        assert!(store.sparsity() > 0.0, "schedule never bit");
        let rb = store.ckpt_row_bytes().unwrap();
        let mut rows = vec![0u8; 60 * rb];
        store.save_rows(0, &mut rows).unwrap();
        let mask = store.aux_params().to_vec();

        let mut rng2 = Pcg32::seeded(77);
        let mut twin =
            PruningStore::init(60, 4, 0.5, 0.99, 50.0, &mut rng2);
        twin.load_rows(0, &rows).unwrap();
        twin.load_aux_params(&mask).unwrap();
        twin.set_step_counter(store.step_counter());
        assert_eq!(twin.table, store.table);
        assert_eq!(twin.mask, store.mask);
        assert_eq!(twin.sparsity(), store.sparsity());
        assert_eq!(twin.step_counter(), 600);
        // a mask carrying non-binary values is rejected
        let mut bad = mask.clone();
        bad[0] = 0.5;
        assert!(twin.load_aux_params(&bad).is_err());
    }
}
