//! Embedding table stores — one per Table-1 method.
//!
//! | store | storage at train | forward sees | step-size |
//! |---|---|---|---|
//! | [`FpStore`] | f32 | exact weights | – |
//! | [`LptStore`] | packed ints + fixed Δ | dequantized | fixed (clip/2^{m-1}) |
//! | [`AlptStore`] | packed ints + learned Δ | dequantized | learned per feature (Alg. 1) |
//! | [`LsqStore`] | f32 master + learned Δ | fake-quantized | learned (Eq. 6–7) |
//! | [`PactStore`] | f32 master + learned α | fake-quantized | α/2^{m-1}, PACT estimator |
//! | [`HashingStore`] | two f32 tables | composed product | – |
//! | [`PruningStore`] | f32 + mask | masked weights | – |
//!
//! The trainer drives every store through the same protocol: `gather`
//! unique rows for the batch, execute the model (PJRT or the Rust nn
//! path), then `update` with the returned gradients. ALPT's second
//! forward/backward (Algorithm 1 step 2) is injected as the
//! `second_pass` callback so the store stays runtime-agnostic.

pub mod alpt;
pub mod fp;
pub mod grouped;
pub mod hashing;
pub mod lpt;
pub mod pruning;
pub mod qat;
pub mod remote;

pub use alpt::AlptStore;
pub use fp::FpStore;
pub use grouped::GroupedStore;
pub use hashing::HashingStore;
pub use lpt::LptStore;
pub use pruning::PruningStore;
pub use qat::{LsqStore, PactStore};
pub use remote::RemoteStore;

use crate::config::{Experiment, Method, RoundingMode};
use crate::quant::{BitWidth, Rounding};
use crate::util::rng::Pcg32;
use anyhow::{bail, ensure, Result};

/// Per-step hyperparameters handed to `update` (LR schedule applied by the
/// trainer via `lr_scale`).
#[derive(Clone, Copy, Debug)]
pub struct UpdateHp {
    pub lr_emb: f32,
    pub wd_emb: f32,
    pub lr_delta: f32,
    pub wd_delta: f32,
    /// Paper §3.2 gradient scale g (already evaluated).
    pub grad_scale: f32,
    /// Epoch LR decay multiplier.
    pub lr_scale: f32,
}

/// Second-pass callback:
/// `(w_new [U*d], delta [U], bit widths [U]) -> d_delta [U]`.
/// Implemented by the trainer as one execution of the `train_fq` artifact
/// (or the Rust fallback); only ALPT invokes it. The per-row bit widths
/// carry each row's quantization bounds — uniform stores pass one width
/// repeated, grouped mixed-precision stores each row's group width.
pub type SecondPass<'a> =
    dyn FnMut(&[f32], &[f32], &[BitWidth]) -> Result<Vec<f32>> + 'a;

/// Persistence capability: how a store's state maps onto checkpoint
/// sections. Split out of [`EmbeddingStore`] so the checkpoint subsystem
/// depends only on what it actually needs, and so each store's
/// persistence story is explicit: packed/float tables persist raw row
/// payloads (`ckpt_row_bytes` is `Some`), parameter-shared stores like
/// hashing persist everything through `aux_params` (`ckpt_row_bytes`
/// stays `None` — their parameters do not decompose into per-feature
/// rows), and per-row scalars (Δ, α, masks) ride in `aux_params` either
/// way.
///
/// Contract: `save_rows` → `load_rows` is bit-identical on the raw
/// payload — packed stores hand over their packed bytes verbatim (never
/// dequantize/requantize), float-backed stores their f32 bits.
pub trait Persistable {
    /// Bytes of one row's raw checkpoint payload, or `None` when this
    /// store has no per-row payload (its state is all in `aux_params`).
    fn ckpt_row_bytes(&self) -> Option<usize> {
        None
    }

    /// Serialize rows `[lo, lo + dst.len()/ckpt_row_bytes())` into `dst`.
    fn save_rows(&self, _lo: usize, _dst: &mut [u8]) -> Result<()> {
        bail!("this store has no per-row checkpoint payload")
    }

    /// Restore rows from bytes produced by `save_rows` (exact inverse).
    fn load_rows(&mut self, _lo: usize, _src: &[u8]) -> Result<()> {
        bail!("this store has no per-row checkpoint payload")
    }

    /// Learned scalars to persist alongside the rows (Δ for ALPT/LSQ, α
    /// for PACT, the mask for pruning, the whole shared parameter block
    /// for hashing); empty for stores without any.
    fn aux_params(&self) -> &[f32] {
        &[]
    }

    /// Restore the scalars `aux_params` returned at save time.
    fn load_aux_params(&mut self, aux: &[f32]) -> Result<()> {
        ensure!(
            aux.is_empty(),
            "this store holds no aux params, checkpoint has {}",
            aux.len()
        );
        Ok(())
    }

    /// Update-step counter feeding the per-step SR stream key (0 for
    /// stores that draw no per-step noise). Persisted so a resumed run
    /// continues the exact noise stream an uninterrupted one would use.
    fn step_counter(&self) -> u64 {
        0
    }

    /// Restore the update-step counter captured by `step_counter`.
    fn set_step_counter(&mut self, _step: u64) {}

    /// Called once before a checkpoint's sections are serialized. Local
    /// stores hold all their state in memory and need nothing; the
    /// distributed [`RemoteStore`] uses this to quiesce its workers and
    /// mirror the per-row Δ table so `aux_params` can serve the
    /// borrowed-slice contract.
    fn prepare_save(&mut self) -> Result<()> {
        Ok(())
    }

    /// Whether per-row delta journaling (`--save-every` incremental
    /// checkpoints) can address this store's rows directly. The remote
    /// store opts out — each journaled row would be a round trip, and
    /// its aux mirror is only coherent at quiesce points — so continuous
    /// saves fall back to full (still atomic) snapshots.
    fn supports_delta_journal(&self) -> bool {
        true
    }
}

/// Per-row access statistics: how often each row was touched by `update`
/// since the last reset. Feeds the budgeted precision planner
/// (`analysis::plan_for_budget`) and end-of-epoch re-planning. Counts are
/// in-memory only — never checkpointed — and reset at every epoch
/// boundary, so boundary saves resume bit-identically whether or not
/// counting is on.
pub trait RowStats {
    /// Per-row update counts indexed by global row id, or `None` when
    /// this store does not track them.
    fn access_counts(&self) -> Option<&[u32]> {
        None
    }

    /// Zero the counters (epoch boundary).
    fn reset_access_counts(&mut self) {}
}

/// Common interface over all embedding-table variants. `Send + Sync` so
/// sharded workers can gather from their partitions in parallel.
///
/// The gather/update core lives here; persistence is the [`Persistable`]
/// supertrait and access-frequency tracking the [`RowStats`] supertrait,
/// so subsystems can depend on exactly the capability they use (and a
/// store's lack of one is a type-level fact, not a runtime surprise).
pub trait EmbeddingStore: Persistable + RowStats + Send + Sync {
    fn method_name(&self) -> &'static str;
    fn n_features(&self) -> usize;
    fn dim(&self) -> usize;

    /// Write the (de-quantized / composed / fake-quantized) rows for
    /// `ids` into `out` (`ids.len() * dim` floats) — what the model's
    /// forward pass consumes.
    fn gather(&self, ids: &[u32], out: &mut [f32]);

    /// Apply one step of gradients `grads` (w.r.t. the gathered rows
    /// `emb_hat`) for `ids`.
    fn update(
        &mut self,
        ids: &[u32],
        emb_hat: &[f32],
        grads: &[f32],
        hp: &UpdateHp,
        rng: &mut Pcg32,
        second_pass: &mut SecondPass,
    ) -> Result<()>;

    /// Integer codes + per-row Δ for `ids` if this store trains in
    /// quantized form (drives the `train_lpt`/`eval_lpt` artifacts).
    /// Returns false when the store is float-backed.
    fn quantized_view(
        &self,
        _ids: &[u32],
        _codes: &mut [i32],
        _delta: &mut [f32],
    ) -> bool {
        false
    }

    /// Bytes of embedding-related state held during training
    /// (Table 1's training-compression column numerator).
    fn train_bytes(&self) -> usize;

    /// Bytes needed to ship the table for inference.
    fn infer_bytes(&self) -> usize;

    /// Hook for per-step housekeeping (pruning schedules).
    fn end_step(&mut self) {}

    /// Hint that `ids` will be the next batch's gather. Local stores
    /// ignore it; the distributed [`RemoteStore`] uses it to issue the
    /// batch-ahead GATHER right behind the current batch's UPDATE
    /// frames, overlapping the round trip with the coordinator's
    /// forward/backward work.
    fn prefetch_ids(&self, _ids: &[u32]) {}

    /// Downcast to the mixed-precision [`GroupedStore`], whose checkpoint
    /// layout (format v2) carries one section run per precision group.
    /// `None` for every single-table store.
    fn as_grouped(&self) -> Option<&GroupedStore> {
        None
    }

    /// Mutable counterpart of [`EmbeddingStore::as_grouped`].
    fn as_grouped_mut(&mut self) -> Option<&mut GroupedStore> {
        None
    }

    /// Downcast to the distributed [`RemoteStore`] (rows live on worker
    /// processes). The trainer uses this for epoch barriers and clean
    /// worker shutdown; `None` for every local store.
    fn as_remote(&self) -> Option<&RemoteStore> {
        None
    }
}

/// Checkpoint row payloads for float-backed tables (`FpStore` / QAT
/// masters): one implementation shared by every store so the encodings
/// cannot drift apart.
pub(crate) fn save_f32_rows(
    table: &[f32],
    n: usize,
    d: usize,
    lo: usize,
    dst: &mut [u8],
) -> Result<()> {
    ensure!(dst.len() % (d * 4) == 0, "unaligned row payload");
    let count = dst.len() / (d * 4);
    ensure!(lo + count <= n, "rows out of range");
    rows_to_le_bytes(&table[lo * d..(lo + count) * d], dst)
}

/// Exact inverse of [`save_f32_rows`].
pub(crate) fn load_f32_rows(
    table: &mut [f32],
    n: usize,
    d: usize,
    lo: usize,
    src: &[u8],
) -> Result<()> {
    ensure!(src.len() % (d * 4) == 0, "unaligned row payload");
    let count = src.len() / (d * 4);
    ensure!(lo + count <= n, "rows out of range");
    rows_from_le_bytes(src, &mut table[lo * d..(lo + count) * d])
}

/// Shared f32 ⇄ little-endian helpers for float-backed row payloads.
pub(crate) fn rows_to_le_bytes(src: &[f32], dst: &mut [u8]) -> Result<()> {
    ensure!(
        dst.len() == src.len() * 4,
        "payload buffer is {} bytes for {} f32s",
        dst.len(),
        src.len()
    );
    for (b4, &x) in dst.chunks_exact_mut(4).zip(src) {
        b4.copy_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

pub(crate) fn rows_from_le_bytes(src: &[u8], dst: &mut [f32]) -> Result<()> {
    ensure!(
        src.len() == dst.len() * 4,
        "payload is {} bytes for {} f32s",
        src.len(),
        dst.len()
    );
    for (o, b4) in dst.iter_mut().zip(src.chunks_exact(4)) {
        *o = f32::from_le_bytes(b4.try_into().unwrap());
    }
    Ok(())
}

/// Full-precision byte count for `n` rows of `d` — the compression-ratio
/// denominator.
pub fn fp_bytes(n: usize, d: usize) -> usize {
    n * d * std::mem::size_of::<f32>()
}

/// Below this many rows per worker, spawn overhead beats the row work, so
/// the sharded paths fall back to the serial loop (results are identical
/// either way — see the counter-RNG determinism contract in `util::rng`).
pub(crate) const MIN_ROWS_PER_THREAD: usize = 64;

/// Resolve a configured thread count: `0` = one worker per hardware
/// thread, anything else taken literally.
pub(crate) fn resolve_threads(configured: usize) -> usize {
    if configured == 0 {
        crate::util::threadpool::default_threads()
    } else {
        configured
    }
}

/// Sharded row-wise gather: split the `ids.len()` output rows into
/// row-aligned chunks and fill them from up to `threads` scoped threads.
/// `fill(batch_pos, id, out_row)` must be a pure function of its
/// arguments plus shared store state, so the result is bit-identical at
/// any thread count.
pub(crate) fn par_gather<F>(
    ids: &[u32],
    d: usize,
    out: &mut [f32],
    threads: usize,
    fill: F,
) where
    F: Fn(usize, u32, &mut [f32]) + Send + Sync,
{
    par_gather_chunks(ids, d, out, threads, |lo, chunk_ids, chunk| {
        for (k, (&id, row)) in
            chunk_ids.iter().zip(chunk.chunks_mut(d)).enumerate()
        {
            fill(lo + k, id, row);
        }
    });
}

/// Chunk-granular flavour of [`par_gather`]: each worker gets its whole
/// contiguous `(ids, rows)` chunk in one call, so stores can run the
/// batched SIMD+prefetch table gather across the chunk instead of a
/// per-row closure. `fill(lo, chunk_ids, chunk_rows)` must be a pure
/// function of its arguments plus shared store state; chunk boundaries
/// are row-aligned, so results stay bit-identical at any thread count.
pub(crate) fn par_gather_chunks<F>(
    ids: &[u32],
    d: usize,
    out: &mut [f32],
    threads: usize,
    fill: F,
) where
    F: Fn(usize, &[u32], &mut [f32]) + Send + Sync,
{
    debug_assert_eq!(out.len(), ids.len() * d);
    let n = ids.len();
    if n == 0 || d == 0 {
        return;
    }
    let max_useful = n.div_ceil(MIN_ROWS_PER_THREAD);
    let threads = threads.max(1).min(max_useful);
    if threads <= 1 {
        fill(0, ids, out);
        return;
    }
    let rows_per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * d).enumerate() {
            let lo = t * rows_per;
            let chunk_ids = &ids[lo..lo + chunk.len() / d];
            let fill = &fill;
            s.spawn(move || fill(lo, chunk_ids, chunk));
        }
    });
}

pub(crate) fn rounding_of(mode: RoundingMode) -> Rounding {
    match mode {
        RoundingMode::Sr => Rounding::Stochastic,
        RoundingMode::Dr => Rounding::Deterministic,
    }
}

/// Build the store an [`Experiment`] asks for.
///
/// Uniform precision plans take exactly the pre-plan construction path
/// (same calls, same generator consumption — byte-identical stores);
/// mixed plans resolve the per-field widths against the experiment's
/// dataset layout and build a [`GroupedStore`] with one packed sub-table
/// per width (plus hashed/pruned structural groups when the plan asks
/// for them). With `replan_budget` set, even uniform plans build through
/// the grouped path — a single-group grouped store is byte-identical to
/// the plain one (property-tested in `grouped.rs`), and end-of-epoch
/// re-planning needs the group machinery to migrate rows.
pub fn build_store(
    exp: &Experiment,
    n_features: usize,
    dim: usize,
    rng: &mut Pcg32,
) -> Result<Box<dyn EmbeddingStore>> {
    if let Some(budget) = exp.bits.auto_budget() {
        bail!(
            "--plan auto:{budget} is an analysis directive, not a store \
             layout: the trainer resolves it into concrete per-field \
             widths before building the table (alternatively, run `alpt \
             plan --budget {budget}` and pass the emitted plan string)"
        );
    }
    let replanning = exp.replan_budget > 0;
    if replanning && !exp.method.trains_quantized() {
        bail!(
            "--replan-budget {} selects online width re-planning, which \
             migrates rows between packed sub-tables — the {} store has \
             no packed rows to requantize; use a quantized-training \
             method (lpt/alpt) or drop --replan-budget",
            exp.replan_budget,
            exp.method.key(),
        );
    }
    if !exp.bits.is_uniform() || replanning {
        let schema = crate::data::registry::schema_for(exp)?;
        let kinds = crate::data::registry::field_kinds(exp)?;
        // from_plan validates the layout (incl. table size >= schema)
        return Ok(Box::new(GroupedStore::from_plan(
            exp, &schema, &kinds, n_features, dim, rng,
        )?));
    }
    let bw = exp.bit_width()?;
    Ok(match exp.method {
        Method::Fp => {
            let mut s = FpStore::init(n_features, dim, rng);
            s.set_threads(exp.threads);
            Box::new(s)
        }
        Method::Lpt(mode) => Box::new(LptStore::init_with_threads(
            n_features,
            dim,
            bw,
            exp.clip,
            rounding_of(mode),
            exp.threads,
            rng,
        )),
        Method::Alpt(mode) => Box::new(AlptStore::init_with_clip_threads(
            n_features,
            dim,
            bw,
            rounding_of(mode),
            exp.clip,
            exp.threads,
            rng,
        )),
        Method::Lsq => Box::new(LsqStore::init(n_features, dim, bw, rng)),
        Method::Pact => {
            Box::new(PactStore::init(n_features, dim, bw, exp.clip, rng))
        }
        Method::Hashing => {
            Box::new(HashingStore::init(n_features, dim, 2, rng))
        }
        Method::Pruning => Box::new(PruningStore::init(
            n_features,
            dim,
            0.5,   // R_x, paper appendix B.2
            0.99,  // D
            3000.0, // U
            rng,
        )),
    })
}

/// Shared initializer: embedding weights ~ N(0, 0.01) (the usual CTR
/// embedding init; keeps |w| within 8-bit range for reasonable Δ).
pub(crate) fn init_weights(n: usize, d: usize, rng: &mut Pcg32) -> Vec<f32> {
    (0..n * d).map(|_| rng.normal_scaled(0.0, 0.01)).collect()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// No-op second pass for stores that never call it.
    pub fn no_second_pass(
    ) -> impl FnMut(&[f32], &[f32], &[BitWidth]) -> Result<Vec<f32>> {
        |_: &[f32], _: &[f32], _: &[BitWidth]| -> Result<Vec<f32>> {
            panic!("second_pass unexpectedly invoked")
        }
    }

    /// Eq. 7 second pass with an all-ones upstream gradient, honouring
    /// each row's own width — the shared test stand-in for the
    /// `train_fq` artifact (uniform and grouped stores alike).
    pub fn eq7_second_pass(
    ) -> impl FnMut(&[f32], &[f32], &[BitWidth]) -> Result<Vec<f32>> {
        move |w_new: &[f32], delta: &[f32], bws: &[BitWidth]| {
            let d = w_new.len() / delta.len();
            let ups = vec![1.0f32; d];
            Ok(delta
                .iter()
                .enumerate()
                .map(|(i, &dl)| {
                    crate::quant::lsq_delta_grad_row(
                        &w_new[i * d..(i + 1) * d],
                        dl,
                        bws[i],
                        &ups,
                    )
                })
                .collect())
        }
    }

    /// Default hyperparameters for unit tests.
    pub fn hp() -> UpdateHp {
        UpdateHp {
            lr_emb: 0.1,
            wd_emb: 0.0,
            lr_delta: 1e-3,
            wd_delta: 0.0,
            grad_scale: 1.0,
            lr_scale: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_store_every_method() {
        let mut rng = Pcg32::seeded(1);
        for method in [
            Method::Fp,
            Method::Lpt(RoundingMode::Sr),
            Method::Lpt(RoundingMode::Dr),
            Method::Alpt(RoundingMode::Sr),
            Method::Alpt(RoundingMode::Dr),
            Method::Lsq,
            Method::Pact,
            Method::Hashing,
            Method::Pruning,
        ] {
            let exp = Experiment { method, ..Experiment::default() };
            let store = build_store(&exp, 100, 8, &mut rng).unwrap();
            assert_eq!(store.n_features(), 100, "{method:?}");
            assert_eq!(store.dim(), 8);
            assert!(store.train_bytes() > 0);
            assert!(store.infer_bytes() > 0);
        }
    }

    #[test]
    fn quantized_methods_compress_training_memory() {
        let mut rng = Pcg32::seeded(2);
        let (n, d) = (1000, 16);
        let fp = fp_bytes(n, d);
        let exp8 = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            bits: crate::config::PrecisionPlan::uniform(8),
            ..Experiment::default()
        };
        let store = build_store(&exp8, n, d, &mut rng).unwrap();
        // ints (n*d) + delta (4n) < fp (4nd): ratio 3.2x at d=16 like Table 1
        let ratio = fp as f64 / store.train_bytes() as f64;
        assert!(
            (ratio - 3.2).abs() < 0.05,
            "8-bit ALPT train ratio = {ratio}"
        );
        let exp2 = Experiment {
            bits: crate::config::PrecisionPlan::uniform(2),
            ..exp8.clone()
        };
        let store2 = build_store(&exp2, n, d, &mut rng).unwrap();
        assert!(store2.train_bytes() < store.train_bytes());
    }
}
