//! Batching and per-batch feature deduplication — for in-memory datasets
//! *and* record streams.
//!
//! The paper's memory story (§2.3) hinges on the observation that a batch
//! touches very few *unique* features relative to the table. Every
//! batcher here produces, per batch, exactly what the AOT artifacts
//! consume:
//!
//! * `unique`    — the batch's unique global feature ids (the only rows
//!                 that get dequantized / updated this step);
//! * `idx`       — `[B, F]` positions into `unique` (i32, scatter/gather
//!                 index matrix; JAX's gather VJP turns this into the
//!                 scatter-add on the backward pass);
//! * `labels`    — `[B]`;
//! * `valid`     — number of real (un-padded) samples; the final batch of
//!                 an epoch is padded by repeating the last record so the
//!                 shape-static HLO always sees a full batch.
//!
//! Two families share one assembly kernel ([`build_batch`]):
//!
//! * [`Batcher`] — the in-memory epoch iterator (full Fisher–Yates
//!   shuffle over sample indices);
//! * [`StreamBatcher`] over a [`RecordStream`] — the streaming pipeline:
//!   [`SplitStream`] (deterministic holdout) → [`ShuffleStream`] (seeded
//!   reservoir window) → batches, optionally assembled on a background
//!   thread by [`with_prefetch`]. Batch contents are a pure function of
//!   stream order, so the prefetched and serial paths are bit-identical.

use super::registry::RecordStream;
use super::Dataset;
use crate::util::rng::{mix64, Pcg32};
use anyhow::Result;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Single-u64 multiplicative hasher for the dedup map — feature ids are
/// already well-distributed, so SipHash's DoS resistance only costs time
/// on the per-step hot path (§Perf: ~3x faster make_batch).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.0 = crate::util::rng::mix64(v as u64);
    }
}

type IdMap = HashMap<u32, i32, BuildHasherDefault<IdHasher>>;

/// One training/eval batch in artifact-ready form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch {
    /// Unique global feature ids, in first-appearance order.
    pub unique: Vec<u32>,
    /// `[B, F]` row-major indices into `unique`.
    pub idx: Vec<i32>,
    /// `[B]` labels (padded tail repeats the last real record's label).
    pub labels: Vec<u8>,
    /// Real sample count (≤ B); the rest is padding.
    pub valid: usize,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.labels.len()
    }

    pub fn n_unique(&self) -> usize {
        self.unique.len()
    }
}

/// The shared assembly kernel behind both batcher families: dedup the
/// `n` real records reachable through the accessors into a `batch_size`
/// batch, padding by repeating the last record. Accessor-based so the
/// in-memory path reads `Dataset` rows in place (no per-step copies on
/// the training hot path) while the stream path reads its fill buffers.
fn dedup_batch<'a>(
    n: usize,
    batch_size: usize,
    n_fields: usize,
    row: impl Fn(usize) -> &'a [u32],
    label: impl Fn(usize) -> u8,
) -> Batch {
    assert!(n > 0 && n <= batch_size);
    let mut unique = Vec::with_capacity(n * n_fields / 4);
    let mut map: IdMap =
        IdMap::with_capacity_and_hasher(n * n_fields, Default::default());
    let mut idx = Vec::with_capacity(batch_size * n_fields);
    let mut labels = Vec::with_capacity(batch_size);

    for bi in 0..batch_size {
        let r = bi.min(n - 1); // pad by repeating the last record
        for &g in row(r) {
            let next_id = unique.len() as i32;
            let slot = *map.entry(g).or_insert_with(|| {
                unique.push(g);
                next_id
            });
            idx.push(slot);
        }
        labels.push(label(r));
    }
    Batch { unique, idx, labels, valid: n }
}

/// Assemble a batch from `labels.len()` records laid out row-major in
/// `features` (`[n, n_fields]` global ids), padding to `batch_size` by
/// repeating the last record (the stream batcher's entry point).
pub fn build_batch(
    features: &[u32],
    labels: &[u8],
    n_fields: usize,
    batch_size: usize,
) -> Batch {
    assert_eq!(features.len(), labels.len() * n_fields);
    dedup_batch(
        labels.len(),
        batch_size,
        n_fields,
        |r| &features[r * n_fields..(r + 1) * n_fields],
        |r| labels[r],
    )
}

/// Assemble a batch from dataset rows `rows` (padding to `batch_size`).
pub fn make_batch(ds: &Dataset, rows: &[usize], batch_size: usize) -> Batch {
    assert!(!rows.is_empty() && rows.len() <= batch_size);
    dedup_batch(
        rows.len(),
        batch_size,
        ds.n_fields(),
        |r| ds.sample(rows[r]),
        |r| ds.labels[rows[r]],
    )
}

/// Epoch iterator: shuffles sample order per epoch (seeded), yields
/// fixed-size batches, pads the final partial batch.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch_size: usize,
    order: Vec<usize>,
    cursor: usize,
    /// drop the final partial batch instead of padding (train-mode option)
    drop_last: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(
        ds: &'a Dataset,
        batch_size: usize,
        shuffle_seed: Option<u64>,
        drop_last: bool,
    ) -> Self {
        assert!(batch_size > 0);
        let mut order: Vec<usize> = (0..ds.n_samples()).collect();
        if let Some(seed) = shuffle_seed {
            let mut rng = Pcg32::new(seed, 0xBA7C);
            rng.shuffle(&mut order);
        }
        Self { ds, batch_size, order, cursor: 0, drop_last }
    }

    pub fn n_batches(&self) -> usize {
        if self.drop_last {
            self.order.len() / self.batch_size
        } else {
            self.order.len().div_ceil(self.batch_size)
        }
    }
}

impl<'a> Iterator for Batcher<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.batch_size).min(self.order.len());
        let rows = &self.order[self.cursor..end];
        if rows.len() < self.batch_size && self.drop_last {
            self.cursor = self.order.len();
            return None;
        }
        let batch = make_batch(self.ds, rows, self.batch_size);
        self.cursor = end;
        Some(batch)
    }
}

// --------------------------------------------------------------- streams

/// Deterministic holdout split over any record stream: record `i` (in
/// stream order) is held out iff `mix64(seed ^ i) % HOLDOUT_EVERY == 0`
/// (~10%). Membership depends only on `(seed, position)`, so it is
/// stable across epochs and identical between the train and val views —
/// no record ever changes sides.
pub const HOLDOUT_EVERY: u64 = 10;

/// Filters a stream down to its training or held-out records.
pub struct SplitStream<S> {
    inner: S,
    seed: u64,
    next_index: u64,
    take_val: bool,
}

impl<S: RecordStream> SplitStream<S> {
    /// The ~9/10 training side.
    pub fn train(inner: S, seed: u64) -> Self {
        Self { inner, seed, next_index: 0, take_val: false }
    }

    /// The ~1/10 held-out side.
    pub fn val(inner: S, seed: u64) -> Self {
        Self { inner, seed, next_index: 0, take_val: true }
    }
}

impl<S: RecordStream> RecordStream for SplitStream<S> {
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>> {
        loop {
            match self.inner.next_record(out)? {
                None => return Ok(None),
                Some(label) => {
                    let i = self.next_index;
                    self.next_index += 1;
                    let held_out =
                        mix64(self.seed ^ i) % HOLDOUT_EVERY == 0;
                    if held_out == self.take_val {
                        return Ok(Some(label));
                    }
                }
            }
        }
    }
}

/// Seeded reservoir-window shuffle over a stream: a `window`-record
/// buffer is kept full; each emission picks a uniform buffered record and
/// replaces it with the next incoming one (draining the buffer at end of
/// stream). A window ≥ the stream length is a full uniform shuffle;
/// smaller windows trade memory for shuffle radius. The output order is
/// a pure function of `(inner order, window, seed)` — reproducible at
/// any thread count and resumable by skipping emitted records.
pub struct ShuffleStream<S> {
    inner: S,
    rng: Pcg32,
    window: Vec<(Vec<u32>, u8)>,
    scratch: Vec<u32>,
    cap: usize,
    primed: bool,
    inner_done: bool,
}

impl<S: RecordStream> ShuffleStream<S> {
    pub fn new(inner: S, window: usize, seed: u64) -> Self {
        Self {
            inner,
            rng: Pcg32::new(seed, 0x5EED),
            window: Vec::new(),
            scratch: Vec::new(),
            cap: window.max(1),
            primed: false,
            inner_done: false,
        }
    }
}

impl<S: RecordStream> RecordStream for ShuffleStream<S> {
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>> {
        if !self.primed {
            self.primed = true;
            self.scratch = vec![0u32; out.len()];
            while self.window.len() < self.cap {
                match self.inner.next_record(&mut self.scratch)? {
                    Some(label) => {
                        self.window.push((self.scratch.clone(), label));
                    }
                    None => {
                        self.inner_done = true;
                        break;
                    }
                }
            }
        }
        if self.window.is_empty() {
            return Ok(None);
        }
        let j = self.rng.below_usize(self.window.len());
        out.copy_from_slice(&self.window[j].0);
        let label = self.window[j].1;
        if self.inner_done {
            self.window.swap_remove(j);
        } else {
            match self.inner.next_record(&mut self.scratch)? {
                Some(next_label) => {
                    self.window[j].0.copy_from_slice(&self.scratch);
                    self.window[j].1 = next_label;
                }
                None => {
                    self.inner_done = true;
                    self.window.swap_remove(j);
                }
            }
        }
        Ok(Some(label))
    }
}

/// Tail policy for the final (partial) batch of a stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tail {
    /// Drop a partial final batch (training: every batch is full, and a
    /// resumed run's record accounting stays `steps × batch_size`).
    Drop,
    /// Pad it by repeating the last record (eval: `valid` marks the real
    /// prefix).
    Pad,
}

/// Assembles fixed-size [`Batch`]es straight from a [`RecordStream`].
pub struct StreamBatcher<S> {
    stream: S,
    n_fields: usize,
    batch_size: usize,
    tail: Tail,
    feat_buf: Vec<u32>,
    label_buf: Vec<u8>,
    row_buf: Vec<u32>,
    done: bool,
}

impl<S: RecordStream> StreamBatcher<S> {
    pub fn new(
        stream: S,
        n_fields: usize,
        batch_size: usize,
        tail: Tail,
    ) -> Self {
        assert!(batch_size > 0 && n_fields > 0);
        Self {
            stream,
            n_fields,
            batch_size,
            tail,
            feat_buf: Vec::with_capacity(batch_size * n_fields),
            label_buf: Vec::with_capacity(batch_size),
            row_buf: vec![0u32; n_fields],
            done: false,
        }
    }
}

impl<S: RecordStream> Iterator for StreamBatcher<S> {
    type Item = Result<Batch>;

    fn next(&mut self) -> Option<Result<Batch>> {
        if self.done {
            return None;
        }
        self.feat_buf.clear();
        self.label_buf.clear();
        while self.label_buf.len() < self.batch_size {
            match self.stream.next_record(&mut self.row_buf) {
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
                Ok(None) => {
                    self.done = true;
                    break;
                }
                Ok(Some(label)) => {
                    self.feat_buf.extend_from_slice(&self.row_buf);
                    self.label_buf.push(label);
                }
            }
        }
        let n = self.label_buf.len();
        if n == 0 || (n < self.batch_size && self.tail == Tail::Drop) {
            return None;
        }
        Some(Ok(build_batch(
            &self.feat_buf,
            &self.label_buf,
            self.n_fields,
            self.batch_size,
        )))
    }
}

/// Run `consume` over the stream's batches while a background thread
/// assembles the next ones (double-buffered through a bounded channel of
/// `depth` batches). Batch contents are a pure function of stream order,
/// so this is bit-identical to iterating [`StreamBatcher`] on one
/// thread. `consume` returns `Ok(true)` to continue, `Ok(false)` to stop
/// early; dropping the receiver unblocks and retires the producer.
pub fn with_prefetch<S, F>(
    stream: S,
    n_fields: usize,
    batch_size: usize,
    tail: Tail,
    depth: usize,
    mut consume: F,
) -> Result<()>
where
    S: RecordStream,
    F: FnMut(Batch) -> Result<bool>,
{
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::sync_channel(depth.max(1));
        scope.spawn(move || {
            let batcher =
                StreamBatcher::new(stream, n_fields, batch_size, tail);
            for item in batcher {
                let is_err = item.is_err();
                if tx.send(item).is_err() || is_err {
                    break;
                }
            }
        });
        for item in rx {
            if !consume(item?)? {
                break;
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::{DataSource, SyntheticSource};
    use crate::data::Schema;
    use crate::util::prop::check;

    fn toy(n: usize) -> Dataset {
        let schema = Schema::new(vec![4, 3]);
        let mut features = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            features.push((i % 4) as u32);
            features.push(4 + (i % 3) as u32);
            labels.push((i % 2) as u8);
        }
        Dataset { schema, features, labels }
    }

    fn toy_source(n: usize) -> SyntheticSource {
        SyntheticSource::from_dataset("toy", toy(n))
    }

    #[test]
    fn dedup_maps_back_exactly() {
        let ds = toy(10);
        let b = make_batch(&ds, &[0, 1, 2, 5], 4);
        assert_eq!(b.valid, 4);
        assert_eq!(b.idx.len(), 4 * 2);
        // reconstruct: unique[idx[b,f]] == original feature id
        for (bi, &row) in [0usize, 1, 2, 5].iter().enumerate() {
            for f in 0..2 {
                let slot = b.idx[bi * 2 + f];
                assert_eq!(b.unique[slot as usize], ds.sample(row)[f]);
            }
        }
    }

    #[test]
    fn dedup_is_minimal() {
        let ds = toy(8); // field0 cycles 4 ids, field1 cycles 3
        let b = make_batch(&ds, &[0, 1, 2, 3, 4, 5, 6, 7], 8);
        // unique ids = 4 + 3 = 7 even though 16 slots reference them
        assert_eq!(b.n_unique(), 7);
        // no duplicate entries in unique
        let mut u = b.unique.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 7);
    }

    #[test]
    fn padding_repeats_and_reports_valid() {
        let ds = toy(3);
        let b = make_batch(&ds, &[0, 1], 4);
        assert_eq!(b.valid, 2);
        assert_eq!(b.batch_size(), 4);
        // padded rows repeat sample index 1
        assert_eq!(b.idx[2 * 2..3 * 2], b.idx[1 * 2..2 * 2]);
        assert_eq!(b.labels[2], ds.labels[1]);
    }

    #[test]
    fn batcher_covers_epoch_once() {
        let ds = toy(103);
        let b = Batcher::new(&ds, 10, Some(1), false);
        assert_eq!(b.n_batches(), 11);
        let mut batches = 0;
        for batch in b {
            batches += 1;
            assert_eq!(batch.batch_size(), 10);
            assert!(batch.valid <= 10);
        }
        assert_eq!(batches, 11);
        // drop_last drops the trailing 3
        let b = Batcher::new(&ds, 10, Some(1), true);
        assert_eq!(b.n_batches(), 10);
        assert_eq!(b.count(), 10);
    }

    #[test]
    fn batcher_shuffle_deterministic() {
        let ds = toy(50);
        let a: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(5), false)
            .map(|b| b.labels)
            .collect();
        let b: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(5), false)
            .map(|b| b.labels)
            .collect();
        assert_eq!(a, b);
        let c: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(6), false)
            .map(|b| b.labels)
            .collect();
        assert_ne!(a, c);
    }

    #[test]
    fn batch_property_roundtrip() {
        check("batch gather reconstructs samples", 60, |g| {
            let n = g.usize_in(1, 80);
            let ds = toy(n.max(1));
            let bs = g.usize_in(1, 16);
            let n_rows = g.usize_in(1, bs);
            let rows: Vec<usize> =
                (0..n_rows).map(|_| g.usize_in(0, n - 1)).collect();
            let b = make_batch(&ds, &rows, bs);
            if b.n_unique() > b.idx.len() {
                return Err("more uniques than slots".into());
            }
            for (bi, &row) in rows.iter().enumerate() {
                for f in 0..2 {
                    let slot = b.idx[bi * 2 + f] as usize;
                    if b.unique[slot] != ds.sample(row)[f] {
                        return Err(format!("mismatch bi={bi} f={f}"));
                    }
                }
            }
            Ok(())
        });
    }

    // ------------------------------------------------------ stream tests

    fn drain(stream: &mut dyn RecordStream) -> Vec<(Vec<u32>, u8)> {
        let mut out = vec![0u32; 2];
        let mut acc = Vec::new();
        while let Some(l) = stream.next_record(&mut out).unwrap() {
            acc.push((out.clone(), l));
        }
        acc
    }

    #[test]
    fn split_partitions_without_overlap() {
        let src = toy_source(300);
        let train =
            drain(&mut SplitStream::train(src.stream().unwrap(), 9));
        let val = drain(&mut SplitStream::val(src.stream().unwrap(), 9));
        assert_eq!(train.len() + val.len(), 300);
        // ~10% of 300, wide bounds (hash split, not a quota)
        assert!(val.len() > 8 && val.len() < 65, "val={}", val.len());
        // split is deterministic
        let val2 = drain(&mut SplitStream::val(src.stream().unwrap(), 9));
        assert_eq!(val, val2);
        // and seed-dependent
        let val3 = drain(&mut SplitStream::val(src.stream().unwrap(), 10));
        assert_ne!(val, val3);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let src = toy_source(97);
        let base = drain(src.stream().unwrap().as_mut());
        for window in [1usize, 7, 97, 500] {
            let mut shuffled = drain(&mut ShuffleStream::new(
                src.stream().unwrap(),
                window,
                42,
            ));
            assert_eq!(shuffled.len(), base.len(), "window={window}");
            let mut b = base.clone();
            b.sort();
            shuffled.sort();
            assert_eq!(shuffled, b, "window={window}: not a permutation");
        }
    }

    #[test]
    fn shuffle_deterministic_by_seed_and_actually_shuffles() {
        let src = toy_source(120);
        let a = drain(&mut ShuffleStream::new(src.stream().unwrap(), 64, 7));
        let b = drain(&mut ShuffleStream::new(src.stream().unwrap(), 64, 7));
        assert_eq!(a, b);
        let c = drain(&mut ShuffleStream::new(src.stream().unwrap(), 64, 8));
        assert_ne!(a, c);
        // window 1 is the identity; window > 1 must move something
        let id = drain(&mut ShuffleStream::new(src.stream().unwrap(), 1, 7));
        assert_eq!(id, drain(src.stream().unwrap().as_mut()));
        assert_ne!(a, id);
    }

    #[test]
    fn stream_batcher_matches_in_memory_batcher() {
        // unshuffled stream batches == unshuffled in-memory batches
        let ds = toy(53);
        let src = SyntheticSource::from_dataset("toy", ds.clone());
        let from_stream: Vec<Batch> =
            StreamBatcher::new(src.stream().unwrap(), 2, 8, Tail::Pad)
                .map(|r| r.unwrap())
                .collect();
        let in_memory: Vec<Batch> =
            Batcher::new(&ds, 8, None, false).collect();
        assert_eq!(from_stream, in_memory);
        // Tail::Drop loses the final partial batch
        let dropped: Vec<Batch> =
            StreamBatcher::new(src.stream().unwrap(), 2, 8, Tail::Drop)
                .map(|r| r.unwrap())
                .collect();
        assert_eq!(dropped.len(), 53 / 8);
        assert_eq!(dropped[..], from_stream[..53 / 8]);
    }

    #[test]
    fn prefetch_is_bit_identical_to_serial() {
        let src = toy_source(211);
        for (tail, depth) in
            [(Tail::Pad, 1), (Tail::Pad, 4), (Tail::Drop, 2)]
        {
            let serial: Vec<Batch> = StreamBatcher::new(
                ShuffleStream::new(src.stream().unwrap(), 32, 3),
                2,
                16,
                tail,
            )
            .map(|r| r.unwrap())
            .collect();
            let mut prefetched = Vec::new();
            with_prefetch(
                ShuffleStream::new(src.stream().unwrap(), 32, 3),
                2,
                16,
                tail,
                depth,
                |b| {
                    prefetched.push(b);
                    Ok(true)
                },
            )
            .unwrap();
            assert_eq!(serial, prefetched, "{tail:?} depth={depth}");
        }
    }

    #[test]
    fn prefetch_consumer_can_stop_early() {
        let src = toy_source(500);
        let mut seen = 0usize;
        with_prefetch(src.stream().unwrap(), 2, 10, Tail::Pad, 2, |_| {
            seen += 1;
            Ok(seen < 3)
        })
        .unwrap();
        assert_eq!(seen, 3);
    }
}
