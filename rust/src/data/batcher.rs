//! Batching and per-batch feature deduplication.
//!
//! The paper's memory story (§2.3) hinges on the observation that a batch
//! touches very few *unique* features relative to the table. The batcher
//! produces, per batch, exactly what the AOT artifacts consume:
//!
//! * `unique`    — the batch's unique global feature ids (the only rows
//!                 that get dequantized / updated this step);
//! * `idx`       — `[B, F]` positions into `unique` (i32, scatter/gather
//!                 index matrix; JAX's gather VJP turns this into the
//!                 scatter-add on the backward pass);
//! * `labels`    — `[B]`;
//! * `valid`     — number of real (un-padded) samples; the final batch of
//!                 an epoch is padded by repeating sample 0 so the
//!                 shape-static HLO always sees a full batch.

use super::Dataset;
use crate::util::rng::Pcg32;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Single-u64 multiplicative hasher for the dedup map — feature ids are
/// already well-distributed, so SipHash's DoS resistance only costs time
/// on the per-step hot path (§Perf: ~3x faster make_batch).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.0 = crate::util::rng::mix64(v as u64);
    }
}

type IdMap = HashMap<u32, i32, BuildHasherDefault<IdHasher>>;

/// One training/eval batch in artifact-ready form.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Unique global feature ids, in first-appearance order.
    pub unique: Vec<u32>,
    /// `[B, F]` row-major indices into `unique`.
    pub idx: Vec<i32>,
    /// `[B]` labels (padded tail repeats sample 0's label).
    pub labels: Vec<u8>,
    /// Real sample count (≤ B); the rest is padding.
    pub valid: usize,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.labels.len()
    }

    pub fn n_unique(&self) -> usize {
        self.unique.len()
    }
}

/// Assemble a batch from dataset rows `rows` (padding to `batch_size`).
pub fn make_batch(ds: &Dataset, rows: &[usize], batch_size: usize) -> Batch {
    assert!(!rows.is_empty() && rows.len() <= batch_size);
    let f = ds.n_fields();
    let mut unique = Vec::with_capacity(rows.len() * f / 4);
    let mut map: IdMap =
        IdMap::with_capacity_and_hasher(rows.len() * f, Default::default());
    let mut idx = Vec::with_capacity(batch_size * f);
    let mut labels = Vec::with_capacity(batch_size);

    for bi in 0..batch_size {
        let r = rows[bi.min(rows.len() - 1)]; // pad by repeating the last row
        let sample = ds.sample(r);
        for &g in sample {
            let next_id = unique.len() as i32;
            let slot = *map.entry(g).or_insert_with(|| {
                unique.push(g);
                next_id
            });
            idx.push(slot);
        }
        labels.push(ds.labels[r]);
    }
    Batch { unique, idx, labels, valid: rows.len() }
}

/// Epoch iterator: shuffles sample order per epoch (seeded), yields
/// fixed-size batches, pads the final partial batch.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch_size: usize,
    order: Vec<usize>,
    cursor: usize,
    /// drop the final partial batch instead of padding (train-mode option)
    drop_last: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(
        ds: &'a Dataset,
        batch_size: usize,
        shuffle_seed: Option<u64>,
        drop_last: bool,
    ) -> Self {
        assert!(batch_size > 0);
        let mut order: Vec<usize> = (0..ds.n_samples()).collect();
        if let Some(seed) = shuffle_seed {
            let mut rng = Pcg32::new(seed, 0xBA7C);
            rng.shuffle(&mut order);
        }
        Self { ds, batch_size, order, cursor: 0, drop_last }
    }

    pub fn n_batches(&self) -> usize {
        if self.drop_last {
            self.order.len() / self.batch_size
        } else {
            self.order.len().div_ceil(self.batch_size)
        }
    }
}

impl<'a> Iterator for Batcher<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.batch_size).min(self.order.len());
        let rows = &self.order[self.cursor..end];
        if rows.len() < self.batch_size && self.drop_last {
            self.cursor = self.order.len();
            return None;
        }
        let batch = make_batch(self.ds, rows, self.batch_size);
        self.cursor = end;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Schema;
    use crate::util::prop::check;

    fn toy(n: usize) -> Dataset {
        let schema = Schema::new(vec![4, 3]);
        let mut features = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            features.push((i % 4) as u32);
            features.push(4 + (i % 3) as u32);
            labels.push((i % 2) as u8);
        }
        Dataset { schema, features, labels }
    }

    #[test]
    fn dedup_maps_back_exactly() {
        let ds = toy(10);
        let b = make_batch(&ds, &[0, 1, 2, 5], 4);
        assert_eq!(b.valid, 4);
        assert_eq!(b.idx.len(), 4 * 2);
        // reconstruct: unique[idx[b,f]] == original feature id
        for (bi, &row) in [0usize, 1, 2, 5].iter().enumerate() {
            for f in 0..2 {
                let slot = b.idx[bi * 2 + f];
                assert_eq!(b.unique[slot as usize], ds.sample(row)[f]);
            }
        }
    }

    #[test]
    fn dedup_is_minimal() {
        let ds = toy(8); // field0 cycles 4 ids, field1 cycles 3
        let b = make_batch(&ds, &[0, 1, 2, 3, 4, 5, 6, 7], 8);
        // unique ids = 4 + 3 = 7 even though 16 slots reference them
        assert_eq!(b.n_unique(), 7);
        // no duplicate entries in unique
        let mut u = b.unique.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 7);
    }

    #[test]
    fn padding_repeats_and_reports_valid() {
        let ds = toy(3);
        let b = make_batch(&ds, &[0, 1], 4);
        assert_eq!(b.valid, 2);
        assert_eq!(b.batch_size(), 4);
        // padded rows repeat sample index 1
        assert_eq!(b.idx[2 * 2..3 * 2], b.idx[1 * 2..2 * 2]);
        assert_eq!(b.labels[2], ds.labels[1]);
    }

    #[test]
    fn batcher_covers_epoch_once() {
        let ds = toy(103);
        let mut seen = vec![0u32; 103];
        let b = Batcher::new(&ds, 10, Some(1), false);
        assert_eq!(b.n_batches(), 11);
        let mut batches = 0;
        for batch in b {
            batches += 1;
            assert_eq!(batch.batch_size(), 10);
            assert!(batch.valid <= 10);
        }
        assert_eq!(batches, 11);
        // drop_last drops the trailing 3
        let b = Batcher::new(&ds, 10, Some(1), true);
        assert_eq!(b.n_batches(), 10);
        assert_eq!(b.count(), 10);
        let _ = &mut seen;
    }

    #[test]
    fn batcher_shuffle_deterministic() {
        let ds = toy(50);
        let a: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(5), false)
            .map(|b| b.labels)
            .collect();
        let b: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(5), false)
            .map(|b| b.labels)
            .collect();
        assert_eq!(a, b);
        let c: Vec<Vec<u8>> = Batcher::new(&ds, 8, Some(6), false)
            .map(|b| b.labels)
            .collect();
        assert_ne!(a, c);
    }

    #[test]
    fn batch_property_roundtrip() {
        check("batch gather reconstructs samples", 60, |g| {
            let n = g.usize_in(1, 80);
            let ds = toy(n.max(1));
            let bs = g.usize_in(1, 16);
            let n_rows = g.usize_in(1, bs);
            let rows: Vec<usize> =
                (0..n_rows).map(|_| g.usize_in(0, n - 1)).collect();
            let b = make_batch(&ds, &rows, bs);
            if b.n_unique() > b.idx.len() {
                return Err("more uniques than slots".into());
            }
            for (bi, &row) in rows.iter().enumerate() {
                for f in 0..2 {
                    let slot = b.idx[bi * 2 + f] as usize;
                    if b.unique[slot] != ds.sample(row)[f] {
                        return Err(format!("mismatch bi={bi} f={f}"));
                    }
                }
            }
            Ok(())
        });
    }
}
