//! CTR data pipeline: schema, in-memory dataset, on-disk binary format,
//! train/val/test splits, and the streaming dataset subsystem.
//!
//! Two ways to feed the trainer:
//!
//! * the [`synthetic`] module generates in-memory datasets with the
//!   properties the paper's experiments exercise (long-tailed Zipf
//!   features, learnable interaction structure — DESIGN.md §5.1);
//! * the [`criteo`] module streams Criteo-format TSV files (the paper's
//!   real workload shape) record by record, hashing categorical tokens
//!   and bucketizing numeric columns on the fly.
//!
//! Both sit behind the [`registry::DataSource`] trait; [`batcher`] turns
//! either into deduplicated fixed-size batches (with an optional
//! background prefetch thread for the streaming path).
//!
//! Feature ids are *global*: field `f`'s local id `j` maps to
//! `field_offset[f] + j`, so one embedding table serves all fields — the
//! same layout CTR systems and the paper use (one row per feature).

pub mod batcher;
pub mod criteo;
pub mod registry;
pub mod synthetic;

pub use registry::{DataSource, DatasetSpec, RecordStream};

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Dataset schema: per-field vocabulary sizes and global-id offsets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    /// Vocabulary size per field (id 0 of every field is its OOV token).
    pub vocabs: Vec<u32>,
    /// Exclusive prefix sum of `vocabs`.
    pub offsets: Vec<u32>,
}

impl Schema {
    pub fn new(vocabs: Vec<u32>) -> Self {
        assert!(!vocabs.is_empty());
        let mut offsets = Vec::with_capacity(vocabs.len());
        let mut acc = 0u32;
        for &v in &vocabs {
            assert!(v > 0, "empty field vocabulary");
            offsets.push(acc);
            acc = acc.checked_add(v).expect("feature space overflows u32");
        }
        Self { vocabs, offsets }
    }

    pub fn n_fields(&self) -> usize {
        self.vocabs.len()
    }

    /// Total number of features across all fields = embedding-table rows.
    pub fn n_features(&self) -> usize {
        (*self.offsets.last().unwrap() + *self.vocabs.last().unwrap())
            as usize
    }

    /// Global feature id for (field, local id).
    #[inline]
    pub fn global_id(&self, field: usize, local: u32) -> u32 {
        debug_assert!(local < self.vocabs[field]);
        self.offsets[field] + local
    }

    /// Which field a global id belongs to.
    pub fn field_of(&self, global: u32) -> usize {
        match self.offsets.binary_search(&global) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }
}

/// In-memory CTR dataset: `[n, F]` global feature ids + binary labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub schema: Schema,
    /// Row-major `[n_samples × n_fields]` global feature ids.
    pub features: Vec<u32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn n_samples(&self) -> usize {
        self.labels.len()
    }

    pub fn n_fields(&self) -> usize {
        self.schema.n_fields()
    }

    /// Feature ids of sample `i`.
    #[inline]
    pub fn sample(&self, i: usize) -> &[u32] {
        let f = self.n_fields();
        &self.features[i * f..(i + 1) * f]
    }

    /// Empirical CTR.
    pub fn ctr(&self) -> f64 {
        if self.labels.is_empty() {
            return 0.0;
        }
        self.labels.iter().map(|&l| l as f64).sum::<f64>()
            / self.labels.len() as f64
    }

    /// Split into (train, val, test) by a shuffled permutation with the
    /// paper's 8:1:1 default.
    pub fn split(
        &self,
        ratios: (f64, f64, f64),
        seed: u64,
    ) -> (Dataset, Dataset, Dataset) {
        let n = self.n_samples();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = crate::util::rng::Pcg32::new(seed, 0x5917);
        rng.shuffle(&mut order);
        let n_train = (n as f64 * ratios.0).round() as usize;
        let n_val = (n as f64 * ratios.1).round() as usize;
        let take = |idx: &[usize]| -> Dataset {
            let f = self.n_fields();
            let mut features = Vec::with_capacity(idx.len() * f);
            let mut labels = Vec::with_capacity(idx.len());
            for &i in idx {
                features.extend_from_slice(self.sample(i));
                labels.push(self.labels[i]);
            }
            Dataset { schema: self.schema.clone(), features, labels }
        };
        (
            take(&order[..n_train]),
            take(&order[n_train..(n_train + n_val).min(n)]),
            take(&order[(n_train + n_val).min(n)..]),
        )
    }

    // ------------------------------------------------------ binary on-disk

    const MAGIC: &'static [u8; 8] = b"ALPTDS01";

    /// Write the dataset in the project's binary format (little endian):
    /// magic, F, n, vocabs[F], features[n*F], labels[n].
    pub fn write(&self, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.n_fields() as u32).to_le_bytes())?;
        w.write_all(&(self.n_samples() as u64).to_le_bytes())?;
        for &v in &self.schema.vocabs {
            w.write_all(&v.to_le_bytes())?;
        }
        for &f in &self.features {
            w.write_all(&f.to_le_bytes())?;
        }
        w.write_all(&self.labels)?;
        w.flush()?;
        Ok(())
    }

    /// Read a dataset written by [`Dataset::write`].
    pub fn read(path: &Path) -> Result<Dataset> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("{} is not an ALPT dataset file", path.display());
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        let n_fields = u32::from_le_bytes(b4) as usize;
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let mut vocabs = Vec::with_capacity(n_fields);
        for _ in 0..n_fields {
            r.read_exact(&mut b4)?;
            vocabs.push(u32::from_le_bytes(b4));
        }
        let schema = Schema::new(vocabs);
        let mut feat_bytes = vec![0u8; n * n_fields * 4];
        r.read_exact(&mut feat_bytes)?;
        let features = feat_bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect::<Vec<_>>();
        let mut labels = vec![0u8; n];
        r.read_exact(&mut labels)?;
        // validate ids
        for (i, &f) in features.iter().enumerate() {
            if (f as usize) >= schema.n_features() {
                bail!("feature id {f} out of range at element {i}");
            }
        }
        Ok(Dataset { schema, features, labels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let schema = Schema::new(vec![3, 2, 4]);
        let features = vec![
            0, 3, 5, // sample 0: field ids (0,0) (1,0) (2,0)
            2, 4, 8, // sample 1
            1, 3, 6, // sample 2
            0, 4, 7, // sample 3
        ];
        Dataset { schema, features, labels: vec![1, 0, 0, 1] }
    }

    #[test]
    fn schema_offsets_and_ids() {
        let s = Schema::new(vec![3, 2, 4]);
        assert_eq!(s.offsets, vec![0, 3, 5]);
        assert_eq!(s.n_features(), 9);
        assert_eq!(s.global_id(0, 2), 2);
        assert_eq!(s.global_id(1, 0), 3);
        assert_eq!(s.global_id(2, 3), 8);
        assert_eq!(s.field_of(0), 0);
        assert_eq!(s.field_of(2), 0);
        assert_eq!(s.field_of(3), 1);
        assert_eq!(s.field_of(8), 2);
    }

    #[test]
    fn dataset_accessors() {
        let d = toy();
        assert_eq!(d.n_samples(), 4);
        assert_eq!(d.sample(1), &[2, 4, 8]);
        assert!((d.ctr() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn split_partitions_everything() {
        let d = toy();
        let (tr, va, te) = d.split((0.5, 0.25, 0.25), 7);
        assert_eq!(tr.n_samples() + va.n_samples() + te.n_samples(), 4);
        assert_eq!(tr.n_samples(), 2);
        // schema preserved
        assert_eq!(tr.schema, d.schema);
    }

    #[test]
    fn split_deterministic_by_seed() {
        let d = toy();
        let (a, _, _) = d.split((0.5, 0.25, 0.25), 42);
        let (b, _, _) = d.split((0.5, 0.25, 0.25), 42);
        assert_eq!(a.features, b.features);
        let (c, _, _) = d.split((0.5, 0.25, 0.25), 43);
        // with 4 samples different seeds *may* coincide; just check both ok
        assert_eq!(c.n_samples(), 2);
    }

    #[test]
    fn io_roundtrip() {
        let d = toy();
        let dir = std::env::temp_dir().join("alpt_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ds");
        d.write(&path).unwrap();
        let back = Dataset::read(&path).unwrap();
        assert_eq!(back.schema, d.schema);
        assert_eq!(back.features, d.features);
        assert_eq!(back.labels, d.labels);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn io_rejects_garbage() {
        let dir = std::env::temp_dir().join("alpt_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ds");
        std::fs::write(&path, b"NOTADATASET").unwrap();
        assert!(Dataset::read(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
