//! Streaming reader for Criteo-format TSV logs — the format the paper's
//! real datasets ship in: one record per line,
//! `label \t I1..I13 \t C1..C26` (13 integer "numeric" columns, 26
//! hex-token categorical columns), any field possibly empty.
//!
//! Records stream straight off a `BufReader`; the file is never loaded
//! into memory, so a 40M-row Kaggle download and the committed ~1k-row
//! fixture go through the identical code path. Features map onto the
//! global embedding-id space on the fly:
//!
//! * **Categorical** fields hash their token into a per-field vocabulary
//!   of `2^hash_bits` slots (id 0 reserved for missing) with a stateless
//!   FNV-1a → mix64 hash salted by the field index. The hash depends only
//!   on `(field, token bytes)` — deterministic across runs, platforms and
//!   thread counts, which the sharded-update determinism contract
//!   (`util::rng`) inherits for free.
//! * **Numeric** fields are log-transformed and bucketized:
//!   `bucket = 1 + floor(log2(1 + v))` for `v ≥ 0`, the last bucket for
//!   negatives, bucket 0 for missing. Log bucketization is the standard
//!   normalization for Criteo's heavy-tailed counts and — unlike
//!   mean/variance scaling — needs no dataset statistics, so streaming
//!   stays single-pass.
//!
//! Malformed lines (wrong column count, unparsable label or integer) are
//! counted and skipped rather than aborting a multi-hour streaming run;
//! empty fields are data, not errors.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::registry::{DataSource, RecordStream};
use super::Schema;
use crate::util::rng::mix64;

/// Criteo column layout: 13 numeric fields then 26 categorical ones.
pub const N_NUMERIC: usize = 13;
pub const N_CATEGORICAL: usize = 26;
pub const N_FIELDS: usize = N_NUMERIC + N_CATEGORICAL;

/// Feature-space configuration for Criteo-format files.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CriteoCfg {
    /// Per-categorical-field vocabulary is `2^hash_bits` ids (id 0 =
    /// missing). Caps the embedding-table rows a full download needs.
    pub hash_bits: u32,
    /// Buckets per numeric field, including the missing (0) and
    /// negative (last) buckets.
    pub numeric_buckets: u32,
}

impl Default for CriteoCfg {
    fn default() -> Self {
        Self { hash_bits: 16, numeric_buckets: 40 }
    }
}

impl CriteoCfg {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (2..=24).contains(&self.hash_bits),
            "hash_bits {} out of range (2..=24)",
            self.hash_bits
        );
        ensure!(
            self.numeric_buckets >= 3,
            "numeric_buckets {} too small (need missing + data + negative)",
            self.numeric_buckets
        );
        Ok(())
    }

    /// The 39-field schema this configuration induces.
    pub fn schema(&self) -> Schema {
        let mut vocabs = vec![self.numeric_buckets; N_NUMERIC];
        vocabs.extend(
            std::iter::repeat(1u32 << self.hash_bits).take(N_CATEGORICAL),
        );
        Schema::new(vocabs)
    }
}

/// Stateless categorical token hash: FNV-1a over the token bytes, salted
/// by the field index, finished with `mix64`, mapped to `[1, vocab)`
/// (id 0 is reserved for missing).
pub fn hash_token(field: usize, token: &[u8], vocab: u32) -> u32 {
    debug_assert!(vocab >= 2);
    let mut h = 0xCBF2_9CE4_8422_2325u64; // FNV-1a offset basis
    for &b in token {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01B3); // FNV-1a prime
    }
    let mixed =
        mix64(h ^ (field as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    1 + (mixed % (vocab as u64 - 1)) as u32
}

/// Log2 bucket of a numeric value (see module docs): 0 is reserved for
/// missing, the last bucket holds negatives, everything else lands at
/// `1 + floor(log2(1 + v))` capped to `buckets - 2`.
pub fn numeric_bucket(v: i64, buckets: u32) -> u32 {
    debug_assert!(buckets >= 3);
    if v < 0 {
        buckets - 1
    } else {
        let lg = 63 - (v as u64 + 1).leading_zeros(); // floor(log2(v + 1))
        (1 + lg).min(buckets - 2)
    }
}

/// Parse one TSV line into per-field *global* feature ids; `None` when
/// the line is malformed (wrong column count, bad label, bad integer).
fn parse_line(
    line: &str,
    cfg: &CriteoCfg,
    schema: &Schema,
    out: &mut [u32],
) -> Option<u8> {
    debug_assert_eq!(out.len(), N_FIELDS);
    let mut cols = line.split('\t');
    let label = match cols.next() {
        Some("0") => 0u8,
        Some("1") => 1u8,
        _ => return None,
    };
    let mut field = 0usize;
    for col in cols {
        if field >= N_FIELDS {
            return None; // too many columns
        }
        let local = if col.is_empty() {
            0 // missing: both numeric and categorical reserve id 0
        } else if field < N_NUMERIC {
            match col.parse::<i64>() {
                Ok(v) => numeric_bucket(v, cfg.numeric_buckets),
                Err(_) => return None,
            }
        } else {
            hash_token(field, col.as_bytes(), 1u32 << cfg.hash_bits)
        };
        out[field] = schema.global_id(field, local);
        field += 1;
    }
    if field != N_FIELDS {
        return None; // too few columns
    }
    Some(label)
}

/// A Criteo-format TSV on disk, streamed record by record. Opening is
/// cheap (a stat); each [`CriteoFile::stream`] call opens a fresh reader,
/// so epochs and eval passes never share file offsets.
pub struct CriteoFile {
    path: PathBuf,
    cfg: CriteoCfg,
    schema: Schema,
    name: String,
    /// Malformed lines in the file, as observed by the most complete
    /// pass so far (streams `fetch_max` their own running count into
    /// this, so repeated epochs do not inflate it). Shared with the
    /// streams so callers can surface data-quality problems through
    /// [`DataSource::warnings`].
    malformed: Arc<AtomicU64>,
}

impl CriteoFile {
    pub fn open(path: &Path, cfg: CriteoCfg) -> Result<Self> {
        cfg.validate()?;
        ensure!(
            path.is_file(),
            "{} does not exist or is not a file",
            path.display()
        );
        Ok(Self {
            path: path.to_path_buf(),
            cfg,
            schema: cfg.schema(),
            name: format!("criteo:{}", path.display()),
            malformed: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn cfg(&self) -> CriteoCfg {
        self.cfg
    }

    /// Malformed lines in the file, per the most complete pass so far.
    pub fn malformed_lines(&self) -> u64 {
        self.malformed.load(Ordering::Relaxed)
    }
}

impl DataSource for CriteoFile {
    fn name(&self) -> &str {
        &self.name
    }

    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn stream(&self) -> Result<Box<dyn RecordStream>> {
        let file = File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        Ok(Box::new(CriteoStream {
            reader: BufReader::with_capacity(1 << 16, file),
            cfg: self.cfg,
            schema: self.schema.clone(),
            line: Vec::new(),
            line_no: 0,
            malformed: 0,
            source_malformed: Arc::clone(&self.malformed),
        }))
    }

    fn warnings(&self) -> Vec<String> {
        let n = self.malformed_lines();
        if n > 0 {
            vec![format!(
                "{n} malformed line(s) skipped in {}",
                self.path.display()
            )]
        } else {
            Vec::new()
        }
    }
}

/// One in-order pass over a Criteo TSV. Malformed lines are skipped and
/// counted; blank lines are ignored.
pub struct CriteoStream {
    reader: BufReader<File>,
    cfg: CriteoCfg,
    schema: Schema,
    /// Raw line buffer — bytes, not `String`, so a stray non-UTF-8 byte
    /// is one more malformed line instead of a run-aborting I/O error.
    line: Vec<u8>,
    line_no: u64,
    malformed: u64,
    /// The owning [`CriteoFile`]'s cross-stream counter.
    source_malformed: Arc<AtomicU64>,
}

impl CriteoStream {
    /// Lines skipped as malformed by *this* stream so far.
    pub fn malformed_lines(&self) -> u64 {
        self.malformed
    }

    fn count_malformed(&mut self) {
        self.malformed += 1;
        // max, not sum: every full pass re-sees the same bad lines, and
        // the source-level number should mean "lines in the file"
        self.source_malformed.fetch_max(self.malformed, Ordering::Relaxed);
    }
}

impl RecordStream for CriteoStream {
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>> {
        loop {
            self.line.clear();
            let n = self
                .reader
                .read_until(b'\n', &mut self.line)
                .with_context(|| format!("reading line {}", self.line_no + 1))?;
            if n == 0 {
                return Ok(None);
            }
            self.line_no += 1;
            let ok = match std::str::from_utf8(&self.line) {
                Ok(t) => {
                    let text = t.trim_end_matches(&['\n', '\r'][..]);
                    if text.is_empty() {
                        continue;
                    }
                    parse_line(text, &self.cfg, &self.schema, out)
                }
                Err(_) => None,
            };
            match ok {
                Some(label) => return Ok(Some(label)),
                None => self.count_malformed(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn cfg8() -> CriteoCfg {
        CriteoCfg { hash_bits: 8, numeric_buckets: 40 }
    }

    /// A well-formed line: label, 13 numerics, 26 categoricals.
    fn good_line(label: u8) -> String {
        let nums: Vec<String> = (0..N_NUMERIC as i64).map(|i| i.to_string()).collect();
        let cats: Vec<String> =
            (0..N_CATEGORICAL).map(|i| format!("{i:08x}")).collect();
        format!("{label}\t{}\t{}", nums.join("\t"), cats.join("\t"))
    }

    fn tmp_file(name: &str, contents: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("alpt_criteo_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path
    }

    #[test]
    fn schema_geometry() {
        let cfg = cfg8();
        let schema = cfg.schema();
        assert_eq!(schema.n_fields(), N_FIELDS);
        assert_eq!(
            schema.n_features(),
            N_NUMERIC * 40 + N_CATEGORICAL * 256
        );
        // numeric fields first, then the hashed categorical ones
        assert_eq!(schema.vocabs[0], 40);
        assert_eq!(schema.vocabs[N_NUMERIC], 256);
    }

    #[test]
    fn cfg_validation() {
        assert!(cfg8().validate().is_ok());
        assert!(CriteoCfg { hash_bits: 1, numeric_buckets: 40 }
            .validate()
            .is_err());
        assert!(CriteoCfg { hash_bits: 30, numeric_buckets: 40 }
            .validate()
            .is_err());
        assert!(CriteoCfg { hash_bits: 8, numeric_buckets: 2 }
            .validate()
            .is_err());
    }

    #[test]
    fn numeric_buckets_monotone_and_special() {
        let b = 40;
        assert_eq!(numeric_bucket(0, b), 1);
        assert_eq!(numeric_bucket(1, b), 2);
        assert_eq!(numeric_bucket(2, b), 2); // log2(3) floors to 1
        assert_eq!(numeric_bucket(3, b), 3);
        assert_eq!(numeric_bucket(-1, b), b - 1);
        assert_eq!(numeric_bucket(i64::MAX, b), b - 2); // capped
        let mut prev = 0;
        for v in 0..10_000i64 {
            let cur = numeric_bucket(v, b);
            assert!(cur >= prev, "bucket not monotone at v={v}");
            assert!(cur >= 1 && cur <= b - 2);
            prev = cur;
        }
    }

    #[test]
    fn hash_token_deterministic_salted_in_range() {
        let vocab = 256;
        let a = hash_token(13, b"deadbeef", vocab);
        assert_eq!(a, hash_token(13, b"deadbeef", vocab));
        // same token in a different field lands elsewhere (salt)
        assert_ne!(a, hash_token(14, b"deadbeef", vocab));
        for t in 0..2000u32 {
            let id = hash_token(20, format!("{t:08x}").as_bytes(), vocab);
            assert!(id >= 1 && id < vocab, "id {id} out of [1, {vocab})");
        }
    }

    #[test]
    fn hash_token_identical_across_threads() {
        // the hash is a pure function, so any thread computes the same id
        let tokens: Vec<String> = (0..64u64)
            .map(|t| format!("{:08x}", t.wrapping_mul(2654435761)))
            .collect();
        let serial: Vec<u32> = tokens
            .iter()
            .map(|t| hash_token(17, t.as_bytes(), 1 << 12))
            .collect();
        let mut threaded = vec![0u32; tokens.len()];
        std::thread::scope(|s| {
            for (chunk_toks, chunk_out) in
                tokens.chunks(8).zip(threaded.chunks_mut(8))
            {
                s.spawn(move || {
                    for (t, o) in chunk_toks.iter().zip(chunk_out.iter_mut())
                    {
                        *o = hash_token(17, t.as_bytes(), 1 << 12);
                    }
                });
            }
        });
        assert_eq!(serial, threaded);
    }

    #[test]
    fn parse_good_line() {
        let cfg = cfg8();
        let schema = cfg.schema();
        let mut out = vec![0u32; N_FIELDS];
        let label =
            parse_line(&good_line(1), &cfg, &schema, &mut out).unwrap();
        assert_eq!(label, 1);
        for (f, &g) in out.iter().enumerate() {
            assert_eq!(schema.field_of(g), f, "field {f} id out of range");
        }
        // numeric 0 -> bucket 1, i.e. global id offset + 1
        assert_eq!(out[0], schema.global_id(0, 1));
    }

    #[test]
    fn parse_empty_fields_map_to_missing() {
        let cfg = cfg8();
        let schema = cfg.schema();
        // every field empty: 13 + 26 empty columns after the label
        let line = format!("0\t{}", vec![""; N_FIELDS].join("\t"));
        let mut out = vec![0u32; N_FIELDS];
        let label = parse_line(&line, &cfg, &schema, &mut out).unwrap();
        assert_eq!(label, 0);
        for (f, &g) in out.iter().enumerate() {
            assert_eq!(g, schema.global_id(f, 0), "field {f} not missing-id");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        let cfg = cfg8();
        let schema = cfg.schema();
        let mut out = vec![0u32; N_FIELDS];
        // bad label
        let bad_label = good_line(1).replacen('1', "7", 1);
        assert!(parse_line(&bad_label, &cfg, &schema, &mut out).is_none());
        // too few columns
        let short = "1\t3\t4";
        assert!(parse_line(short, &cfg, &schema, &mut out).is_none());
        // too many columns
        let long = format!("{}\textra", good_line(0));
        assert!(parse_line(&long, &cfg, &schema, &mut out).is_none());
        // non-integer numeric
        let mut cols: Vec<String> =
            good_line(0).split('\t').map(|s| s.to_string()).collect();
        cols[3] = "not-a-number".into();
        assert!(parse_line(&cols.join("\t"), &cfg, &schema, &mut out)
            .is_none());
    }

    #[test]
    fn stream_skips_malformed_and_counts() {
        let contents = format!(
            "{}\ngarbage line\n{}\n\n2\tbadlabel\n{}\n",
            good_line(1),
            good_line(0),
            good_line(1)
        );
        let path = tmp_file("mixed.tsv", &contents);
        let src = CriteoFile::open(&path, cfg8()).unwrap();
        let mut stream = src.stream().unwrap();
        let mut out = vec![0u32; N_FIELDS];
        let mut labels = Vec::new();
        while let Some(l) = stream.next_record(&mut out).unwrap() {
            labels.push(l);
        }
        assert_eq!(labels, vec![1, 0, 1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_counter_is_observable_on_the_source() {
        let contents =
            format!("nonsense\n{}\nalso bad\t\t\n", good_line(1));
        let path = tmp_file("counted.tsv", &contents);
        let src = CriteoFile::open(&path, cfg8()).unwrap();
        assert!(src.warnings().is_empty(), "clean before any stream");
        let mut stream = src.stream().unwrap();
        let mut out = vec![0u32; N_FIELDS];
        let mut n = 0;
        while stream.next_record(&mut out).unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 1);
        assert_eq!(src.malformed_lines(), 2);
        let warnings = src.warnings();
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("2 malformed"), "{warnings:?}");
        // a second pass re-sees the same lines: max, not sum — the count
        // stays "lines in the file", however many epochs stream it
        let mut again = src.stream().unwrap();
        while again.next_record(&mut out).unwrap().is_some() {}
        assert_eq!(src.malformed_lines(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_utf8_bytes_are_malformed_lines_not_errors() {
        let dir = std::env::temp_dir().join("alpt_criteo_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("binary.tsv");
        let mut contents = good_line(1).into_bytes();
        contents.push(b'\n');
        contents.extend_from_slice(b"1\t\xFF\xFE broken bytes\n");
        contents.extend_from_slice(good_line(0).as_bytes());
        contents.push(b'\n');
        std::fs::write(&path, &contents).unwrap();
        let src = CriteoFile::open(&path, cfg8()).unwrap();
        let mut stream = src.stream().unwrap();
        let mut out = vec![0u32; N_FIELDS];
        let mut labels = Vec::new();
        while let Some(l) = stream.next_record(&mut out).unwrap() {
            labels.push(l);
        }
        // the corrupt line is skipped, not fatal, and both sides survive
        assert_eq!(labels, vec![1, 0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn two_streams_are_identical() {
        // re-opening the source must reproduce the exact record sequence
        let mut contents = String::new();
        for i in 0..50 {
            contents.push_str(&good_line((i % 2) as u8));
            contents.push('\n');
        }
        let path = tmp_file("repeat.tsv", &contents);
        let src = CriteoFile::open(&path, cfg8()).unwrap();
        let read_all = |s: &mut dyn RecordStream| {
            let mut out = vec![0u32; N_FIELDS];
            let mut acc = Vec::new();
            while let Some(l) = s.next_record(&mut out).unwrap() {
                acc.push((out.clone(), l));
            }
            acc
        };
        let a = read_all(src.stream().unwrap().as_mut());
        let b = read_all(src.stream().unwrap().as_mut());
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_rejects_missing_file() {
        let err = CriteoFile::open(
            Path::new("/nonexistent/criteo.tsv"),
            cfg8(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("does not exist"));
    }
}
