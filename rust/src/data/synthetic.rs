//! Synthetic CTR dataset generation (the Criteo/Avazu substitute —
//! DESIGN.md §5.1).
//!
//! Sampling model, chosen to preserve what the paper's experiments
//! exercise:
//!
//! * each field draws a *rank* from Zipf(s) and maps it to a feature id
//!   through a per-field permutation — long-tailed frequencies (rare
//!   features get few gradient updates, making their embeddings the
//!   quantization-sensitive tail);
//! * ground truth is a latent logistic model: a per-feature weight drawn
//!   N(0, σ_f²) (frequency-independent) plus `n_pairs` random field-pair
//!   interactions whose strength is a stateless hash of the two ids —
//!   first-order signal for the deep tower, second-order for the cross
//!   network;
//! * the bias calibrates the average CTR to the target (Avazu ≈ 0.17,
//!   Criteo ≈ 0.26).
//!
//! Generation parallelizes over sample chunks with per-chunk PRNG streams,
//! so output is reproducible regardless of thread count.

use super::{Dataset, Schema};
use crate::util::rng::{mix64, Pcg32, Zipf};
use crate::util::threadpool::parallel_chunks;

/// Specification for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    /// Per-field vocabulary sizes.
    pub vocabs: Vec<u32>,
    /// Zipf exponent for feature frequencies (> 1 = heavy head).
    pub zipf_s: f64,
    /// Per-feature latent weight scale.
    pub weight_std: f32,
    /// Number of random field pairs with interaction terms.
    pub n_pairs: usize,
    /// Interaction strength.
    pub pair_std: f32,
    /// Target average CTR.
    pub target_ctr: f64,
    pub seed: u64,
}

impl SyntheticSpec {
    /// Avazu-like: 24 fields, ~400k features, CTR ≈ 0.17 (10×-scaled from
    /// the paper's 4.4M-feature processed Avazu).
    pub fn avazu(seed: u64) -> Self {
        // a few huge id-like fields plus many small categorical ones,
        // echoing Avazu's device_id/device_ip dominance
        let mut vocabs = vec![120_000u32, 90_000, 60_000, 40_000, 20_000];
        vocabs.extend([8_000, 4_000, 2_500, 1_500, 1_000]);
        vocabs.extend([500, 300, 250, 200, 100, 60, 30, 24, 10, 8, 7, 4, 3, 2]);
        assert_eq!(vocabs.len(), 24);
        Self {
            name: "avazu-syn".into(),
            vocabs,
            zipf_s: 1.1,
            weight_std: 0.9,
            n_pairs: 12,
            pair_std: 0.5,
            target_ctr: 0.17,
            seed,
        }
    }

    /// Criteo-like: 39 fields (26 categorical + 13 bucketized numeric),
    /// ~120k features, CTR ≈ 0.26.
    pub fn criteo(seed: u64) -> Self {
        let mut vocabs = vec![40_000u32, 25_000, 15_000, 10_000, 8_000];
        vocabs.extend([5_000, 3_000, 2_000, 1_500, 1_200, 1_000, 800]);
        vocabs.extend([600, 500, 400, 300, 250, 200, 150, 120, 100, 80, 60,
                       40, 30, 20]);
        // 13 "numeric" fields bucketized to ~40 bins each (log2 transform)
        vocabs.extend(std::iter::repeat(40).take(13));
        assert_eq!(vocabs.len(), 39);
        Self {
            name: "criteo-syn".into(),
            vocabs,
            zipf_s: 1.05,
            weight_std: 0.8,
            n_pairs: 20,
            pair_std: 0.5,
            target_ctr: 0.26,
            seed,
        }
    }

    /// Tiny spec matching the `tiny` model config (tests / quickstart).
    pub fn tiny(seed: u64) -> Self {
        Self {
            name: "tiny-syn".into(),
            vocabs: vec![2_000, 1_000, 500, 200, 100, 50, 20, 8],
            zipf_s: 1.1,
            weight_std: 1.2,
            n_pairs: 4,
            pair_std: 0.6,
            target_ctr: 0.25,
            seed,
        }
    }

    /// Resolve a dataset name (+ Table-3 vocab scaling) to its spec —
    /// the single registry shared by the CLI (`alpt train`/`gen`),
    /// checkpoint serving and warm-start, so the feature space a
    /// checkpoint echo describes is rebuilt identically everywhere.
    pub fn for_dataset(
        dataset: &str,
        seed: u64,
        vocab_scale: f64,
    ) -> anyhow::Result<SyntheticSpec> {
        let spec = match dataset {
            "avazu" => SyntheticSpec::avazu(seed),
            "criteo" => SyntheticSpec::criteo(seed),
            "tiny" => SyntheticSpec::tiny(seed),
            other => anyhow::bail!("unknown dataset {other:?}"),
        };
        Ok(if (vocab_scale - 1.0).abs() > 1e-9 {
            spec.scale_vocabs(vocab_scale)
        } else {
            spec
        })
    }

    /// Scale every vocabulary by `factor` (Table 3's "more categorical
    /// features" setting: lower OOV threshold ⇒ larger vocab).
    pub fn scale_vocabs(mut self, factor: f64) -> Self {
        for v in &mut self.vocabs {
            *v = ((*v as f64 * factor).round() as u32).max(2);
        }
        self.name = format!("{}-x{factor:.1}", self.name);
        self
    }
}

/// The latent ground-truth model (kept so experiments can report the Bayes
/// logloss and verify learnability).
pub struct GroundTruth {
    spec: SyntheticSpec,
    schema: Schema,
    /// Per-global-feature latent weight.
    weights: Vec<f32>,
    /// Interaction field pairs.
    pairs: Vec<(usize, usize)>,
    bias: f32,
}

impl GroundTruth {
    pub fn new(spec: SyntheticSpec) -> Self {
        let schema = Schema::new(spec.vocabs.clone());
        let n = schema.n_features();
        let mut rng = Pcg32::new(spec.seed, 0x17EA);
        let mut weights = vec![0.0f32; n];
        // normalize per-field so total logit variance is O(weight_std²)
        let per_field = spec.weight_std / (spec.vocabs.len() as f32).sqrt();
        for w in weights.iter_mut() {
            *w = rng.normal_scaled(0.0, per_field);
        }
        let n_fields = schema.n_fields();
        let mut pairs = Vec::with_capacity(spec.n_pairs);
        while pairs.len() < spec.n_pairs.min(n_fields * (n_fields - 1) / 2) {
            let a = rng.below_usize(n_fields);
            let b = rng.below_usize(n_fields);
            if a != b && !pairs.contains(&(a.min(b), a.max(b))) {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        // Calibrate the bias empirically: Jensen's inequality drags
        // E[sigmoid(b + Z)] toward 0.5 for any non-degenerate logit
        // distribution Z, and Z here is a Zipf-weighted sum (not Gaussian),
        // so closed-form corrections miss. Draw a few thousand bias-free
        // logits from the real sampling path and bisect b.
        let mut gt = Self { spec, schema, weights, pairs, bias: 0.0 };
        let zipfs: Vec<Zipf> = gt
            .spec
            .vocabs
            .iter()
            .map(|&v| Zipf::new(v as usize, gt.spec.zipf_s))
            .collect();
        let mut cal_rng = Pcg32::new(gt.spec.seed, 0xCA11);
        let n_cal = 4000;
        let n_fields = gt.schema.n_fields();
        let mut sample = vec![0u32; n_fields];
        let mut raw = Vec::with_capacity(n_cal);
        for _ in 0..n_cal {
            sample_features(&gt.spec, &gt.schema, &zipfs, &mut cal_rng,
                            &mut sample);
            raw.push(gt.logit(&sample) as f64);
        }
        let (mut lo, mut hi) = (-10.0f64, 10.0f64);
        for _ in 0..50 {
            let mid = 0.5 * (lo + hi);
            let mean: f64 = raw
                .iter()
                .map(|z| 1.0 / (1.0 + (-(z + mid)).exp()))
                .sum::<f64>()
                / n_cal as f64;
            if mean < gt.spec.target_ctr {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        gt.bias = (0.5 * (lo + hi)) as f32;
        gt
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// True logit for a sample of global feature ids.
    pub fn logit(&self, sample: &[u32]) -> f32 {
        let mut z = self.bias;
        for &g in sample {
            z += self.weights[g as usize];
        }
        let scale = self.spec.pair_std
            / (self.pairs.len().max(1) as f32).sqrt();
        for &(a, b) in &self.pairs {
            z += interaction(self.spec.seed, sample[a], sample[b]) * scale;
        }
        z
    }
}

/// Stateless N(0,1)-ish interaction weight for an id pair (hash → uniform
/// pair → Box–Muller), so the ground truth needs no quadratic storage.
fn interaction(seed: u64, a: u32, b: u32) -> f32 {
    let h = mix64(seed ^ ((a as u64) << 32 | b as u64));
    let u1 = ((h >> 11) as f64 / (1u64 << 53) as f64).max(1e-12);
    let h2 = mix64(h ^ 0x9E37_79B9_7F4A_7C15);
    let u2 = (h2 >> 11) as f64 / (1u64 << 53) as f64;
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// Generate `n_samples` samples from the spec (parallel, deterministic).
pub fn generate(spec: &SyntheticSpec, n_samples: usize) -> Dataset {
    let truth = GroundTruth::new(spec.clone());
    generate_with_truth(&truth, n_samples)
}

/// Generate from an existing ground truth (lets callers keep `truth` for
/// Bayes-optimal baselines).
pub fn generate_with_truth(truth: &GroundTruth, n_samples: usize) -> Dataset {
    let spec = &truth.spec;
    let schema = truth.schema.clone();
    let n_fields = schema.n_fields();
    let zipfs: Vec<Zipf> = spec
        .vocabs
        .iter()
        .map(|&v| Zipf::new(v as usize, spec.zipf_s))
        .collect();

    let mut features = vec![0u32; n_samples * n_fields];
    let mut labels = vec![0u8; n_samples];

    // chunked parallel generation with per-chunk streams
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(16);
    let chunk = n_samples.div_ceil(threads).max(1);

    // generate features and labels chunk-by-chunk
    let feat_chunks: Vec<&mut [u32]> =
        features.chunks_mut(chunk * n_fields).collect();
    let label_chunks: Vec<&mut [u8]> = labels.chunks_mut(chunk).collect();
    let mut zipped: Vec<(usize, (&mut [u32], &mut [u8]))> = feat_chunks
        .into_iter()
        .zip(label_chunks)
        .enumerate()
        .collect();

    parallel_chunks(&mut zipped, threads, |_, items| {
        for (ci, (feat, lab)) in items.iter_mut() {
            let mut rng = Pcg32::new(spec.seed ^ mix64(*ci as u64), 0xFEED);
            let rows = lab.len();
            for r in 0..rows {
                let sample = &mut feat[r * n_fields..(r + 1) * n_fields];
                sample_features(spec, &schema, &zipfs, &mut rng, sample);
                let z = truth.logit(sample);
                let p = 1.0 / (1.0 + (-z).exp());
                lab[r] = rng.bernoulli(p) as u8;
            }
        }
    });

    Dataset { schema, features, labels }
}

/// Draw one sample's feature ids: per-field Zipf rank mapped through a
/// fixed per-field permutation, so "popular" ids are spread across the id
/// space (as in real logs) while keeping the Zipf frequency profile.
fn sample_features(
    spec: &SyntheticSpec,
    schema: &Schema,
    zipfs: &[Zipf],
    rng: &mut Pcg32,
    out: &mut [u32],
) {
    for (f, z) in zipfs.iter().enumerate() {
        let rank = z.sample(rng) as u64;
        let vocab = spec.vocabs[f] as u64;
        let id = permute(rank, vocab, spec.seed ^ f as u64) as u32;
        out[f] = schema.global_id(f, id);
    }
}

/// Cheap bijective permutation of [0, n): a few rounds of a hash-based
/// Feistel-ish cycle-walk on the next power of two.
fn permute(x: u64, n: u64, seed: u64) -> u64 {
    if n <= 1 {
        return 0;
    }
    let bits = 64 - (n - 1).leading_zeros();
    let mask = (1u64 << bits) - 1;
    let mut v = x;
    loop {
        // 3 rounds of masked mixing (bijective on [0, 2^bits))
        for r in 0..3u64 {
            let k = mix64(seed ^ r.wrapping_mul(0xA5A5_A5A5));
            v ^= (k >> 7) & mask;
            v = v.wrapping_mul(0x9E37_79B9 | 1) & mask;
            v ^= v >> (bits / 2).max(1);
            v &= mask;
        }
        if v < n {
            return v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permute_is_bijective() {
        for n in [1u64, 2, 7, 100, 1000] {
            let mut seen = vec![false; n as usize];
            for x in 0..n {
                let y = permute(x, n, 42);
                assert!(y < n);
                assert!(!seen[y as usize], "collision at n={n} x={x}");
                seen[y as usize] = true;
            }
        }
    }

    #[test]
    fn generate_shapes_and_ranges() {
        let spec = SyntheticSpec::tiny(1);
        let ds = generate(&spec, 2_000);
        assert_eq!(ds.n_samples(), 2_000);
        assert_eq!(ds.n_fields(), 8);
        let n_feat = ds.schema.n_features();
        for (i, &g) in ds.features.iter().enumerate() {
            assert!((g as usize) < n_feat, "id out of range at {i}");
            // id must belong to its field's slice
            let field = i % 8;
            assert_eq!(ds.schema.field_of(g), field);
        }
    }

    #[test]
    fn generate_deterministic() {
        let spec = SyntheticSpec::tiny(7);
        let a = generate(&spec, 500);
        let b = generate(&spec, 500);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&SyntheticSpec::tiny(1), 500);
        let b = generate(&SyntheticSpec::tiny(2), 500);
        assert_ne!(a.features, b.features);
    }

    #[test]
    fn ctr_near_target() {
        let spec = SyntheticSpec::tiny(3);
        let ds = generate(&spec, 20_000);
        let ctr = ds.ctr();
        assert!(
            (ctr - spec.target_ctr).abs() < 0.05,
            "ctr={ctr} target={}",
            spec.target_ctr
        );
    }

    #[test]
    fn frequencies_are_long_tailed() {
        let spec = SyntheticSpec::tiny(5);
        let ds = generate(&spec, 20_000);
        // count frequencies of field 0 (vocab 2000)
        let mut counts = vec![0u32; ds.schema.n_features()];
        for s in 0..ds.n_samples() {
            counts[ds.sample(s)[0] as usize] += 1;
        }
        let mut field0: Vec<u32> =
            counts[..spec.vocabs[0] as usize].to_vec();
        field0.sort_unstable_by(|a, b| b.cmp(a));
        let top10: u32 = field0[..10].iter().sum();
        let total: u32 = field0.iter().sum();
        assert!(total > 0);
        // Zipf(1.1) over 2000: top-10 ranks carry a large share
        assert!(
            top10 as f64 > 0.25 * total as f64,
            "top10={top10} total={total}"
        );
        // and a long tail exists: many features seen at most once
        let singletons = field0.iter().filter(|&&c| c <= 1).count();
        assert!(singletons > 500, "singletons={singletons}");
    }

    #[test]
    fn labels_learnable_from_truth() {
        // Bayes-optimal predictor (the true logit) must separate classes:
        // AUC well above random.
        let spec = SyntheticSpec::tiny(9);
        let truth = GroundTruth::new(spec.clone());
        let ds = generate_with_truth(&truth, 8_000);
        let logits: Vec<f32> =
            (0..ds.n_samples()).map(|i| truth.logit(ds.sample(i))).collect();
        let auc = crate::metrics::auc(&logits, &ds.labels);
        assert!(auc > 0.70, "bayes auc={auc}");
    }

    #[test]
    fn avazu_criteo_specs_consistent() {
        let a = SyntheticSpec::avazu(1);
        assert_eq!(a.vocabs.len(), 24);
        let c = SyntheticSpec::criteo(1);
        assert_eq!(c.vocabs.len(), 39);
        let scaled = SyntheticSpec::tiny(1).scale_vocabs(2.0);
        assert_eq!(scaled.vocabs[0], 4_000);
    }
}
