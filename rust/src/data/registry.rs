//! Dataset registry: one trait over every sample source — the in-memory
//! synthetic generators and streaming Criteo-format files — plus the
//! `--dataset` spec grammar and the epoch-stream assembly (holdout split
//! → seeded window shuffle) shared by the trainer and the serving path.
//!
//! Spec grammar (the `dataset` config key / `--dataset` flag):
//!
//! * `tiny` / `avazu` / `criteo` — in-memory synthetic specs (the
//!   pre-existing path: full shuffle, 8:1:1 split);
//! * `synthetic` / `synthetic:NAME` — the same generators consumed
//!   through the streaming interface (identical code path to files);
//! * `criteo:PATH` — Criteo-format TSV streamed from disk
//!   (see [`super::criteo`]).

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::batcher::{ShuffleStream, SplitStream};
use super::criteo::{CriteoCfg, CriteoFile};
use super::synthetic::{generate, SyntheticSpec};
use super::{Dataset, Schema};
use crate::config::{Experiment, FieldKind};

/// One in-order pass over a dataset's records. `Send` so the prefetching
/// batcher can pull records from a background thread.
pub trait RecordStream: Send {
    /// Write the next record's global feature ids into `out`
    /// (`schema.n_fields()` slots) and return its label, or `None` at the
    /// end of the stream.
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>>;
}

impl<T: RecordStream + ?Sized> RecordStream for Box<T> {
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>> {
        (**self).next_record(out)
    }
}

/// A source of CTR records: a schema plus the ability to open fresh
/// streams (one per epoch or eval pass). Sources are cheap handles; the
/// heavy state (open files, buffers) lives in the streams they mint.
pub trait DataSource: Send + Sync {
    fn name(&self) -> &str;
    fn schema(&self) -> &Schema;
    /// Record count when known without scanning (in-memory sources).
    fn len_hint(&self) -> Option<usize> {
        None
    }
    /// Open a fresh stream over all records in file/generation order.
    fn stream(&self) -> Result<Box<dyn RecordStream>>;
    /// Data-quality warnings accumulated by this source's streams so far
    /// (e.g. malformed lines skipped); empty when clean. Callers should
    /// surface these after a pass — a file whose every line is skipped
    /// would otherwise "train" silently on nothing.
    fn warnings(&self) -> Vec<String> {
        Vec::new()
    }
}

/// Parsed `--dataset` value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DatasetSpec {
    /// Synthetic generator consumed in memory (the pre-existing path).
    Synthetic(String),
    /// Synthetic generator consumed through the streaming interface.
    SyntheticStream(String),
    /// Criteo-format TSV streamed from disk.
    CriteoFile(std::path::PathBuf),
}

impl DatasetSpec {
    pub fn parse(s: &str) -> DatasetSpec {
        if let Some(path) = s.strip_prefix("criteo:") {
            DatasetSpec::CriteoFile(path.into())
        } else if let Some(name) = s.strip_prefix("synthetic:") {
            DatasetSpec::SyntheticStream(name.to_string())
        } else if s == "synthetic" {
            DatasetSpec::SyntheticStream("tiny".to_string())
        } else {
            DatasetSpec::Synthetic(s.to_string())
        }
    }

    /// Does this spec train through the streaming pipeline (vs the
    /// in-memory split/shuffle path)?
    pub fn is_streaming(&self) -> bool {
        !matches!(self, DatasetSpec::Synthetic(_))
    }
}

/// Build the [`DataSource`] an experiment's `dataset` key names.
pub fn open_source(exp: &Experiment) -> Result<Box<dyn DataSource>> {
    match DatasetSpec::parse(&exp.dataset) {
        DatasetSpec::Synthetic(name)
        | DatasetSpec::SyntheticStream(name) => {
            let spec =
                SyntheticSpec::for_dataset(&name, exp.seed, exp.vocab_scale)?;
            let name = spec.name.clone();
            let ds = generate(&spec, exp.n_samples);
            Ok(Box::new(SyntheticSource::from_dataset(&name, ds)))
        }
        DatasetSpec::CriteoFile(path) => {
            let cfg = CriteoCfg {
                hash_bits: exp.hash_bits,
                numeric_buckets: exp.numeric_buckets,
            };
            Ok(Box::new(CriteoFile::open(&path, cfg).with_context(
                || format!("opening dataset {}", path.display()),
            )?))
        }
    }
}

/// The schema (and so the embedding-table row count) a dataset spec
/// induces, without generating or scanning any data.
pub fn schema_for(exp: &Experiment) -> Result<Schema> {
    match DatasetSpec::parse(&exp.dataset) {
        DatasetSpec::Synthetic(name)
        | DatasetSpec::SyntheticStream(name) => {
            let spec =
                SyntheticSpec::for_dataset(&name, exp.seed, exp.vocab_scale)?;
            Ok(Schema::new(spec.vocabs))
        }
        DatasetSpec::CriteoFile(_) => {
            let cfg = CriteoCfg {
                hash_bits: exp.hash_bits,
                numeric_buckets: exp.numeric_buckets,
            };
            cfg.validate()?;
            Ok(cfg.schema())
        }
    }
}

/// The per-field kinds a dataset spec induces — the layout precision
/// plans (`--plan cat:4,num:8`) resolve against. Criteo-format files
/// carry 13 numeric fields then 26 categorical ones; the synthetic
/// generators are all-categorical. Like [`schema_for`], this needs no
/// data generation or file access.
pub fn field_kinds(exp: &Experiment) -> Result<Vec<FieldKind>> {
    match DatasetSpec::parse(&exp.dataset) {
        DatasetSpec::Synthetic(name)
        | DatasetSpec::SyntheticStream(name) => {
            let spec =
                SyntheticSpec::for_dataset(&name, exp.seed, exp.vocab_scale)?;
            Ok(vec![FieldKind::Categorical; spec.vocabs.len()])
        }
        DatasetSpec::CriteoFile(_) => {
            let mut kinds =
                vec![FieldKind::Numeric; super::criteo::N_NUMERIC];
            kinds.extend(vec![
                FieldKind::Categorical;
                super::criteo::N_CATEGORICAL
            ]);
            Ok(kinds)
        }
    }
}

/// Streaming view over an in-memory dataset (synthetic generators, test
/// fixtures). The data is shared, not copied, across streams.
pub struct SyntheticSource {
    name: String,
    ds: Arc<Dataset>,
}

impl SyntheticSource {
    pub fn from_dataset(name: &str, ds: Dataset) -> Self {
        Self { name: name.to_string(), ds: Arc::new(ds) }
    }
}

impl DataSource for SyntheticSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn schema(&self) -> &Schema {
        &self.ds.schema
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.ds.n_samples())
    }

    fn stream(&self) -> Result<Box<dyn RecordStream>> {
        Ok(Box::new(SyntheticStream { ds: Arc::clone(&self.ds), next: 0 }))
    }
}

struct SyntheticStream {
    ds: Arc<Dataset>,
    next: usize,
}

impl RecordStream for SyntheticStream {
    fn next_record(&mut self, out: &mut [u32]) -> Result<Option<u8>> {
        if self.next >= self.ds.n_samples() {
            return Ok(None);
        }
        out.copy_from_slice(self.ds.sample(self.next));
        let label = self.ds.labels[self.next];
        self.next += 1;
        Ok(Some(label))
    }
}

/// Training-split stream for `epoch` (1-based): held-out records removed,
/// remainder shuffled through a seeded reservoir window. The per-epoch
/// seed uses the same mixing as the in-memory `Trainer::train` loop, so
/// every epoch sees a fresh deterministic order.
pub fn train_epoch_stream(
    source: &dyn DataSource,
    exp: &Experiment,
    epoch: usize,
) -> Result<Box<dyn RecordStream>> {
    let split = SplitStream::train(source.stream()?, exp.seed);
    let epoch_seed = exp.seed ^ (epoch as u64).wrapping_mul(0x9E37);
    Ok(Box::new(ShuffleStream::new(
        split,
        exp.shuffle_window,
        epoch_seed,
    )))
}

/// Held-out split stream (deterministic order, no shuffle) — the eval
/// counterpart of [`train_epoch_stream`].
pub fn val_stream(
    source: &dyn DataSource,
    exp: &Experiment,
) -> Result<Box<dyn RecordStream>> {
    Ok(Box::new(SplitStream::val(source.stream()?, exp.seed)))
}

/// The single dataset ↔ model/table compatibility rule shared by the
/// training and serving paths (one definition, so it cannot drift):
/// field counts must match the model exactly; the embedding table may be
/// *larger* than the schema needs (e.g. warm-started from a bigger run),
/// never smaller.
pub fn ensure_compat(
    source: &dyn DataSource,
    model: &str,
    fields: usize,
    table_rows: usize,
) -> Result<()> {
    ensure!(
        source.schema().n_fields() == fields,
        "dataset {} has {} fields, model {model:?} expects {fields}",
        source.name(),
        source.schema().n_fields(),
    );
    ensure!(
        source.schema().n_features() <= table_rows,
        "dataset {} needs {} feature rows, the table holds {table_rows}",
        source.name(),
        source.schema().n_features(),
    );
    Ok(())
}

/// Discard `n` already-consumed records — the resume-from-checkpoint
/// fast-forward. The stream is a deterministic function of
/// (source, seed, epoch), so skipping reproduces the remainder exactly.
/// Errors when the stream runs out early: that means the data changed
/// under the checkpoint (truncated or different file), and continuing
/// would silently break the bit-identical-resume contract.
pub fn skip_records(
    stream: &mut dyn RecordStream,
    n_fields: usize,
    n: u64,
) -> Result<()> {
    let mut buf = vec![0u32; n_fields];
    for i in 0..n {
        ensure!(
            stream.next_record(&mut buf)?.is_some(),
            "stream ended after {i} of {n} skipped records — has the \
             dataset changed since the checkpoint was written?"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_source(n: usize) -> SyntheticSource {
        let schema = Schema::new(vec![4, 3]);
        let mut features = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            features.push((i % 4) as u32);
            features.push(4 + (i % 3) as u32);
            labels.push((i % 2) as u8);
        }
        SyntheticSource::from_dataset(
            "toy",
            Dataset { schema, features, labels },
        )
    }

    #[test]
    fn spec_grammar() {
        assert_eq!(
            DatasetSpec::parse("tiny"),
            DatasetSpec::Synthetic("tiny".into())
        );
        assert_eq!(
            DatasetSpec::parse("synthetic"),
            DatasetSpec::SyntheticStream("tiny".into())
        );
        assert_eq!(
            DatasetSpec::parse("synthetic:avazu"),
            DatasetSpec::SyntheticStream("avazu".into())
        );
        assert_eq!(
            DatasetSpec::parse("criteo:/data/day_0.tsv"),
            DatasetSpec::CriteoFile("/data/day_0.tsv".into())
        );
        // plain "criteo" stays the synthetic spec (back-compat)
        assert!(!DatasetSpec::parse("criteo").is_streaming());
        assert!(DatasetSpec::parse("criteo:x").is_streaming());
        assert!(DatasetSpec::parse("synthetic").is_streaming());
    }

    #[test]
    fn synthetic_source_streams_every_record_in_order() {
        let src = toy_source(23);
        assert_eq!(src.len_hint(), Some(23));
        let mut stream = src.stream().unwrap();
        let mut out = vec![0u32; 2];
        let mut n = 0usize;
        while let Some(label) = stream.next_record(&mut out).unwrap() {
            assert_eq!(out[0], (n % 4) as u32);
            assert_eq!(label, (n % 2) as u8);
            n += 1;
        }
        assert_eq!(n, 23);
        // a second stream starts over
        let mut again = src.stream().unwrap();
        assert!(again.next_record(&mut out).unwrap().is_some());
        assert_eq!(out[0], 0);
    }

    #[test]
    fn field_kinds_match_the_layouts() {
        let exp = Experiment {
            dataset: "criteo:/data/train.tsv".into(),
            ..Experiment::default()
        };
        let kinds = field_kinds(&exp).unwrap();
        assert_eq!(kinds.len(), 39);
        assert!(kinds[..13].iter().all(|&k| k == FieldKind::Numeric));
        assert!(kinds[13..].iter().all(|&k| k == FieldKind::Categorical));
        let exp = Experiment {
            dataset: "synthetic:tiny".into(),
            ..Experiment::default()
        };
        let kinds = field_kinds(&exp).unwrap();
        assert_eq!(kinds.len(), schema_for(&exp).unwrap().n_fields());
        assert!(kinds.iter().all(|&k| k == FieldKind::Categorical));
    }

    #[test]
    fn schema_for_matches_sources() {
        let exp = Experiment {
            dataset: "synthetic:tiny".into(),
            ..Experiment::default()
        };
        let schema = schema_for(&exp).unwrap();
        let src = open_source(&exp).unwrap();
        assert_eq!(&schema, src.schema());

        let exp = Experiment {
            dataset: "criteo:/no/such/file".into(),
            hash_bits: 8,
            ..Experiment::default()
        };
        // schema needs no file ...
        let schema = schema_for(&exp).unwrap();
        assert_eq!(schema.n_fields(), 39);
        // ... but opening the source does
        assert!(open_source(&exp).is_err());
    }

    #[test]
    fn train_and_val_streams_partition_the_source() {
        let src = toy_source(200);
        let exp = Experiment {
            shuffle_window: 1, // identity shuffle: order preserved
            ..Experiment::default()
        };
        let count = |s: &mut dyn RecordStream| {
            let mut out = vec![0u32; 2];
            let mut n = 0usize;
            while s.next_record(&mut out).unwrap().is_some() {
                n += 1;
            }
            n
        };
        let n_train =
            count(train_epoch_stream(&src, &exp, 1).unwrap().as_mut());
        let n_val = count(val_stream(&src, &exp).unwrap().as_mut());
        assert_eq!(n_train + n_val, 200);
        // ~10% holdout (wide bounds: the split is a hash, not a quota)
        assert!((5..=45).contains(&n_val), "n_val={n_val}");
    }

    #[test]
    fn skip_records_fast_forwards_exactly() {
        let src = toy_source(60);
        let exp = Experiment::default();
        let mut full = train_epoch_stream(&src, &exp, 2).unwrap();
        let mut out = vec![0u32; 2];
        let mut tail_expected = Vec::new();
        let mut i = 0u64;
        while let Some(l) = full.next_record(&mut out).unwrap() {
            if i >= 17 {
                tail_expected.push((out.clone(), l));
            }
            i += 1;
        }
        let mut skipped = train_epoch_stream(&src, &exp, 2).unwrap();
        skip_records(skipped.as_mut(), 2, 17).unwrap();
        let mut tail = Vec::new();
        while let Some(l) = skipped.next_record(&mut out).unwrap() {
            tail.push((out.clone(), l));
        }
        assert_eq!(tail, tail_expected);

        // skipping past the end is a dataset-changed error, not a no-op
        let mut short = train_epoch_stream(&src, &exp, 2).unwrap();
        let err = skip_records(short.as_mut(), 2, 10_000).unwrap_err();
        assert!(
            format!("{err:#}").contains("dataset changed"),
            "{err:#}"
        );
    }
}
