//! # ALPT — Adaptive Low-Precision Training for CTR embeddings
//!
//! Production-style reproduction of *Adaptive Low-Precision Training for
//! Embeddings in Click-Through Rate Prediction* (AAAI 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the training system: quantized embedding
//!   tables (bit-packed integers + per-feature learned step sizes), the
//!   data pipeline, batching/dedup, optimizers, metrics, a sharded
//!   leader/worker simulation with communication accounting, and the PJRT
//!   runtime that executes the AOT-compiled model. Python never runs on
//!   the training path.
//! * **Layer 2** — the DCN backbone in JAX (`python/compile/model.py`),
//!   lowered once to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — Pallas kernels for dequantize / SR-DR quantize / LSQ
//!   fake-quant / DCN cross layer (`python/compile/kernels/`).
//!
//! Entry points: [`coordinator::Trainer`] for training,
//! [`serve::InferenceEngine`] for online scoring (and [`serve::http`]
//! for the HTTP server behind `alpt serve --listen`),
//! [`runtime::Runtime`] for artifact execution, [`embedding`] for the
//! paper's table variants (FP / LPT / ALPT / hashing / pruning / QAT).

pub mod analysis;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embedding;
pub mod experiments;
pub mod metrics;
pub mod nn;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
