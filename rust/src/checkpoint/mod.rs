//! Versioned checkpoint & warm-start subsystem for embedding tables.
//!
//! The deploy half of the paper: training compresses the table (packed
//! int codes + per-row step sizes), and this module makes that artifact
//! *durable* — one binary file holding the store's raw packed rows
//! (bit-identical, never dequantized), the learned per-row scalars, the
//! DCN dense parameters, and the optimizer/trainer state needed to resume
//! training exactly where it stopped.
//!
//! Structure:
//!
//! * [`format`] — magic/version constants, section kinds, CRC32, codecs;
//! * [`writer`] — streaming [`CheckpointWriter`] (one section at a time);
//! * [`reader`] — [`Checkpoint`]: full validation up front (magic,
//!   version, bounds, per-section CRC) before any payload is used;
//! * this module — the store-level API: [`save_store`] / [`load_store`]
//!   plus the `Experiment` echo that lets a checkpoint rebuild its own
//!   training configuration.
//!
//! **Determinism contract.** A checkpoint's bytes are a pure function of
//! the store contents and the experiment — *never* of the thread count:
//! rows are sharded into fixed [`SHARD_ROWS`]-row sections, and the
//! metadata records the store's update-step counter (the `StreamKey`
//! input), so a resumed trainer draws exactly the SR noise an
//! uninterrupted run would have drawn. Save → load → save produces
//! byte-identical files.
//!
//! The same contract makes distributed checkpoints reshardable: rows are
//! always persisted in canonical *global* order regardless of how a
//! `RemoteStore` had them partitioned, and the worker partition
//! (`coordinator::sharding::RowPartition`) is a pure function of
//! `(id, n_shards)` that never enters the file — so a table trained on N
//! workers resumes on M (or one process) from the unchanged v1/v2/v3
//! formats.

pub mod failpoint;
pub mod format;
pub mod journal;
pub mod reader;
pub mod writer;

pub use format::SectionKind;
pub use journal::{journal_path, Delta, DeltaChain, JournalWriter};
pub use reader::{Checkpoint, Section};
pub use writer::{tmp_path, CheckpointWriter};

use std::path::Path;

use anyhow::{anyhow, ensure, Result};

use crate::config::{Experiment, Method, PrecisionPlan};
use crate::embedding::{build_store, EmbeddingStore, GroupedStore};
use crate::quant::GradScale;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use format::{parse_f32s, put_f32s, VERSION, VERSION_GROUPED,
             VERSION_KINDED};

/// Rows per `Rows` section. Fixed (not tied to the thread config) so the
/// file layout is identical no matter how the writer was parallelized;
/// also bounds the writer/reader shard buffer (64 Ki rows).
pub const SHARD_ROWS: usize = 1 << 16;

/// Open a writer whose header version matches `store`'s checkpoint
/// format: single-group stores with per-row payloads write version 1
/// (byte-identical to the pre-grouping layout), grouped mixed-precision
/// stores version 2, and anything holding aux-only state — hashing, or a
/// grouped store with structural (hashed/pruned) groups — version 3.
pub fn writer_for_store(
    path: &Path,
    store: &dyn EmbeddingStore,
) -> Result<CheckpointWriter> {
    CheckpointWriter::create_with_version(path, store_version(store))
}

/// The checkpoint format version `store` serializes as (see
/// [`writer_for_store`]).
fn store_version(store: &dyn EmbeddingStore) -> u32 {
    match store.as_grouped() {
        Some(gs) if gs.has_structural_groups() => VERSION_KINDED,
        Some(_) => VERSION_GROUPED,
        None if store.ckpt_row_bytes().is_none() => VERSION_KINDED,
        None => VERSION,
    }
}

/// Serialize `store` (rows + aux scalars + metadata echoing `exp`) to
/// `path`, returning the published file's anchor id.
pub fn save_store(
    path: &Path,
    store: &dyn EmbeddingStore,
    exp: &Experiment,
) -> Result<u32> {
    let mut w = writer_for_store(path, store)?;
    write_store_sections(&mut w, store, exp)?;
    w.finish()
}

/// Write the store-owned sections (`Meta`, `Rows` shards, `Aux`) into an
/// open writer. `Trainer::save_checkpoint` appends its own sections
/// (dense / optimizer / rng) after this. Grouped mixed-precision stores
/// take the format-v2 layout (one section run per precision group);
/// everything else writes the version-1 layout unchanged.
pub fn write_store_sections(
    w: &mut CheckpointWriter,
    store: &dyn EmbeddingStore,
    exp: &Experiment,
) -> Result<()> {
    if let Some(gs) = store.as_grouped() {
        return write_grouped_sections(w, gs, exp);
    }
    // aux-only stores (hashing: shared tables, no per-row payload) write
    // row_bytes 0 / n_shards 0 and persist everything through Aux —
    // that's the version-3 single-store layout
    let row_bytes = store.ckpt_row_bytes().unwrap_or(0);
    let n = store.n_features();
    let n_shards =
        if row_bytes == 0 { 0 } else { n.div_ceil(SHARD_ROWS) };
    let aux_len = store.aux_params().len();
    let version =
        if row_bytes == 0 { VERSION_KINDED } else { VERSION };

    let meta = Json::obj(vec![
        ("aux_len", Json::num(aux_len as f64)),
        ("d", Json::num(store.dim() as f64)),
        ("experiment", experiment_to_json(exp)),
        ("format", Json::str("alpt-checkpoint")),
        ("method", Json::str(exp.method.key())),
        ("n", Json::num(n as f64)),
        ("n_shards", Json::num(n_shards as f64)),
        ("row_bytes", Json::num(row_bytes as f64)),
        ("shard_rows", Json::num(SHARD_ROWS as f64)),
        ("step", Json::num(store.step_counter() as f64)),
        ("version", Json::num(version as f64)),
    ]);
    w.section(SectionKind::Meta, 0, meta.to_string().as_bytes())?;

    // one reusable shard buffer bounds peak memory at SHARD_ROWS rows
    let mut buf = vec![0u8; SHARD_ROWS.min(n.max(1)) * row_bytes];
    for shard in 0..n_shards {
        let lo = shard * SHARD_ROWS;
        let rows = SHARD_ROWS.min(n - lo);
        let dst = &mut buf[..rows * row_bytes];
        store.save_rows(lo, dst)?;
        w.section(SectionKind::Rows, shard as u32, dst)?;
    }

    if aux_len > 0 {
        let mut aux_bytes = Vec::with_capacity(aux_len * 4);
        put_f32s(&mut aux_bytes, store.aux_params());
        w.section(SectionKind::Aux, 0, &aux_bytes)?;
    }
    Ok(())
}

/// Format-v2/-v3 store sections: the meta carries one `{aux_len, bits,
/// row_bytes, rows}` header per precision group; `Rows` sections run
/// group by group with one global shard counter; each group's per-row
/// scalars live in an `Aux` section indexed by the group number. Every
/// group's payload goes through the same [`EmbeddingStore`] hooks the
/// single-group path uses, so the raw packed bytes stay verbatim.
///
/// Plans with structural (hashed/pruned) groups write version 3: each
/// group header additionally names its `kind`, and aux-only groups
/// (hashing) record `row_bytes` 0 and contribute no `Rows` sections.
/// The `kind` key is withheld from version-2 files so packed-only plans
/// keep their pre-v3 bytes.
fn write_grouped_sections(
    w: &mut CheckpointWriter,
    gs: &GroupedStore,
    exp: &Experiment,
) -> Result<()> {
    let n = gs.n_features();
    let kinded = gs.has_structural_groups();
    let version =
        if kinded { VERSION_KINDED } else { VERSION_GROUPED };
    let groups_json = Json::Array(
        (0..gs.n_groups())
            .map(|g| {
                let sub = gs.group_store(g);
                let row_bytes = sub.ckpt_row_bytes().unwrap_or(0);
                let mut fields = vec![
                    ("aux_len", Json::num(sub.aux_params().len() as f64)),
                    ("bits", Json::num(gs.group_bits(g) as f64)),
                ];
                if kinded {
                    fields.push(("kind", Json::str(gs.group_kind(g))));
                }
                fields.push(("row_bytes", Json::num(row_bytes as f64)));
                fields.push(("rows", Json::num(gs.group_rows(g) as f64)));
                Json::obj(fields)
            })
            .collect(),
    );
    let meta = Json::obj(vec![
        ("d", Json::num(gs.dim() as f64)),
        ("experiment", experiment_to_json(exp)),
        ("format", Json::str("alpt-checkpoint")),
        ("groups", groups_json),
        ("method", Json::str(exp.method.key())),
        ("n", Json::num(n as f64)),
        ("shard_rows", Json::num(SHARD_ROWS as f64)),
        ("step", Json::num(gs.step_counter() as f64)),
        ("version", Json::num(version as f64)),
    ]);
    w.section(SectionKind::Meta, 0, meta.to_string().as_bytes())?;

    let mut buf = Vec::new();
    let mut shard_idx = 0u32;
    for g in 0..gs.n_groups() {
        let sub = gs.group_store(g);
        let Some(row_bytes) = sub.ckpt_row_bytes() else {
            continue; // aux-only group: no Rows sections
        };
        let rows_total = gs.group_rows(g);
        for shard in 0..rows_total.div_ceil(SHARD_ROWS) {
            let lo = shard * SHARD_ROWS;
            let rows = SHARD_ROWS.min(rows_total - lo);
            buf.resize(rows * row_bytes, 0);
            sub.save_rows(lo, &mut buf)?;
            w.section(SectionKind::Rows, shard_idx, &buf)?;
            shard_idx += 1;
        }
    }
    for g in 0..gs.n_groups() {
        let aux = gs.group_store(g).aux_params();
        if !aux.is_empty() {
            let mut aux_bytes = Vec::with_capacity(aux.len() * 4);
            put_f32s(&mut aux_bytes, aux);
            w.section(SectionKind::Aux, g as u32, &aux_bytes)?;
        }
    }
    Ok(())
}

/// Rebuild the store a checkpoint describes: construct it from the
/// echoed `Experiment`, then overwrite every row payload, aux scalar and
/// the update-step counter with the persisted values. The packed bytes
/// are restored verbatim — no dequantize/requantize round-trip.
pub fn load_store(
    ckpt: &Checkpoint,
) -> Result<(Box<dyn EmbeddingStore>, Experiment)> {
    let exp = experiment_from_json(ckpt.meta.get("experiment")?)?;
    let n = ckpt.meta_usize("n")?;
    let d = ckpt.meta_usize("d")?;
    ensure!(
        ckpt.meta_str("method")? == exp.method.key(),
        "metadata method disagrees with the experiment echo"
    );

    // throwaway generator: every value it seeds is overwritten below
    let mut store =
        build_store(&exp, n, d, &mut Pcg32::new(exp.seed, 0xC4C7))?;
    load_store_into(store.as_mut(), ckpt)?;
    Ok((store, exp))
}

/// Overwrite an existing store's rows, aux scalars and step counter from
/// a validated checkpoint. The store's geometry must match the file —
/// every mismatch (rows, dims, row payload width) errors before any
/// state is touched. Used by `load_store` and by `Trainer::restore_from`
/// (which loads straight into the trainer's own store instead of
/// building a second table).
pub fn load_store_into(
    store: &mut dyn EmbeddingStore,
    ckpt: &Checkpoint,
) -> Result<()> {
    let n = ckpt.meta_usize("n")?;
    let d = ckpt.meta_usize("d")?;
    ensure!(
        n == store.n_features() && d == store.dim(),
        "geometry mismatch: checkpoint is {n} x {d}, the {} store is \
         {} x {}",
        store.method_name(),
        store.n_features(),
        store.dim()
    );
    if ckpt.meta.opt("groups").is_some() {
        return load_grouped_into(store, ckpt);
    }
    ensure!(
        store.as_grouped().is_none(),
        "single-group checkpoint cannot restore the grouped {} store \
         (precision plan mismatch?)",
        store.method_name()
    );
    let row_bytes = store.ckpt_row_bytes().unwrap_or(0);
    ensure!(
        row_bytes == ckpt.meta_usize("row_bytes")?,
        "row payload width mismatch: checkpoint has {} bytes/row, the \
         rebuilt {} store expects {} (bits or dim changed?)",
        ckpt.meta_usize("row_bytes")?,
        store.method_name(),
        row_bytes
    );
    let shard_rows = ckpt.meta_usize("shard_rows")?;
    ensure!(shard_rows > 0, "shard_rows must be positive");
    let n_shards = ckpt.meta_usize("n_shards")?;
    let want_shards =
        if row_bytes == 0 { 0 } else { n.div_ceil(shard_rows) };
    ensure!(
        n_shards == want_shards,
        "inconsistent shard count: {n_shards} sections for {n} rows at \
         {shard_rows} rows/shard"
    );

    for shard in 0..n_shards {
        let lo = shard * shard_rows;
        let rows = shard_rows.min(n - lo);
        let sec = ckpt.section(SectionKind::Rows, shard as u32)?;
        ensure!(
            sec.payload.len() == rows * row_bytes,
            "rows shard {shard}: payload is {} bytes, expected {}",
            sec.payload.len(),
            rows * row_bytes
        );
        store.load_rows(lo, sec.payload)?;
    }

    let aux_len = ckpt.meta_usize("aux_len")?;
    if aux_len > 0 {
        let sec = ckpt.section(SectionKind::Aux, 0)?;
        let aux = parse_f32s(sec.payload)?;
        ensure!(
            aux.len() == aux_len,
            "aux section holds {} values, metadata says {aux_len}",
            aux.len()
        );
        store.load_aux_params(&aux)?;
    } else {
        ensure!(
            store.aux_params().is_empty(),
            "{} expects aux params but the checkpoint has none",
            store.method_name()
        );
    }

    store.set_step_counter(ckpt.meta_usize("step")? as u64);
    Ok(())
}

/// Restore a grouped store from a format-v2 checkpoint: every group
/// header (bits / rows / row payload width / aux count) is validated
/// against the rebuilt store before its sections load, so a plan or
/// layout mismatch errors with the offending group named.
fn load_grouped_into(
    store: &mut dyn EmbeddingStore,
    ckpt: &Checkpoint,
) -> Result<()> {
    let gs = store.as_grouped_mut().ok_or_else(|| {
        anyhow!(
            "checkpoint has precision groups but the rebuilt store is \
             single-group (precision plan mismatch?)"
        )
    })?;
    let shard_rows = ckpt.meta_usize("shard_rows")?;
    ensure!(shard_rows > 0, "shard_rows must be positive");
    let groups_meta = ckpt.meta.get("groups")?.as_array()?;
    ensure!(
        groups_meta.len() == gs.n_groups(),
        "checkpoint has {} precision groups, the rebuilt store {}",
        groups_meta.len(),
        gs.n_groups()
    );

    let mut shard_idx = 0u32;
    for (g, gm) in groups_meta.iter().enumerate() {
        let bits = gm.get("bits")?.as_usize()? as u32;
        let rows = gm.get("rows")?.as_usize()?;
        let row_bytes = gm.get("row_bytes")?.as_usize()?;
        let aux_len = gm.get("aux_len")?.as_usize()?;
        ensure!(
            bits == gs.group_bits(g) && rows == gs.group_rows(g),
            "group {g}: checkpoint holds {rows} rows at {bits} bits, the \
             rebuilt store expects {} rows at {} bits",
            gs.group_rows(g),
            gs.group_bits(g)
        );
        // v3 headers name their kind; validate when present (v2 files
        // predate kinds and are packed-only by construction)
        if let Some(k) = gm.opt("kind") {
            let kind = k.as_str()?;
            ensure!(
                kind == gs.group_kind(g),
                "group {g}: checkpoint holds a {kind:?} group, the \
                 rebuilt store has {:?} (precision plan mismatch?)",
                gs.group_kind(g)
            );
        }
        let sub_row_bytes =
            gs.group_store(g).ckpt_row_bytes().unwrap_or(0);
        ensure!(
            row_bytes == sub_row_bytes,
            "group {g}: row payload width mismatch ({row_bytes} vs \
             {sub_row_bytes} bytes/row)"
        );
        let n_shards =
            if row_bytes == 0 { 0 } else { rows.div_ceil(shard_rows) };
        for shard in 0..n_shards {
            let lo = shard * shard_rows;
            let count = shard_rows.min(rows - lo);
            let sec = ckpt.section(SectionKind::Rows, shard_idx)?;
            ensure!(
                sec.payload.len() == count * row_bytes,
                "group {g} rows shard {shard}: payload is {} bytes, \
                 expected {}",
                sec.payload.len(),
                count * row_bytes
            );
            gs.group_store_mut(g).load_rows(lo, sec.payload)?;
            shard_idx += 1;
        }
        if aux_len > 0 {
            let sec = ckpt.section(SectionKind::Aux, g as u32)?;
            let aux = parse_f32s(sec.payload)?;
            ensure!(
                aux.len() == aux_len,
                "group {g}: aux section holds {} values, metadata says \
                 {aux_len}",
                aux.len()
            );
            gs.group_store_mut(g).load_aux_params(&aux)?;
        } else {
            ensure!(
                gs.group_store(g).aux_params().is_empty(),
                "group {g} expects aux params but the checkpoint has none"
            );
        }
    }
    gs.set_step_counter(ckpt.meta_usize("step")? as u64);
    Ok(())
}

/// The dense-parameter vector persisted by `Trainer::save_checkpoint`
/// (also present in serving fixtures).
pub fn dense_params(ckpt: &Checkpoint) -> Result<Vec<f32>> {
    parse_f32s(ckpt.section(SectionKind::Dense, 0)?.payload)
}

// ------------------------------------------------------- experiment echo

/// Serialize the full `Experiment` so a checkpoint can rebuild its own
/// training configuration. f32 fields widen to f64 exactly and the JSON
/// number round-trips the f64 exactly; u64 seeds are encoded as decimal
/// strings (a JSON number only carries 53 bits) — so the echo is
/// lossless for every representable value.
pub fn experiment_to_json(exp: &Experiment) -> Json {
    let mut fields = vec![
        ("artifacts_dir", Json::str(&exp.artifacts_dir)),
        // uniform plans echo as a plain number (byte-identical to the
        // pre-plan format); mixed plans as the plan string
        ("bits", exp.bits.echo_json()),
        ("clip", Json::num(exp.clip as f64)),
        ("compact_every", Json::num(exp.compact_every as f64)),
        ("dataset", Json::str(&exp.dataset)),
        ("dropout_seed", Json::str(&exp.dropout_seed.to_string())),
        ("epochs", Json::num(exp.epochs as f64)),
        ("grad_scale", Json::str(exp.grad_scale.key())),
        ("hash_bits", Json::num(exp.hash_bits as f64)),
        ("lr_delta", Json::num(exp.lr_delta as f64)),
        ("lr_dense", Json::num(exp.lr_dense as f64)),
        ("lr_emb", Json::num(exp.lr_emb as f64)),
        ("lr_gamma", Json::num(exp.lr_gamma as f64)),
        (
            "lr_milestones",
            Json::Array(
                exp.lr_milestones
                    .iter()
                    .map(|&m| Json::num(m as f64))
                    .collect(),
            ),
        ),
        ("method", Json::str(exp.method.key())),
        ("model", Json::str(&exp.model)),
        ("n_samples", Json::num(exp.n_samples as f64)),
        ("numeric_buckets", Json::num(exp.numeric_buckets as f64)),
        ("patience", Json::num(exp.patience as f64)),
        ("prefetch_batches", Json::num(exp.prefetch_batches as f64)),
        ("save_every", Json::num(exp.save_every as f64)),
        ("seed", Json::str(&exp.seed.to_string())),
        ("shuffle_window", Json::num(exp.shuffle_window as f64)),
        ("threads", Json::num(exp.threads as f64)),
        ("use_runtime", Json::Bool(exp.use_runtime)),
        ("vocab_scale", Json::num(exp.vocab_scale)),
        ("wd_delta", Json::num(exp.wd_delta as f64)),
        ("wd_emb", Json::num(exp.wd_emb as f64)),
    ];
    // emitted only when set so every pre-replan configuration keeps its
    // exact pre-PR echo bytes (the byte-identity fixtures pin them)
    if exp.replan_budget != 0 {
        let at = fields
            .iter()
            .position(|(k, _)| *k == "save_every")
            .expect("echo always carries save_every");
        fields.insert(
            at,
            ("replan_budget", Json::num(exp.replan_budget as f64)),
        );
    }
    Json::obj(fields)
}

/// Inverse of [`experiment_to_json`].
pub fn experiment_from_json(v: &Json) -> Result<Experiment> {
    let f32_of = |key: &str| -> Result<f32> {
        Ok(v.get(key)?.as_f64()? as f32)
    };
    // u64 seeds are strings (full 64-bit range); integral JSON numbers
    // are accepted too for hand-written files, exact below 2^53
    let u64_of = |key: &str| -> Result<u64> {
        match v.get(key)? {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow!("{key}: bad u64 string {s:?}")),
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0
                && *x <= 9.0e15 => Ok(*x as u64),
            _ => Err(anyhow!("{key}: expected a u64 string")),
        }
    };
    // streaming-pipeline keys arrived after format v1 shipped; absent in
    // older echoes, they fall back to the defaults those runs used
    let opt_usize = |key: &str, default: usize| -> Result<usize> {
        match v.opt(key) {
            Some(x) => x.as_usize(),
            None => Ok(default),
        }
    };
    let defaults = Experiment::default();
    Ok(Experiment {
        dataset: v.get("dataset")?.as_str()?.to_string(),
        vocab_scale: v.get("vocab_scale")?.as_f64()?,
        n_samples: v.get("n_samples")?.as_usize()?,
        model: v.get("model")?.as_str()?.to_string(),
        method: Method::parse(v.get("method")?.as_str()?)?,
        bits: PrecisionPlan::from_json(v.get("bits")?)?,
        epochs: v.get("epochs")?.as_usize()?,
        seed: u64_of("seed")?,
        lr_dense: f32_of("lr_dense")?,
        lr_emb: f32_of("lr_emb")?,
        lr_delta: f32_of("lr_delta")?,
        wd_emb: f32_of("wd_emb")?,
        wd_delta: f32_of("wd_delta")?,
        grad_scale: match v.get("grad_scale")?.as_str()? {
            "one" => GradScale::One,
            "inv_sqrt_dq" => GradScale::InvSqrtDq,
            "inv_sqrt_bdq" => GradScale::InvSqrtBdq,
            other => anyhow::bail!("unknown grad_scale {other:?}"),
        },
        clip: f32_of("clip")?,
        lr_milestones: v.get("lr_milestones")?.usize_array()?,
        lr_gamma: f32_of("lr_gamma")?,
        dropout_seed: u64_of("dropout_seed")?,
        patience: v.get("patience")?.as_usize()?,
        artifacts_dir: v.get("artifacts_dir")?.as_str()?.to_string(),
        use_runtime: v.get("use_runtime")?.as_bool()?,
        threads: v.get("threads")?.as_usize()?,
        hash_bits: opt_usize("hash_bits", defaults.hash_bits as usize)?
            as u32,
        numeric_buckets: opt_usize(
            "numeric_buckets",
            defaults.numeric_buckets as usize,
        )? as u32,
        shuffle_window: opt_usize(
            "shuffle_window",
            defaults.shuffle_window,
        )?,
        prefetch_batches: opt_usize(
            "prefetch_batches",
            defaults.prefetch_batches,
        )?,
        save_every: opt_usize("save_every", defaults.save_every)?,
        compact_every: opt_usize(
            "compact_every",
            defaults.compact_every,
        )?,
        replan_budget: opt_usize("replan_budget", 0)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoundingMode;
    use crate::coordinator::Trainer;
    use crate::data::batcher::{Batch, Batcher};
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::embedding::testutil::hp;
    use crate::util::prop::{check, Gen};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_ckpt_mod_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn exp_for(method: Method, bits: u32, threads: usize) -> Experiment {
        Experiment {
            method,
            bits: PrecisionPlan::uniform(bits),
            threads,
            use_runtime: false,
            model: "tiny".into(),
            ..Experiment::default()
        }
    }

    /// Save `store`, load it back, save the loaded copy, and require the
    /// two files to be byte-identical (the acceptance contract). Returns
    /// the loaded store.
    fn roundtrip(
        name: &str,
        store: &dyn EmbeddingStore,
        exp: &Experiment,
    ) -> Box<dyn EmbeddingStore> {
        let p1 = tmp(&format!("{name}.1.ckpt"));
        let p2 = tmp(&format!("{name}.2.ckpt"));
        save_store(&p1, store, exp).unwrap();
        let ck = Checkpoint::read(&p1).unwrap();
        let (loaded, exp2) = load_store(&ck).unwrap();
        save_store(&p2, loaded.as_ref(), &exp2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        assert_eq!(b1, b2, "{name}: save→load→save changed bytes");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
        loaded
    }

    fn gather_all(store: &dyn EmbeddingStore) -> Vec<f32> {
        let ids: Vec<u32> = (0..store.n_features() as u32).collect();
        let mut out = vec![0.0f32; ids.len() * store.dim()];
        store.gather(&ids, &mut out);
        out
    }

    #[test]
    fn experiment_echo_is_lossless() {
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Dr),
            bits: PrecisionPlan::uniform(4),
            clip: 0.001,
            lr_delta: 2e-5,
            lr_milestones: vec![3, 5, 11],
            use_runtime: false,
            threads: 3,
            // above 2^53: would corrupt through an f64 JSON number
            seed: u64::MAX - 12,
            dropout_seed: (1u64 << 53) + 1,
            hash_bits: 10,
            numeric_buckets: 33,
            shuffle_window: 777,
            prefetch_batches: 5,
            save_every: 123,
            compact_every: 9,
            ..Experiment::default()
        };
        let back =
            experiment_from_json(&experiment_to_json(&exp)).unwrap();
        assert_eq!(back.method, exp.method);
        assert_eq!(back.bits, exp.bits);
        assert_eq!(back.clip.to_bits(), exp.clip.to_bits());
        assert_eq!(back.lr_delta.to_bits(), exp.lr_delta.to_bits());
        assert_eq!(back.lr_dense.to_bits(), exp.lr_dense.to_bits());
        assert_eq!(back.wd_emb.to_bits(), exp.wd_emb.to_bits());
        assert_eq!(back.lr_milestones, exp.lr_milestones);
        assert_eq!(back.dataset, exp.dataset);
        assert_eq!(back.model, exp.model);
        assert_eq!(back.seed, exp.seed);
        assert_eq!(back.dropout_seed, exp.dropout_seed);
        assert_eq!(back.threads, exp.threads);
        assert_eq!(back.grad_scale, exp.grad_scale);
        assert!(!back.use_runtime);
        assert_eq!(back.hash_bits, 10);
        assert_eq!(back.numeric_buckets, 33);
        assert_eq!(back.shuffle_window, 777);
        assert_eq!(back.prefetch_batches, 5);
        assert_eq!(back.save_every, 123);
        assert_eq!(back.compact_every, 9);
    }

    #[test]
    fn pre_streaming_echo_still_parses() {
        // checkpoints written before the streaming pipeline lack its
        // keys; they must load with the defaults those runs used
        let json = experiment_to_json(&Experiment::default());
        let mut map = match json {
            crate::util::json::Json::Object(m) => m,
            _ => unreachable!(),
        };
        for key in [
            "hash_bits",
            "numeric_buckets",
            "shuffle_window",
            "prefetch_batches",
            "save_every",
            "compact_every",
        ] {
            assert!(map.remove(key).is_some(), "echo is missing {key}");
        }
        let back =
            experiment_from_json(&crate::util::json::Json::Object(map))
                .unwrap();
        let d = Experiment::default();
        assert_eq!(back.hash_bits, d.hash_bits);
        assert_eq!(back.numeric_buckets, d.numeric_buckets);
        assert_eq!(back.shuffle_window, d.shuffle_window);
        assert_eq!(back.prefetch_batches, d.prefetch_batches);
        assert_eq!(back.save_every, d.save_every);
        assert_eq!(back.compact_every, d.compact_every);
    }

    #[test]
    fn roundtrip_every_method_and_bit_width_at_odd_dims() {
        // property: packed bytes and per-row scalars survive save→load
        // bit-identically for every BitWidth, including ragged (odd-dim)
        // rows, for every checkpointable store family.
        check("checkpoint roundtrip", 16, |g: &mut Gen| {
            let bits = *g.pick(&[2u32, 4, 8, 16]);
            let method = *g.pick(&[
                Method::Fp,
                Method::Lpt(RoundingMode::Sr),
                Method::Alpt(RoundingMode::Sr),
                Method::Lsq,
                Method::Pact,
            ]);
            let n = g.usize_in(40, 200);
            let d = 2 * g.usize_in(1, 6) + 1; // odd on purpose
            let exp = exp_for(method, bits, 1);
            let mut rng = Pcg32::seeded(g.u32_any() as u64);
            let store = build_store(&exp, n, d, &mut rng).unwrap();
            let name = format!("prop_{bits}_{n}_{d}");
            let loaded = roundtrip(&name, store.as_ref(), &exp);
            let (a, b) = (gather_all(store.as_ref()), gather_all(loaded.as_ref()));
            if a != b {
                return Err(format!(
                    "{method:?} {bits}bit n={n} d={d}: gather diverged"
                ));
            }
            if loaded.train_bytes() != store.train_bytes() {
                return Err("train_bytes diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn loaded_store_continues_updates_bit_identically() {
        // the step counter must survive: an update after load draws the
        // same SR noise as an update on the original store.
        for method in
            [Method::Lpt(RoundingMode::Sr), Method::Alpt(RoundingMode::Sr)]
        {
            let exp = exp_for(method, 8, 1);
            let (n, d) = (90usize, 5usize);
            let mut rng = Pcg32::seeded(31);
            let mut store = build_store(&exp, n, d, &mut rng).unwrap();
            // advance the step counter past zero before saving
            let ids: Vec<u32> = (0..n as u32).collect();
            let mut what = vec![0.0f32; n * d];
            let grads: Vec<f32> =
                (0..n * d).map(|i| ((i % 7) as f32 - 3.0) * 0.01).collect();
            let mut sp = crate::embedding::testutil::eq7_second_pass();
            let mut step_rng = Pcg32::seeded(77);
            for _ in 0..2 {
                store.gather(&ids, &mut what);
                store
                    .update(&ids, &what, &grads, &hp(), &mut step_rng,
                            &mut sp)
                    .unwrap();
            }

            let mut loaded =
                roundtrip(&format!("step_{:?}", exp.method), store.as_ref(),
                          &exp);
            assert_eq!(loaded.step_counter(), store.step_counter());

            // one more update on each side from identical generators
            let mut rng_a = Pcg32::seeded(99);
            let mut rng_b = Pcg32::seeded(99);
            store.gather(&ids, &mut what);
            let mut what_b = what.clone();
            loaded.gather(&ids, &mut what_b);
            assert_eq!(what, what_b);
            store
                .update(&ids, &what, &grads, &hp(), &mut rng_a, &mut sp)
                .unwrap();
            loaded
                .update(&ids, &what_b, &grads, &hp(), &mut rng_b, &mut sp)
                .unwrap();
            assert_eq!(
                gather_all(store.as_ref()),
                gather_all(loaded.as_ref()),
                "{method:?}: post-load update diverged"
            );
        }
    }

    #[test]
    fn sharding_spans_multiple_sections() {
        // n > SHARD_ROWS forces a multi-shard file; d = 1 keeps it small.
        let exp = exp_for(Method::Lpt(RoundingMode::Sr), 8, 0);
        let n = SHARD_ROWS + 37;
        let mut rng = Pcg32::seeded(5);
        let store = build_store(&exp, n, 1, &mut rng).unwrap();
        let path = tmp("multishard.ckpt");
        save_store(&path, store.as_ref(), &exp).unwrap();
        let ck = Checkpoint::read(&path).unwrap();
        assert_eq!(ck.sections_of(SectionKind::Rows).len(), 2);
        assert_eq!(ck.meta_usize("n_shards").unwrap(), 2);
        let (loaded, _) = load_store(&ck).unwrap();
        assert_eq!(gather_all(store.as_ref()), gather_all(loaded.as_ref()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn grouped_checkpoint_roundtrip_and_versions() {
        // mixed plan → version-2 file with per-group headers; its
        // save→load→save is byte-identical, and uniform plans keep
        // writing version-1 files with no groups array
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            bits: PrecisionPlan::parse("f0:4,f1:8,default:2").unwrap(),
            dataset: "tiny".into(),
            model: "tiny".into(),
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = crate::data::registry::schema_for(&exp)
            .unwrap()
            .n_features();
        let mut rng = Pcg32::seeded(17);
        let store = build_store(&exp, n, 5, &mut rng).unwrap();
        assert!(store.as_grouped().is_some());
        let loaded = roundtrip("grouped_mixed", store.as_ref(), &exp);
        assert_eq!(gather_all(store.as_ref()), gather_all(loaded.as_ref()));
        assert_eq!(loaded.step_counter(), store.step_counter());

        let p = tmp("grouped_v2.ckpt");
        save_store(&p, store.as_ref(), &exp).unwrap();
        let ck = Checkpoint::read(&p).unwrap();
        assert_eq!(ck.version, VERSION_GROUPED);
        let groups = ck.meta.get("groups").unwrap().as_array().unwrap();
        assert_eq!(groups.len(), 3, "2-, 4- and 8-bit groups");
        // ascending-width group headers
        let bits: Vec<usize> = groups
            .iter()
            .map(|g| g.get("bits").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(bits, vec![2, 4, 8]);
        std::fs::remove_file(&p).ok();

        let u_exp = exp_for(Method::Lpt(RoundingMode::Sr), 8, 1);
        let mut rng = Pcg32::seeded(18);
        let u_store = build_store(&u_exp, 50, 4, &mut rng).unwrap();
        let p = tmp("uniform_v1.ckpt");
        save_store(&p, u_store.as_ref(), &u_exp).unwrap();
        let ck = Checkpoint::read(&p).unwrap();
        assert_eq!(ck.version, VERSION, "uniform plans stay version 1");
        assert!(ck.meta.opt("groups").is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mixed_echo_roundtrips_the_plan() {
        let exp = Experiment {
            bits: PrecisionPlan::parse("cat:4,num:8").unwrap(),
            ..Experiment::default()
        };
        let back =
            experiment_from_json(&experiment_to_json(&exp)).unwrap();
        assert_eq!(back.bits, exp.bits);
    }

    #[test]
    fn aux_only_and_masked_stores_roundtrip() {
        // the former checkpoint-refusing orphans: hashing persists
        // aux-only (format v3), pruning per-row f32 rows + mask aux (v1)
        for method in [Method::Hashing, Method::Pruning] {
            let exp = exp_for(method, 8, 1);
            let mut rng = Pcg32::seeded(9);
            let store = build_store(&exp, 50, 4, &mut rng).unwrap();
            let loaded =
                roundtrip(&format!("orphan_{method:?}"), store.as_ref(),
                          &exp);
            assert_eq!(
                gather_all(store.as_ref()),
                gather_all(loaded.as_ref()),
                "{method:?}: gather diverged after load"
            );
            assert_eq!(loaded.infer_bytes(), store.infer_bytes());
        }

        let h_exp = exp_for(Method::Hashing, 8, 1);
        let mut rng = Pcg32::seeded(10);
        let h = build_store(&h_exp, 64, 4, &mut rng).unwrap();
        let p = tmp("hashing_v3.ckpt");
        save_store(&p, h.as_ref(), &h_exp).unwrap();
        let ck = Checkpoint::read(&p).unwrap();
        assert_eq!(ck.version, VERSION_KINDED, "aux-only store is v3");
        assert_eq!(ck.meta_usize("row_bytes").unwrap(), 0);
        assert_eq!(ck.meta_usize("n_shards").unwrap(), 0);
        assert!(ck.sections_of(SectionKind::Rows).is_empty());
        std::fs::remove_file(&p).ok();

        let pr_exp = exp_for(Method::Pruning, 8, 1);
        let pr = build_store(&pr_exp, 64, 4, &mut rng).unwrap();
        let p = tmp("pruning_v1.ckpt");
        save_store(&p, pr.as_ref(), &pr_exp).unwrap();
        let ck = Checkpoint::read(&p).unwrap();
        assert_eq!(ck.version, VERSION, "per-row stores stay v1");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn structural_grouped_checkpoint_is_v3_with_kinds() {
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            bits: PrecisionPlan::parse("f0:hash,f1:prune,default:4")
                .unwrap(),
            dataset: "tiny".into(),
            model: "tiny".into(),
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = crate::data::registry::schema_for(&exp)
            .unwrap()
            .n_features();
        let mut rng = Pcg32::seeded(23);
        let store = build_store(&exp, n, 5, &mut rng).unwrap();
        let loaded =
            roundtrip("grouped_structural", store.as_ref(), &exp);
        assert_eq!(gather_all(store.as_ref()), gather_all(loaded.as_ref()));
        assert_eq!(loaded.step_counter(), store.step_counter());

        let p = tmp("grouped_v3.ckpt");
        save_store(&p, store.as_ref(), &exp).unwrap();
        let ck = Checkpoint::read(&p).unwrap();
        assert_eq!(ck.version, VERSION_KINDED);
        let groups = ck.meta.get("groups").unwrap().as_array().unwrap();
        let kinds: Vec<&str> = groups
            .iter()
            .map(|g| g.get("kind").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(kinds, vec!["alpt", "hash", "prune"]);
        assert_eq!(
            groups[1].get("row_bytes").unwrap().as_usize().unwrap(),
            0,
            "hashed group is aux-only"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn replan_budget_echo_is_conditional() {
        // absent at the default (pre-PR echoes must stay byte-identical),
        // round-trips when set
        let off = experiment_to_json(&Experiment::default());
        assert!(off.opt("replan_budget").is_none());
        let exp = Experiment {
            replan_budget: 1 << 20,
            ..Experiment::default()
        };
        let back =
            experiment_from_json(&experiment_to_json(&exp)).unwrap();
        assert_eq!(back.replan_budget, 1 << 20);
        let missing =
            experiment_from_json(&off).unwrap();
        assert_eq!(missing.replan_budget, 0);
    }

    #[test]
    fn wrong_geometry_is_rejected() {
        // save at 8 bits, doctor the echo to 4 bits: row widths disagree
        let exp = exp_for(Method::Lpt(RoundingMode::Sr), 8, 1);
        let mut rng = Pcg32::seeded(13);
        let store = build_store(&exp, 30, 6, &mut rng).unwrap();
        let path = tmp("geometry.ckpt");
        save_store(&path, store.as_ref(), &exp).unwrap();
        // rebuild the file with a doctored (but correctly CRC-signed)
        // meta section, so only the geometry check can fail
        let ck = Checkpoint::read(&path).unwrap();
        let meta_text =
            ck.meta.to_string().replace("\"bits\":8", "\"bits\":4");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, meta_text.as_bytes()).unwrap();
        for sec in ck.sections_of(SectionKind::Rows) {
            w.section(SectionKind::Rows, sec.index, sec.payload).unwrap();
        }
        w.finish().unwrap();
        let ck2 = Checkpoint::read(&path).unwrap();
        let err = format!("{:#}", load_store(&ck2).unwrap_err());
        assert!(err.contains("row payload width"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    // ------------------------------------------------- trainer save/resume

    fn step_batches(ds: &crate::data::Dataset, b: usize) -> Vec<Batch> {
        Batcher::new(ds, b, Some(11), true).collect()
    }

    #[test]
    fn trainer_resume_continues_bit_identically() {
        for method in
            [Method::Lpt(RoundingMode::Sr), Method::Alpt(RoundingMode::Sr)]
        {
            let spec = SyntheticSpec::tiny(3);
            let ds = generate(&spec, 2000);
            let exp = Experiment {
                method,
                model: "tiny".into(),
                use_runtime: false,
                threads: 1,
                epochs: 1,
                lr_emb: 0.3,
                lr_delta: 1e-4,
                ..Experiment::default()
            };
            let n_features = ds.schema.n_features();
            let batches = step_batches(&ds, 64);
            assert!(batches.len() >= 8, "need 8 batches for the test");

            let mut reference =
                Trainer::new(exp.clone(), n_features).unwrap();
            for b in &batches[..4] {
                reference.step(b, 1).unwrap();
            }
            let path = tmp(&format!("resume_{method:?}.ckpt"));
            reference.save_checkpoint(&path).unwrap();

            // uninterrupted continuation
            let mut ref_losses = Vec::new();
            for b in &batches[4..8] {
                ref_losses.push(reference.step(b, 1).unwrap().loss);
            }

            // resumed continuation must match bit for bit
            let mut resumed = Trainer::resume(&path).unwrap();
            assert_eq!(resumed.exp.method, exp.method);
            let mut res_losses = Vec::new();
            for b in &batches[4..8] {
                res_losses.push(resumed.step(b, 1).unwrap().loss);
            }
            assert_eq!(ref_losses, res_losses, "{method:?}: losses diverged");
            assert_eq!(
                reference.dense, resumed.dense,
                "{method:?}: dense params diverged"
            );
            assert_eq!(
                gather_all(reference.store.as_ref()),
                gather_all(resumed.store.as_ref()),
                "{method:?}: embedding tables diverged"
            );
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn resume_continues_epoch_numbering() {
        // the progress section: a resumed run must not replay epoch 1's
        // LR schedule position or shuffle seeds
        let spec = SyntheticSpec::tiny(9);
        let ds = generate(&spec, 1200);
        let (train, val, _) = ds.split((0.8, 0.1, 0.1), 1);
        let exp = Experiment {
            method: Method::Fp,
            model: "tiny".into(),
            use_runtime: false,
            threads: 1,
            epochs: 2,
            patience: 0,
            ..Experiment::default()
        };
        let mut tr = Trainer::new(exp, ds.schema.n_features()).unwrap();
        let res = tr.train(&train, &val, false).unwrap();
        assert_eq!(res.epochs_run, 2);
        assert_eq!(tr.epochs_done, 2);
        let path = tmp("epochs.ckpt");
        tr.save_checkpoint(&path).unwrap();

        let mut back = Trainer::resume(&path).unwrap();
        assert_eq!(back.epochs_done, 2);
        // epoch budget exhausted: nothing is replayed
        let res2 = back.train(&train, &val, false).unwrap();
        assert_eq!(res2.epochs_run, 0);
        // a raised budget continues from epoch 3, not epoch 1
        back.exp.epochs = 3;
        let res3 = back.train(&train, &val, false).unwrap();
        assert_eq!(res3.epochs_run, 1);
        assert_eq!(res3.history[0].epoch, 3);
        assert_eq!(back.epochs_done, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trainer_checkpoint_save_load_save_is_byte_identical() {
        let spec = SyntheticSpec::tiny(5);
        let ds = generate(&spec, 1500);
        let exp = Experiment {
            method: Method::Alpt(RoundingMode::Sr),
            model: "tiny".into(),
            use_runtime: false,
            threads: 1,
            epochs: 1,
            ..Experiment::default()
        };
        let mut tr = Trainer::new(exp, ds.schema.n_features()).unwrap();
        for b in &step_batches(&ds, 64)[..3] {
            tr.step(b, 1).unwrap();
        }
        let p1 = tmp("trainer.1.ckpt");
        let p2 = tmp("trainer.2.ckpt");
        tr.save_checkpoint(&p1).unwrap();
        let mut resumed = Trainer::resume(&p1).unwrap();
        resumed.save_checkpoint(&p2).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "trainer save→resume→save changed bytes"
        );
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn fp_store_checkpoint_keeps_serving_outputs() {
        // float path: gather after load is bit-identical, so serving from
        // a warm-started FP model is indistinguishable from the original.
        let exp = exp_for(Method::Fp, 8, 1);
        let mut rng = Pcg32::seeded(21);
        let store = build_store(&exp, 120, 8, &mut rng).unwrap();
        let loaded = roundtrip("fp_serve", store.as_ref(), &exp);
        assert_eq!(gather_all(store.as_ref()), gather_all(loaded.as_ref()));
    }
}
