//! On-disk checkpoint format primitives: magic/version constants, the
//! section table, CRC32, and little-endian scalar codecs.
//!
//! Layout of a checkpoint file (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"ALPTCKPT"
//! 8       4     u32    format version (1)
//! 12      4     u32    section count
//! 16      ...   sections, back to back
//! ```
//!
//! Each section:
//!
//! ```text
//! +0      4     u32    kind (SectionKind)
//! +4      4     u32    index (shard number for Rows, 0 otherwise)
//! +8      8     u64    payload length in bytes
//! +16     4     u32    CRC32 (IEEE) of the payload
//! +20     len   payload
//! ```
//!
//! The CRC is checked on read before any payload byte is interpreted, so
//! truncated or bit-flipped files fail fast with the offending section
//! named. The metadata payload (kind `Meta`) is compact JSON produced by
//! [`crate::util::json::Json`]; every other payload is raw bytes whose
//! meaning the metadata pins down (packed embedding rows, f32 vectors,
//! u64 counters).

use anyhow::{bail, ensure, Result};

/// File magic: 8 bytes at offset 0.
pub const MAGIC: &[u8; 8] = b"ALPTCKPT";

/// Single-group format version — everything a uniform precision plan
/// writes. Kept at 1 so uniform-plan checkpoints stay byte-identical
/// across the mixed-precision refactor.
pub const VERSION: u32 = 1;

/// Grouped format version: the meta section carries a `groups` array
/// (one `{bits, rows, row_bytes, aux_len}` header per precision group),
/// `Rows` sections run group by group with a global shard index, and
/// each group's per-row scalars live in an `Aux` section whose index is
/// the group number. Readers accept both versions; version-1 files load
/// as a single-group plan.
pub const VERSION_GROUPED: u32 = 2;

/// Kinded format version: like [`VERSION_GROUPED`], but group headers
/// carry a `kind` token ("lpt" / "alpt" / "hash" / "prune") and groups —
/// or whole single-store files — may be *aux-only* (`row_bytes` 0, no
/// `Rows` sections): their state is one shared parameter block persisted
/// through the `Aux` section alone, the layout hashing's
/// quotient–remainder tables need. Written only when a structural group
/// or aux-only store is present, so every pre-existing plan keeps its
/// version-1/-2 bytes unchanged. Readers accept all three versions.
pub const VERSION_KINDED: u32 = 3;

/// Fixed byte size of the file header (magic + version + section count).
pub const HEADER_BYTES: usize = 16;

/// Fixed byte size of a section header (kind + index + len + crc).
pub const SECTION_HEADER_BYTES: usize = 20;

/// What a section's payload holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionKind {
    /// Compact-JSON metadata: geometry, method, determinism key,
    /// `Experiment` echo. Exactly one per file.
    Meta,
    /// One shard of raw row payloads (packed codes for int stores, f32 LE
    /// for float-backed stores); `index` is the shard number.
    Rows,
    /// Per-row learned scalars (Δ for ALPT/LSQ, α for PACT), f32 LE.
    Aux,
    /// Flat dense-parameter vector, f32 LE.
    Dense,
    /// Adam state: `t` (u64) then `m` then `v` (each f32 LE × P).
    Optimizer,
    /// Trainer generator states: 4 × u64 (rng state/inc, mask state/inc).
    Rng,
    /// Training progress, all u64 LE: epochs completed; records consumed
    /// from the current epoch's train stream (streaming runs, 0 at epoch
    /// boundaries); then the early-stop bookkeeping — best epoch,
    /// consecutive non-improving epochs, best val AUC (f64 bits), best
    /// val logloss (f64 bits). `--resume` continues the LR schedule,
    /// shuffle seeds, mid-stream position and patience instead of
    /// replaying from epoch 1. Older files carry 8- or 16-byte prefixes
    /// of this layout; readers accept all three widths.
    Progress,
}

impl SectionKind {
    pub fn as_u32(self) -> u32 {
        match self {
            SectionKind::Meta => 1,
            SectionKind::Rows => 2,
            SectionKind::Aux => 3,
            SectionKind::Dense => 4,
            SectionKind::Optimizer => 5,
            SectionKind::Rng => 6,
            SectionKind::Progress => 7,
        }
    }

    pub fn from_u32(v: u32) -> Option<Self> {
        match v {
            1 => Some(SectionKind::Meta),
            2 => Some(SectionKind::Rows),
            3 => Some(SectionKind::Aux),
            4 => Some(SectionKind::Dense),
            5 => Some(SectionKind::Optimizer),
            6 => Some(SectionKind::Rng),
            7 => Some(SectionKind::Progress),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SectionKind::Meta => "meta",
            SectionKind::Rows => "rows",
            SectionKind::Aux => "aux",
            SectionKind::Dense => "dense",
            SectionKind::Optimizer => "optimizer",
            SectionKind::Rng => "rng",
            SectionKind::Progress => "progress",
        }
    }
}

// ------------------------------------------------------------------ crc32

/// 256-entry table for reflected CRC-32 (polynomial 0xEDB88320) — the
/// same parameters as zlib's `crc32`, so fixtures can be produced by any
/// standard tool.
const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// IEEE CRC-32 of `bytes` (init 0xFFFFFFFF, reflected, final xor).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        let idx = ((crc ^ b as u32) & 0xFF) as usize;
        crc = (crc >> 8) ^ CRC_TABLE[idx];
    }
    !crc
}

// --------------------------------------------------------- scalar codecs

/// Append a u32 little-endian.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a u64 little-endian.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append f32s little-endian.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Read a u32 at `pos`, advancing it.
pub fn take_u32(src: &[u8], pos: &mut usize) -> Result<u32> {
    let end = *pos + 4;
    ensure!(end <= src.len(), "truncated file (u32 at byte {})", *pos);
    let v = u32::from_le_bytes(src[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

/// Read a u64 at `pos`, advancing it.
pub fn take_u64(src: &[u8], pos: &mut usize) -> Result<u64> {
    let end = *pos + 8;
    ensure!(end <= src.len(), "truncated file (u64 at byte {})", *pos);
    let v = u64::from_le_bytes(src[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

/// Decode a whole payload as little-endian f32s.
pub fn parse_f32s(src: &[u8]) -> Result<Vec<f32>> {
    if src.len() % 4 != 0 {
        bail!("f32 payload length {} is not a multiple of 4", src.len());
    }
    Ok(src
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the standard CRC-32 check value, shared with zlib.crc32
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"ALPTCKPT"), crc32(b"ALPTCKPT"));
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn crc32_sensitive_to_single_bitflip() {
        let mut data = vec![0u8; 1024];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i * 31) as u8;
        }
        let base = crc32(&data);
        data[517] ^= 0x10;
        assert_ne!(base, crc32(&data));
    }

    #[test]
    fn section_kind_roundtrip() {
        for kind in [
            SectionKind::Meta,
            SectionKind::Rows,
            SectionKind::Aux,
            SectionKind::Dense,
            SectionKind::Optimizer,
            SectionKind::Rng,
            SectionKind::Progress,
        ] {
            assert_eq!(SectionKind::from_u32(kind.as_u32()), Some(kind));
        }
        assert_eq!(SectionKind::from_u32(0), None);
        assert_eq!(SectionKind::from_u32(8), None);
    }

    #[test]
    fn scalar_codecs_roundtrip() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, 0x0123_4567_89AB_CDEF);
        put_f32s(&mut buf, &[1.5, -0.25, f32::MIN_POSITIVE]);
        let mut pos = 0;
        assert_eq!(take_u32(&buf, &mut pos).unwrap(), 0xDEAD_BEEF);
        assert_eq!(take_u64(&buf, &mut pos).unwrap(), 0x0123_4567_89AB_CDEF);
        let floats = parse_f32s(&buf[pos..]).unwrap();
        assert_eq!(floats, vec![1.5, -0.25, f32::MIN_POSITIVE]);
        // truncation errors
        assert!(take_u32(&buf[..2], &mut 0).is_err());
        assert!(take_u64(&buf[..7], &mut 0).is_err());
        assert!(parse_f32s(&buf[..3]).is_err());
    }
}
