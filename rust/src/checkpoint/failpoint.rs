//! Env-gated fault-injection for the durability paths.
//!
//! A *failpoint* is a named site inside the checkpoint writer, delta
//! journal appender, or compactor where a test (or the CI
//! `crash-recovery` job) can make the process fail mid-operation. Sites
//! are armed through the environment:
//!
//! ```text
//! ALPT_FAILPOINT=ckpt.publish=crash
//! ALPT_FAILPOINT=ckpt.section.3=truncate,journal.append=bitflip
//! ```
//!
//! Actions:
//!
//! * `crash` — abort the process immediately, leaving whatever bytes the
//!   OS already has (the `kill -9` model);
//! * `truncate` — write roughly half of the pending bytes, flush them to
//!   the OS, then abort (the torn-write model);
//! * `bitflip` — flip one bit of the pending bytes and *continue* (the
//!   silent-corruption model, for exercising CRC detection).
//!
//! The registry is process-global: parsed from the environment once, and
//! overridable programmatically for in-process tests via
//! [`set_failpoint`] / [`clear_failpoints`]. Every hook compiles to a
//! single mutex-free `AtomicBool` load when no failpoint has ever been
//! armed, so the production write path pays nothing measurable.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// Environment variable holding the armed failpoints.
pub const FAILPOINT_ENV: &str = "ALPT_FAILPOINT";

/// What an armed failpoint does when its site is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailAction {
    /// Abort the process before the pending bytes are written.
    Crash,
    /// Write about half of the pending bytes, flush, then abort.
    Truncate,
    /// Flip one bit of the pending bytes and keep running.
    Bitflip,
}

impl FailAction {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "crash" => Some(Self::Crash),
            "truncate" => Some(Self::Truncate),
            "bitflip" => Some(Self::Bitflip),
            _ => None,
        }
    }
}

/// Fast-path gate: false until the first failpoint is armed (from the
/// environment or a test), after which sites consult the registry map.
static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, FailAction>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, FailAction>>> =
        OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map = HashMap::new();
        if let Ok(spec) = std::env::var(FAILPOINT_ENV) {
            for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
                match parse_entry(part) {
                    Some((site, action)) => {
                        map.insert(site, action);
                    }
                    None => eprintln!(
                        "[failpoint] ignoring malformed {FAILPOINT_ENV} \
                         entry {part:?} (want <site>=crash|truncate|bitflip)"
                    ),
                }
            }
        }
        if !map.is_empty() {
            ARMED.store(true, Ordering::SeqCst);
        }
        Mutex::new(map)
    })
}

fn parse_entry(part: &str) -> Option<(String, FailAction)> {
    let (site, action) = part.trim().split_once('=')?;
    let site = site.trim();
    if site.is_empty() {
        return None;
    }
    Some((site.to_string(), FailAction::parse(action.trim())?))
}

/// Arm `site` programmatically (tests). Overrides any env-armed action.
pub fn set_failpoint(site: &str, action: FailAction) {
    registry().lock().unwrap().insert(site.to_string(), action);
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm every failpoint (tests). The fast-path gate stays armed so
/// concurrently-running tests keep consulting the map.
pub fn clear_failpoints() {
    registry().lock().unwrap().clear();
}

/// The action armed at `site`, if any. Forces env parsing on first use.
pub fn armed_action(site: &str) -> Option<FailAction> {
    if !ARMED.load(Ordering::SeqCst) {
        // cheap gate; still touch the registry once so env arming works
        // even before any set_failpoint call
        registry();
        if !ARMED.load(Ordering::SeqCst) {
            return None;
        }
    }
    registry().lock().unwrap().get(site).copied()
}

/// Abort the process the way a `kill -9` would: no unwinding, no
/// destructors, no buffered-writer flushes.
fn die(site: &str) -> ! {
    eprintln!("[failpoint] {site}: aborting process");
    std::process::abort();
}

/// Byte sink a failpoint can tear mid-write. `write` appends bytes at
/// the current position; `sync` must push them through OS buffers so a
/// torn prefix is actually on disk when the process dies.
pub trait FailSink {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()>;
    fn sync(&mut self) -> std::io::Result<()>;
}

impl FailSink for std::io::BufWriter<std::fs::File> {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        std::io::Write::write_all(self, bytes)
    }

    fn sync(&mut self) -> std::io::Result<()> {
        std::io::Write::flush(self)?;
        self.get_ref().sync_data()
    }
}

impl FailSink for std::fs::File {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        std::io::Write::write_all(self, bytes)
    }

    fn sync(&mut self) -> std::io::Result<()> {
        self.sync_data()
    }
}

/// Fire `site` against `pending`, the bytes about to be written.
///
/// * unarmed → write `pending` into `sink` and return `Ok`;
/// * `crash` → abort before writing;
/// * `truncate` → write the first half, sync, abort;
/// * `bitflip` → flip one deterministic bit and write the damaged copy.
pub fn write_through(
    site: &str,
    pending: &[u8],
    sink: &mut dyn FailSink,
) -> std::io::Result<()> {
    match armed_action(site) {
        None => sink.write(pending),
        Some(FailAction::Crash) => die(site),
        Some(FailAction::Truncate) => {
            let half = pending.len() / 2;
            let _ = sink.write(&pending[..half]);
            let _ = sink.sync();
            die(site)
        }
        Some(FailAction::Bitflip) => {
            if pending.is_empty() {
                return sink.write(pending);
            }
            let mut damaged = pending.to_vec();
            // deterministic target: middle byte, low bit
            let at = damaged.len() / 2;
            damaged[at] ^= 1;
            eprintln!(
                "[failpoint] {site}: flipped bit 0 of byte {at}/{}",
                damaged.len()
            );
            sink.write(&damaged)
        }
    }
}

/// Fire a write-free `site` (e.g. right after a rename): `crash` and
/// `truncate` abort, `bitflip` is a no-op.
pub fn hit(site: &str) {
    match armed_action(site) {
        None | Some(FailAction::Bitflip) => {}
        Some(FailAction::Crash) | Some(FailAction::Truncate) => die(site),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct VecSink(Vec<u8>);

    impl FailSink for VecSink {
        fn write(&mut self, bytes: &[u8]) -> std::io::Result<()> {
            self.0.extend_from_slice(bytes);
            Ok(())
        }

        fn sync(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn parses_entries() {
        assert_eq!(
            parse_entry("ckpt.publish=crash"),
            Some(("ckpt.publish".into(), FailAction::Crash))
        );
        assert_eq!(
            parse_entry(" journal.append = truncate "),
            Some(("journal.append".into(), FailAction::Truncate))
        );
        assert_eq!(
            parse_entry("x=bitflip"),
            Some(("x".into(), FailAction::Bitflip))
        );
        assert_eq!(parse_entry("no-action"), None);
        assert_eq!(parse_entry("=crash"), None);
        assert_eq!(parse_entry("x=explode"), None);
    }

    #[test]
    fn bitflip_damages_exactly_one_bit_and_continues() {
        let site = "test.unit.bitflip";
        set_failpoint(site, FailAction::Bitflip);
        let pending = [0u8; 8];
        let mut sink = VecSink(Vec::new());
        write_through(site, &pending, &mut sink).unwrap();
        assert_eq!(sink.0.len(), 8);
        let flipped: u32 = sink
            .0
            .iter()
            .zip(&pending)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1);
        registry().lock().unwrap().remove(site);
    }

    #[test]
    fn unarmed_sites_write_verbatim() {
        let mut sink = VecSink(Vec::new());
        write_through("test.unit.unarmed", &[1, 2, 3], &mut sink).unwrap();
        assert_eq!(sink.0, vec![1, 2, 3]);
        hit("test.unit.unarmed"); // must not abort
    }
}
