//! Streaming checkpoint writer.
//!
//! Sections are appended one at a time; the section count in the header
//! is patched in by [`CheckpointWriter::finish`], so the writer never has
//! to buffer more than one section payload. Callers that serialize big
//! tables reuse one shard-sized buffer across [`CheckpointWriter::section`]
//! calls (see `checkpoint::write_store_sections`), keeping peak memory
//! bounded by the shard size rather than the table size.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::format::{crc32, SectionKind, MAGIC, VERSION};

/// Writes one checkpoint file section by section.
pub struct CheckpointWriter {
    out: BufWriter<File>,
    n_sections: u32,
}

impl CheckpointWriter {
    /// Create `path` (truncating any existing file) and write the header
    /// with a zero section count placeholder. The default (version-1)
    /// single-group format; grouped mixed-precision stores use
    /// [`CheckpointWriter::create_with_version`].
    pub fn create(path: &Path) -> Result<Self> {
        Self::create_with_version(path, VERSION)
    }

    /// Like [`CheckpointWriter::create`] with an explicit header format
    /// version (`format::VERSION` or `format::VERSION_GROUPED`).
    pub fn create_with_version(path: &Path, version: u32) -> Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut out = BufWriter::new(file);
        out.write_all(MAGIC)?;
        out.write_all(&version.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?; // patched by finish()
        Ok(Self { out, n_sections: 0 })
    }

    /// Append one section (header + CRC + payload).
    pub fn section(
        &mut self,
        kind: SectionKind,
        index: u32,
        payload: &[u8],
    ) -> Result<()> {
        self.out.write_all(&kind.as_u32().to_le_bytes())?;
        self.out.write_all(&index.to_le_bytes())?;
        self.out.write_all(&(payload.len() as u64).to_le_bytes())?;
        self.out.write_all(&crc32(payload).to_le_bytes())?;
        self.out.write_all(payload)?;
        self.n_sections += 1;
        Ok(())
    }

    /// Patch the section count into the header and flush everything.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        let count = self.n_sections;
        let file = self.out.get_mut();
        file.seek(SeekFrom::Start(12))?;
        file.write_all(&count.to_le_bytes())?;
        file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::HEADER_BYTES;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_ckpt_writer_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn header_and_count_patched() {
        let path = tmp("basic.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{}").unwrap();
        w.section(SectionKind::Rows, 3, &[1, 2, 3, 4]).unwrap();
        w.finish().unwrap();

        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC);
        assert_eq!(
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
            VERSION
        );
        assert_eq!(u32::from_le_bytes(bytes[12..16].try_into().unwrap()), 2);
        // first section starts right after the header
        assert_eq!(
            u32::from_le_bytes(
                bytes[HEADER_BYTES..HEADER_BYTES + 4].try_into().unwrap()
            ),
            SectionKind::Meta.as_u32()
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rewrite_truncates_previous_content() {
        let path = tmp("truncate.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Dense, 0, &[0u8; 256]).unwrap();
        w.finish().unwrap();
        let long = std::fs::metadata(&path).unwrap().len();

        let w = CheckpointWriter::create(&path).unwrap();
        w.finish().unwrap();
        let short = std::fs::metadata(&path).unwrap().len();
        assert!(short < long);
        assert_eq!(short as usize, HEADER_BYTES);
        std::fs::remove_file(&path).ok();
    }
}
