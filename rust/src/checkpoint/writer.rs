//! Streaming checkpoint writer with atomic publish.
//!
//! Sections are appended one at a time; the section count in the header
//! is patched in by [`CheckpointWriter::finish`], so the writer never has
//! to buffer more than one section payload. Callers that serialize big
//! tables reuse one shard-sized buffer across [`CheckpointWriter::section`]
//! calls (see `checkpoint::write_store_sections`), keeping peak memory
//! bounded by the shard size rather than the table size.
//!
//! Durability contract: every byte goes to `<path>.tmp`; `finish` fsyncs
//! the temp file, renames it over `path`, and fsyncs the parent
//! directory. A crash at any instant — including inside the rename —
//! leaves either the complete old file or the complete new file at
//! `path`, never a torn one. An unfinished writer removes its temp file
//! on drop, so failed saves cannot litter the checkpoint directory.
//!
//! `finish` also returns the checkpoint's *anchor id*: the CRC-32 of the
//! per-section payload CRCs in file order. The reader recomputes the
//! same id from the section table ([`Checkpoint::anchor_id`]), and the
//! delta journal chains off it — no re-hash of the file is ever needed.
//!
//! Failpoint sites (`checkpoint::failpoint`): `ckpt.section.<k>` before
//! section `k`'s bytes, `ckpt.finish` before the header patch,
//! `ckpt.publish` before the rename, `ckpt.published` right after it.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::failpoint;
use super::format::{crc32, SectionKind, MAGIC, VERSION};

/// Writes one checkpoint file section by section, publishing atomically
/// on [`CheckpointWriter::finish`].
pub struct CheckpointWriter {
    out: Option<BufWriter<File>>,
    tmp: PathBuf,
    target: PathBuf,
    n_sections: u32,
    /// Little-endian payload CRCs in file order; the anchor id is the
    /// CRC-32 of this byte string.
    crc_trail: Vec<u8>,
    published: bool,
}

/// The temp path a checkpoint at `path` is staged through.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

impl CheckpointWriter {
    /// Stage a checkpoint for `path` (writing to `tmp_path(path)`) with
    /// the default (version-1) single-group format; grouped
    /// mixed-precision stores use [`CheckpointWriter::create_with_version`].
    pub fn create(path: &Path) -> Result<Self> {
        Self::create_with_version(path, VERSION)
    }

    /// Like [`CheckpointWriter::create`] with an explicit header format
    /// version (`format::VERSION` or `format::VERSION_GROUPED`).
    pub fn create_with_version(path: &Path, version: u32) -> Result<Self> {
        let tmp = tmp_path(path);
        let file = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut out = BufWriter::new(file);
        out.write_all(MAGIC)?;
        out.write_all(&version.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?; // patched by finish()
        Ok(Self {
            out: Some(out),
            tmp,
            target: path.to_path_buf(),
            n_sections: 0,
            crc_trail: Vec::new(),
            published: false,
        })
    }

    /// Append one section (header + CRC + payload).
    pub fn section(
        &mut self,
        kind: SectionKind,
        index: u32,
        payload: &[u8],
    ) -> Result<()> {
        let crc = crc32(payload);
        let out = self.out.as_mut().expect("writer already finished");
        let site = format!("ckpt.section.{}", self.n_sections);
        if failpoint::armed_action(&site).is_some() {
            // slow path: assemble the full record so the failpoint can
            // tear or damage it as one unit
            let mut pending =
                Vec::with_capacity(20 + payload.len());
            pending.extend_from_slice(&kind.as_u32().to_le_bytes());
            pending.extend_from_slice(&index.to_le_bytes());
            pending
                .extend_from_slice(&(payload.len() as u64).to_le_bytes());
            pending.extend_from_slice(&crc.to_le_bytes());
            pending.extend_from_slice(payload);
            failpoint::write_through(&site, &pending, out)?;
        } else {
            out.write_all(&kind.as_u32().to_le_bytes())?;
            out.write_all(&index.to_le_bytes())?;
            out.write_all(&(payload.len() as u64).to_le_bytes())?;
            out.write_all(&crc.to_le_bytes())?;
            out.write_all(payload)?;
        }
        self.crc_trail.extend_from_slice(&crc.to_le_bytes());
        self.n_sections += 1;
        Ok(())
    }

    /// Patch the section count into the header, fsync the temp file,
    /// rename it over the target, and fsync the parent directory.
    /// Returns the anchor id the delta journal chains off.
    pub fn finish(mut self) -> Result<u32> {
        let mut out = self.out.take().expect("writer already finished");
        out.flush()?;
        let count = self.n_sections;
        let file = out.get_mut();
        file.seek(SeekFrom::Start(12))?;
        failpoint::write_through(
            "ckpt.finish",
            &count.to_le_bytes(),
            file,
        )?;
        file.sync_all().with_context(|| {
            format!("fsyncing {}", self.tmp.display())
        })?;
        drop(out);
        failpoint::hit("ckpt.publish");
        std::fs::rename(&self.tmp, &self.target).with_context(|| {
            format!(
                "publishing {} over {}",
                self.tmp.display(),
                self.target.display()
            )
        })?;
        self.published = true;
        failpoint::hit("ckpt.published");
        sync_parent_dir(&self.target);
        Ok(crc32(&self.crc_trail))
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        if !self.published {
            // abandoned writer (error mid-save): the staged bytes are
            // garbage, remove them; the published file is untouched
            std::fs::remove_file(&self.tmp).ok();
        }
    }
}

/// Best-effort fsync of `path`'s parent directory so the rename itself
/// is durable (directories may not be openable on every platform —
/// failing to sync is not worth failing the save that just published).
pub(crate) fn sync_parent_dir(path: &Path) {
    let dir = path
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .unwrap_or(Path::new("."));
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::HEADER_BYTES;
    use crate::checkpoint::Checkpoint;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_ckpt_writer_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn header_and_count_patched() {
        let path = tmp("basic.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{}").unwrap();
        w.section(SectionKind::Rows, 3, &[1, 2, 3, 4]).unwrap();
        w.finish().unwrap();

        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC);
        assert_eq!(
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
            VERSION
        );
        assert_eq!(u32::from_le_bytes(bytes[12..16].try_into().unwrap()), 2);
        // first section starts right after the header
        assert_eq!(
            u32::from_le_bytes(
                bytes[HEADER_BYTES..HEADER_BYTES + 4].try_into().unwrap()
            ),
            SectionKind::Meta.as_u32()
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rewrite_truncates_previous_content() {
        let path = tmp("truncate.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Dense, 0, &[0u8; 256]).unwrap();
        w.finish().unwrap();
        let long = std::fs::metadata(&path).unwrap().len();

        let w = CheckpointWriter::create(&path).unwrap();
        w.finish().unwrap();
        let short = std::fs::metadata(&path).unwrap().len();
        assert!(short < long);
        assert_eq!(short as usize, HEADER_BYTES);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn publish_is_atomic_and_leaves_no_temp_file() {
        let path = tmp("atomic.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{\"v\":1}").unwrap();
        // mid-save, the target does not exist yet (or still holds the
        // previous bytes) and the staged bytes sit in the temp file
        assert!(!path.exists(), "target appeared before finish");
        assert!(tmp_path(&path).exists(), "no staged temp file");
        w.finish().unwrap();
        assert!(path.exists());
        assert!(!tmp_path(&path).exists(), "temp file left after publish");

        // overwrite keeps the old file readable at every instant: stage a
        // new checkpoint and read the old one before finishing
        let old = std::fs::read(&path).unwrap();
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{\"v\":2}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), old);
        w.finish().unwrap();
        assert_ne!(std::fs::read(&path).unwrap(), old);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn abandoned_writer_removes_temp_and_keeps_target() {
        let path = tmp("abandoned.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{\"keep\":1}").unwrap();
        w.finish().unwrap();
        let published = std::fs::read(&path).unwrap();

        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.section(SectionKind::Meta, 0, b"{\"junk\":1}").unwrap();
            // dropped without finish — simulated failed save
        }
        assert!(!tmp_path(&path).exists(), "temp file survived the drop");
        assert_eq!(std::fs::read(&path).unwrap(), published);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn anchor_id_matches_reader() {
        let path = tmp("anchor.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{\"n\":1}").unwrap();
        w.section(SectionKind::Rows, 0, &[1, 2, 3]).unwrap();
        w.section(SectionKind::Rows, 1, &[4, 5, 6]).unwrap();
        let anchor = w.finish().unwrap();
        let ck = Checkpoint::read(&path).unwrap();
        assert_eq!(ck.anchor_id(), anchor);

        // different content → different anchor
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, b"{\"n\":1}").unwrap();
        w.section(SectionKind::Rows, 0, &[1, 2, 7]).unwrap();
        w.section(SectionKind::Rows, 1, &[4, 5, 6]).unwrap();
        let anchor2 = w.finish().unwrap();
        assert_ne!(anchor, anchor2);
        std::fs::remove_file(&path).ok();
    }
}
