//! Checkpoint reader: parses and validates the whole file up front.
//!
//! Validation order is chosen so corrupt files fail fast with a precise
//! message: magic → version → section table bounds → per-section CRC →
//! metadata JSON. No payload byte is interpreted before its CRC passes.
//!
//! Payloads are *not* copied out of the file buffer: sections record
//! byte ranges into the single owned buffer, and [`Checkpoint::section`]
//! hands out borrowed slices — peak memory while loading is one file
//! image, matching the writer's shard-bounded design.

use std::ops::Range;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::format::{
    crc32, take_u32, take_u64, SectionKind, HEADER_BYTES, MAGIC,
    SECTION_HEADER_BYTES, VERSION, VERSION_GROUPED, VERSION_KINDED,
};
use crate::util::json::Json;

/// One decoded section: a borrowed view into the checkpoint's buffer.
#[derive(Clone, Copy, Debug)]
pub struct Section<'a> {
    pub kind: SectionKind,
    pub index: u32,
    pub payload: &'a [u8],
}

/// Section table entry (kind, index, payload range into the buffer,
/// payload CRC as stored — revalidated at parse time).
#[derive(Clone, Debug)]
struct SectionEntry {
    kind: SectionKind,
    index: u32,
    payload: Range<usize>,
    crc: u32,
}

/// A fully validated checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub version: u32,
    /// Parsed metadata (the `Meta` section's JSON).
    pub meta: Json,
    bytes: Vec<u8>,
    sections: Vec<SectionEntry>,
}

impl Checkpoint {
    /// Read and validate `path`.
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(bytes)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse a checkpoint, taking ownership of its raw bytes (payload
    /// access borrows from this buffer — no copies).
    pub fn parse(bytes: Vec<u8>) -> Result<Checkpoint> {
        ensure!(
            bytes.len() >= HEADER_BYTES,
            "not a checkpoint: {} bytes is shorter than the header",
            bytes.len()
        );
        if &bytes[..8] != MAGIC {
            bail!("bad magic: not an ALPT checkpoint file");
        }
        let mut pos = 8;
        let version = take_u32(&bytes, &mut pos)?;
        if version != VERSION
            && version != VERSION_GROUPED
            && version != VERSION_KINDED
        {
            bail!(
                "unsupported checkpoint version {version} (this build \
                 reads versions {VERSION} through {VERSION_KINDED})"
            );
        }
        let n_sections = take_u32(&bytes, &mut pos)? as usize;

        let mut sections = Vec::with_capacity(n_sections.min(1024));
        for s in 0..n_sections {
            ensure!(
                pos + SECTION_HEADER_BYTES <= bytes.len(),
                "truncated file: section {s} header runs past EOF"
            );
            let kind_raw = take_u32(&bytes, &mut pos)?;
            let kind = SectionKind::from_u32(kind_raw).ok_or_else(|| {
                anyhow::anyhow!("section {s}: unknown kind {kind_raw}")
            })?;
            let index = take_u32(&bytes, &mut pos)?;
            let len64 = take_u64(&bytes, &mut pos)?;
            let crc_want = take_u32(&bytes, &mut pos)?;
            // len is untrusted: guard the cast and the end-offset sum so a
            // crafted header errors instead of wrapping into a panic
            let len = usize::try_from(len64).ok().filter(|&l| {
                pos.checked_add(l).is_some_and(|end| end <= bytes.len())
            });
            let Some(len) = len else {
                bail!(
                    "truncated file: section {s} ({}/{index}) payload of \
                     {len64} bytes runs past EOF",
                    kind.name()
                );
            };
            let payload = pos..pos + len;
            pos += len;
            let crc_got = crc32(&bytes[payload.clone()]);
            ensure!(
                crc_got == crc_want,
                "CRC mismatch in section {s} ({}/{index}): file is \
                 corrupt (stored {crc_want:#010x}, computed {crc_got:#010x})",
                kind.name()
            );
            sections.push(SectionEntry { kind, index, payload, crc: crc_got });
        }
        ensure!(
            pos == bytes.len(),
            "trailing garbage: {} bytes past the last section",
            bytes.len() - pos
        );

        // (kind, index) addresses a section: a duplicate means the file
        // is corrupt (e.g. a flipped bit in a section header relabeled
        // one) — refuse it rather than silently resolving to the first
        let mut seen: Vec<(u32, u32)> = sections
            .iter()
            .map(|s| (s.kind.as_u32(), s.index))
            .collect();
        seen.sort_unstable();
        if let Some(w) = seen.windows(2).find(|w| w[0] == w[1]) {
            bail!(
                "duplicate section {}/{}: file is corrupt",
                SectionKind::from_u32(w[0].0)
                    .map(|k| k.name())
                    .unwrap_or("?"),
                w[0].1
            );
        }

        let metas: Vec<&SectionEntry> = sections
            .iter()
            .filter(|s| s.kind == SectionKind::Meta)
            .collect();
        ensure!(
            metas.len() == 1,
            "expected exactly one meta section, found {}",
            metas.len()
        );
        let meta_text = std::str::from_utf8(&bytes[metas[0].payload.clone()])
            .context("meta section is not UTF-8")?;
        let meta = Json::parse(meta_text).context("meta section JSON")?;

        Ok(Checkpoint { version, meta, bytes, sections })
    }

    fn view(&self, entry: &SectionEntry) -> Section<'_> {
        Section {
            kind: entry.kind,
            index: entry.index,
            payload: &self.bytes[entry.payload.clone()],
        }
    }

    /// The section of `kind` with `index`, or an error naming it.
    pub fn section(
        &self,
        kind: SectionKind,
        index: u32,
    ) -> Result<Section<'_>> {
        self.opt_section(kind, index).ok_or_else(|| {
            anyhow::anyhow!(
                "checkpoint has no {}/{index} section",
                kind.name()
            )
        })
    }

    /// The section of `kind` with `index`, if present.
    pub fn opt_section(
        &self,
        kind: SectionKind,
        index: u32,
    ) -> Option<Section<'_>> {
        self.sections
            .iter()
            .find(|s| s.kind == kind && s.index == index)
            .map(|s| self.view(s))
    }

    /// All sections of `kind`, in file order.
    pub fn sections_of(&self, kind: SectionKind) -> Vec<Section<'_>> {
        self.sections
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| self.view(s))
            .collect()
    }

    /// The checkpoint's anchor id: CRC-32 over the per-section payload
    /// CRCs in file order. Matches what `CheckpointWriter::finish`
    /// returned when this file was written — the delta journal chains
    /// off it without anyone re-hashing the file.
    pub fn anchor_id(&self) -> u32 {
        let mut trail = Vec::with_capacity(self.sections.len() * 4);
        for s in &self.sections {
            trail.extend_from_slice(&s.crc.to_le_bytes());
        }
        crc32(&trail)
    }

    /// Convenience: a required integer metadata field.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.as_usize())
            .with_context(|| format!("checkpoint meta key {key:?}"))
    }

    /// Convenience: a required string metadata field.
    pub fn meta_str(&self, key: &str) -> Result<&str> {
        self.meta
            .get(key)
            .and_then(|v| v.as_str())
            .with_context(|| format!("checkpoint meta key {key:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::writer::CheckpointWriter;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_ckpt_reader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_minimal(path: &std::path::Path) {
        let mut w = CheckpointWriter::create(path).unwrap();
        w.section(SectionKind::Meta, 0, br#"{"n":4,"d":2}"#).unwrap();
        w.section(SectionKind::Rows, 0, &[9, 8, 7, 6, 5]).unwrap();
        w.finish().unwrap();
    }

    #[test]
    fn roundtrips_sections_and_meta() {
        let path = tmp("ok.ckpt");
        write_minimal(&path);
        let ck = Checkpoint::read(&path).unwrap();
        assert_eq!(ck.version, VERSION);
        assert_eq!(ck.meta_usize("n").unwrap(), 4);
        assert_eq!(ck.meta_usize("d").unwrap(), 2);
        assert_eq!(
            ck.section(SectionKind::Rows, 0).unwrap().payload,
            &[9, 8, 7, 6, 5]
        );
        assert!(ck.opt_section(SectionKind::Dense, 0).is_none());
        assert!(ck.section(SectionKind::Dense, 0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("magic.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        let err = format!("{:#}", Checkpoint::read(&path).unwrap_err());
        assert!(err.contains("magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_version() {
        let path = tmp("version.ckpt");
        write_minimal(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8] = 0xFE; // version -> 0x...FE
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::read(&path).unwrap_err());
        assert!(err.contains("version"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_payload() {
        let path = tmp("crc.ckpt");
        write_minimal(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1; // inside the Rows payload
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::read(&path).unwrap_err());
        assert!(err.contains("CRC"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncation() {
        let path = tmp("trunc.ckpt");
        write_minimal(&path);
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() - 3, 30, HEADER_BYTES + 6, 10, 3] {
            let err = format!(
                "{:#}",
                Checkpoint::parse(bytes[..cut].to_vec()).unwrap_err()
            );
            assert!(
                err.contains("truncated")
                    || err.contains("shorter")
                    || err.contains("meta"),
                "cut={cut}: {err}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_overflowing_section_length() {
        // a crafted header whose u64 length would wrap `pos + len` must
        // error cleanly, not panic on a slice index
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&SectionKind::Rows.as_u32().to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        let err = format!("{:#}", Checkpoint::parse(bytes).unwrap_err());
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn rejects_duplicate_section_address() {
        let path = tmp("dup.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Meta, 0, br#"{"n":4}"#).unwrap();
        w.section(SectionKind::Rows, 2, &[1, 2]).unwrap();
        w.section(SectionKind::Rows, 2, &[3, 4]).unwrap();
        w.finish().unwrap();
        let err = format!("{:#}", Checkpoint::read(&path).unwrap_err());
        assert!(err.contains("duplicate"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn anchor_id_is_stable_across_reads() {
        let path = tmp("anchor_stable.ckpt");
        write_minimal(&path);
        let a = Checkpoint::read(&path).unwrap().anchor_id();
        let b = Checkpoint::read(&path).unwrap().anchor_id();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_missing_meta() {
        let path = tmp("nometa.ckpt");
        let mut w = CheckpointWriter::create(&path).unwrap();
        w.section(SectionKind::Rows, 0, &[1]).unwrap();
        w.finish().unwrap();
        let err = format!("{:#}", Checkpoint::read(&path).unwrap_err());
        assert!(err.contains("meta"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
