//! CRC-chained delta journal: continuous checkpointing between anchors.
//!
//! A full checkpoint (the *anchor*) is expensive to rewrite every
//! `--save-every` steps, but between saves only the rows the sharded
//! update path actually touched have changed — and ALPT persists rows as
//! packed int codes, so a delta of a few thousand dirty rows is tiny
//! even next to an 8-bit table. The journal makes those deltas durable:
//!
//! ```text
//! <ckpt>            the anchor — a complete checkpoint file
//! <ckpt>.journal    header + append-only chain of delta records
//! ```
//!
//! Journal layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"ALPTJRNL"
//! 8       4     u32    journal format version (1)
//! 12      4     u32    anchor id — CRC-32 of the anchor's section CRCs
//! 16      8     u64    anchor step — the store step counter at anchor
//! 24      ...   records, back to back
//! ```
//!
//! Each record:
//!
//! ```text
//! +0      4     u32    marker b"DELT"
//! +4      8     u64    sequence number (1-based, dense)
//! +12     4     u32    previous link's payload CRC (record 1: anchor id)
//! +16     8     u64    payload length in bytes
//! +24     4     u32    CRC-32 of the payload
//! +28     len   payload (a serialized [`Delta`])
//! ```
//!
//! The chain is what makes recovery decisive. Every record names its
//! predecessor by CRC, record 1 names the anchor by its id, and the
//! anchor id is recomputable from the checkpoint's own section table
//! ([`super::Checkpoint::anchor_id`]) — so a journal can never be
//! replayed onto the wrong anchor, records can never apply out of
//! order, and a single flipped bit anywhere in the chain is caught
//! before any payload byte is interpreted.
//!
//! Salvage semantics: a crash during an append leaves a *prefix* of the
//! final record (the appender writes each record with one `write` call
//! and fsyncs before acknowledging). Readers therefore treat an
//! incomplete trailing record — header cut short, or payload shorter
//! than its declared length — as torn and ignore it, returning the
//! valid prefix of the chain instead of refusing the whole run. Damage
//! *inside* a complete record (bad marker, CRC mismatch, broken chain
//! link, out-of-order sequence) is never salvaged: that is corruption,
//! not a crash artifact, and loading errors out with the record named.
//!
//! A journal whose anchor fields match neither expectation is *stale*
//! (left behind by a pre-compaction anchor when the process died
//! between publishing the new anchor and resetting the journal — the
//! `compact.reset` failpoint window); it is ignored, because the fresh
//! anchor already contains everything the old chain held. Staleness
//! requires both a different anchor id *and* an older anchor step;
//! any other mismatch is corruption and errors precisely.

use std::fs::File;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::embedding::EmbeddingStore;

use super::failpoint;
use super::format::{crc32, put_u32, put_u64, take_u32, take_u64};
use super::writer::sync_parent_dir;

/// Journal file magic: 8 bytes at offset 0.
pub const JOURNAL_MAGIC: &[u8; 8] = b"ALPTJRNL";

/// Journal format version.
pub const JOURNAL_VERSION: u32 = 1;

/// Fixed byte size of the journal header.
pub const JOURNAL_HEADER_BYTES: usize = 24;

/// Fixed byte size of a record header (marker + seq + prev CRC + len +
/// payload CRC).
pub const RECORD_HEADER_BYTES: usize = 28;

/// Record marker, b"DELT" read little-endian.
pub const RECORD_MARKER: u32 = u32::from_le_bytes(*b"DELT");

/// The journal path for a checkpoint at `path`.
pub fn journal_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".journal");
    PathBuf::from(os)
}

// ---------------------------------------------------------------- payload

/// One delta: everything that changed since the previous link — the
/// dirty embedding rows (raw packed bytes, verbatim) plus the small
/// trainer state that changes every step. Applying the full chain onto
/// its anchor reproduces a full checkpoint of the same moment bit for
/// bit.
#[derive(Clone, Debug, PartialEq)]
pub struct Delta {
    /// Store update-step counter after the steps this delta covers.
    pub store_step: u64,
    /// Dirty row ids, strictly ascending.
    pub ids: Vec<u32>,
    /// Concatenated raw row payloads in `ids` order. Row widths are not
    /// stored: they are a function of the store geometry, which the
    /// anchor pins down.
    pub rows: Vec<u8>,
    /// Concatenated per-row aux scalars (Δ/α) in `ids` order; empty for
    /// stores without aux params.
    pub aux: Vec<f32>,
    /// The full dense-parameter vector (small next to the table).
    pub dense: Vec<f32>,
    /// Raw optimizer state, in the `Optimizer` section's encoding.
    pub opt: Vec<u8>,
    /// Trainer generator states, as in the `Rng` section (4 × u64).
    pub rng: [u64; 4],
    /// Training progress, as in the `Progress` section (6 × u64).
    pub progress: [u64; 6],
}

impl Delta {
    /// Serialize to the journal payload encoding.
    pub fn encode(&self) -> Vec<u8> {
        debug_assert!(
            self.ids.windows(2).all(|w| w[0] < w[1]),
            "delta ids must be strictly ascending"
        );
        let mut out = Vec::with_capacity(
            8 + 8
                + self.ids.len() * 4
                + 8
                + self.aux.len() * 4
                + 8
                + self.dense.len() * 4
                + 8
                + self.opt.len()
                + 32
                + 48
                + 8
                + self.rows.len(),
        );
        put_u64(&mut out, self.store_step);
        put_u64(&mut out, self.ids.len() as u64);
        for &id in &self.ids {
            put_u32(&mut out, id);
        }
        put_u64(&mut out, self.aux.len() as u64);
        for &x in &self.aux {
            out.extend_from_slice(&x.to_le_bytes());
        }
        put_u64(&mut out, self.dense.len() as u64);
        for &x in &self.dense {
            out.extend_from_slice(&x.to_le_bytes());
        }
        put_u64(&mut out, self.opt.len() as u64);
        out.extend_from_slice(&self.opt);
        for &v in &self.rng {
            put_u64(&mut out, v);
        }
        for &v in &self.progress {
            put_u64(&mut out, v);
        }
        put_u64(&mut out, self.rows.len() as u64);
        out.extend_from_slice(&self.rows);
        out
    }

    /// Exact inverse of [`Delta::encode`]. The payload CRC has already
    /// been checked by the chain reader, so a structural error here
    /// means a writer bug or a hand-crafted file — it is never salvaged.
    pub fn decode(src: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let store_step = take_u64(src, &mut pos)?;
        let n = take_u64(src, &mut pos)? as usize;
        ensure!(
            n <= (src.len() - pos) / 4,
            "delta claims {n} dirty rows, payload too short"
        );
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(take_u32(src, &mut pos)?);
        }
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "delta ids are not strictly ascending"
        );
        let take_f32s = |pos: &mut usize| -> Result<Vec<f32>> {
            let len = take_u64(src, pos)? as usize;
            ensure!(
                len <= (src.len() - *pos) / 4,
                "delta f32 run of {len} values overruns the payload"
            );
            let out = src[*pos..*pos + len * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            *pos += len * 4;
            Ok(out)
        };
        let aux = take_f32s(&mut pos)?;
        let dense = take_f32s(&mut pos)?;
        let opt_len = take_u64(src, &mut pos)? as usize;
        ensure!(
            opt_len <= src.len() - pos,
            "delta optimizer blob of {opt_len} bytes overruns the payload"
        );
        let opt = src[pos..pos + opt_len].to_vec();
        pos += opt_len;
        let mut rng = [0u64; 4];
        for v in &mut rng {
            *v = take_u64(src, &mut pos)?;
        }
        let mut progress = [0u64; 6];
        for v in &mut progress {
            *v = take_u64(src, &mut pos)?;
        }
        let rows_len = take_u64(src, &mut pos)? as usize;
        ensure!(
            rows_len <= src.len() - pos,
            "delta rows blob of {rows_len} bytes overruns the payload"
        );
        let rows = src[pos..pos + rows_len].to_vec();
        pos += rows_len;
        ensure!(
            pos == src.len(),
            "delta payload has {} trailing bytes",
            src.len() - pos
        );
        Ok(Self { store_step, ids, rows, aux, dense, opt, rng, progress })
    }
}

// ------------------------------------------------------- row capture/apply

/// Serialize the rows and aux scalars for `ids` (strictly ascending)
/// out of `store`, in the journal's concatenated encoding. Grouped
/// mixed-precision stores serialize each row through its own group's
/// sub-store, so widths vary per row exactly as the anchor's format-v2
/// layout does.
pub fn capture_rows(
    store: &dyn EmbeddingStore,
    ids: &[u32],
) -> Result<(Vec<u8>, Vec<f32>)> {
    debug_assert!(ids.windows(2).all(|w| w[0] < w[1]));
    let mut rows = Vec::new();
    let mut aux = Vec::new();
    if let Some(gs) = store.as_grouped() {
        let per_row: Vec<usize> = (0..gs.n_groups())
            .map(|g| aux_per_row(gs.group_store(g), gs.group_rows(g)))
            .collect::<Result<_>>()?;
        for &id in ids {
            let (g, local) = gs.row_location(id);
            let sub = gs.group_store(g);
            let rb = sub.ckpt_row_bytes().ok_or_else(|| {
                anyhow!("group {g} does not support checkpointing")
            })?;
            let at = rows.len();
            rows.resize(at + rb, 0);
            sub.save_rows(local, &mut rows[at..])?;
            let p = per_row[g];
            if p > 0 {
                let a = sub.aux_params();
                aux.extend_from_slice(&a[local * p..(local + 1) * p]);
            }
        }
        return Ok((rows, aux));
    }
    let rb = store.ckpt_row_bytes().ok_or_else(|| {
        anyhow!("{} does not support checkpointing", store.method_name())
    })?;
    let p = aux_per_row(store, store.n_features())?;
    rows.resize(ids.len() * rb, 0);
    for (i, &id) in ids.iter().enumerate() {
        store.save_rows(id as usize, &mut rows[i * rb..(i + 1) * rb])?;
        if p > 0 {
            let a = store.aux_params();
            let lo = id as usize * p;
            aux.extend_from_slice(&a[lo..lo + p]);
        }
    }
    Ok((rows, aux))
}

/// Aux scalars per row, derived from the full aux vector (0 when the
/// store has none).
fn aux_per_row(store: &dyn EmbeddingStore, rows: usize) -> Result<usize> {
    let len = store.aux_params().len();
    if len == 0 {
        return Ok(0);
    }
    ensure!(
        rows > 0 && len % rows == 0,
        "{}: {len} aux params do not divide {rows} rows",
        store.method_name()
    );
    Ok(len / rows)
}

/// Apply one delta's dirty rows, aux scalars and step counter onto
/// `store`. Geometry is validated — ids in bounds, blob lengths exactly
/// accounted for — before any row is touched.
pub fn apply_rows(store: &mut dyn EmbeddingStore, d: &Delta) -> Result<()> {
    let n = store.n_features();
    if let Some(&last) = d.ids.last() {
        ensure!(
            (last as usize) < n,
            "delta touches row {last}, the store has {n}"
        );
    }
    ensure!(
        d.ids.windows(2).all(|w| w[0] < w[1]),
        "delta ids are not strictly ascending"
    );
    if store.as_grouped().is_some() {
        return apply_rows_grouped(store, d);
    }
    let rb = store.ckpt_row_bytes().ok_or_else(|| {
        anyhow!("{} does not support checkpointing", store.method_name())
    })?;
    let p = aux_per_row(store, n)?;
    ensure!(
        d.rows.len() == d.ids.len() * rb,
        "delta rows blob is {} bytes for {} rows of {rb}",
        d.rows.len(),
        d.ids.len()
    );
    ensure!(
        d.aux.len() == d.ids.len() * p,
        "delta aux run is {} values for {} rows of {p}",
        d.aux.len(),
        d.ids.len()
    );
    for (i, &id) in d.ids.iter().enumerate() {
        store.load_rows(id as usize, &d.rows[i * rb..(i + 1) * rb])?;
    }
    if p > 0 {
        let mut full = store.aux_params().to_vec();
        for (i, &id) in d.ids.iter().enumerate() {
            full[id as usize * p..(id as usize + 1) * p]
                .copy_from_slice(&d.aux[i * p..(i + 1) * p]);
        }
        store.load_aux_params(&full)?;
    }
    store.set_step_counter(d.store_step);
    Ok(())
}

fn apply_rows_grouped(
    store: &mut dyn EmbeddingStore,
    d: &Delta,
) -> Result<()> {
    let gs = store.as_grouped_mut().expect("checked by apply_rows");
    // resolve and validate the whole layout before mutating anything
    let per_row: Vec<usize> = (0..gs.n_groups())
        .map(|g| aux_per_row(gs.group_store(g), gs.group_rows(g)))
        .collect::<Result<_>>()?;
    let locs: Vec<(usize, usize)> =
        d.ids.iter().map(|&id| gs.row_location(id)).collect();
    let (mut rows_need, mut aux_need) = (0usize, 0usize);
    for &(g, _) in &locs {
        rows_need += gs.group_store(g).ckpt_row_bytes().ok_or_else(
            || anyhow!("group {g} does not support checkpointing"),
        )?;
        aux_need += per_row[g];
    }
    ensure!(
        d.rows.len() == rows_need,
        "delta rows blob is {} bytes, the grouped layout needs {rows_need}",
        d.rows.len()
    );
    ensure!(
        d.aux.len() == aux_need,
        "delta aux run is {} values, the grouped layout needs {aux_need}",
        d.aux.len()
    );
    let mut row_at = 0usize;
    let mut aux_at = 0usize;
    // groups whose aux vectors were patched, rewritten once at the end
    let mut patched: Vec<Option<Vec<f32>>> = vec![None; gs.n_groups()];
    for &(g, local) in &locs {
        let rb = gs.group_store(g).ckpt_row_bytes().unwrap();
        gs.group_store_mut(g)
            .load_rows(local, &d.rows[row_at..row_at + rb])?;
        row_at += rb;
        let p = per_row[g];
        if p > 0 {
            let full = patched[g].get_or_insert_with(|| {
                gs.group_store(g).aux_params().to_vec()
            });
            full[local * p..(local + 1) * p]
                .copy_from_slice(&d.aux[aux_at..aux_at + p]);
            aux_at += p;
        }
    }
    for (g, full) in patched.into_iter().enumerate() {
        if let Some(full) = full {
            gs.group_store_mut(g).load_aux_params(&full)?;
        }
    }
    gs.set_step_counter(d.store_step);
    Ok(())
}

// --------------------------------------------------------------- appender

/// Appends CRC-chained delta records to `<ckpt>.journal`. Creating a
/// writer truncates any previous journal — the caller does so right
/// after publishing the anchor the new chain hangs off.
pub struct JournalWriter {
    file: File,
    seq: u64,
    prev_crc: u32,
}

impl JournalWriter {
    /// Start a fresh journal for the anchor at `ckpt_path` (truncating
    /// any previous one), fsyncing the header and the directory before
    /// returning. Failpoint site: `journal.reset`.
    pub fn create(
        ckpt_path: &Path,
        anchor_id: u32,
        anchor_step: u64,
    ) -> Result<Self> {
        let path = journal_path(ckpt_path);
        let mut file = File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut header = Vec::with_capacity(JOURNAL_HEADER_BYTES);
        header.extend_from_slice(JOURNAL_MAGIC);
        put_u32(&mut header, JOURNAL_VERSION);
        put_u32(&mut header, anchor_id);
        put_u64(&mut header, anchor_step);
        failpoint::write_through("journal.reset", &header, &mut file)?;
        file.sync_data()
            .with_context(|| format!("fsyncing {}", path.display()))?;
        sync_parent_dir(&path);
        Ok(Self { file, seq: 0, prev_crc: anchor_id })
    }

    /// Append one delta; the record is written in a single system write
    /// and fsynced before this returns, so a crash at any instant leaves
    /// at most a torn *tail*, never a torn middle. Returns the record's
    /// sequence number. Failpoint site: `journal.append`.
    pub fn append(&mut self, delta: &Delta) -> Result<u64> {
        let payload = delta.encode();
        let payload_crc = crc32(&payload);
        let mut pending =
            Vec::with_capacity(RECORD_HEADER_BYTES + payload.len());
        put_u32(&mut pending, RECORD_MARKER);
        put_u64(&mut pending, self.seq + 1);
        put_u32(&mut pending, self.prev_crc);
        put_u64(&mut pending, payload.len() as u64);
        put_u32(&mut pending, payload_crc);
        pending.extend_from_slice(&payload);
        failpoint::write_through(
            "journal.append",
            &pending,
            &mut self.file,
        )?;
        self.file.sync_data().context("fsyncing journal append")?;
        self.seq += 1;
        self.prev_crc = payload_crc;
        Ok(self.seq)
    }

    /// Records appended so far on this chain.
    pub fn len(&self) -> u64 {
        self.seq
    }

    /// True until the first append.
    pub fn is_empty(&self) -> bool {
        self.seq == 0
    }
}

// ----------------------------------------------------------------- reader

/// A validated delta chain, ready to fold onto its anchor.
pub struct DeltaChain {
    /// The chained deltas, in sequence order.
    pub deltas: Vec<Delta>,
    /// Bytes of torn trailing record that were salvaged away (0 for a
    /// cleanly closed journal).
    pub salvaged_bytes: u64,
}

/// Read and validate the delta chain next to `ckpt_path`, where the
/// anchor's id is `anchor_id` and its store step `anchor_step` (both
/// recomputable from the checkpoint itself).
///
/// Returns `None` when there is nothing to fold: no journal, a torn
/// header (the process died inside the reset that follows a fresh
/// anchor), or a stale journal left behind by a superseded anchor.
/// Everything else either validates into a [`DeltaChain`] — possibly
/// with a torn tail salvaged by ignoring it — or errors precisely.
pub fn read_chain(
    ckpt_path: &Path,
    anchor_id: u32,
    anchor_step: u64,
) -> Result<Option<DeltaChain>> {
    let path = journal_path(ckpt_path);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(None)
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("reading {}", path.display()))
        }
    };
    if bytes.len() < JOURNAL_HEADER_BYTES {
        // torn header: only a crash inside the journal reset leaves
        // this, and the anchor published just before already holds
        // everything the previous chain did
        return Ok(None);
    }
    ensure!(
        &bytes[..8] == JOURNAL_MAGIC,
        "{} is not a delta journal (bad magic)",
        path.display()
    );
    let mut pos = 8usize;
    let version = take_u32(&bytes, &mut pos)?;
    ensure!(
        version == JOURNAL_VERSION,
        "unsupported journal version {version} (expected \
         {JOURNAL_VERSION})"
    );
    let file_anchor = take_u32(&bytes, &mut pos)?;
    let file_step = take_u64(&bytes, &mut pos)?;
    if file_anchor != anchor_id && file_step < anchor_step {
        // stale: chained off an earlier anchor the current one already
        // folded in (died between compact-publish and journal reset)
        return Ok(None);
    }
    ensure!(
        file_anchor == anchor_id,
        "journal anchors {file_anchor:#010x}, the checkpoint is \
         {anchor_id:#010x}: file is corrupt"
    );
    ensure!(
        file_step == anchor_step,
        "journal anchor step {file_step} disagrees with the \
         checkpoint's {anchor_step}: file is corrupt"
    );

    let mut deltas = Vec::new();
    let mut prev_crc = anchor_id;
    let mut next_seq = 1u64;
    let mut salvaged = 0u64;
    while pos < bytes.len() {
        let remaining = bytes.len() - pos;
        if remaining < RECORD_HEADER_BYTES {
            salvaged = remaining as u64; // torn record header
            break;
        }
        let marker = take_u32(&bytes, &mut pos)?;
        ensure!(
            marker == RECORD_MARKER,
            "journal record {next_seq}: bad marker {marker:#010x}: \
             file is corrupt"
        );
        let seq = take_u64(&bytes, &mut pos)?;
        ensure!(
            seq == next_seq,
            "journal record out of order: found seq {seq}, expected \
             {next_seq}"
        );
        let link = take_u32(&bytes, &mut pos)?;
        ensure!(
            link == prev_crc,
            "journal record {seq}: chain break (links {link:#010x}, \
             previous payload is {prev_crc:#010x})"
        );
        let len = take_u64(&bytes, &mut pos)? as usize;
        if len > bytes.len() - pos - 4 {
            // payload cut short: a torn append tail, by construction
            // the last bytes of the file — salvage by ignoring it
            salvaged = (remaining) as u64;
            break;
        }
        let crc_want = take_u32(&bytes, &mut pos)?;
        let payload = &bytes[pos..pos + len];
        let crc_got = crc32(payload);
        ensure!(
            crc_got == crc_want,
            "journal record {seq}: payload CRC mismatch (stored \
             {crc_want:#010x}, computed {crc_got:#010x}): file is \
             corrupt"
        );
        let delta = Delta::decode(payload).with_context(|| {
            format!("decoding journal record {seq}")
        })?;
        deltas.push(delta);
        pos += len;
        prev_crc = crc_want;
        next_seq += 1;
    }
    Ok(Some(DeltaChain { deltas, salvaged_bytes: salvaged }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Experiment, Method, PrecisionPlan, RoundingMode};
    use crate::embedding::build_store;
    use crate::util::rng::Pcg32;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("alpt_journal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_delta(k: u64) -> Delta {
        Delta {
            store_step: 10 + k,
            ids: vec![1, 5, 9 + k as u32],
            rows: vec![k as u8; 18],
            aux: vec![0.5 + k as f32, -1.25],
            dense: vec![1.0, 2.0, 3.0 * k as f32],
            opt: vec![7u8; 12],
            rng: [k, k + 1, k + 2, k + 3],
            progress: [1, 2, 3, 4, 5, 6 + k],
        }
    }

    #[test]
    fn delta_payload_roundtrips() {
        let d = sample_delta(3);
        let back = Delta::decode(&d.encode()).unwrap();
        assert_eq!(back, d);
        // empty delta too
        let empty = Delta {
            store_step: 0,
            ids: vec![],
            rows: vec![],
            aux: vec![],
            dense: vec![],
            opt: vec![],
            rng: [0; 4],
            progress: [0; 6],
        };
        assert_eq!(Delta::decode(&empty.encode()).unwrap(), empty);
        // trailing garbage is rejected
        let mut enc = d.encode();
        enc.push(0);
        assert!(Delta::decode(&enc).is_err());
    }

    #[test]
    fn chain_roundtrips_and_validates() {
        let ckpt = tmp("chain.ckpt");
        let (anchor, step) = (0xABCD_1234u32, 40u64);
        let mut w = JournalWriter::create(&ckpt, anchor, step).unwrap();
        let deltas: Vec<Delta> = (0..3).map(sample_delta).collect();
        for (i, d) in deltas.iter().enumerate() {
            assert_eq!(w.append(d).unwrap(), i as u64 + 1);
        }
        drop(w);

        let chain = read_chain(&ckpt, anchor, step).unwrap().unwrap();
        assert_eq!(chain.salvaged_bytes, 0);
        assert_eq!(chain.deltas, deltas);

        // no journal at all
        assert!(read_chain(&tmp("nope.ckpt"), 1, 1).unwrap().is_none());

        // stale journal (older anchor): ignored
        assert!(read_chain(&ckpt, anchor ^ 1, step + 5)
            .unwrap()
            .is_none());
        // same step but different anchor: corrupt, not stale
        assert!(read_chain(&ckpt, anchor ^ 1, step).is_err());
        // same anchor, different step: corrupt
        assert!(read_chain(&ckpt, anchor, step + 1).is_err());
        std::fs::remove_file(journal_path(&ckpt)).ok();
    }

    #[test]
    fn torn_tail_salvages_and_mid_chain_damage_errors() {
        let ckpt = tmp("torn.ckpt");
        let (anchor, step) = (77u32, 5u64);
        let mut w = JournalWriter::create(&ckpt, anchor, step).unwrap();
        let deltas: Vec<Delta> = (0..3).map(sample_delta).collect();
        for d in &deltas {
            w.append(d).unwrap();
        }
        drop(w);
        let jp = journal_path(&ckpt);
        let full = std::fs::read(&jp).unwrap();
        let rec_bytes = RECORD_HEADER_BYTES
            + deltas[0].encode().len();
        let two_and_a_bit = JOURNAL_HEADER_BYTES + 2 * rec_bytes
            + deltas[2].encode().len() / 2;

        // torn tail (mid-record truncation): first two records salvage
        std::fs::write(&jp, &full[..two_and_a_bit]).unwrap();
        let chain = read_chain(&ckpt, anchor, step).unwrap().unwrap();
        assert!(chain.salvaged_bytes > 0);
        assert_eq!(chain.deltas, deltas[..2]);

        // truncation inside a record *header* also salvages
        std::fs::write(
            &jp,
            &full[..JOURNAL_HEADER_BYTES + rec_bytes + 9],
        )
        .unwrap();
        let chain = read_chain(&ckpt, anchor, step).unwrap().unwrap();
        assert_eq!(chain.deltas, deltas[..1]);

        // torn journal header: nothing to fold, not an error
        std::fs::write(&jp, &full[..JOURNAL_HEADER_BYTES / 2]).unwrap();
        assert!(read_chain(&ckpt, anchor, step).unwrap().is_none());

        // a flipped bit in a complete record is corruption, not a tear
        for at in [
            JOURNAL_HEADER_BYTES + 1,              // record 1 marker
            JOURNAL_HEADER_BYTES + rec_bytes / 2,  // record 1 payload
            JOURNAL_HEADER_BYTES + rec_bytes + 12, // record 2 prev link
        ] {
            let mut bad = full.clone();
            bad[at] ^= 1;
            std::fs::write(&jp, &bad).unwrap();
            let err = read_chain(&ckpt, anchor, step);
            assert!(err.is_err(), "flip at byte {at} was not caught");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(
                msg.contains("corrupt")
                    || msg.contains("chain break")
                    || msg.contains("out of order"),
                "imprecise error for flip at {at}: {msg}"
            );
        }
        std::fs::remove_file(&jp).ok();
    }

    #[test]
    fn capture_apply_roundtrips_uniform_and_grouped() {
        // stores A (source of truth) and B (stale copy) built from
        // different seeds: applying A's captured rows onto B must make
        // the touched rows — and only those — match A.
        let cases: Vec<Experiment> = vec![
            Experiment {
                method: Method::Alpt(RoundingMode::Sr),
                bits: PrecisionPlan::uniform(8),
                model: "tiny".into(),
                use_runtime: false,
                threads: 1,
                ..Experiment::default()
            },
            Experiment {
                method: Method::Alpt(RoundingMode::Sr),
                bits: PrecisionPlan::parse("f0:4,f1:8,default:2").unwrap(),
                dataset: "tiny".into(),
                model: "tiny".into(),
                use_runtime: false,
                threads: 1,
                ..Experiment::default()
            },
        ];
        for exp in cases {
            let n = crate::data::registry::schema_for(&exp)
                .unwrap()
                .n_features();
            let d = 4;
            let a =
                build_store(&exp, n, d, &mut Pcg32::seeded(1)).unwrap();
            let mut b =
                build_store(&exp, n, d, &mut Pcg32::seeded(2)).unwrap();
            let ids: Vec<u32> =
                (0..n as u32).filter(|i| i % 7 == 2).collect();
            let (rows, aux) = capture_rows(a.as_ref(), &ids).unwrap();
            let delta = Delta {
                store_step: 123,
                ids: ids.clone(),
                rows,
                aux,
                dense: vec![],
                opt: vec![],
                rng: [0; 4],
                progress: [0; 6],
            };
            apply_rows(b.as_mut(), &delta).unwrap();
            assert_eq!(b.step_counter(), 123);
            let mut wa = vec![0.0f32; ids.len() * d];
            let mut wb = wa.clone();
            a.gather(&ids, &mut wa);
            b.gather(&ids, &mut wb);
            assert_eq!(wa, wb, "{:?}: touched rows diverged", exp.bits);
            // an untouched row keeps B's own value
            let (rows_a, _) = capture_rows(a.as_ref(), &[0]).unwrap();
            let (rows_b, _) = capture_rows(b.as_ref(), &[0]).unwrap();
            assert_ne!(rows_a, rows_b, "untouched row was overwritten");
        }
    }

    #[test]
    fn apply_validates_before_mutating() {
        let exp = Experiment {
            method: Method::Lpt(RoundingMode::Sr),
            bits: PrecisionPlan::uniform(8),
            model: "tiny".into(),
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let mut store =
            build_store(&exp, 50, 4, &mut Pcg32::seeded(3)).unwrap();
        let (before, _) =
            capture_rows(store.as_ref(), &(0..50).collect::<Vec<_>>())
                .unwrap();
        let bad = Delta {
            store_step: 9,
            ids: vec![10, 99], // 99 is out of bounds
            rows: vec![0u8; 8],
            aux: vec![],
            dense: vec![],
            opt: vec![],
            rng: [0; 4],
            progress: [0; 6],
        };
        assert!(apply_rows(store.as_mut(), &bad).is_err());
        let (after, _) =
            capture_rows(store.as_ref(), &(0..50).collect::<Vec<_>>())
                .unwrap();
        assert_eq!(before, after, "failed apply mutated the store");
        assert_ne!(store.step_counter(), 9);
    }
}
