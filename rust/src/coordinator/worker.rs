//! The `alpt worker` process: owns one shard of the packed embedding
//! table and serves GATHER/UPDATE over the `net` RPC.
//!
//! A worker dials the coordinator, registers with HELLO, receives its
//! shard assignment (shard index, table geometry, and the full
//! experiment config so hyperparameter derivations match), and then
//! serves the coordinator's request loop until SHUTDOWN.
//!
//! Determinism: the worker applies exactly the update arithmetic of the
//! local stores (`LptStore`/`AlptStore`), in the same f32 operation
//! order, and draws stochastic-rounding noise from the same
//! counter-based streams — `StreamKey::for_step(draw, step)` arrives in
//! each UPDATE frame and rows key their streams by *global* id, so a
//! row quantizes identically whether it lives in-process or on any
//! shard of any N-worker layout.
//!
//! The serve loop is strictly serial — read one frame, process it,
//! respond, repeat — and that seriality is a load-bearing part of the
//! coordinator's pipelining contract: when the coordinator writes
//! UPDATE(k) and the batch-ahead GATHER(k+1) back to back, TCP's
//! per-connection ordering plus this loop guarantee update k is fully
//! applied before gather k+1 reads a single row. Requests queued
//! behind the one being served sit in the buffered reader; responses
//! go out in arrival order, which is what the coordinator's FIFO
//! response matching asserts.

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::experiment_from_json;
use crate::config::Method;
use crate::coordinator::net::{
    read_frame, write_frame, GatherReq, GatherResp, LoadReq, Op, RpcConfig,
    UpdateReq, WorkerLink, BARRIER_ATTACHED, FLAG_RESPONSE, PROTO_VERSION,
};
use crate::coordinator::sharding::RowPartition;
use crate::embedding::{rounding_of, AlptStore, LptStore, Persistable};
use crate::util::json::Json;
use crate::util::rng::{Pcg32, StreamKey};

/// `alpt worker` configuration (all CLI-level; nothing here is part of
/// the experiment, so checkpoints stay layout-independent).
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator address (HOST:PORT).
    pub connect: String,
    /// Die if the coordinator is silent this long — the worker-side
    /// heartbeat (the coordinator pings every worker at least once per
    /// epoch barrier).
    pub idle_timeout_ms: u64,
    /// Largest accepted frame payload.
    pub max_frame: u64,
    /// Connection attempts before giving up (workers usually start
    /// before the coordinator).
    pub connect_retries: u32,
    pub retry_delay_ms: u64,
    /// Fault injection for tests/CI: abort (without responding) once
    /// this many UPDATE frames have been served. `None` in production.
    pub die_after_updates: Option<u64>,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        let rpc = RpcConfig::default();
        Self {
            connect: "127.0.0.1:4700".into(),
            idle_timeout_ms: 600_000,
            max_frame: rpc.max_frame,
            connect_retries: rpc.connect_retries,
            retry_delay_ms: rpc.retry_delay_ms,
            die_after_updates: None,
        }
    }
}

/// One shard of the table: the uniform quantized stores are the only
/// layouts the distributed path supports (mixed-precision plans and
/// re-planning migrate rows between groups, which the row partition
/// does not model yet).
enum ShardStore {
    Lpt(LptStore),
    Alpt(AlptStore),
}

impl ShardStore {
    fn row_bytes(&self) -> usize {
        match self {
            ShardStore::Lpt(s) => s.ckpt_row_bytes().unwrap(),
            ShardStore::Alpt(s) => s.ckpt_row_bytes().unwrap(),
        }
    }

    fn load_rows(&mut self, lo: usize, src: &[u8]) -> Result<()> {
        match self {
            ShardStore::Lpt(s) => s.load_rows(lo, src),
            ShardStore::Alpt(s) => s.load_rows(lo, src),
        }
    }

    fn save_row(&self, local: usize, dst: &mut [u8]) -> Result<()> {
        match self {
            ShardStore::Lpt(s) => s.save_rows(local, dst),
            ShardStore::Alpt(s) => s.save_rows(local, dst),
        }
    }

    fn read_dequant(&self, local: usize, out: &mut [f32]) {
        match self {
            ShardStore::Lpt(s) => s.read_row_dequant_into(local, out),
            ShardStore::Alpt(s) => s.read_row_dequant_into(local, out),
        }
    }

    fn delta_of(&self, local: usize) -> f32 {
        match self {
            ShardStore::Lpt(s) => s.delta(),
            ShardStore::Alpt(s) => s.delta_of(local as u32),
        }
    }
}

/// The worker's shard assignment, as decoded from the HELLO reply.
struct Assignment {
    shard: usize,
    part: RowPartition,
    d: usize,
    row_bytes: usize,
    step: u64,
    store: ShardStore,
}

fn build_assignment(reply: &[u8]) -> Result<Assignment> {
    let text = std::str::from_utf8(reply)
        .context("HELLO reply is not UTF-8")?;
    let v = Json::parse(text).context("HELLO reply is not JSON")?;
    let shard = v.get("shard")?.as_usize()?;
    let n_shards = v.get("n_shards")?.as_usize()?;
    let n = v.get("n")?.as_usize()?;
    let d = v.get("d")?.as_usize()?;
    let row_bytes = v.get("row_bytes")?.as_usize()?;
    let step = v.get("step")?.as_f64()? as u64;
    let exp = experiment_from_json(v.get("experiment")?)
        .context("HELLO reply experiment")?;
    ensure!(shard < n_shards, "assigned shard {shard} of {n_shards}");

    let part = RowPartition::new(n, n_shards);
    let shard_n = part.shard_rows(shard);
    let bw = exp.bit_width().context(
        "distributed training requires a uniform precision plan",
    )?;
    // throwaway generator: every row is overwritten by the LOAD stream
    let mut rng = Pcg32::seeded(0);
    let store = match exp.method {
        Method::Lpt(mode) => ShardStore::Lpt(LptStore::init_with_threads(
            shard_n.max(1),
            d,
            bw,
            exp.clip,
            rounding_of(mode),
            exp.threads,
            &mut rng,
        )),
        Method::Alpt(mode) => {
            ShardStore::Alpt(AlptStore::init_with_clip_threads(
                shard_n.max(1),
                d,
                bw,
                rounding_of(mode),
                exp.clip,
                exp.threads,
                &mut rng,
            ))
        }
        other => bail!(
            "distributed training shards packed tables; method {} has \
             none (use lpt/alpt)",
            other.key()
        ),
    };
    ensure!(
        store.row_bytes() == row_bytes,
        "row_bytes mismatch: coordinator says {row_bytes}, shard table \
         has {}",
        store.row_bytes()
    );
    Ok(Assignment { shard, part, d, row_bytes, step, store })
}

/// Apply one UPDATE frame — the worker-side half of
/// `LptStore::update`/`AlptStore::update`, bit-identical to the local
/// stores: `what` is re-dequantized from the shard's packed bytes
/// (equal to the coordinator's gathered `emb_hat` by construction),
/// the f32 arithmetic runs in the same order, and the SR stream is
/// keyed by (draw, step, global id).
fn apply_update(a: &mut Assignment, req: &UpdateReq) -> Result<()> {
    let d = a.d;
    ensure!(
        req.grads.len() == req.ids.len() * d,
        "update grads: {} f32s for {} rows of dim {d}",
        req.grads.len(),
        req.ids.len()
    );
    if let ShardStore::Alpt(_) = a.store {
        ensure!(
            req.d_delta.len() == req.ids.len(),
            "update delta grads: {} for {} rows",
            req.d_delta.len(),
            req.ids.len()
        );
    }
    let [lr_emb, wd_emb, lr_delta, wd_delta, grad_scale, lr_scale] = req.hp;
    let lr = lr_emb * lr_scale;
    let wd = wd_emb;
    let lr_d = lr_delta * lr_scale;
    let key = StreamKey::for_step(req.draw, req.step);
    let mut what = vec![0.0f32; d];
    let mut w_new = vec![0.0f32; d];
    for (k, &gid) in req.ids.iter().enumerate() {
        ensure!(
            a.part.shard_of(gid) == a.shard,
            "row {gid} does not belong to shard {}",
            a.shard
        );
        let local = a.part.local_of(gid) as usize;
        a.store.read_dequant(local, &mut what);
        let g = &req.grads[k * d..(k + 1) * d];
        for j in 0..d {
            w_new[j] = what[j] - lr * (g[j] + wd * what[j]);
        }
        let mut rrng = key.row_rng(gid as u64);
        match &mut a.store {
            ShardStore::Lpt(s) => {
                s.write_row_from_f32(local, &w_new, &mut rrng);
            }
            ShardStore::Alpt(s) => {
                let dl = s.delta_of(local as u32);
                let gd = grad_scale * req.d_delta[k] + wd_delta * dl;
                let dl_new = (dl - lr_d * gd).max(1e-8);
                s.write_row_from_f32(local, &w_new, dl_new, &mut rrng);
            }
        }
    }
    Ok(())
}

fn serve_gather(a: &Assignment, req: &GatherReq) -> Result<Vec<u8>> {
    let rb = a.row_bytes;
    let count = req.ids.len();
    let mut rows = if req.aux_only {
        Vec::new()
    } else {
        vec![0u8; count * rb]
    };
    let mut aux = Vec::new();
    let want_aux = matches!(a.store, ShardStore::Alpt(_));
    if want_aux {
        aux.reserve(count);
    }
    for (k, &gid) in req.ids.iter().enumerate() {
        ensure!(
            a.part.shard_of(gid) == a.shard,
            "row {gid} does not belong to shard {}",
            a.shard
        );
        let local = a.part.local_of(gid) as usize;
        if !req.aux_only {
            a.store.save_row(local, &mut rows[k * rb..(k + 1) * rb])?;
        }
        if want_aux {
            aux.push(a.store.delta_of(local));
        }
    }
    let resp = GatherResp {
        row_bytes: if req.aux_only { 0 } else { rb as u32 },
        rows,
        aux,
    };
    Ok(resp.encode())
}

/// Run one worker to completion: connect, register, serve, shut down.
/// Any protocol or application error is returned (nonzero process
/// exit); a silent coordinator trips the idle timeout rather than
/// hanging forever.
pub fn run_worker(opts: &WorkerOpts) -> Result<()> {
    let cfg = RpcConfig {
        timeout_ms: opts.idle_timeout_ms,
        connect_retries: opts.connect_retries,
        retry_delay_ms: opts.retry_delay_ms,
        max_frame: opts.max_frame,
        ..RpcConfig::default()
    };
    let mut link = WorkerLink::connect(&opts.connect, &cfg)
        .with_context(|| format!("worker dialing {}", opts.connect))?;
    let mut hello = Vec::new();
    crate::checkpoint::format::put_u32(&mut hello, PROTO_VERSION);
    let reply = link
        .call(Op::Hello, &hello)
        .context("worker registration (HELLO)")?;
    let mut a = build_assignment(&reply)?;
    eprintln!(
        "[worker] shard {}/{} of {} rows: {} local rows, {} bytes/row",
        a.shard,
        a.part.n_shards(),
        a.part.n_rows(),
        a.part.shard_rows(a.shard),
        a.row_bytes,
    );

    // The Δ table streamed by LOAD is staged here and armed at the
    // attach barrier (load_aux_params wants the whole shard at once).
    let mut delta_stage = vec![0.0f32; a.part.shard_rows(a.shard).max(1)];
    let mut updates_served: u64 = 0;
    // split the connection: pipelined coordinators write several
    // requests back to back, so reads go through a buffer (one syscall
    // can pull in the whole burst) while responses flush per frame
    let stream = link.into_stream();
    let mut writer = stream.try_clone().context("worker stream clone")?;
    let mut reader = std::io::BufReader::new(stream);
    loop {
        let (op, flags, seq, payload) = read_frame(&mut reader, cfg.max_frame)
            .with_context(|| {
                format!(
                    "worker shard {}: coordinator connection lost or \
                     silent past {} ms",
                    a.shard, opts.idle_timeout_ms
                )
            })?;
        if flags & FLAG_RESPONSE != 0 {
            bail!("worker received a response frame as a request");
        }
        if op == Op::Update {
            if let Some(limit) = opts.die_after_updates {
                if updates_served >= limit {
                    bail!(
                        "worker shard {}: failpoint death after {limit} \
                         updates",
                        a.shard
                    );
                }
            }
            updates_served += 1;
        }
        let result: Result<Vec<u8>> = (|| match op {
            Op::Load => {
                let req = LoadReq::decode(&payload)?;
                ensure!(
                    req.row_bytes as usize == a.row_bytes,
                    "LOAD row_bytes {} != shard row_bytes {}",
                    req.row_bytes,
                    a.row_bytes
                );
                let lo = req.start_local as usize;
                a.store.load_rows(lo, &req.rows)?;
                if !req.aux.is_empty() {
                    ensure!(
                        req.aux.len() == req.count(),
                        "LOAD aux count {} != row count {}",
                        req.aux.len(),
                        req.count()
                    );
                    ensure!(
                        lo + req.aux.len() <= delta_stage.len(),
                        "LOAD aux out of range"
                    );
                    delta_stage[lo..lo + req.aux.len()]
                        .copy_from_slice(&req.aux);
                }
                Ok(Vec::new())
            }
            Op::Gather => {
                let req = GatherReq::decode(&payload)?;
                serve_gather(&a, &req)
            }
            Op::Update => {
                let req = UpdateReq::decode(&payload)?;
                apply_update(&mut a, &req)?;
                Ok(Vec::new())
            }
            Op::Barrier => {
                ensure!(payload.len() == 1, "BARRIER payload");
                if payload[0] == BARRIER_ATTACHED {
                    if let ShardStore::Alpt(s) = &mut a.store {
                        s.load_aux_params(&delta_stage)?;
                        s.set_step_counter(a.step);
                    }
                    if let ShardStore::Lpt(s) = &mut a.store {
                        s.set_step_counter(a.step);
                    }
                }
                // quiesce/epoch barriers need no action: the serve loop
                // is serial, so replying at all proves every prior
                // update has been applied
                Ok(Vec::new())
            }
            Op::Shutdown => Ok(Vec::new()),
            other => bail!("unexpected request opcode {other:?}"),
        })();
        match result {
            Ok(resp) => {
                write_frame(&mut writer, op, FLAG_RESPONSE, seq, &resp)?;
                if op == Op::Shutdown {
                    eprintln!(
                        "[worker] shard {} served {} updates, shutting down",
                        a.shard, updates_served
                    );
                    return Ok(());
                }
            }
            Err(e) => {
                // tell the coordinator why before dying loudly
                let msg = format!("{e:#}");
                write_frame(
                    &mut writer,
                    Op::Err,
                    FLAG_RESPONSE,
                    seq,
                    msg.as_bytes(),
                )
                .ok();
                return Err(e);
            }
        }
    }
}
